"""Kernel-vs-reference correctness: the CORE L1 signal.

Every Pallas kernel must match its pure-jnp oracle in ref.py. Hypothesis
sweeps shapes/dtypes/values; fixed seeds keep the suite deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import absmean, attention, fakequant, qmatmul, scaled_fakequant
from compile.kernels import ref
from compile.kernels.fakequant import pick_block


def rng(seed):
    return np.random.default_rng(seed)


def arr(r, shape, scale=1.0, offset=0.0):
    return jnp.asarray(r.normal(offset, scale, shape).astype(np.float32))


# ---------------------------------------------------------------- fakequant


@settings(max_examples=20, deadline=None)
@given(
    n_groups=st.integers(1, 8),
    group=st.sampled_from([16, 32, 64]),
    m=st.sampled_from([32, 64, 128, 192, 256]),
    bits=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fakequant_matches_ref(n_groups, group, m, bits, seed):
    w = arr(rng(seed), (n_groups * group, m), scale=2.0)
    got = fakequant(w, bits=bits, group=group)
    want = ref.ref_fakequant(w, bits, group)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 2**31 - 1),
    scale_mag=st.floats(0.1, 4.0),
)
def test_scaled_fakequant_matches_ref(bits, seed, scale_mag):
    r = rng(seed)
    w = arr(r, (128, 96), scale=1.5)
    s = jnp.asarray((np.abs(r.normal(0, scale_mag, 128)) + 0.2).astype(np.float32))
    got = scaled_fakequant(w, s, bits=bits, group=32)
    want = ref.ref_scaled_fakequant(w, s, bits, 32)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fakequant_idempotent():
    """Quantizing an already-quantized matrix is a fixed point."""
    w = arr(rng(7), (64, 64), scale=3.0)
    once = ref.ref_fakequant(w, 4, 32)
    twice = ref.ref_fakequant(once, 4, 32)
    np.testing.assert_allclose(once, twice, rtol=1e-5, atol=1e-6)


def test_fakequant_constant_group():
    """All-equal groups (delta==0 guard) dequantize to the constant."""
    w = jnp.full((32, 16), 0.7, dtype=jnp.float32)
    got = fakequant(w, bits=3, group=32)
    np.testing.assert_allclose(got, w, atol=1e-6)


def test_fakequant_error_decreases_with_bits():
    w = arr(rng(11), (256, 64), scale=1.0)
    errs = [
        float(jnp.mean((ref.ref_fakequant(w, b, 32) - w) ** 2)) for b in (2, 3, 4, 8)
    ]
    assert errs == sorted(errs, reverse=True), errs


def test_pick_block():
    assert pick_block(256) == 128
    assert pick_block(192) == 64
    assert pick_block(64) == 64
    assert pick_block(24) == 8


# ------------------------------------------------------------------ absmean


@settings(max_examples=15, deadline=None)
@given(
    rows=st.sampled_from([128, 256, 512]),
    n=st.sampled_from([16, 64, 96, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_absmean_matches_ref(rows, n, seed):
    a = arr(rng(seed), (rows, n), scale=2.0, offset=0.3)
    np.testing.assert_allclose(absmean(a), ref.ref_absmean(a), rtol=1e-5, atol=1e-6)


def test_absmean_nonneg_and_zero():
    a = jnp.zeros((128, 32))
    assert float(jnp.max(absmean(a))) == 0.0
    a2 = arr(rng(3), (128, 32))
    assert float(jnp.min(absmean(a2))) >= 0.0


# ------------------------------------------------------------------ qmatmul


@settings(max_examples=12, deadline=None)
@given(
    s_rows=st.sampled_from([64, 128]),
    n_groups=st.integers(1, 4),
    m=st.sampled_from([32, 64, 128]),
    bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_ref(s_rows, n_groups, m, bits, seed):
    r = rng(seed)
    group = 32
    n = n_groups * group
    w = arr(r, (n, m), scale=1.2)
    a = arr(r, (s_rows, n))
    inv_s = jnp.asarray((np.abs(r.normal(0, 1, n)) + 0.3).astype(np.float32))
    q, d, z = ref.ref_quantize_ints(w, bits, group)
    got = qmatmul(a, q, d, z, inv_s, group=group)
    want = ref.ref_qmatmul(a, q, d, z, inv_s, group)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_qmatmul_equals_fp_matmul_at_high_bits():
    """8-bit quantized matmul approximates the FP product closely."""
    r = rng(5)
    w = arr(r, (64, 64))
    a = arr(r, (64, 64))
    q, d, z = ref.ref_quantize_ints(w, 8, 32)
    ones = jnp.ones(64)
    got = qmatmul(a, q, d, z, ones, group=32)
    np.testing.assert_allclose(got, a @ w, rtol=0.05, atol=0.25)


# ---------------------------------------------------------------- attention


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([16, 64, 128]),
    hd=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, t, hd, seed):
    r = rng(seed)
    q, k, v = (arr(r, (b, h, t, hd)) for _ in range(3))
    np.testing.assert_allclose(
        attention(q, k, v), ref.ref_attention(q, k, v), rtol=1e-4, atol=1e-5
    )


def test_attention_is_causal():
    """Changing future tokens must not change past outputs."""
    r = rng(9)
    q, k, v = (arr(r, (1, 2, 32, 16)) for _ in range(3))
    out1 = np.asarray(attention(q, k, v))
    k2 = k.at[:, :, 20:, :].set(99.0)
    v2 = v.at[:, :, 20:, :].set(-99.0)
    out2 = np.asarray(attention(q, k2, v2))
    np.testing.assert_allclose(out1[:, :, :20], out2[:, :, :20], rtol=1e-5, atol=1e-6)
    assert not np.allclose(out1[:, :, 20:], out2[:, :, 20:])


def test_attention_rows_softmax_normalized():
    """With v = ones, attention output is exactly ones (probs sum to 1)."""
    r = rng(13)
    q, k = arr(r, (1, 1, 32, 16)), arr(r, (1, 1, 32, 16))
    v = jnp.ones((1, 1, 32, 16))
    np.testing.assert_allclose(attention(q, k, v), v, rtol=1e-5, atol=1e-5)
