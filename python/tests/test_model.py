"""L2 model graph tests: shapes, pallas-vs-jnp path agreement, training
step sanity, capture statistics semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref


CFG = M.CONFIGS["pico"]


def init_params(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    flat = []
    for name, shape in M.param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_g"):
            flat.append(jnp.ones(shape))
        else:
            scale = 0.08 if "emb" in name else 1.0 / np.sqrt(shape[0])
            flat.append(scale * jax.random.normal(sub, shape))
    return tuple(flat)


def toks(cfg, seed=1, extra=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(
        r.integers(0, cfg.vocab, (cfg.batch, cfg.seq + extra)).astype(np.int32)
    )


PARAMS = init_params(CFG)
TOKENS = toks(CFG)


def test_param_specs_count():
    # 2 embeddings + 6 per block + final ln + head
    assert len(M.param_specs(CFG)) == 2 + 6 * CFG.n_layer + 2


def test_fwd_logits_shape():
    (logits,) = M.fwd_logits(CFG, *PARAMS, TOKENS)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pallas_and_jnp_paths_agree():
    p = M.unflatten(CFG, PARAMS)
    lp, _ = M._forward(CFG, p, TOKENS, use_pallas=True)
    lr, _ = M._forward(CFG, p, TOKENS, use_pallas=False)
    np.testing.assert_allclose(lp, lr, rtol=1e-4, atol=1e-4)


def test_capture_shapes_and_stats():
    outs = M.fwd_capture(CFG, *PARAMS, TOKENS)
    L, R, d, ff = CFG.n_layer, CFG.batch * CFG.seq, CFG.d_model, CFG.d_ff
    acts_qkv, acts_o, acts_up, acts_down = outs[:4]
    st_qkv, st_o, st_up, st_down = outs[4:]
    assert acts_qkv.shape == (L, R, d) and acts_down.shape == (L, R, ff)
    assert st_qkv.shape == (L, d) and st_down.shape == (L, ff)
    # Stats must equal mean |acts| computed directly.
    np.testing.assert_allclose(
        st_qkv[0], ref.ref_absmean(acts_qkv[0]), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        st_down[-1], ref.ref_absmean(acts_down[-1]), rtol=1e-5, atol=1e-6
    )


def test_capture_acts_feed_layer_loss():
    """Captured qkv activations + the block's weight give a finite loss that
    increases when bits decrease."""
    outs = M.fwd_capture(CFG, *PARAMS, TOKENS)
    acts_qkv = outs[0]
    p = M.unflatten(CFG, PARAMS)
    a = acts_qkv[0][:256]
    w = p["blk0.w_qkv"]
    s = jnp.ones(w.shape[0])
    (l3,) = M.layer_loss(a, w, s, bits=3, group=32)
    (l4,) = M.layer_loss(a, w, s, bits=4, group=32)
    assert float(l3) > float(l4) > 0.0


def test_train_step_decreases_loss():
    cfg = CFG
    n = len(M.param_specs(cfg))
    params = list(init_params(cfg, seed=3))
    ms = [jnp.zeros_like(p) for p in params]
    vs = [jnp.zeros_like(p) for p in params]
    step = jnp.float32(0.0)
    t = toks(cfg, seed=5, extra=1)
    first = None
    fn = jax.jit(lambda *a: M.train_step(cfg, *a))
    for it in range(8):
        out = fn(*params, *ms, *vs, step, t)
        params = list(out[:n])
        ms = list(out[n : 2 * n])
        vs = list(out[2 * n : 3 * n])
        step, loss = out[3 * n], out[3 * n + 1]
        if first is None:
            first = float(loss)
    assert float(loss) < first, (first, float(loss))
    assert float(step) == 8.0


def test_fwd_logits_q_matches_fakequant_eval():
    """The quantized-deployment graph (qmatmul kernel from int codes) must
    agree with running fwd_logits on host-side fake-quantized weights."""
    cfg = CFG
    group, bits = 32, 4
    p = M.unflatten(cfg, PARAMS)

    qargs = [p["tok_emb"], p["pos_emb"]]
    fq_flat = []
    for name, shape in M.param_specs(cfg):
        arr = p[name]
        if ".w_" in name:
            s = jnp.ones(arr.shape[0])
            fq_flat.append(ref.ref_scaled_fakequant(arr, s, bits, group))
        else:
            fq_flat.append(arr)
    for b in range(cfg.n_layer):
        qargs.append(p[f"blk{b}.ln1_g"])
        for role, wname in (("qkv", "w_qkv"), ("o", "w_o")):
            w = p[f"blk{b}.{wname}"]
            q, d, z = ref.ref_quantize_ints(w, bits, group)
            qargs += [q, d, z, jnp.ones(w.shape[0])]
        qargs.append(p[f"blk{b}.ln2_g"])
        for role, wname in (("up", "w_up"), ("down", "w_down")):
            w = p[f"blk{b}.{wname}"]
            q, d, z = ref.ref_quantize_ints(w, bits, group)
            qargs += [q, d, z, jnp.ones(w.shape[0])]
    qargs += [p["lnf_g"], p["w_head"], TOKENS]

    (logits_q,) = M.fwd_logits_q(cfg, group, *qargs)
    (logits_fq,) = M.fwd_logits(cfg, *fq_flat, TOKENS)
    np.testing.assert_allclose(logits_q, logits_fq, rtol=2e-3, atol=2e-3)


def test_qfwd_arg_specs_count():
    specs = M.qfwd_arg_specs(CFG, 32)
    # 2 emb + per-block (2 ln + 4 roles x 4 tensors) + lnf + head + tokens
    assert len(specs) == 2 + CFG.n_layer * 18 + 3


def test_loss_fn_matches_manual_xent():
    t = toks(CFG, seed=7, extra=1)
    loss = M._loss_fn(CFG, PARAMS, t)
    p = M.unflatten(CFG, PARAMS)
    logits, _ = M._forward(CFG, p, t[:, :-1], use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    gold = jnp.take_along_axis(logp, t[:, 1:][..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(float(loss), float(-gold.mean()), rtol=1e-5)
