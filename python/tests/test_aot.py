"""AOT lowering tests: artifact files, manifest format, incremental no-op."""

import pathlib
import tempfile

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(out, ["pico"], group=64, loss_rows=128, force=True)
    return out


def test_all_files_exist(built):
    cfg = M.CONFIGS["pico"]
    for entry, _, _ in M.entrypoints(cfg, group=64, loss_rows=128):
        p = built / "pico" / f"{entry}.hlo.txt"
        assert p.exists(), entry
        text = p.read_text()
        assert "ENTRY" in text, f"{entry} is not HLO text"


def test_manifest_structure(built):
    lines = (built / "manifest.txt").read_text().splitlines()
    kinds = {}
    for line in lines:
        if not line or line.startswith("#"):
            continue
        kinds.setdefault(line.split()[0], []).append(line)
    assert kinds["group"][0] == "group 64"
    assert kinds["loss_rows"][0] == "loss_rows 128"
    assert len(kinds["config"]) == 1
    # param count: 2 emb + 6/block + lnf + head
    cfg = M.CONFIGS["pico"]
    assert len(kinds["param"]) == 2 + 6 * cfg.n_layer + 2
    assert len(kinds["artifact"]) == len(M.entrypoints(cfg, group=64, loss_rows=128))
    # nargs recorded for every artifact
    for a in kinds["artifact"]:
        assert "nargs=" in a


def test_incremental_noop(built, capsys):
    aot.build(built, ["pico"], group=64, loss_rows=128, force=False)
    out = capsys.readouterr().out
    assert "up to date" in out


def test_param_change_invalidates(built):
    want_before = aot.src_hash("configs=pico;group=64;loss_rows=128;v3")
    want_after = aot.src_hash("configs=pico;group=32;loss_rows=128;v3")
    assert want_before != want_after


def test_entrypoint_arity_matches_manifest(built):
    cfg = M.CONFIGS["pico"]
    lines = (built / "manifest.txt").read_text().splitlines()
    recorded = {}
    for line in lines:
        if line.startswith("artifact "):
            toks = line.split()
            recorded[toks[2]] = int(toks[4].split("=")[1])
    for entry, _, specs in M.entrypoints(cfg, group=64, loss_rows=128):
        assert recorded[entry] == len(specs), entry
