"""L2: the transformer compute graphs, AOT-lowered to HLO text artifacts.

GPT-style decoder-only LM family (S4 in DESIGN.md). Everything here runs
at BUILD TIME only — `aot.py` lowers the jitted entrypoints once and the
rust coordinator executes the resulting HLO on the PJRT CPU client.

Entrypoints (shapes fixed per ModelCfg; see DESIGN.md §6):
  fwd_logits    (params…, tokens[B,T])           -> logits[B,T,V]
  fwd_capture   (params…, tokens[B,T])           -> per-role acts + absmean stats
  fwd_logits_q  (qparams…, tokens[B,T])          -> logits via the qmatmul kernel
  layer_loss    (a[S,n], w[n,m], s[n])           -> scalar recon loss (per role/bits)
  train_step    (params…, m…, v…, step, tok[B,T+1]) -> updated state + loss

Parameter convention: weights are [n_in, n_out] (y = a @ W); AWQ/FAQ scale
vectors index the *input* channel (rows). The canonical flat parameter
order is defined by `param_specs` and mirrored by rust/src/model/.

Differentiability note: pallas_call has no VJP, so `train_step` uses the
pure-jnp reference ops (ref.py) while the inference/capture graphs use the
Pallas kernels; pytest asserts both paths agree (test_model.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import absmean, attention, qmatmul, scaled_fakequant
from .kernels import ref


# --------------------------------------------------------------------------
# Configs — must match rust/src/model/config.rs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    n_layer: int
    d_model: int
    n_head: int
    d_ff: int
    vocab: int
    seq: int = 128
    batch: int = 4

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_head


CONFIGS: Dict[str, ModelCfg] = {
    c.name: c
    for c in [
        ModelCfg("pico", n_layer=2, d_model=64, n_head=2, d_ff=256, vocab=256),
        ModelCfg("nano", n_layer=4, d_model=128, n_head=4, d_ff=512, vocab=384),
        ModelCfg("tiny", n_layer=6, d_model=192, n_head=6, d_ff=768, vocab=384),
        ModelCfg("small", n_layer=8, d_model=256, n_head=8, d_ff=1024, vocab=512),
    ]
}

# The four quantizable linear roles per block and their [n_in, n_out] shapes.
ROLES = ("qkv", "o", "up", "down")


def role_shape(cfg: ModelCfg, role: str) -> Tuple[int, int]:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "qkv": (d, 3 * d),
        "o": (d, d),
        "up": (d, ff),
        "down": (ff, d),
    }[role]


def param_specs(cfg: ModelCfg) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical flat parameter order: (name, shape) — shared with rust."""
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq, cfg.d_model)),
    ]
    for b in range(cfg.n_layer):
        specs.append((f"blk{b}.ln1_g", (cfg.d_model,)))
        specs.append((f"blk{b}.w_qkv", role_shape(cfg, "qkv")))
        specs.append((f"blk{b}.w_o", role_shape(cfg, "o")))
        specs.append((f"blk{b}.ln2_g", (cfg.d_model,)))
        specs.append((f"blk{b}.w_up", role_shape(cfg, "up")))
        specs.append((f"blk{b}.w_down", role_shape(cfg, "down")))
    specs.append(("lnf_g", (cfg.d_model,)))
    specs.append(("w_head", (cfg.d_model, cfg.vocab)))
    return specs


def unflatten(cfg: ModelCfg, flat: Tuple[jnp.ndarray, ...]) -> Dict[str, jnp.ndarray]:
    specs = param_specs(cfg)
    assert len(flat) == len(specs), f"{len(flat)} params != {len(specs)} specs"
    return {name: arr for (name, _), arr in zip(specs, flat)}


# --------------------------------------------------------------------------
# Core ops
# --------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _split_heads(x: jnp.ndarray, n_head: int) -> jnp.ndarray:
    b, t, d = x.shape
    return x.reshape(b, t, n_head, d // n_head).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, t, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * hd)


def _block_fwd(cfg: ModelCfg, p: Dict[str, jnp.ndarray], b: int, x: jnp.ndarray, use_pallas: bool):
    """One transformer block. Returns (x_out, role_inputs dict)."""
    attn_fn = attention if use_pallas else ref.ref_attention
    h = rmsnorm(x, p[f"blk{b}.ln1_g"])  # qkv_in
    qkv = h @ p[f"blk{b}.w_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q, k, v = (_split_heads(t, cfg.n_head) for t in (q, k, v))
    att = _merge_heads(attn_fn(q, k, v))  # o_in
    x = x + att @ p[f"blk{b}.w_o"]
    h2 = rmsnorm(x, p[f"blk{b}.ln2_g"])  # up_in
    u = jax.nn.gelu(h2 @ p[f"blk{b}.w_up"])  # down_in
    x = x + u @ p[f"blk{b}.w_down"]
    return x, {"qkv": h, "o": att, "up": h2, "down": u}


def _forward(cfg: ModelCfg, p: Dict[str, jnp.ndarray], tokens: jnp.ndarray, use_pallas: bool):
    """Full forward. Returns (logits, list of per-block role inputs)."""
    b, t = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :t, :]
    roles = []
    for blk in range(cfg.n_layer):
        x, r = _block_fwd(cfg, p, blk, x, use_pallas)
        roles.append(r)
    logits = rmsnorm(x, p["lnf_g"]) @ p["w_head"]
    return logits, roles


# --------------------------------------------------------------------------
# Entrypoints
# --------------------------------------------------------------------------


def fwd_logits(cfg: ModelCfg, *args):
    """(params…, tokens) -> (logits,). Inference graph with Pallas attention."""
    tokens = args[-1]
    p = unflatten(cfg, args[:-1])
    logits, _ = _forward(cfg, p, tokens, use_pallas=True)
    return (logits,)


def fwd_capture(cfg: ModelCfg, *args):
    """(params…, tokens) -> calibration capture.

    Returns, in order:
      acts_qkv  [L, R, d]   acts_o [L, R, d]   acts_up [L, R, d]
      acts_down [L, R, ff]
      stats_qkv [L, d]      stats_o [L, d]     stats_up [L, d]
      stats_down[L, ff]
    where R = B*T rows. Stats are per-channel mean |a| via the Pallas
    absmean kernel — the inputs to the AWQ/FAQ scale rule.
    """
    tokens = args[-1]
    p = unflatten(cfg, args[:-1])
    _, roles = _forward(cfg, p, tokens, use_pallas=True)
    outs = []
    for role in ROLES:
        acts = jnp.stack(
            [r[role].reshape(-1, r[role].shape[-1]) for r in roles]
        )  # [L, R, n]
        outs.append(acts)
    for role in ROLES:
        stats = jnp.stack(
            [absmean(r[role].reshape(-1, r[role].shape[-1])) for r in roles]
        )  # [L, n]
        outs.append(stats)
    return tuple(outs)


def layer_loss(a: jnp.ndarray, w: jnp.ndarray, s: jnp.ndarray, *, bits: int, group: int):
    """Grid-search objective (paper eq. 3/7): MSE between the FP layer output
    and the output with W quantized under channel scale s."""
    y_fp = a @ w
    wq = scaled_fakequant(w, s, bits=bits, group=group)
    y_q = a @ wq
    d = y_q - y_fp
    return (jnp.mean(d * d),)


def layer_loss_sweep(
    a: jnp.ndarray, w: jnp.ndarray, scales: jnp.ndarray, *, bits: int, group: int
):
    """Whole-alpha-grid objective (§Perf): evaluates the recon loss for all
    candidate scale vectors in ONE execution — scales [n_alpha, n] ->
    losses [n_alpha]. Unrolled at trace time (pallas_call has no batching
    rule); XLA fuses the shared a@w across candidates."""
    y_fp = a @ w
    losses = []
    for i in range(scales.shape[0]):
        wq = scaled_fakequant(w, scales[i], bits=bits, group=group)
        d = a @ wq - y_fp
        losses.append(jnp.mean(d * d))
    return (jnp.stack(losses),)


def fakequant_artifact(w: jnp.ndarray, s: jnp.ndarray, *, bits: int, group: int):
    """Standalone scaled-fakequant for rust<->python bit-parity tests."""
    return (scaled_fakequant(w, s, bits=bits, group=group),)


def fwd_logits_q(cfg: ModelCfg, group: int, *args):
    """Quantized-deployment forward: every block linear is executed by the
    qmatmul Pallas kernel from integer codes + dequant params.

    Flat arg order (mirrored by rust/src/runtime/registry.rs):
      tok_emb, pos_emb,
      per block: ln1_g, [q,delta,z,inv_s] x (qkv,o,up,down), ln2_g
                 — i.e. ln1_g, qkv4, o4, ln2_g, up4, down4 —
      lnf_g, w_head, tokens
    """
    it = iter(args)

    def nxt():
        return next(it)

    tok_emb, pos_emb = nxt(), nxt()
    blocks = []
    for _ in range(cfg.n_layer):
        ln1 = nxt()
        qkv = tuple(nxt() for _ in range(4))
        o = tuple(nxt() for _ in range(4))
        ln2 = nxt()
        up = tuple(nxt() for _ in range(4))
        down = tuple(nxt() for _ in range(4))
        blocks.append((ln1, qkv, o, ln2, up, down))
    lnf_g, w_head, tokens = nxt(), nxt(), nxt()
    rest = list(it)
    assert not rest, f"{len(rest)} extra args to fwd_logits_q"

    bsz, t = tokens.shape
    d = cfg.d_model

    def qlin(x2d, qp):
        q, delta, z, inv_s = qp
        return qmatmul(x2d, q, delta, z, inv_s, group=group)

    x = tok_emb[tokens] + pos_emb[None, :t, :]
    for ln1, qkvp, op, ln2, upp, downp in blocks:
        h = rmsnorm(x, ln1)
        qkv = qlin(h.reshape(bsz * t, d), qkvp).reshape(bsz, t, 3 * d)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q, k, v = (_split_heads(tt, cfg.n_head) for tt in (q, k, v))
        att = _merge_heads(attention(q, k, v))
        x = x + qlin(att.reshape(bsz * t, d), op).reshape(bsz, t, d)
        h2 = rmsnorm(x, ln2)
        u = jax.nn.gelu(qlin(h2.reshape(bsz * t, d), upp).reshape(bsz, t, cfg.d_ff))
        x = x + qlin(u.reshape(bsz * t, cfg.d_ff), downp).reshape(bsz, t, d)
    logits = rmsnorm(x, lnf_g) @ w_head
    return (logits,)


# --------------------------------------------------------------------------
# Training (S5): fwd/bwd + AdamW, pure-jnp ops (differentiable)
# --------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS, WEIGHT_DECAY, LR = 0.9, 0.95, 1e-8, 0.01, 3e-3


def _loss_fn(cfg: ModelCfg, flat_params, tokens: jnp.ndarray):
    """Next-token cross-entropy. tokens: [B, T+1] int32."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    p = unflatten(cfg, flat_params)
    logits, _ = _forward(cfg, p, inp, use_pallas=False)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def train_step(cfg: ModelCfg, *args):
    """(params… , m…, v…, step, tokens[B,T+1]) -> (params'…, m'…, v'…, loss)."""
    n = len(param_specs(cfg))
    params = args[:n]
    ms = args[n : 2 * n]
    vs = args[2 * n : 3 * n]
    step, tokens = args[3 * n], args[3 * n + 1]

    loss, grads = jax.value_and_grad(lambda fp: _loss_fn(cfg, fp, tokens))(params)
    step = step + 1.0
    bc1 = 1.0 - ADAM_B1**step
    bc2 = 1.0 - ADAM_B2**step
    new_p, new_m, new_v = [], [], []
    for (name, _), p, m, v, g in zip(param_specs(cfg), params, ms, vs, grads):
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + ADAM_EPS)
        decay = 0.0 if name.endswith("_g") or "emb" in name else WEIGHT_DECAY
        p = p - LR * (upd + decay * p)
        new_p.append(p)
        new_m.append(m)
        new_v.append(v)
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (step, loss)


# --------------------------------------------------------------------------
# Shape specs for AOT lowering
# --------------------------------------------------------------------------


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def fwd_arg_specs(cfg: ModelCfg):
    return [f32(s) for _, s in param_specs(cfg)] + [i32((cfg.batch, cfg.seq))]


def train_arg_specs(cfg: ModelCfg):
    ps = [f32(s) for _, s in param_specs(cfg)]
    return ps + ps + ps + [f32(())] + [i32((cfg.batch, cfg.seq + 1))]


def qfwd_arg_specs(cfg: ModelCfg, group: int):
    specs = [f32((cfg.vocab, cfg.d_model)), f32((cfg.seq, cfg.d_model))]
    for _ in range(cfg.n_layer):
        specs.append(f32((cfg.d_model,)))  # ln1_g
        for role in ("qkv", "o"):
            n, m = role_shape(cfg, role)
            specs += [f32((n, m)), f32((n // group, m)), f32((n // group, m)), f32((n,))]
        specs.append(f32((cfg.d_model,)))  # ln2_g
        for role in ("up", "down"):
            n, m = role_shape(cfg, role)
            specs += [f32((n, m)), f32((n // group, m)), f32((n // group, m)), f32((n,))]
    specs += [f32((cfg.d_model,)), f32((cfg.d_model, cfg.vocab))]
    specs += [i32((cfg.batch, cfg.seq))]
    return specs


def layer_loss_arg_specs(cfg: ModelCfg, role: str, loss_rows: int):
    n, m = role_shape(cfg, role)
    return [f32((loss_rows, n)), f32((n, m)), f32((n,))]


N_ALPHA = 20  # alpha-grid size baked into the sweep artifacts


def entrypoints(cfg: ModelCfg, *, group: int, loss_rows: int, bits_list=(3, 4)):
    """All (name, fn, arg_specs) triples to lower for one model config."""
    eps = [
        ("fwd_logits", functools.partial(fwd_logits, cfg), fwd_arg_specs(cfg)),
        ("fwd_capture", functools.partial(fwd_capture, cfg), fwd_arg_specs(cfg)),
        ("fwd_logits_q", functools.partial(fwd_logits_q, cfg, group), qfwd_arg_specs(cfg, group)),
        ("train_step", functools.partial(train_step, cfg), train_arg_specs(cfg)),
    ]
    for role in ROLES:
        n, m = role_shape(cfg, role)
        for bits in bits_list:
            eps.append(
                (
                    f"layer_loss_{role}_b{bits}",
                    functools.partial(layer_loss, bits=bits, group=group),
                    layer_loss_arg_specs(cfg, role, loss_rows),
                )
            )
            eps.append(
                (
                    f"layer_loss_sweep_{role}_b{bits}",
                    functools.partial(layer_loss_sweep, bits=bits, group=group),
                    [f32((loss_rows, n)), f32((n, m)), f32((N_ALPHA, n))],
                )
            )
    return eps
