"""AOT lowering: jax entrypoints -> artifacts/<cfg>/<name>.hlo.txt + manifest.

HLO *text* (NOT `lowered.compile()` / proto `.serialize()`) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the rust `xla` crate binds)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Incremental: a sha256 over python/compile/**/*.py and the lowering
parameters is stored in artifacts/.srchash — if unchanged and every
expected file exists, the build is a no-op (so `make artifacts` is cheap
on the rust iteration loop).

The manifest (artifacts/manifest.txt) is a line-based format parsed by
rust/src/runtime/registry.rs:
    config <name> key=value ...
    param <cfg> <idx> <name> <d0>x<d1>...
    artifact <cfg> <entry> <relpath> nargs=<n> nouts=<n>
"""

from __future__ import annotations

import argparse
import hashlib
import pathlib
import sys

import jax

from . import model as M


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def src_hash(params: str) -> str:
    h = hashlib.sha256()
    root = pathlib.Path(__file__).parent
    for p in sorted(root.rglob("*.py")):
        h.update(p.read_bytes())
    h.update(params.encode())
    return h.hexdigest()


def lower_one(fn, arg_specs) -> str:
    # keep_unused=True: the artifact ABI is the canonical parameter list —
    # entrypoints like fwd_capture deliberately ignore some params (e.g.
    # lm_head) and the rust side always passes the full set.
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*arg_specs))


def build(out_dir: pathlib.Path, cfg_names, group: int, loss_rows: int, force: bool) -> None:
    params = f"configs={','.join(cfg_names)};group={group};loss_rows={loss_rows};v3"
    out_dir.mkdir(parents=True, exist_ok=True)
    hash_file = out_dir / ".srchash"
    manifest_file = out_dir / "manifest.txt"
    want = src_hash(params)

    manifest = [
        "# faquant artifact manifest v1",
        f"group {group}",
        f"loss_rows {loss_rows}",
    ]
    expected = [manifest_file]
    plans = []  # (cfg_name, entry, relpath, fn, specs)
    for name in cfg_names:
        cfg = M.CONFIGS[name]
        manifest.append(
            f"config {cfg.name} n_layer={cfg.n_layer} d_model={cfg.d_model} "
            f"n_head={cfg.n_head} d_ff={cfg.d_ff} vocab={cfg.vocab} "
            f"seq={cfg.seq} batch={cfg.batch}"
        )
        for idx, (pname, shape) in enumerate(M.param_specs(cfg)):
            dims = "x".join(str(d) for d in shape) if shape else "scalar"
            manifest.append(f"param {cfg.name} {idx} {pname} {dims}")
        for entry, fn, specs in M.entrypoints(cfg, group=group, loss_rows=loss_rows):
            rel = f"{cfg.name}/{entry}.hlo.txt"
            expected.append(out_dir / rel)
            plans.append((cfg.name, entry, rel, fn, specs))
            manifest.append(f"artifact {cfg.name} {entry} {rel} nargs={len(specs)}")

    if (
        not force
        and hash_file.exists()
        and hash_file.read_text().strip() == want
        and all(p.exists() for p in expected)
    ):
        print(f"artifacts up to date ({len(plans)} HLO modules)")
        return

    for cfg_name, entry, rel, fn, specs in plans:
        path = out_dir / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        text = lower_one(fn, specs)
        path.write_text(text)
        print(f"  lowered {cfg_name}/{entry}: {len(specs)} args, {len(text)//1024} KiB")

    manifest_file.write_text("\n".join(manifest) + "\n")
    hash_file.write_text(want)
    print(f"wrote {manifest_file} ({len(plans)} artifacts)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="pico,nano,tiny,small")
    ap.add_argument("--group", type=int, default=64)
    ap.add_argument("--loss-rows", type=int, default=512)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    build(
        pathlib.Path(args.out),
        [c for c in args.configs.split(",") if c],
        args.group,
        args.loss_rows,
        args.force,
    )


if __name__ == "__main__":
    sys.exit(main())
