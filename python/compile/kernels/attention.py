"""Pallas kernel: causal multi-head self-attention.

The transformer forward's compute hot-spot. One grid step owns one
(batch, head) pair with the full (T, head_dim) Q/K/V panels resident in
VMEM — at T=128, hd<=64 that is 3 * 32 KiB, trivially VMEM-fit, so the
FlashAttention streaming decomposition is unnecessary at these shapes
(DESIGN.md §7); the QK^T and PV contractions hit the MXU directly and the
softmax runs on the VPU over the lane-aligned T axis.

Numerics: max-subtracted softmax in f32, additive -1e30 causal mask —
bit-compatible with ref.ref_attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[0]  # [T, hd]
    k = k_ref[0]
    v = v_ref[0]
    t = q.shape[0]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    logits = jnp.where(col <= row, logits, -1e30)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Causal MHA. q,k,v: [B, H, T, hd] -> [B, H, T, hd]."""
    b, h, t, hd = q.shape
    scale = 1.0 / float(hd) ** 0.5
    qf = q.reshape(b * h, t, hd)
    kf = k.reshape(b * h, t, hd)
    vf = v.reshape(b * h, t, hd)
    out = pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, hd), jnp.float32),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, t, hd)
