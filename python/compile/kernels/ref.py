"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: each kernel in this package must
match its `ref_*` counterpart to float32 tolerance (pytest + hypothesis in
python/tests/). They are also used directly by model.py when a shape is too
small/ragged to tile (the kernels require block-aligned shapes).

Quantization convention (asymmetric, group-wise along the *input* dim):
  W: [n, m]  (y = a @ W, input channels are rows)
  groups of size g along n; each (group, output-column) pair has its own
  step `delta` and integer zero-point `z`:
      delta = (max - min) / (2^b - 1)
      z     = round(-min / delta)
      q     = clip(round(w / delta) + z, 0, 2^b - 1)
      deq   = (q - z) * delta
This mirrors AWQ's deployed INTxFP scheme (paper Sec. 2.1 uses the
symmetric form for exposition; Sec. 3.1 states asymmetric is used).
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_group_minmax(w: jnp.ndarray, group: int):
    """Per-(group, out-col) min/max. w: [n, m] -> ([n//g, m], [n//g, m])."""
    n, m = w.shape
    assert n % group == 0, f"n={n} not divisible by group={group}"
    wg = w.reshape(n // group, group, m)
    return wg.min(axis=1), wg.max(axis=1)


def ref_fakequant(w: jnp.ndarray, bits: int, group: int) -> jnp.ndarray:
    """Asymmetric group quant-dequant of w [n, m] along input dim."""
    n, m = w.shape
    qmax = float(2**bits - 1)
    lo, hi = ref_group_minmax(w, group)
    delta = (hi - lo) / qmax
    # Guard all-equal groups (delta == 0): pick delta = |lo| (or 1 if the
    # group is all-zero) so the constant reconstructs exactly with integer
    # codes: q = 0, z = round(-lo/delta) in {-1, 0, 1}.
    degen = delta <= 0.0
    delta = jnp.where(degen, jnp.where(jnp.abs(lo) > 0.0, jnp.abs(lo), 1.0), delta)
    z = jnp.round(-lo / delta)
    wg = w.reshape(n // group, group, m)
    q = jnp.clip(jnp.round(wg / delta[:, None, :]) + z[:, None, :], 0.0, qmax)
    deq = (q - z[:, None, :]) * delta[:, None, :]
    return deq.reshape(n, m)


def ref_scaled_fakequant(w: jnp.ndarray, s: jnp.ndarray, bits: int, group: int) -> jnp.ndarray:
    """AWQ/FAQ transform: fakequant(W * s) / s with per-input-channel s [n]."""
    ws = w * s[:, None]
    return ref_fakequant(ws, bits, group) / s[:, None]


def ref_absmean(a: jnp.ndarray) -> jnp.ndarray:
    """Per-channel mean |a| over rows. a: [rows, n] -> [n]."""
    return jnp.mean(jnp.abs(a), axis=0)


def ref_quantize_ints(w: jnp.ndarray, bits: int, group: int):
    """Integer-domain quantization: returns (q int [n,m], delta [n//g,m], z [n//g,m])."""
    n, m = w.shape
    qmax = float(2**bits - 1)
    lo, hi = ref_group_minmax(w, group)
    delta = (hi - lo) / qmax
    degen = delta <= 0.0
    delta = jnp.where(degen, jnp.where(jnp.abs(lo) > 0.0, jnp.abs(lo), 1.0), delta)
    z = jnp.round(-lo / delta)
    wg = w.reshape(n // group, group, m)
    q = jnp.clip(jnp.round(wg / delta[:, None, :]) + z[:, None, :], 0.0, qmax)
    return q.reshape(n, m), delta, z


def ref_qmatmul(
    a: jnp.ndarray,
    q: jnp.ndarray,
    delta: jnp.ndarray,
    z: jnp.ndarray,
    inv_s: jnp.ndarray,
    group: int,
) -> jnp.ndarray:
    """Quantized linear: (a * inv_s) @ dequant(q).

    a: [S, n] activations; q: [n, m] integer codes (stored as f32 or i8);
    delta, z: [n//g, m]; inv_s: [n] reciprocal AWQ channel scale.
    """
    n, m = q.shape
    qg = q.astype(jnp.float32).reshape(n // group, group, m)
    deq = ((qg - z[:, None, :]) * delta[:, None, :]).reshape(n, m)
    return (a * inv_s[None, :]) @ deq


def ref_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Causal multi-head attention. q,k,v: [B, H, T, hd] -> [B, H, T, hd]."""
    _, _, t, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
