"""Pallas kernel: per-channel mean of |a| over the row (token) dimension.

Calibration statistics capture (phase A): every linear's input activation
a [rows, n] is reduced to the per-channel mean magnitude that drives the
AWQ/FAQ scale rule s = a_bar ** alpha.

TPU mapping: rows stream HBM->VMEM in block_r chunks; the channel axis n
stays whole on the lane dimension so the reduction is a column-sum VPU op
accumulated into a VMEM-resident output row. Output aliasing across grid
steps implements the accumulator (sequential grid on TPU guarantees
ordering; interpret mode preserves it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _abssum_kernel(a_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(jnp.abs(a_ref[...]), axis=0, keepdims=True)


def absmean(a: jnp.ndarray, *, block_r: int = 128) -> jnp.ndarray:
    """Mean |a| per channel. a: [rows, n] -> [n]. rows % block_r == 0."""
    rows, n = a.shape
    block_r = min(block_r, rows)
    assert rows % block_r == 0, f"rows={rows} % block_r={block_r} != 0"
    grid = (rows // block_r,)
    out = pl.pallas_call(
        _abssum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), a.dtype),
        interpret=True,
    )(a)
    return out[0] / jnp.float32(rows)
