"""Pallas kernel: asymmetric group fake-quantization of a weight matrix.

This is the hot spot of the AWQ/FAQ calibration grid search: for every
candidate alpha, the scaled weight `W * s` must be quantize-dequantized and
the layer reconstruction loss evaluated. The kernel tiles W into
(group, block_m) stripes so each grid step owns exactly one quantization
group per output-column block.

TPU mapping (DESIGN.md §7): the group axis (rows) streams HBM->VMEM one
stripe at a time; the output-column axis sits on the 128-wide lane
dimension so min/max/round are full-width VPU ops. A (group=32, bm=128)
f32 tile is 16 KiB — far under VMEM, leaving room for double buffering.

Lowered with interpret=True (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(m: int, prefer: int = 128) -> int:
    """Largest power-of-two block <= prefer that divides m (min 8)."""
    b = prefer
    while b > 8 and m % b != 0:
        b //= 2
    assert m % b == 0, f"no power-of-two block divides m={m}"
    return b


def _fakequant_kernel(w_ref, o_ref, *, qmax: float):
    """One (group, block_m) stripe: asym quant-dequant along axis 0."""
    w = w_ref[...]  # [group, bm]
    lo = jnp.min(w, axis=0, keepdims=True)
    hi = jnp.max(w, axis=0, keepdims=True)
    delta = (hi - lo) / qmax
    degen = delta <= 0.0
    delta = jnp.where(degen, jnp.where(jnp.abs(lo) > 0.0, jnp.abs(lo), 1.0), delta)
    z = jnp.round(-lo / delta)
    q = jnp.clip(jnp.round(w / delta) + z, 0.0, qmax)
    o_ref[...] = (q - z) * delta


def fakequant(w: jnp.ndarray, *, bits: int, group: int, block_m: int = 128) -> jnp.ndarray:
    """Asymmetric group quant-dequant of w [n, m] along the input (row) dim.

    Requires n % group == 0 and m % block_m == 0 (callers pick block_m to
    divide m; model shapes are multiples of 64).
    """
    n, m = w.shape
    assert n % group == 0, f"n={n} % group={group} != 0"
    block_m = pick_block(m, prefer=block_m)
    qmax = float(2**bits - 1)
    grid = (n // group, m // block_m)
    return pl.pallas_call(
        functools.partial(_fakequant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((group, block_m), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((group, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), w.dtype),
        interpret=True,
    )(w)


def scaled_fakequant(
    w: jnp.ndarray, s: jnp.ndarray, *, bits: int, group: int, block_m: int = 128
) -> jnp.ndarray:
    """AWQ/FAQ weight transform: fakequant(W * diag(s)) / diag(s).

    The row scaling and un-scaling are elementwise and fuse into the
    surrounding HLO; the grouped min/max/round core runs in the kernel.
    """
    ws = w * s[:, None]
    return fakequant(ws, bits=bits, group=group, block_m=block_m) / s[:, None]
