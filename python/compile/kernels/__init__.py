# L1: Pallas kernels for the paper's compute hot-spots, plus the pure-jnp
# reference oracles in ref.py. All kernels lower with interpret=True so the
# surrounding jax program AOT-lowers to plain HLO runnable on CPU PJRT.
from .absmean import absmean
from .attention import attention
from .fakequant import fakequant, scaled_fakequant
from .qmatmul import qmatmul

__all__ = ["absmean", "attention", "fakequant", "scaled_fakequant", "qmatmul"]
