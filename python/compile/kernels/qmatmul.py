"""Pallas kernel: quantized linear — (a * inv_s) @ dequant(q).

The deployed inference path (edge serving / quantized eval): weights live
as low-bit integer codes plus per-(group, out-col) dequant params; the
kernel dequantizes one (group, block_m) weight stripe into VMEM and feeds
the MXU, so INT->FP conversion is hidden behind the systolic pipeline
(DESIGN.md §7 — the TPU analogue of AWQ's fused CUDA INTxFP GEMM).

Grid: (S/block_s, m/block_m, n/group). The k axis (quant groups) is the
innermost sequential dimension; the f32 accumulator lives in the output
block, initialized at k == 0. Each k step consumes exactly one quant group
so delta/z are scalars-per-column, keeping the dequant a rank-1 VPU op.

Codes are carried as f32 holding integer values: XLA CPU (and the MXU
story) prefer f32 multiplies, and 2^bits-1 <= 15 is exactly representable.
Packing to int3/int4 words is the rust store's job (quant/packing.rs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmatmul_kernel(a_ref, q_ref, d_ref, z_ref, is_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...] * is_ref[...]  # [bs, g] scaled activations
    deq = (q_ref[...] - z_ref[...]) * d_ref[...]  # [g, bm] dequant stripe
    o_ref[...] += jnp.dot(a, deq, preferred_element_type=jnp.float32)


def qmatmul(
    a: jnp.ndarray,
    q: jnp.ndarray,
    delta: jnp.ndarray,
    z: jnp.ndarray,
    inv_s: jnp.ndarray,
    *,
    group: int,
    block_s: int = 128,
    block_m: int = 128,
) -> jnp.ndarray:
    """Quantized matmul. a [S,n] f32; q [n,m] f32-coded ints; delta,z [n/g,m];
    inv_s [n]. Returns [S, m] f32."""
    from .fakequant import pick_block

    s_rows, n = a.shape
    n2, m = q.shape
    assert n == n2 and n % group == 0
    block_s = pick_block(s_rows, prefer=block_s)
    block_m = pick_block(m, prefer=block_m)
    grid = (s_rows // block_s, m // block_m, n // group)
    inv_s2 = inv_s.reshape(1, n)
    return pl.pallas_call(
        _qmatmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, group), lambda i, j, k: (i, k)),
            pl.BlockSpec((group, block_m), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_m), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_m), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, group), lambda i, j, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((block_s, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s_rows, m), jnp.float32),
        interpret=True,
    )(a, q, delta, z, inv_s2)
