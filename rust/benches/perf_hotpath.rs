//! §Perf hot-path microbenchmarks (EXPERIMENTS.md §Perf).
//!
//! Times the three request-path stages in isolation so the optimization
//! loop can attribute regressions:
//!   1. grid-search step  — one layer_loss execution (L1 fakequant path)
//!   2. capture batch     — one fwd_capture execution (L1 absmean path)
//!   3. eval batch        — one fwd_logits execution (attention kernel)
//!   4. qserve batch      — one fwd_logits_q execution (qmatmul kernel)
//!   5. host quantize     — rust-side scaled_quantize_ints + bit-pack
//!
//! Also reports the coordinator-overhead ratio (time outside PJRT execute
//! during a full search) — the L3 perf target is < 5% (DESIGN.md §9).
//!
//! ```bash
//! cargo bench --offline --bench perf_hotpath
//! ```

mod common;

use faquant::benchkit::{bench, report};
use faquant::calib::capture;
use faquant::config::RunConfig;
use faquant::coordinator::Pipeline;
use faquant::corpus::Batcher;
use faquant::eval::{calib_ids, canonical_tokenizer};
use faquant::quant::{packing, scaled_quantize_ints, search_alpha};
use faquant::runtime::{lit_f32, lit_i32, Runtime};
use faquant::serve::qmodel_literals;
use faquant::tensor::Rng;

fn main() {
    let rt: Runtime = common::runtime();
    let mut cfg: RunConfig = common::base_cfg();
    cfg.model = faquant::config::ModelConfig::preset("nano").expect("preset");

    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint().expect("checkpoint");
    let (calib, _) = pipe.calibrate(&params).expect("calibrate");
    let (qm, _) = pipe.quantize(&params, Some(&calib)).expect("quantize");

    let tok = canonical_tokenizer(&cfg.model);
    let ids = calib_ids(&cfg.model, &tok, 8, 1);
    let batch = Batcher::new(cfg.model.batch, cfg.model.seq)
        .eval_batches(&ids)
        .expect("batch")[0]
        .clone();

    // 1. grid-search single step (the calibration hot path).
    let w = params.role_weight(0, "qkv").expect("w").clone();
    let acts = calib.acts_for(0, 0).clone();
    let stats = calib.stats_for(0, 0).to_vec();
    let s = bench("grid_search_20alphas(qkv)", 1, 5, || {
        search_alpha(&rt, &cfg.model.name, "qkv", 3, &acts, &w, &stats, 20).expect("search");
    });
    println!("{}", report(&s));

    // 2. capture batch.
    let s = bench("fwd_capture(batch=4xT128)", 1, 5, || {
        capture(&rt, &cfg.model, &params, std::slice::from_ref(&batch), 1).expect("capture");
    });
    println!("{}", report(&s));

    // 3. eval batch (fp path).
    let mut args = Vec::new();
    for t in &params.tensors {
        args.push(lit_f32(t).expect("lit"));
    }
    args.push(lit_i32(&batch).expect("lit"));
    let s = bench("fwd_logits(batch=4xT128)", 1, 8, || {
        rt.exec(&cfg.model.name, "fwd_logits", &args).expect("exec");
    });
    println!("{}", report(&s));
    let eval_its = s.throughput(1.0);

    // 4. quantized serve batch (int-code path).
    let mut qargs = qmodel_literals(&params, &qm).expect("qlits");
    qargs.push(lit_i32(&batch).expect("lit"));
    let s = bench("fwd_logits_q(batch=4xT128)", 1, 8, || {
        rt.exec(&cfg.model.name, "fwd_logits_q", &qargs).expect("exec");
    });
    println!("{}", report(&s));
    println!(
        "  -> quantized/fp batch throughput ratio: {:.2}x",
        s.throughput(1.0) / eval_its
    );

    // 5. host-side quantize + pack (per linear).
    let mut rng = Rng::new(1);
    let wbig = faquant::tensor::Tensor::randn(&mut rng, &[512, 512], 1.0);
    let sv = vec![1.0f32; 512];
    let s = bench("host_quantize_pack(512x512,b3)", 1, 10, || {
        let (ints, _) = scaled_quantize_ints(&wbig, &sv, 3, 64).expect("q");
        let _ = packing::pack(&ints.q, 3).expect("pack");
    });
    println!("{}", report(&s));

    // Coordinator-overhead ratio over a fresh full search.
    let rt2 = common::runtime();
    let pipe2 = Pipeline::new(&rt2, cfg.clone());
    let (p2, _) = pipe2.checkpoint().expect("ckpt");
    let (c2, _) = pipe2.calibrate(&p2).expect("calib");
    let compile_before: f32 = rt2.stats().values().map(|s| s.compile_secs).sum();
    let exec_before: f32 = rt2.stats().values().map(|s| s.exec_secs).sum();
    let t0 = std::time::Instant::now();
    let _ = pipe2.quantize(&p2, Some(&c2)).expect("quantize");
    let wall = t0.elapsed().as_secs_f32();
    let stats = rt2.stats();
    let inside: f32 =
        stats.values().map(|s| s.exec_secs).sum::<f32>() - exec_before;
    // First-use executable compilation is a one-time cost, not coordinator
    // overhead — exclude it from the ratio.
    let compile: f32 =
        stats.values().map(|s| s.compile_secs).sum::<f32>() - compile_before;
    let steady = (wall - compile).max(1e-6);
    println!(
        "search wall {wall:.2}s (compile {compile:.2}s), steady-state {steady:.2}s, \
         inside PJRT {inside:.2}s -> coordinator overhead {:.1}%",
        (1.0 - inside / steady) * 100.0
    );
}
