//! §Perf hot-path microbenchmarks (EXPERIMENTS.md §Perf).
//!
//! Times the request-path stages in isolation so the optimization loop
//! can attribute regressions:
//!   1.  grid-search step — one layer_loss sweep (fakequant path)
//!   2.  capture batch    — one fwd_capture execution (absmean path)
//!   3.  eval batch       — one fwd_logits execution (attention kernel)
//!   4.  qserve batch     — one fwd_logits_q execution (qmatmul path)
//!   4b. weight prepare   — one-time dequantize-once panel pack (§11)
//!   4c. prepared batch   — fwd_logits_q over the prepared bundle
//!   4d. int batch        — fwd_logits_qi, the integer W4A8 path (§17)
//!   5.  host quantize    — rust-side scaled_quantize_ints + bit-pack
//!   6.  generation       — KV-cached continuous-batching decode engine
//!                          (prefill/decode tokens-per-second split)
//!   6b. prepared decode  — same workload, prepared bundle (the
//!                          decode_prepared_tokens_per_sec headline)
//!   6c. shared prefix    — paged engine + radix prefix cache (§12);
//!                          fraction of prompt tokens never fed
//!   6d. paged memory     — peak in-use KV bytes vs the dense slab
//!   6e. sharded router   — workload fanned over crash-isolated engine
//!                          workers (§16); fleet-merged router_ttft_* /
//!                          router_per_token_* latency percentiles
//!   6f. int decode       — decode on the int8xint4 kernel (§17):
//!                          decode_int_tokens_per_sec + per-pass weight
//!                          bytes read, f32 panels vs packed int codes
//!
//! Then the threading headline: the end-to-end Phase-B quantize at
//! 1 thread vs the effective `FAQUANT_THREADS`, and the coordinator
//! overhead ratio (time outside backend execution during a full search,
//! measured single-threaded so per-entry exec sums compare to wall
//! time) — the L3 perf target is < 5% (DESIGN.md §9).
//!
//! Everything is written machine-readably to `BENCH_perf.json` at the
//! repo root (committed, so the perf trajectory is tracked across PRs).
//!
//! ```bash
//! cargo bench --offline --bench perf_hotpath                  # nano
//! FAQUANT_BENCH_PRESET=pico cargo bench --bench perf_hotpath  # CI smoke
//! ```

mod common;

use faquant::benchkit::{bench, report, PerfReport};
use faquant::calib::capture;
use faquant::config::RunConfig;
use faquant::coordinator::Pipeline;
use faquant::corpus::Batcher;
use faquant::engine::{Engine, GenConfig, GenRequest};
use faquant::eval::{calib_ids, canonical_tokenizer};
use faquant::quant::{packing, scaled_quantize_ints, search_alpha};
use faquant::runtime::{lit_f32, lit_i32, Buffer, Runtime};
use faquant::serve::{qmodel_literals, router::run_router, RouterConfig, Stepper};
use faquant::tensor::{par, Rng};

fn main() {
    let preset =
        std::env::var("FAQUANT_BENCH_PRESET").unwrap_or_else(|_| "nano".to_string());
    let rt: Runtime = common::runtime();
    let mut cfg: RunConfig = common::base_cfg();
    cfg.model = faquant::config::ModelConfig::preset(&preset).expect("preset");

    let threads = par::threads();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("preset {preset}, threads {threads}, cores {cores}");

    let pipe = Pipeline::new(&rt, cfg.clone());
    let (params, _) = pipe.checkpoint().expect("checkpoint");
    let (calib, _) = pipe.calibrate(&params).expect("calibrate");
    let (qm, _) = pipe.quantize(&params, Some(&calib)).expect("quantize");

    let tok = canonical_tokenizer(&cfg.model);
    let ids = calib_ids(&cfg.model, &tok, 8, 1);
    let batch = Batcher::new(cfg.model.batch, cfg.model.seq)
        .eval_batches(&ids)
        .expect("batch")[0]
        .clone();

    let mut stages = Vec::new();

    // 1. grid-search single step (the calibration hot path).
    let w = params.role_weight(0, "qkv").expect("w").clone();
    let acts = calib.acts_for(0, 0).clone();
    let stats = calib.stats_for(0, 0).to_vec();
    let s = bench("grid_search_20alphas(qkv)", 1, 5, || {
        search_alpha(&rt, &cfg.model.name, "qkv", 3, &acts, &w, &stats, 20).expect("search");
    });
    println!("{}", report(&s));
    stages.push(s);

    // 2. capture batch.
    let s = bench("fwd_capture(batch=4xT128)", 1, 5, || {
        capture(&rt, &cfg.model, &params, std::slice::from_ref(&batch), 1).expect("capture");
    });
    println!("{}", report(&s));
    stages.push(s);

    // 3. eval batch (fp path).
    let mut args = Vec::new();
    for t in &params.tensors {
        args.push(lit_f32(t).expect("lit"));
    }
    args.push(lit_i32(&batch).expect("lit"));
    let s = bench("fwd_logits(batch=4xT128)", 1, 8, || {
        rt.exec(&cfg.model.name, "fwd_logits", &args).expect("exec");
    });
    println!("{}", report(&s));
    let eval_its = s.throughput(1.0);
    stages.push(s);

    // 4. quantized serve batch (int-code path, per-call dequant).
    let qlits = qmodel_literals(&params, &qm).expect("qlits");
    let mut qargs = qlits.clone();
    qargs.push(lit_i32(&batch).expect("lit"));
    let s = bench("fwd_logits_q(batch=4xT128)", 1, 8, || {
        rt.exec(&cfg.model.name, "fwd_logits_q", &qargs).expect("exec");
    });
    println!("{}", report(&s));
    println!(
        "  -> quantized/fp batch throughput ratio: {:.2}x",
        s.throughput(1.0) / eval_its
    );
    let fwdq_its = s.throughput(1.0);
    stages.push(s);

    // 4b. one-time weight prepare (dequantize-once panel pack, DESIGN
    // §11). One pre-built runtime per iteration, kept alive past the
    // timer, so neither runtime bring-up/teardown nor the prepared-state
    // cache skews the measurement.
    let mut fresh_rts: Vec<Runtime> = (0..3).map(|_| common::runtime()).collect();
    let mut used_rts: Vec<Runtime> = Vec::new();
    let s = bench("prepare_secs", 0, 3, || {
        let fresh = fresh_rts.pop().expect("one runtime per iteration");
        fresh
            .prepare_qweights(&cfg.model.name, &qlits)
            .expect("prepare");
        used_rts.push(fresh);
    });
    drop(used_rts);
    println!("{}", report(&s));
    let prepare_secs = s.mean;
    stages.push(s);

    // 4c. quantized serve batch over the prepared bundle.
    let qbufs = rt
        .prepare_qweights(&cfg.model.name, &qlits)
        .expect("prepare");
    let tok_buf = rt.upload_i32(&batch).expect("upload");
    let s = bench("fwd_logits_q_prepared(batch=4xT128)", 1, 8, || {
        let mut args: Vec<&Buffer> = qbufs.iter().collect();
        args.push(&tok_buf);
        rt.exec_b(&cfg.model.name, "fwd_logits_q", &args).expect("exec");
    });
    println!("{}", report(&s));
    println!(
        "  -> prepared/unprepared batch throughput ratio: {:.2}x",
        s.throughput(1.0) / fwdq_its
    );
    stages.push(s);

    // 4d. Same prepared bundle through the integer W4A8 path (int8
    // activations x stored int4 codes, DESIGN §17). Skipped when the
    // artifact's codes don't fit int4 (bits > 4).
    let int_ready = match qbufs.first() {
        Some(Buffer::PreparedQ(pm)) => pm.int_reason().is_none(),
        _ => false,
    };
    if int_ready {
        let s = bench("fwd_logits_qi(batch=4xT128)", 1, 8, || {
            let mut args: Vec<&Buffer> = qbufs.iter().collect();
            args.push(&tok_buf);
            rt.exec_b(&cfg.model.name, "fwd_logits_qi", &args).expect("exec");
        });
        println!("{}", report(&s));
        stages.push(s);
    } else {
        println!("fwd_logits_qi: skipped (codes don't fit int4)");
    }

    // 5. host-side quantize + pack (per linear).
    let mut rng = Rng::new(1);
    let wbig = faquant::tensor::Tensor::randn(&mut rng, &[512, 512], 1.0);
    let sv = vec![1.0f32; 512];
    let s = bench("host_quantize_pack(512x512,b3)", 1, 10, || {
        let (ints, _) = scaled_quantize_ints(&wbig, &sv, 3, 64).expect("q");
        let _ = packing::pack(&ints.q, 3).expect("pack");
    });
    println!("{}", report(&s));
    stages.push(s);

    // 6. KV-cached generation: continuous-batching decode engine over
    // decode_step_q, unprepared (per-step dequant) vs prepared
    // (dequantize-once packed panels, DESIGN §11) — logits are
    // bit-identical, only the wall moves. The prefill/decode
    // tokens-per-second split is the serving headline (mean_s of the
    // *_tokens_per_sec stages is seconds per token; the top-level report
    // carries the tok/s values).
    let prompt_len = cfg.model.seq / 4;
    let max_new = cfg.model.seq / 4;
    let n_seqs = cfg.model.batch * 2;
    let gen_ids = calib_ids(&cfg.model, &tok, n_seqs + 4, 99);
    let reqs: Vec<GenRequest> = (0..n_seqs)
        .map(|i| {
            let start = (i * prompt_len) % (gen_ids.len() - prompt_len);
            GenRequest {
                id: i,
                prompt: gen_ids[start..start + prompt_len].to_vec(),
                max_new,
                stop_id: None,
                ..Default::default()
            }
        })
        .collect();
    let mut engine = Engine::new(
        &rt,
        &cfg.model,
        &params,
        &qm,
        GenConfig {
            prepared: false,
            paged: false, // the dense seed store is the baseline
            ..GenConfig::default()
        },
    )
    .expect("engine");
    let s = bench(
        &format!("generate({n_seqs}seq,prefill{prompt_len},decode{max_new})"),
        0,
        1,
        || {
            engine.generate(reqs.clone()).expect("generate");
        },
    );
    println!("{}", report(&s));
    stages.push(s);
    let grep = engine.report();
    let (prefill_tps, decode_tps) = (grep.prefill_tps(), grep.decode_tps());
    println!(
        "  -> prefill {prefill_tps:.0} tok/s, decode {decode_tps:.0} tok/s \
         (occupancy {:.0}%, {} steps)",
        grep.mean_slot_occupancy * 100.0,
        grep.steps
    );
    let lat = grep.latency;
    println!("  -> {}", lat.summary_line());
    let us = |v: u64| v as f32 / 1e6;
    stages.push(PerfReport::per_token_stage(
        "prefill_tokens_per_sec",
        grep.prefill_tokens,
        grep.prefill_secs,
    ));
    stages.push(PerfReport::per_token_stage(
        "decode_tokens_per_sec",
        grep.decode_tokens,
        grep.decode_secs,
    ));

    // 6b. Same workload over the prepared weight bundle (still dense).
    let mut engine_p = Engine::new(
        &rt,
        &cfg.model,
        &params,
        &qm,
        GenConfig {
            paged: false,
            ..GenConfig::default()
        },
    )
    .expect("engine(prepared)");
    let s = bench(
        &format!("generate_prepared({n_seqs}seq,prefill{prompt_len},decode{max_new})"),
        0,
        1,
        || {
            engine_p.generate(reqs.clone()).expect("generate");
        },
    );
    println!("{}", report(&s));
    stages.push(s);
    let grep_p = engine_p.report();
    let decode_prepared_tps = grep_p.decode_tps();
    println!(
        "  -> prepared: prefill {:.0} tok/s, decode {decode_prepared_tps:.0} tok/s \
         ({:.2}x unprepared decode; prepare cost {prepare_secs:.4}s)",
        grep_p.prefill_tps(),
        decode_prepared_tps / decode_tps.max(1e-9)
    );
    stages.push(PerfReport::per_token_stage(
        "prefill_prepared_tokens_per_sec",
        grep_p.prefill_tokens,
        grep_p.prefill_secs,
    ));
    stages.push(PerfReport::per_token_stage(
        "decode_prepared_tokens_per_sec",
        grep_p.decode_tokens,
        grep_p.decode_secs,
    ));

    // 6c. Shared-prefix generation over the paged engine (block pool +
    // radix prefix cache, DESIGN §12): every request carries the same
    // long prompt prefix plus a short distinct tail — the shared-system-
    // prompt pattern. After the first sequences seed the cache, later
    // admissions skip the shared portion of prefill entirely; the
    // headline is the fraction of prompt tokens never fed.
    let shared_len = cfg.model.seq / 2;
    let tail = 4usize;
    let shared_reqs: Vec<GenRequest> = (0..n_seqs)
        .map(|i| {
            let mut p = gen_ids[..shared_len].to_vec();
            for k in 0..tail {
                p.push(gen_ids[(shared_len + i * tail + k) % gen_ids.len()]);
            }
            GenRequest {
                id: i,
                prompt: p,
                max_new,
                stop_id: None,
                ..Default::default()
            }
        })
        .collect();
    let total_prompt: usize = shared_reqs.iter().map(|r| r.prompt.len()).sum();
    let mut engine_px = Engine::new(
        &rt,
        &cfg.model,
        &params,
        &qm,
        GenConfig {
            slots: 2,
            block_tokens: 8,
            ..GenConfig::default()
        },
    )
    .expect("engine(paged)");
    let s = bench(
        &format!("generate_shared_prefix({n_seqs}seq,shared{shared_len},tail{tail})"),
        0,
        1,
        || {
            engine_px.generate(shared_reqs.clone()).expect("generate");
        },
    );
    println!("{}", report(&s));
    stages.push(s);
    let grep_px = engine_px.report();
    let prefix_hit_prefill_savings = grep_px.prefix_hit_tokens as f32 / total_prompt as f32;
    println!(
        "  -> prefix cache skipped {} of {total_prompt} prompt tokens \
         ({:.0}% of prefill), {} block refs evicted, peak {} / {} blocks",
        grep_px.prefix_hit_tokens,
        prefix_hit_prefill_savings * 100.0,
        grep_px.evicted_blocks,
        grep_px.peak_blocks_in_use,
        grep_px.pool_blocks
    );

    // 6d. Many short sequences through the paged pool (prefix cache off
    // isolates pure paging): peak in-use KV bytes vs the dense engine's
    // always-resident `slots x T_max` slab.
    let mut engine_mem = Engine::new(
        &rt,
        &cfg.model,
        &params,
        &qm,
        GenConfig {
            prefix_cache: false,
            ..GenConfig::default()
        },
    )
    .expect("engine(mem)");
    engine_mem.generate(reqs.clone()).expect("generate");
    let grep_m = engine_mem.report();
    // Bytes per cached token row: K + V, f32, all layers.
    let row_bytes = (cfg.model.n_layer * cfg.model.d_model * 2 * 4) as f32;
    let paged_peak_kv_bytes =
        (grep_m.peak_blocks_in_use * grep_m.block_tokens) as f32 * row_bytes;
    let dense_kv_slab_bytes = (cfg.model.batch * cfg.model.seq) as f32 * row_bytes;
    println!(
        "  -> paged peak KV {:.0} KiB vs dense slab {:.0} KiB ({:.2}x smaller, \
         {} short seqs)",
        paged_peak_kv_bytes / 1024.0,
        dense_kv_slab_bytes / 1024.0,
        dense_kv_slab_bytes / paged_peak_kv_bytes.max(1.0),
        n_seqs
    );

    // 6e. Sharded router: the baseline generation workload fanned out
    // over two crash-isolated engine workers (DESIGN §16). Wall time
    // includes dispatch/collect overhead; the latency percentiles are
    // the fleet-merged deterministic engine histograms from the router
    // report (the `serve bench` subcommand reports the same fields
    // under live closed-loop load).
    let router_workers = 2usize;
    let mut router_lat = faquant::obs::LatencyStats::default();
    let mut router_line = String::new();
    let s = bench(
        &format!("router_generate({n_seqs}seq,{router_workers}workers)"),
        0,
        1,
        || {
            let (_, rep) = run_router(
                &rt,
                &cfg.model,
                &params,
                &qm,
                GenConfig::default(),
                RouterConfig {
                    workers: router_workers,
                    ..RouterConfig::default()
                },
                |router| {
                    let mut n = 0usize;
                    for req in reqs.clone() {
                        if router.submit(req).is_some() {
                            n += 1;
                        }
                    }
                    while router.has_work() {
                        n += router.step()?.len();
                    }
                    Ok(n)
                },
            )
            .expect("router");
            router_lat = rep.latency;
            router_line = rep.summary_line();
        },
    );
    println!("{}", report(&s));
    println!("  -> {router_line}");
    stages.push(s);

    // 6f. Int decode (DESIGN §17): the baseline workload again, dense
    // prepared engine, but decoding through the fused int8xint4 kernel
    // on the stored codes — directly comparable to 6b. The weight-bytes
    // accounting is the bandwidth story: what one full block-linear
    // pass reads on each path (the head is shared and excluded).
    let mut decode_int_tps = 0.0f32;
    let mut int_kernel = String::new();
    let mut weight_bytes_f32 = 0.0f32;
    let mut weight_bytes_int = 0.0f32;
    if int_ready {
        let mut engine_i = Engine::new(
            &rt,
            &cfg.model,
            &params,
            &qm,
            GenConfig {
                paged: false,
                int_compute: true,
                ..GenConfig::default()
            },
        )
        .expect("engine(int)");
        let s = bench(
            &format!("generate_int({n_seqs}seq,prefill{prompt_len},decode{max_new})"),
            0,
            1,
            || {
                engine_i.generate(reqs.clone()).expect("generate");
            },
        );
        println!("{}", report(&s));
        stages.push(s);
        let grep_i = engine_i.report();
        decode_int_tps = grep_i.decode_tps();
        int_kernel = faquant::tensor::intkern::active_kernel().to_string();
        if let Some(Buffer::PreparedQ(pm)) = qbufs.first() {
            let (f, i) = pm.weight_bytes();
            weight_bytes_f32 = f as f32;
            weight_bytes_int = i as f32;
        }
        println!(
            "  -> int decode {decode_int_tps:.0} tok/s on the {int_kernel} kernel \
             ({:.2}x prepared f32 decode); weight read/pass {:.0} KiB int vs {:.0} KiB f32",
            decode_int_tps / decode_prepared_tps.max(1e-9),
            weight_bytes_int / 1024.0,
            weight_bytes_f32 / 1024.0
        );
        stages.push(PerfReport::per_token_stage(
            "decode_int_tokens_per_sec",
            grep_i.decode_tokens,
            grep_i.decode_secs,
        ));
    } else {
        println!("generate_int: skipped (codes don't fit int4)");
    }

    // Threading headline: end-to-end Phase-B quantize, 1 thread vs the
    // effective thread count (same runtime/calibration — results are
    // bit-identical by the determinism contract; only the wall moves).
    // While pinned to 1 thread, also measure the DESIGN §9 coordinator
    // overhead: single-threaded, the per-entry exec-seconds sum is
    // directly comparable to wall time.
    par::set_threads(1);
    let exec_before: f64 = rt.stats().values().map(|s| s.exec_secs).sum();
    let compile_before: f64 = rt.stats().values().map(|s| s.compile_secs).sum();
    let s1 = bench("quantize_e2e(1 thread)", 0, 3, || {
        pipe.quantize(&params, Some(&calib)).expect("quantize");
    });
    let inside =
        (rt.stats().values().map(|s| s.exec_secs).sum::<f64>() - exec_before) as f32;
    let compile =
        (rt.stats().values().map(|s| s.compile_secs).sum::<f64>() - compile_before) as f32;
    println!("{}", report(&s1));

    par::set_threads(0);
    let sn = bench(&format!("quantize_e2e({threads} threads)"), 0, 3, || {
        pipe.quantize(&params, Some(&calib)).expect("quantize");
    });
    println!("{}", report(&sn));

    let wall_1t = s1.mean * s1.iters as f32;
    let steady = (wall_1t - compile).max(1e-6);
    let overhead = (1.0 - inside / steady).max(0.0);
    let speedup = s1.mean / sn.mean.max(1e-9);
    println!(
        "quantize speedup {speedup:.2}x over 1 thread ({threads} threads, {cores} cores); \
         coordinator overhead {:.1}% (1-thread wall {wall_1t:.2}s, inside backend {inside:.2}s)",
        overhead * 100.0
    );

    let quantize_secs_1t = s1.mean;
    let quantize_secs_nt = sn.mean;
    stages.push(s1);
    stages.push(sn);

    let perf = PerfReport {
        preset,
        threads,
        cores,
        stages,
        quantize_secs_1t,
        quantize_secs_nt,
        speedup,
        coordinator_overhead: overhead,
        prefill_tps,
        decode_tps,
        prepare_secs,
        decode_prepared_tps,
        prefix_hit_prefill_savings,
        paged_peak_kv_bytes,
        dense_kv_slab_bytes,
        ttft_p50: us(lat.ttft_p50_us),
        ttft_p95: us(lat.ttft_p95_us),
        ttft_p99: us(lat.ttft_p99_us),
        per_token_p50: us(lat.per_token_p50_us),
        per_token_p95: us(lat.per_token_p95_us),
        per_token_p99: us(lat.per_token_p99_us),
        queue_wait_p95: us(lat.queue_wait_p95_us),
        router_workers,
        router_ttft_p50: us(router_lat.ttft_p50_us),
        router_ttft_p95: us(router_lat.ttft_p95_us),
        router_ttft_p99: us(router_lat.ttft_p99_us),
        router_per_token_p50: us(router_lat.per_token_p50_us),
        router_per_token_p95: us(router_lat.per_token_p95_us),
        router_per_token_p99: us(router_lat.per_token_p99_us),
        decode_int_tps,
        int_kernel,
        weight_bytes_f32,
        weight_bytes_int,
    };
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_perf.json");
    std::fs::write(&path, perf.to_json()).expect("write BENCH_perf.json");
    println!("wrote {}", path.display());
}
