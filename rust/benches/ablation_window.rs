//! Ablation: FAQ preview window length + layer-wise vs window-wise
//! preview (paper Sec. 2.2 defines both; §3.1 pre-searches window = 3).
//!
//! ```bash
//! cargo bench --offline --bench ablation_window
//! ```

mod common;

use faquant::eval::report::ablation_window;

fn main() {
    let rt = common::runtime();
    let cfg = common::base_cfg();
    let model = common::models("nano")[0].clone();
    let t0 = std::time::Instant::now();
    let table = ablation_window(&rt, &model, &cfg, &[1, 2, 3, 4]).expect("sweep");
    println!("{}", table.markdown());
    println!("window ablation in {:.1}s", t0.elapsed().as_secs_f32());
}
