//! Regenerates the paper's **Table 3**: calibration-set-size robustness.
//! AWQ vs FAQ at N in {16, 32, 64, 128} calibration sequences, each N
//! drawn with a different seed (disjoint biased samples); reports per-N
//! perplexity plus mean/std across N.
//!
//! Expected shape: FAQ's std across N is lower than AWQ's (the preview
//! window averages activation statistics over layers, damping sampling
//! bias), and FAQ's mean is <= AWQ's.
//!
//! ```bash
//! cargo bench --offline --bench table3_calib
//! ```

mod common;

use faquant::eval::report::table3;

fn main() {
    let rt = common::runtime();
    let cfg = common::base_cfg();
    let model = common::models("nano")[0].clone();
    let t0 = std::time::Instant::now();
    let table = table3(&rt, &model, &cfg, &[16, 32, 64, 128]).expect("table3");
    println!("{}", table.markdown());
    println!("table3 regenerated in {:.1}s", t0.elapsed().as_secs_f32());
}
