//! Regenerates the paper's **Table 1**: models x {FP, RTN, AWQ, FAQ} x
//! {wikitext2 ppl, c4 ppl, six zero-shot accuracies} at 3-bit.
//!
//! Expected reproduction *shape* (not absolute values — our substrate is
//! tiny trained LMs on synthetic corpora, DESIGN.md §4/5): FAQ <= AWQ <
//! RTN on perplexity for most cells, FP best everywhere.
//!
//! ```bash
//! cargo bench --offline --bench table1_main
//! FAQUANT_BENCH_MODELS=pico,nano,tiny,small cargo bench --offline --bench table1_main
//! ```

mod common;

use faquant::eval::report::table1;

fn main() {
    let rt = common::runtime();
    let cfg = common::base_cfg();
    let models = common::models("pico,nano,tiny");
    let refs: Vec<&str> = models.iter().map(String::as_str).collect();
    let t0 = std::time::Instant::now();
    let table = table1(&rt, &refs, &cfg).expect("table1");
    println!("{}", table.markdown());
    println!(
        "table1 regenerated in {:.1}s ({} models; exec time inside PJRT: {:.1}s)",
        t0.elapsed().as_secs_f32(),
        refs.len(),
        rt.total_exec_secs()
    );
}
