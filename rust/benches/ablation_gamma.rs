//! Ablation: FAQ fusion factor gamma (paper §3.1 fixes gamma = 0.85 via
//! pre-search — this bench regenerates that pre-search). gamma = 1.0
//! degenerates to AWQ; small gamma over-trusts the future layers.
//!
//! ```bash
//! cargo bench --offline --bench ablation_gamma
//! ```

mod common;

use faquant::eval::report::ablation_gamma;

fn main() {
    let rt = common::runtime();
    let cfg = common::base_cfg();
    let model = common::models("nano")[0].clone();
    let t0 = std::time::Instant::now();
    let table =
        ablation_gamma(&rt, &model, &cfg, &[0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95]).expect("sweep");
    println!("{}", table.markdown());
    println!("gamma ablation in {:.1}s", t0.elapsed().as_secs_f32());
}
