//! Regenerates the paper's **Table 2**: boolq accuracy at 3-bit vs 4-bit
//! for each method.
//!
//! Expected shape: FAQ's advantage over AWQ is larger at 3-bit and
//! shrinks (or disappears) at 4-bit — lower bit-widths amplify the error
//! accumulation FAQ's preview mitigates.
//!
//! ```bash
//! cargo bench --offline --bench table2_bits
//! ```

mod common;

use faquant::eval::report::table2;

fn main() {
    let rt = common::runtime();
    let cfg = common::base_cfg();
    let models = common::models("pico,nano");
    let refs: Vec<&str> = models.iter().map(String::as_str).collect();
    let t0 = std::time::Instant::now();
    let table = table2(&rt, &refs, &cfg).expect("table2");
    println!("{}", table.markdown());
    println!("table2 regenerated in {:.1}s", t0.elapsed().as_secs_f32());
}
