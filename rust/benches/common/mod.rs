//! Shared bench scaffolding: runtime bring-up + budget knobs.
//!
//! All paper-table benches run real pipelines; budgets are sized so the
//! full `cargo bench` sweep finishes on a single CPU core. Environment
//! overrides:
//!   FAQUANT_BENCH_MODELS   comma list (default per-bench)
//!   FAQUANT_BENCH_STEPS    training steps (default 300)
//!   FAQUANT_BENCH_EVAL     eval seqs per corpus (default 12)
//!   FAQUANT_BENCH_ITEMS    items per suite (default 24)

use faquant::config::RunConfig;
use faquant::runtime::Runtime;
use std::path::Path;

pub fn runtime() -> Runtime {
    // Native backend by default; PJRT + AOT artifacts under --features pjrt.
    Runtime::new(Path::new("artifacts")).expect("runtime bring-up")
}

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[allow(dead_code)]
pub fn models(default: &str) -> Vec<String> {
    std::env::var("FAQUANT_BENCH_MODELS")
        .unwrap_or_else(|_| default.to_string())
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

pub fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::new("pico").expect("preset");
    cfg.train_steps = env_usize("FAQUANT_BENCH_STEPS", 300);
    cfg.eval_seqs = env_usize("FAQUANT_BENCH_EVAL", 12);
    cfg.task_items = env_usize("FAQUANT_BENCH_ITEMS", 24);
    cfg
}
