//! Allocation probe for the prepared decode hot path (DESIGN.md §11).
//!
//! Asserts the two halves of the prepared-model contract:
//!
//! 1. a steady-state prepared quantized linear (the decode hot path's
//!    per-token weight work) performs **zero** heap allocations — the
//!    scaled activation and the matmul output cycle through the
//!    per-thread scratch arena;
//! 2. a whole steady-state `decode_step_q` allocates fewer bytes than
//!    the *smallest* dequantized weight matrix of the model — i.e. no
//!    weight dequantization and no weight-panel packing can be hiding
//!    anywhere in step time;
//! 3. emitting trace events on a *disabled* [`faquant::obs::Trace`]
//!    performs zero heap allocations — tracing off must be free on the
//!    decode hot path (DESIGN.md §15);
//! 4. a steady-state *integer* quantized linear (DESIGN.md §17: row
//!    int8 quantize + fused int8×int4 kernel + f32 fixup) performs
//!    **zero** heap allocations — the i8/i32 scratch is thread-local
//!    and resized in place, the f32 ends cycle through the arena.
//!
//! Requires the bench-only counting global allocator:
//!
//! ```bash
//! cargo bench --bench alloc_probe --features alloc-count
//! ```

#[cfg(not(feature = "alloc-count"))]
fn main() {
    println!(
        "alloc_probe: counting allocator disabled; run with \
         `cargo bench --bench alloc_probe --features alloc-count`"
    );
}

#[cfg(feature = "alloc-count")]
fn main() {
    use faquant::benchkit::alloc;
    use faquant::config::{Method, ModelConfig, QuantConfig};
    use faquant::model::Params;
    use faquant::quant::quantize_model;
    use faquant::runtime::{native, Buffer, Runtime, Value};
    use faquant::serve::qmodel_literals;
    use faquant::tensor::{par, Rng, Tensor, TensorI32};

    // The zero-allocation contract is about the serial hot path; pool
    // dispatch bookkeeping is out of scope (and tiny decode shapes never
    // cross the dispatch threshold anyway).
    par::set_threads(1);

    let rt = Runtime::native();
    let cfg = ModelConfig::preset("pico").expect("preset");
    let params = Params::init(&cfg, 7);
    let qcfg = QuantConfig::with_method(Method::Rtn);
    let qm = quantize_model(&rt, &qcfg, &params, None).expect("quantize");
    let lits = qmodel_literals(&params, &qm).expect("lits");
    let bufs = rt.prepare_qweights(&cfg.name, &lits).expect("prepare");
    let Buffer::PreparedQ(pm) = &bufs[0] else {
        panic!("native prepare_qweights must return a prepared bundle");
    };

    // --- 1. The quantized-linear path itself: exactly 0 allocations. ---
    let mut rng = Rng::new(3);
    let x = Tensor::randn(&mut rng, &[1, cfg.d_model], 1.0);
    for _ in 0..4 {
        native::prepared_qlin_probe(pm, 0, 0, &x).expect("probe warmup");
    }
    let (a0, b0) = alloc::snapshot();
    let numel = native::prepared_qlin_probe(pm, 0, 0, &x).expect("probe");
    let (a1, b1) = alloc::snapshot();
    println!(
        "prepared qlin (out numel {numel}): {} allocations, {} bytes",
        a1 - a0,
        b1 - b0
    );
    assert_eq!(
        a1 - a0,
        0,
        "steady-state prepared quantized linear must not allocate"
    );

    // --- 2. A whole steady-state decode step: no weight work. ---
    let (l, d, t_max) = (cfg.n_layer, cfg.d_model, cfg.seq);
    let k_buf = Buffer::Host(Value::F32(Tensor::zeros(&[l, 1, t_max, d])));
    let v_buf = Buffer::Host(Value::F32(Tensor::zeros(&[l, 1, t_max, d])));
    let pos_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[1], vec![0]).expect("pos")));
    let tok_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[1], vec![3]).expect("tok")));
    let args: Vec<&Buffer> = vec![&bufs[0], &k_buf, &v_buf, &pos_buf, &tok_buf];
    for _ in 0..5 {
        rt.exec_b(&cfg.name, "decode_step_q", &args).expect("step");
    }
    let (a0, b0) = alloc::snapshot();
    rt.exec_b(&cfg.name, "decode_step_q", &args).expect("step");
    let (a1, b1) = alloc::snapshot();
    // The smallest quantized linear is the o-projection, [d, d].
    let smallest_weight_bytes = d * d * std::mem::size_of::<f32>();
    println!(
        "steady-state decode_step_q: {} allocations, {} bytes \
         (smallest dequantized weight = {} bytes)",
        a1 - a0,
        b1 - b0,
        smallest_weight_bytes
    );
    assert!(
        b1 - b0 < smallest_weight_bytes,
        "a steady-state decode step allocated {} bytes, >= the smallest dequantized \
         weight matrix ({} bytes): weight dequant/packing leaked into step time",
        b1 - b0,
        smallest_weight_bytes
    );

    // --- 3. Disabled tracing: emit() is a no-op with 0 allocations. ---
    use faquant::obs::{Trace, TraceEvent};
    let trace = Trace::disabled();
    let (a0, b0) = alloc::snapshot();
    for tick in 0..1024u64 {
        trace.emit(tick, TraceEvent::Step { batch: 4, prefill: 1, decode: 3 });
        trace.emit(tick, TraceEvent::BlockAlloc { block: tick as usize });
    }
    let (a1, b1) = alloc::snapshot();
    println!(
        "disabled-trace emit x2048: {} allocations, {} bytes",
        a1 - a0,
        b1 - b0
    );
    assert_eq!(
        (a1 - a0, b1 - b0),
        (0, 0),
        "emitting on a disabled Trace must not allocate"
    );

    // --- 4. The int linear path: exactly 0 allocations once warm. ---
    // The first calls may grow the thread-local i8/i32 scratch; steady
    // state must not touch the allocator at all.
    if let Some(reason) = pm.int_reason() {
        panic!("pico RTN codes must fit int4: {reason}");
    }
    for _ in 0..4 {
        native::prepared_int_qlin_probe(pm, 0, 0, &x).expect("int probe warmup");
    }
    let (a0, b0) = alloc::snapshot();
    let numel = native::prepared_int_qlin_probe(pm, 0, 0, &x).expect("int probe");
    let (a1, b1) = alloc::snapshot();
    println!(
        "prepared int qlin (out numel {numel}): {} allocations, {} bytes",
        a1 - a0,
        b1 - b0
    );
    assert_eq!(
        a1 - a0,
        0,
        "steady-state int quantized linear (activation quantize + int8xint4 \
         kernel + fixup) must not allocate"
    );

    par::set_threads(0);
    println!("alloc_probe: OK");
}
