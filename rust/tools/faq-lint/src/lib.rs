//! faq-lint: repo-specific determinism & soundness static analysis.
//!
//! The repo's headline contract (DESIGN.md §13) is that quantized
//! forward, decode, and paged decode are **bitwise identical** across
//! thread counts and KV-store layouts. The compiler cannot see that
//! contract; this tool enforces the source-level invariants behind it:
//!
//! - `hash-iteration` (D1): no `HashMap`/`HashSet` *iteration* in
//!   determinism-critical modules (`tensor/`, `quant/`, `runtime/`,
//!   `engine/`, `serve/`). Keyed lookup is fine; iteration order
//!   leaking into results, reports, or error messages is not.
//! - `unordered-reduction` (D2): no float reduction via `.sum()` or a
//!   `.fold(float-acc, ..)` in kernel modules (`tensor/`, `quant/`,
//!   `runtime/native/`) outside functions allow-marked
//!   `// faq-lint: allow(unordered-reduction)`. Folds seeded with
//!   `f32::INFINITY`/`NEG_INFINITY`/`MIN`/`MAX` are per-element
//!   min/max comparisons, not accumulations, and are exempt.
//! - `int-accum-order` (D2b): widening integer accumulation in kernel
//!   modules — `+= .. as i32/i64` statements and integer-SIMD
//!   accumulate intrinsics (`_mm*_add_epi*`, `vmla*`, `vaddq_s*`) —
//!   must carry a `// faq-lint: accum(ascending-k)` marker. The i32
//!   sums are exact, so their *value* is order-independent; the marker
//!   pins the traversal-order convention that licenses the scalar and
//!   SIMD int kernels (`tensor/intkern.rs`, DESIGN.md §17) to claim
//!   bit-identity with each other. A stale marker is flagged like a
//!   stale allow.
//! - `panic-in-serve` (D3): no `unwrap()`/`expect()`/panic-family
//!   macros/direct indexing on the request-serving path (`serve/`,
//!   `engine/scheduler.rs`, `engine/lifecycle.rs`) — structured
//!   errors only.
//! - `missing-safety` (S1): every `unsafe` block or `unsafe impl`
//!   must carry a `// SAFETY:` comment (same line or contiguous
//!   comment lines immediately above).
//! - `time-or-env` (S2): no `Instant`/`SystemTime`/`env::` reads in
//!   kernel modules — wall-clock and environment reads belong to the
//!   coordinator layer.
//! - `untracked-clock` (CLK): in `engine/` and `serve/`, clock
//!   *acquisition* (`Instant::now()`, any `SystemTime`) must go through
//!   the `EngineClock`/obs seam (DESIGN.md §14/§15); the audited
//!   exceptions carry `// faq-lint: allow(untracked-clock)`. Storing
//!   or diffing an `Instant` handed in through the seam is fine.
//! - `unused-allow`: an allow-marker that suppresses nothing is
//!   itself an error, so markers cannot rot in place.
//!
//! The analysis is a hand-rolled lexer plus token-pattern rules — no
//! syn/proc-macro dependencies, matching the repo's zero-dependency
//! rule. `#[cfg(test)]` items are skipped: the contract binds shipped
//! code, and tests intentionally use `unwrap()` and ad-hoc sums.
//!
//! Known limit: hash-typedness is tracked per file from declarations
//! (`name: ..HashMap..`, `let name = HashMap::new()`), so a hash map
//! returned by a function in *another* file is invisible to D1. The
//! self-check test (`faq-lint` clean on the real tree) plus review
//! keep that gap from widening.

use std::collections::BTreeSet;
use std::fmt;
use std::path::Path;

/// The rules, in severity/report order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    HashIteration,
    UnorderedReduction,
    IntAccumOrder,
    PanicInServe,
    MissingSafety,
    TimeOrEnv,
    UntrackedClock,
    UnusedAllow,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::HashIteration => "hash-iteration",
            Rule::UnorderedReduction => "unordered-reduction",
            Rule::IntAccumOrder => "int-accum-order",
            Rule::PanicInServe => "panic-in-serve",
            Rule::MissingSafety => "missing-safety",
            Rule::TimeOrEnv => "time-or-env",
            Rule::UntrackedClock => "untracked-clock",
            Rule::UnusedAllow => "unused-allow",
        }
    }

    fn from_name(s: &str) -> Option<Rule> {
        match s {
            "hash-iteration" => Some(Rule::HashIteration),
            "unordered-reduction" => Some(Rule::UnorderedReduction),
            "int-accum-order" => Some(Rule::IntAccumOrder),
            "panic-in-serve" => Some(Rule::PanicInServe),
            "missing-safety" => Some(Rule::MissingSafety),
            "time-or-env" => Some(Rule::TimeOrEnv),
            "untracked-clock" => Some(Rule::UntrackedClock),
            _ => None,
        }
    }
}

/// One finding: `path:line: rule — message`.
#[derive(Clone, Debug)]
pub struct Finding {
    pub path: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {} — {}",
            self.path,
            self.line,
            self.rule.name(),
            self.message
        )
    }
}

// ---------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Ident(String),
    Punct(char),
    Num(String),
    Str,
    Life,
}

#[derive(Clone, Debug)]
struct Token {
    kind: Tok,
    line: usize,
}

struct Lexed {
    tokens: Vec<Token>,
    /// Per 1-indexed line: all comment text on that line (line comments
    /// and any block comment overlapping it), or empty.
    comments: Vec<String>,
    /// Per 1-indexed line: does any token (code) sit on it?
    has_code: Vec<bool>,
    nlines: usize,
}

fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let nlines = src.split('\n').count();
    let mut comments = vec![String::new(); nlines + 2];
    let mut has_code = vec![false; nlines + 2];
    let mut tokens: Vec<Token> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            let text: String = cs[start..i].iter().collect();
            comments[line].push_str(&text);
            comments[line].push(' ');
            continue;
        }
        // block comment (nested)
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            let text: String = cs[start..i.min(n)].iter().collect();
            for l in start_line..=line.min(nlines) {
                comments[l].push_str(&text);
                comments[l].push(' ');
            }
            continue;
        }
        // raw / byte strings, or identifiers starting with r/b
        if c.is_alphabetic() || c == '_' {
            if let Some((ni, nl)) = try_raw_or_byte_string(&cs, i, line) {
                tokens.push(Token {
                    kind: Tok::Str,
                    line,
                });
                has_code[line] = true;
                i = ni;
                line = nl;
                continue;
            }
            let start = i;
            while i < n && (cs[i].is_alphanumeric() || cs[i] == '_') {
                i += 1;
            }
            let word: String = cs[start..i].iter().collect();
            tokens.push(Token {
                kind: Tok::Ident(word),
                line,
            });
            has_code[line] = true;
            continue;
        }
        // string literal
        if c == '"' {
            let (ni, nl) = scan_string(&cs, i, line);
            tokens.push(Token {
                kind: Tok::Str,
                line,
            });
            has_code[line] = true;
            i = ni;
            line = nl;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && cs[i + 1] == '\\' {
                // escaped char literal: skip \x, then closing quote
                let mut j = i + 2;
                while j < n && cs[j] != '\'' {
                    if cs[j] == '\\' {
                        j += 1;
                    }
                    j += 1;
                }
                tokens.push(Token {
                    kind: Tok::Str,
                    line,
                });
                has_code[line] = true;
                i = (j + 1).min(n);
                continue;
            }
            let is_life = i + 1 < n
                && (cs[i + 1].is_alphabetic() || cs[i + 1] == '_')
                && !(i + 2 < n && cs[i + 2] == '\'');
            if is_life {
                let mut j = i + 1;
                while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                tokens.push(Token {
                    kind: Tok::Life,
                    line,
                });
                has_code[line] = true;
                i = j;
                continue;
            }
            // plain char literal 'x'
            let mut j = i + 1;
            while j < n && cs[j] != '\'' && cs[j] != '\n' {
                j += 1;
            }
            tokens.push(Token {
                kind: Tok::Str,
                line,
            });
            has_code[line] = true;
            i = (j + 1).min(n);
            continue;
        }
        // number literal
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                let d = cs[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                } else if d == '.' {
                    // consume the dot for `1.0` and trailing `1.`, but
                    // not for ranges (`0..n`) or method calls (`1.max(..)`)
                    let next = cs.get(i + 1).copied().unwrap_or(' ');
                    if next.is_ascii_digit() {
                        i += 2;
                    } else if next != '.' && !(next.is_alphabetic() || next == '_') {
                        i += 1;
                        break;
                    } else {
                        break;
                    }
                } else if (d == '+' || d == '-')
                    && matches!(cs.get(i - 1), Some('e') | Some('E'))
                    && !cs[start..i].iter().collect::<String>().starts_with("0x")
                {
                    i += 1;
                } else {
                    break;
                }
            }
            let text: String = cs[start..i].iter().collect();
            tokens.push(Token {
                kind: Tok::Num(text),
                line,
            });
            has_code[line] = true;
            continue;
        }
        // punctuation, one char at a time
        tokens.push(Token {
            kind: Tok::Punct(c),
            line,
        });
        has_code[line] = true;
        i += 1;
    }

    Lexed {
        tokens,
        comments,
        has_code,
        nlines,
    }
}

/// If `cs[i..]` begins a raw string (`r"`, `r#"`), byte string (`b"`),
/// raw byte string (`br"`), or byte char (`b'`), scan it and return the
/// (next index, next line). Otherwise None (it is a plain identifier).
fn try_raw_or_byte_string(cs: &[char], i: usize, line: usize) -> Option<(usize, usize)> {
    let n = cs.len();
    let c = cs[i];
    if c != 'r' && c != 'b' {
        return None;
    }
    let mut j = i + 1;
    if c == 'b' && j < n && cs[j] == 'r' {
        j += 1;
    }
    let mut hashes = 0usize;
    while j < n && cs[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < n && cs[j] == '"' && (hashes > 0 || c != 'b' || cs[i + 1] == '"' || cs[i + 1] == 'r') {
        // raw string if any hashes or an r prefix; plain b"..." also lands here
        let raw = hashes > 0 || c == 'r' || (c == 'b' && i + 1 < n && cs[i + 1] == 'r');
        if raw {
            let mut k = j + 1;
            let mut l = line;
            while k < n {
                if cs[k] == '\n' {
                    l += 1;
                } else if cs[k] == '"' {
                    let mut m = 0usize;
                    while m < hashes && k + 1 + m < n && cs[k + 1 + m] == '#' {
                        m += 1;
                    }
                    if m == hashes {
                        return Some((k + 1 + hashes, l));
                    }
                }
                k += 1;
            }
            return Some((n, l));
        }
        // b"..." — ordinary escaped string
        let (ni, nl) = scan_string(cs, j, line);
        return Some((ni, nl));
    }
    if c == 'b' && hashes == 0 && i + 1 < n && cs[i + 1] == '\'' {
        // byte char literal b'x' / b'\n'
        let mut k = i + 2;
        while k < n && cs[k] != '\'' {
            if cs[k] == '\\' {
                k += 1;
            }
            k += 1;
        }
        return Some(((k + 1).min(n), line));
    }
    None
}

/// Scan a normal `"..."` string starting at the opening quote.
fn scan_string(cs: &[char], i: usize, line: usize) -> (usize, usize) {
    let n = cs.len();
    let mut j = i + 1;
    let mut l = line;
    while j < n {
        match cs[j] {
            '\\' => j += 2,
            '\n' => {
                l += 1;
                j += 1;
            }
            '"' => return (j + 1, l),
            _ => j += 1,
        }
    }
    (n, l)
}

// ---------------------------------------------------------------------
// Token helpers
// ---------------------------------------------------------------------

fn is_p(t: &[Token], i: usize, c: char) -> bool {
    matches!(t.get(i), Some(Token { kind: Tok::Punct(p), .. }) if *p == c)
}

fn is_id(t: &[Token], i: usize, s: &str) -> bool {
    matches!(t.get(i), Some(Token { kind: Tok::Ident(w), .. }) if w == s)
}

fn ident(t: &[Token], i: usize) -> Option<&str> {
    match t.get(i) {
        Some(Token {
            kind: Tok::Ident(w),
            ..
        }) => Some(w.as_str()),
        _ => None,
    }
}

/// Index of the token matching `open` at `open_idx` (which must hold
/// `open`), scanning forward.
fn match_forward(t: &[Token], open_idx: usize, open: char, close: char) -> Option<usize> {
    let mut depth = 0usize;
    for (k, tok) in t.iter().enumerate().skip(open_idx) {
        if let Tok::Punct(p) = tok.kind {
            if p == open {
                depth += 1;
            } else if p == close {
                if depth <= 1 {
                    return if depth == 1 { Some(k) } else { None };
                }
                depth -= 1;
            }
        }
    }
    None
}

/// Index of the token matching `close` at `close_idx`, scanning backward.
fn match_backward(t: &[Token], close_idx: usize, open: char, close: char) -> Option<usize> {
    let mut depth = 0usize;
    let mut k = close_idx;
    loop {
        if let Tok::Punct(p) = t[k].kind {
            if p == close {
                depth += 1;
            } else if p == open {
                if depth <= 1 {
                    return if depth == 1 { Some(k) } else { None };
                }
                depth -= 1;
            }
        }
        if k == 0 {
            return None;
        }
        k -= 1;
    }
}

const KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "false", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move",
    "mut", "pub", "ref", "return", "static", "struct", "super", "trait", "true", "type", "unsafe",
    "use", "where", "while",
];

// ---------------------------------------------------------------------
// #[cfg(test)] regions
// ---------------------------------------------------------------------

/// Per-line mask of `#[cfg(test)]`-gated items (mod/fn/impl bodies).
fn test_line_mask(lx: &Lexed) -> Vec<bool> {
    let t = &lx.tokens;
    let mut mask = vec![false; lx.nlines + 2];
    let mut i = 0usize;
    while i < t.len() {
        let hit = is_p(t, i, '#')
            && is_p(t, i + 1, '[')
            && is_id(t, i + 2, "cfg")
            && is_p(t, i + 3, '(')
            && is_id(t, i + 4, "test")
            && is_p(t, i + 5, ')')
            && is_p(t, i + 6, ']');
        if !hit {
            i += 1;
            continue;
        }
        // skip any further attributes, then find the item's body
        let mut j = i + 7;
        while is_p(t, j, '#') && is_p(t, j + 1, '[') {
            match match_forward(t, j + 1, '[', ']') {
                Some(k) => j = k + 1,
                None => break,
            }
        }
        let mut k = j;
        while k < t.len() && !is_p(t, k, '{') && !is_p(t, k, ';') {
            k += 1;
        }
        if is_p(t, k, '{') {
            if let Some(end) = match_forward(t, k, '{', '}') {
                for l in t[i].line..=t[end].line.min(lx.nlines) {
                    mask[l] = true;
                }
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    mask
}

// ---------------------------------------------------------------------
// Allow-markers
// ---------------------------------------------------------------------

#[derive(Debug)]
struct Marker {
    line: usize,
    rule: Rule,
    start: usize,
    end: usize,
    used: bool,
    /// An `accum(ascending-k)` ordering marker rather than an
    /// `allow(..)` suppression — same span rules, different stale
    /// message.
    accum: bool,
}

fn collect_markers(lx: &Lexed, tmask: &[bool]) -> Vec<Marker> {
    let mut out = Vec::new();
    for line in 1..=lx.nlines {
        if tmask[line] {
            continue;
        }
        let text = &lx.comments[line];
        let mut rest = text.as_str();
        while let Some(p) = rest.find("faq-lint: allow(") {
            let after = &rest[p + "faq-lint: allow(".len()..];
            if let Some(close) = after.find(')') {
                if let Some(rule) = Rule::from_name(&after[..close]) {
                    let (start, end) = marker_range(lx, line);
                    out.push(Marker {
                        line,
                        rule,
                        start,
                        end,
                        used: false,
                        accum: false,
                    });
                }
                rest = &after[close + 1..];
            } else {
                break;
            }
        }
        let mut rest = text.as_str();
        while let Some(p) = rest.find("faq-lint: accum(ascending-k)") {
            let (start, end) = marker_range(lx, line);
            out.push(Marker {
                line,
                rule: Rule::IntAccumOrder,
                start,
                end,
                used: false,
                accum: true,
            });
            rest = &rest[p + "faq-lint: accum(ascending-k)".len()..];
        }
    }
    out
}

/// The line span an allow-marker covers: its own line when trailing
/// code; otherwise the following item — the whole function body when
/// the next code begins a `fn`, else just the next code line.
fn marker_range(lx: &Lexed, line: usize) -> (usize, usize) {
    if lx.has_code[line] {
        return (line, line);
    }
    let t = &lx.tokens;
    let mut i = 0usize;
    while i < t.len() && t[i].line <= line {
        i += 1;
    }
    if i >= t.len() {
        return (line, line);
    }
    // skip attributes on the following item
    while is_p(t, i, '#') && is_p(t, i + 1, '[') {
        match match_forward(t, i + 1, '[', ']') {
            Some(k) => i = k + 1,
            None => return (line, t[i].line),
        }
    }
    let first_code_line = t[i].line;
    // fn with optional modifiers: pub(..) const unsafe async extern "C"
    let mut j = i;
    loop {
        match ident(t, j) {
            Some("pub") => {
                j += 1;
                if is_p(t, j, '(') {
                    match match_forward(t, j, '(', ')') {
                        Some(k) => j = k + 1,
                        None => break,
                    }
                }
            }
            Some("const") | Some("unsafe") | Some("async") => j += 1,
            Some("extern") => {
                j += 1;
                if matches!(t.get(j), Some(Token { kind: Tok::Str, .. })) {
                    j += 1;
                }
            }
            _ => break,
        }
    }
    if is_id(t, j, "fn") {
        let mut k = j;
        while k < t.len() && !is_p(t, k, '{') && !is_p(t, k, ';') {
            k += 1;
        }
        if is_p(t, k, '{') {
            if let Some(end) = match_forward(t, k, '{', '}') {
                return (line, t[end].line);
            }
        }
    }
    (line, first_code_line)
}

// ---------------------------------------------------------------------
// Scopes
// ---------------------------------------------------------------------

struct Scope {
    d1: bool,
    d2: bool,
    d3: bool,
    s2: bool,
    /// untracked-clock: engine/serve code must take time through the
    /// `EngineClock` / `obs` seam, never read it ad hoc.
    clk: bool,
}

fn scope_of(rel: &str) -> Scope {
    let kernel = rel.starts_with("tensor/")
        || rel.starts_with("quant/")
        || rel.starts_with("runtime/native/");
    Scope {
        d1: rel.starts_with("tensor/")
            || rel.starts_with("quant/")
            || rel.starts_with("runtime/")
            || rel.starts_with("engine/")
            || rel.starts_with("serve/"),
        d2: kernel,
        d3: rel.starts_with("serve/")
            || rel == "engine/scheduler.rs"
            || rel == "engine/lifecycle.rs",
        s2: kernel,
        clk: rel.starts_with("engine/") || rel.starts_with("serve/"),
    }
}

// ---------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------

const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "into_keys",
    "values",
    "values_mut",
    "into_values",
    "drain",
    "retain",
];

/// Names declared with a HashMap/HashSet type in this file: typed
/// bindings/fields/params (`name: ..HashMap..`) and direct constructor
/// bindings (`let name = HashMap::new()`).
fn hash_typed_names(t: &[Token]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 0..t.len() {
        if !matches!(ident(t, i), Some("HashMap") | Some("HashSet")) {
            continue;
        }
        let mut j = i;
        let mut steps = 0usize;
        while j > 0 && steps < 64 {
            j -= 1;
            steps += 1;
            match &t[j].kind {
                Tok::Ident(w) => {
                    if KEYWORDS.contains(&w.as_str()) {
                        break;
                    }
                }
                Tok::Life => {}
                Tok::Punct('<') | Tok::Punct('>') | Tok::Punct(',') | Tok::Punct('&')
                | Tok::Punct('(') => {}
                Tok::Punct(':') => {
                    if j > 0 && is_p(t, j - 1, ':') {
                        j -= 1; // path `::`, keep walking
                        continue;
                    }
                    if j > 0 {
                        if let Some(name) = ident(t, j - 1) {
                            if !KEYWORDS.contains(&name) {
                                names.insert(name.to_string());
                            }
                        }
                    }
                    break;
                }
                Tok::Punct('=') => {
                    if j > 1 {
                        if let Some(name) = ident(t, j - 1) {
                            if is_id(t, j - 2, "let") || is_id(t, j - 2, "mut") {
                                names.insert(name.to_string());
                            }
                        }
                    }
                    break;
                }
                _ => break,
            }
        }
    }
    names
}

/// Walk a postfix chain backward from the `.` at `dot_idx`; return the
/// first hash-typed name found in the chain, if any.
fn chain_hash_base(t: &[Token], dot_idx: usize, names: &BTreeSet<String>) -> Option<String> {
    let mut hit: Option<String> = None;
    let mut j = dot_idx; // t[j] is '.'
    let mut steps = 0usize;
    while j > 0 && steps < 256 {
        steps += 1;
        let mut k = j - 1;
        // skip trailing (), [], ? of the previous chain element
        loop {
            if is_p(t, k, ')') {
                match match_backward(t, k, '(', ')') {
                    Some(o) if o > 0 => k = o - 1,
                    _ => return hit,
                }
            } else if is_p(t, k, ']') {
                match match_backward(t, k, '[', ']') {
                    Some(o) if o > 0 => k = o - 1,
                    _ => return hit,
                }
            } else if is_p(t, k, '?') {
                if k == 0 {
                    return hit;
                }
                k -= 1;
            } else {
                break;
            }
        }
        match &t[k].kind {
            Tok::Ident(s) => {
                if names.contains(s) {
                    hit = Some(s.clone());
                }
                if k == 0 {
                    break;
                }
                if is_p(t, k - 1, '.') {
                    j = k - 1;
                    continue;
                }
                break;
            }
            _ => break,
        }
    }
    hit
}

fn rule_hash_iteration(t: &[Token], tmask: &[bool], out: &mut Vec<Finding>) {
    let names = hash_typed_names(t);
    if names.is_empty() {
        return;
    }
    for i in 0..t.len() {
        let line = t[i].line;
        if tmask[line] {
            continue;
        }
        if let Some(m) = ident(t, i) {
            if ITER_METHODS.contains(&m)
                && i > 0
                && is_p(t, i - 1, '.')
                && is_p(t, i + 1, '(')
            {
                if let Some(base) = chain_hash_base(t, i - 1, &names) {
                    out.push(Finding {
                        path: String::new(),
                        line,
                        rule: Rule::HashIteration,
                        message: format!(
                            "iteration over hash-ordered `{base}` via `.{m}()` — \
                             order is nondeterministic; use BTreeMap or sort first"
                        ),
                    });
                }
            }
        }
        if is_id(t, i, "for") {
            // find `in` at bracket depth 0, then scan its expression
            let mut depth = 0i32;
            let mut j = i + 1;
            let mut in_idx = None;
            while j < t.len() && j < i + 80 {
                match &t[j].kind {
                    Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                    Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                    Tok::Punct('{') if depth == 0 => break,
                    Tok::Ident(w) if w == "in" && depth == 0 => {
                        in_idx = Some(j);
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            if let Some(start) = in_idx {
                let mut depth = 0i32;
                let mut k = start + 1;
                while k < t.len() && k < start + 80 {
                    match &t[k].kind {
                        Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                        Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                        Tok::Punct('{') if depth == 0 => break,
                        Tok::Ident(w) if names.contains(w) => {
                            out.push(Finding {
                                path: String::new(),
                                line,
                                rule: Rule::HashIteration,
                                message: format!(
                                    "`for .. in` over hash-ordered `{w}` — order is \
                                     nondeterministic; use BTreeMap or sort first"
                                ),
                            });
                            break;
                        }
                        _ => {}
                    }
                    k += 1;
                }
            }
        }
    }
}

fn is_float_literal(s: &str) -> bool {
    if s.starts_with("0x") || s.starts_with("0b") || s.starts_with("0o") {
        return false;
    }
    s.contains('.') || s.ends_with("f32") || s.ends_with("f64") || s.contains('e') || s.contains('E')
}

/// True when the first argument of `.fold(` (open paren at `open_idx`)
/// is a float accumulator seed. Folds seeded with f32/f64 INFINITY /
/// NEG_INFINITY / MIN / MAX are min/max scans, not accumulations.
fn fold_seeds_float_acc(t: &[Token], open_idx: usize) -> bool {
    let mut depth = 0usize;
    let mut j = open_idx + 1;
    let mut saw_float = false;
    while j < t.len() {
        match &t[j].kind {
            Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
            Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => {
                if depth == 0 {
                    break;
                }
                depth -= 1;
            }
            Tok::Punct(',') if depth == 0 => break,
            Tok::Ident(w) if w == "f32" || w == "f64" => {
                if is_p(t, j + 1, ':') && is_p(t, j + 2, ':') {
                    if let Some(c) = ident(t, j + 3) {
                        if matches!(c, "INFINITY" | "NEG_INFINITY" | "MIN" | "MAX") {
                            return false;
                        }
                    }
                }
                saw_float = true;
            }
            Tok::Num(s) if is_float_literal(s) => saw_float = true,
            _ => {}
        }
        j += 1;
    }
    saw_float
}

fn rule_unordered_reduction(t: &[Token], tmask: &[bool], out: &mut Vec<Finding>) {
    for i in 0..t.len() {
        let line = t[i].line;
        if tmask[line] || i == 0 || !is_p(t, i - 1, '.') {
            continue;
        }
        if is_id(t, i, "sum") && (is_p(t, i + 1, '(') || is_p(t, i + 1, ':')) {
            out.push(Finding {
                path: String::new(),
                line,
                rule: Rule::UnorderedReduction,
                message: "`.sum()` reduction in a kernel module — accumulation order \
                          must be pinned; allow-mark the fn if in-order by construction"
                    .to_string(),
            });
        }
        if is_id(t, i, "fold") && is_p(t, i + 1, '(') && fold_seeds_float_acc(t, i + 1) {
            out.push(Finding {
                path: String::new(),
                line,
                rule: Rule::UnorderedReduction,
                message: "`.fold()` over a float accumulator in a kernel module — \
                          accumulation order must be pinned; allow-mark the fn if \
                          in-order by construction"
                    .to_string(),
            });
        }
    }
}

/// int-accum-order (D2b): widening integer accumulation sites in
/// kernel modules must carry a `// faq-lint: accum(ascending-k)`
/// marker. Two idioms are recognized: a `+=` statement whose
/// right-hand side widens with `as i32`/`as i64`, and integer-SIMD
/// accumulate intrinsics (`_mm*_add_epi*`, `vmla*`, `vaddq_s*`). The
/// exact i32 sums are order-independent in *value*; the marker keeps
/// the ascending-k traversal convention auditable, which is what lets
/// the scalar and SIMD int kernels claim bit-identity.
fn rule_int_accum_order(t: &[Token], tmask: &[bool], out: &mut Vec<Finding>) {
    for i in 0..t.len() {
        let line = t[i].line;
        if tmask[line] {
            continue;
        }
        if let Some(w) = ident(t, i) {
            let simd_acc = (w.starts_with("_mm") && w.contains("add_epi"))
                || w.starts_with("vmla")
                || w.starts_with("vaddq_s");
            if simd_acc {
                out.push(Finding {
                    path: String::new(),
                    line,
                    rule: Rule::IntAccumOrder,
                    message: format!(
                        "integer-SIMD accumulate `{w}` without an \
                         `accum(ascending-k)` marker — pin the traversal-order \
                         convention on the enclosing fn"
                    ),
                });
                continue;
            }
        }
        if !(is_p(t, i, '+') && is_p(t, i + 1, '=')) {
            continue;
        }
        // Scan the right-hand side (to the `;` ending the statement) for
        // a widening integer cast.
        let mut depth = 0i32;
        let mut j = i + 2;
        while j < t.len() {
            match &t[j].kind {
                Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => {
                    if depth == 0 {
                        break;
                    }
                    depth -= 1;
                }
                Tok::Punct(';') if depth == 0 => break,
                Tok::Ident(w) if w == "as" => {
                    if matches!(ident(t, j + 1), Some("i32") | Some("i64")) {
                        out.push(Finding {
                            path: String::new(),
                            line,
                            rule: Rule::IntAccumOrder,
                            message: "widening integer `+=` accumulation without an \
                                      `accum(ascending-k)` marker — pin the traversal \
                                      order the loop runs in"
                                .to_string(),
                        });
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
    }
}

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

fn rule_panic_in_serve(t: &[Token], tmask: &[bool], out: &mut Vec<Finding>) {
    for i in 0..t.len() {
        let line = t[i].line;
        if tmask[line] {
            continue;
        }
        match ident(t, i) {
            Some(m @ ("unwrap" | "expect"))
                if i > 0 && is_p(t, i - 1, '.') && is_p(t, i + 1, '(') =>
            {
                out.push(Finding {
                    path: String::new(),
                    line,
                    rule: Rule::PanicInServe,
                    message: format!(
                        "`.{m}()` on the request-serving path — return a structured \
                         error instead"
                    ),
                });
            }
            Some(m) if PANIC_MACROS.contains(&m) && is_p(t, i + 1, '!') => {
                out.push(Finding {
                    path: String::new(),
                    line,
                    rule: Rule::PanicInServe,
                    message: format!(
                        "`{m}!` on the request-serving path — return a structured \
                         error instead"
                    ),
                });
            }
            _ => {}
        }
        if is_p(t, i, '[') && i > 0 {
            let indexing = match &t[i - 1].kind {
                Tok::Ident(w) => !KEYWORDS.contains(&w.as_str()),
                Tok::Punct(')') | Tok::Punct(']') => true,
                _ => false,
            };
            if indexing {
                out.push(Finding {
                    path: String::new(),
                    line,
                    rule: Rule::PanicInServe,
                    message: "direct index (`x[..]`) may panic on the serving path — \
                              use `.get(..)` and handle the miss"
                        .to_string(),
                });
            }
        }
    }
}

fn has_safety_comment(lx: &Lexed, line: usize) -> bool {
    if lx.comments[line].contains("SAFETY:") {
        return true;
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        let commented = !lx.comments[l].is_empty() && !lx.has_code[l];
        if !commented {
            return false;
        }
        if lx.comments[l].contains("SAFETY:") {
            return true;
        }
    }
    false
}

fn rule_missing_safety(lx: &Lexed, tmask: &[bool], out: &mut Vec<Finding>) {
    let t = &lx.tokens;
    for i in 0..t.len() {
        if !is_id(t, i, "unsafe") {
            continue;
        }
        let line = t[i].line;
        if tmask[line] {
            continue;
        }
        let kind = if is_p(t, i + 1, '{') {
            "block"
        } else if is_id(t, i + 1, "impl") {
            "impl"
        } else {
            continue; // `unsafe fn` declarations document at the call site
        };
        if !has_safety_comment(lx, line) {
            out.push(Finding {
                path: String::new(),
                line,
                rule: Rule::MissingSafety,
                message: format!("`unsafe {kind}` without a `// SAFETY:` comment"),
            });
        }
    }
}

fn rule_time_or_env(t: &[Token], tmask: &[bool], out: &mut Vec<Finding>) {
    for i in 0..t.len() {
        let line = t[i].line;
        if tmask[line] {
            continue;
        }
        match ident(t, i) {
            Some(w @ ("Instant" | "SystemTime")) => {
                out.push(Finding {
                    path: String::new(),
                    line,
                    rule: Rule::TimeOrEnv,
                    message: format!(
                        "`{w}` in a kernel module — wall-clock reads break \
                         reproducibility; time at the coordinator layer instead"
                    ),
                });
            }
            Some("env") if is_p(t, i + 1, ':') && is_p(t, i + 2, ':') => {
                out.push(Finding {
                    path: String::new(),
                    line,
                    rule: Rule::TimeOrEnv,
                    message: "`env::` read in a kernel module — environment reads \
                              break reproducibility; plumb configuration explicitly"
                        .to_string(),
                });
            }
            _ => {}
        }
    }
}

/// untracked-clock (engine/serve scope): reading the clock directly —
/// `Instant::now()` or any `SystemTime` use — bypasses the sanctioned
/// seams (`EngineClock` for scheduling decisions, the `obs` trace/metrics
/// layer for measurement). Ad-hoc reads are exactly how wall time leaks
/// into scheduling and breaks the virtual-clock determinism contract
/// (DESIGN.md §14/§15). Legitimate sites — the clock seam itself,
/// report-only stamps — carry an audited `allow(untracked-clock)`.
/// Merely *storing* an `Instant` is fine; only acquisition is flagged.
fn rule_untracked_clock(t: &[Token], tmask: &[bool], out: &mut Vec<Finding>) {
    for i in 0..t.len() {
        let line = t[i].line;
        if tmask[line] {
            continue;
        }
        match ident(t, i) {
            Some("Instant")
                if is_p(t, i + 1, ':')
                    && is_p(t, i + 2, ':')
                    && ident(t, i + 3) == Some("now") =>
            {
                out.push(Finding {
                    path: String::new(),
                    line,
                    rule: Rule::UntrackedClock,
                    message: "`Instant::now()` outside the clock seam — take time \
                              through `EngineClock`/obs so virtual-clock runs stay \
                              deterministic"
                        .to_string(),
                });
            }
            Some("SystemTime") => {
                out.push(Finding {
                    path: String::new(),
                    line,
                    rule: Rule::UntrackedClock,
                    message: "`SystemTime` in engine/serve code — take time through \
                              `EngineClock`/obs so virtual-clock runs stay deterministic"
                        .to_string(),
                });
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------

/// Lint one file. `rel_path` (forward-slash, relative to the scanned
/// source root, e.g. `tensor/par.rs`) selects rule scopes;
/// `display_path` is what findings report.
pub fn lint_source_at(rel_path: &str, display_path: &str, src: &str) -> Vec<Finding> {
    let lx = lex(src);
    let tmask = test_line_mask(&lx);
    let mut markers = collect_markers(&lx, &tmask);
    let scope = scope_of(rel_path);

    let mut raw: Vec<Finding> = Vec::new();
    if scope.d1 {
        rule_hash_iteration(&lx.tokens, &tmask, &mut raw);
    }
    if scope.d2 {
        rule_unordered_reduction(&lx.tokens, &tmask, &mut raw);
        rule_int_accum_order(&lx.tokens, &tmask, &mut raw);
    }
    if scope.d3 {
        rule_panic_in_serve(&lx.tokens, &tmask, &mut raw);
    }
    rule_missing_safety(&lx, &tmask, &mut raw);
    if scope.s2 {
        rule_time_or_env(&lx.tokens, &tmask, &mut raw);
    }
    if scope.clk {
        rule_untracked_clock(&lx.tokens, &tmask, &mut raw);
    }

    let mut out: Vec<Finding> = Vec::new();
    for f in raw {
        let covered = markers
            .iter_mut()
            .find(|m| m.rule == f.rule && m.start <= f.line && f.line <= m.end);
        if let Some(m) = covered {
            m.used = true;
            continue;
        }
        out.push(f);
    }
    for m in &markers {
        if !m.used {
            out.push(Finding {
                path: String::new(),
                line: m.line,
                rule: Rule::UnusedAllow,
                message: if m.accum {
                    "accum(ascending-k) marker covers no integer accumulation — \
                     remove it"
                        .to_string()
                } else {
                    format!("allow({}) marker suppresses nothing — remove it", m.rule.name())
                },
            });
        }
    }
    for f in &mut out {
        f.path = display_path.to_string();
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Lint one file with scope inferred from (and reported as) `rel_path`.
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Finding> {
    lint_source_at(rel_path, rel_path, src)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?
        .collect::<std::io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (or `root` itself when it is a
/// file). Files are visited in sorted order so output is byte-stable.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    if root.is_file() {
        files.push(root.to_path_buf());
    } else {
        collect_rs(root, &mut files)?;
    }
    let mut out = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let rel = f.strip_prefix(root).unwrap_or(f);
        let rel_s = rel.to_string_lossy().replace('\\', "/");
        let display = f.to_string_lossy().replace('\\', "/");
        out.extend(lint_source_at(&rel_s, &display, &src));
    }
    Ok(out)
}

/// Escape a string for inclusion in a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render findings as a JSON array (machine-readable mode).
pub fn to_json(findings: &[Finding]) -> String {
    let mut out = String::from("[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"path\":\"{}\",\"line\":{},\"rule\":\"{}\",\"message\":\"{}\"}}",
            json_escape(&f.path),
            f.line,
            f.rule.name(),
            json_escape(&f.message)
        ));
    }
    if !findings.is_empty() {
        out.push('\n');
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, src: &str) -> Vec<(usize, Rule)> {
        lint_source(rel, src)
            .into_iter()
            .map(|f| (f.line, f.rule))
            .collect()
    }

    #[test]
    fn strings_and_comments_are_inert() {
        // `.sum()`, `[0]`, and `unwrap()` inside a comment or string literal
        // are data, not code, in every scope.
        let src = "pub fn f() -> String {\n    // .sum() in a comment\n    String::from(\".sum() [0] unwrap()\")\n}\n";
        assert!(rules("tensor/x.rs", src).is_empty());
        assert!(rules("serve/x.rs", src).is_empty());
    }

    #[test]
    fn raw_string_with_embedded_quote_does_not_derail_the_lexer() {
        // If the `"` inside the raw string ended the literal early, the lexer
        // would swallow the real reduction on line 5.
        let src = "pub fn f() -> &'static str {\n    r#\"contains \" quote\"#\n}\npub fn g(xs: &[f32]) -> f32 {\n    xs.iter().sum()\n}\n";
        assert_eq!(
            rules("tensor/x.rs", src),
            vec![(5, Rule::UnorderedReduction)]
        );
    }

    #[test]
    fn ranges_and_tuple_access_are_not_floats_or_indexing() {
        let src = "pub fn f(n: usize) -> usize {\n    let pair = (n, n);\n    let mut acc = 0usize;\n    for i in 0..n {\n        acc += i + pair.0;\n    }\n    acc\n}\n";
        assert!(rules("tensor/x.rs", src).is_empty());
        assert!(rules("serve/x.rs", src).is_empty());
    }

    #[test]
    fn scope_selects_which_rules_run() {
        let src = "pub fn f(xs: &[f32]) -> f32 {\n    xs.iter().sum()\n}\n";
        assert_eq!(
            rules("tensor/x.rs", src),
            vec![(2, Rule::UnorderedReduction)]
        );
        // Same source outside any kernel module: D2 does not apply.
        assert!(rules("cli/x.rs", src).is_empty());
    }

    #[test]
    fn router_module_is_pinned_inside_serve_scope() {
        // The sharded-router supervisor (serve/router.rs) must stay
        // under the D3 no-panic / no-indexing rule, the untracked-clock
        // rule, and D1 — its crash-isolation and failover-determinism
        // guarantees lean on exactly these lints. Pinning the scope
        // here means moving the file out of serve/ (or an edit to
        // scope_of) fails loudly instead of silently dropping coverage.
        let s = scope_of("serve/router.rs");
        assert!(s.d3, "serve/router.rs must be in the D3 no-panic scope");
        assert!(s.clk, "serve/router.rs must be in the untracked-clock scope");
        assert!(s.d1, "serve/router.rs must be in the D1 float-determinism scope");
        // And the rules actually fire there, not just the scope bits.
        let src = "pub fn f(xs: &[i32]) -> i32 {\n    xs[0]\n}\n";
        assert_eq!(rules("serve/router.rs", src), vec![(2, Rule::PanicInServe)]);
        let clk = "pub fn f() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
        assert_eq!(
            rules("serve/router.rs", clk),
            vec![(2, Rule::UntrackedClock)]
        );
    }

    #[test]
    fn keyed_hash_access_is_fine_iteration_is_not() {
        let src = "use std::collections::HashMap;\npub fn f(m: &HashMap<u32, u32>) -> Vec<u32> {\n    let _one = m.get(&1).copied();\n    m.values().copied().collect()\n}\n";
        assert_eq!(rules("engine/x.rs", src), vec![(4, Rule::HashIteration)]);
    }

    #[test]
    fn fn_level_marker_covers_the_body_and_is_audited() {
        let marked = "// faq-lint: allow(unordered-reduction) — summed in index order\npub fn f(xs: &[f32]) -> f32 {\n    xs.iter().sum()\n}\n";
        assert!(rules("tensor/x.rs", marked).is_empty());
        // The same marker on a function with nothing to suppress is itself
        // a finding, so stale exemptions cannot accumulate.
        let stale = "// faq-lint: allow(unordered-reduction) — stale\npub fn f(x: f32) -> f32 {\n    x\n}\n";
        assert_eq!(rules("tensor/x.rs", stale), vec![(1, Rule::UnusedAllow)]);
    }

    #[test]
    fn int_accum_order_covers_intkern_scope() {
        // tensor/intkern.rs sits in the kernel scope: both the D2 float
        // rule and the D2b integer rule run there. This pin keeps a
        // future scope refactor from silently dropping the int kernel.
        let src = "pub fn f(xq: &[i8]) -> i32 {\n    let mut s = 0i32;\n    for &q in xq {\n        s += q as i32;\n    }\n    s\n}\n";
        assert_eq!(
            rules("tensor/intkern.rs", src),
            vec![(4, Rule::IntAccumOrder)]
        );
        assert!(rules("engine/mod.rs", src).is_empty());
        let marked = "// faq-lint: accum(ascending-k) — in slice order\npub fn f(xq: &[i8]) -> i32 {\n    let mut s = 0i32;\n    for &q in xq {\n        s += q as i32;\n    }\n    s\n}\npub fn g(xs: &[f32]) -> f32 {\n    xs.iter().sum()\n}\n";
        assert_eq!(
            rules("tensor/intkern.rs", marked),
            vec![(10, Rule::UnorderedReduction)]
        );
        // A stale accum marker is flagged just like a stale allow.
        let stale = "// faq-lint: accum(ascending-k) — stale\npub fn f(x: i32) -> i32 {\n    x\n}\n";
        assert_eq!(
            rules("tensor/intkern.rs", stale),
            vec![(1, Rule::UnusedAllow)]
        );
    }

    #[test]
    fn untracked_clock_flags_acquisition_in_engine_and_serve_only() {
        let src = "use std::time::Instant;\npub fn f() -> Instant {\n    Instant::now()\n}\n";
        assert_eq!(rules("engine/x.rs", src), vec![(3, Rule::UntrackedClock)]);
        assert_eq!(rules("serve/x.rs", src), vec![(3, Rule::UntrackedClock)]);
        // Outside the scope — obs (the seam itself), coordinator, CLI —
        // the rule does not run.
        assert!(rules("obs/x.rs", src).is_empty());
        assert!(rules("coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn untracked_clock_allows_storing_and_diffing_instants() {
        // Only acquisition is flagged: holding an `Instant` handed in
        // through the seam, or calling `.elapsed()` on one, is fine.
        let src = "use std::time::{Duration, Instant};\npub fn f(t0: Instant) -> Duration {\n    let copy: Instant = t0;\n    copy.elapsed()\n}\n";
        assert!(rules("engine/x.rs", src).is_empty());
    }

    #[test]
    fn untracked_clock_flags_system_time_anywhere_in_scope() {
        let src = "pub fn f() -> u64 {\n    let t = std::time::SystemTime::now();\n    t.elapsed().map(|d| d.as_secs()).unwrap_or(0)\n}\n";
        assert_eq!(rules("serve/x.rs", src), vec![(2, Rule::UntrackedClock)]);
    }

    #[test]
    fn untracked_clock_marker_is_audited() {
        let ok = "use std::time::Instant;\npub fn f() -> Instant {\n    Instant::now() // faq-lint: allow(untracked-clock) — report stamp\n}\n";
        assert!(rules("serve/x.rs", ok).is_empty());
        let stale = "// faq-lint: allow(untracked-clock) — stale\npub fn f(x: u32) -> u32 {\n    x\n}\n";
        assert_eq!(rules("engine/x.rs", stale), vec![(1, Rule::UnusedAllow)]);
    }

    #[test]
    fn cfg_test_modules_are_exempt() {
        let src = "pub fn f(x: f32) -> f32 {\n    x\n}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let s: f32 = [1.0f32].iter().sum();\n        assert!(s > 0.0);\n    }\n}\n";
        assert!(rules("tensor/x.rs", src).is_empty());
    }

    #[test]
    fn json_output_escapes_quotes_and_backslashes() {
        let f = Finding {
            path: "a\"b".into(),
            line: 3,
            rule: Rule::PanicInServe,
            message: "x\\y".into(),
        };
        let j = to_json(&[f]);
        assert!(j.contains("a\\\"b"), "{j}");
        assert!(j.contains("x\\\\y"), "{j}");
    }
}
