//! CLI: `faq-lint [--json] [paths...]` — lint `.rs` trees against the
//! repo's determinism & soundness rules (DESIGN.md §13).
//!
//! Exit codes: 0 clean, 1 findings, 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: faq-lint [--json] [paths...]
Lints Rust source trees against the faquant determinism & soundness
rules (hash-iteration, unordered-reduction, panic-in-serve,
missing-safety, time-or-env, untracked-clock, unused-allow). With no
paths, lints
rust/src relative to the current directory (the workspace root under
`cargo run -p faq-lint`).";

fn main() -> ExitCode {
    let mut json = false;
    let mut paths: Vec<PathBuf> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            s if s.starts_with('-') => {
                eprintln!("faq-lint: unknown flag `{s}`\n{USAGE}");
                return ExitCode::from(2);
            }
            s => paths.push(PathBuf::from(s)),
        }
    }
    if paths.is_empty() {
        paths.push(PathBuf::from("rust/src"));
    }

    let mut findings = Vec::new();
    for p in &paths {
        match faq_lint::lint_tree(p) {
            Ok(fs) => findings.extend(fs),
            Err(e) => {
                eprintln!("faq-lint: {}: {e}", p.display());
                return ExitCode::from(2);
            }
        }
    }

    if json {
        println!("{}", faq_lint::to_json(&findings));
    } else {
        for f in &findings {
            println!("{f}");
        }
        if !findings.is_empty() {
            eprintln!("faq-lint: {} finding(s)", findings.len());
        }
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
