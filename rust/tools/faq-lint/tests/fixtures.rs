//! Fixture corpus for faq-lint.
//!
//! Each tree under `tests/fixtures/` is a miniature `rust/src` layout that
//! exercises exactly one rule: `<rule>-fail` trees must produce a pinned set
//! of (path, line, rule) findings and `<rule>-pass` trees must lint clean.
//! Pinning lines (not just rule names) is deliberate — the acceptance test
//! for this linter is "revert a real fix and the tool points at the exact
//! line", so the fixtures hold the pointer itself to account.

use faq_lint::{lint_tree, Finding, Rule};
use std::path::PathBuf;

fn lint_fixture(tree: &str) -> Vec<Finding> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(tree);
    lint_tree(&root).unwrap_or_else(|e| panic!("fixture tree {tree} unreadable: {e}"))
}

/// Findings as (path-suffix, line, rule), where the suffix is the last two
/// path components — enough to identify a fixture file unambiguously.
fn hits(tree: &str) -> Vec<(String, usize, Rule)> {
    lint_fixture(tree)
        .into_iter()
        .map(|f| {
            let mut parts = f.path.rsplit('/');
            let file = parts.next().unwrap_or_default();
            let dir = parts.next().unwrap_or_default();
            (format!("{dir}/{file}"), f.line, f.rule)
        })
        .collect()
}

fn expect_clean(tree: &str) {
    let findings = lint_fixture(tree);
    assert!(
        findings.is_empty(),
        "{tree} should lint clean, got:\n{}",
        findings
            .iter()
            .map(ToString::to_string)
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn d1_hash_iteration() {
    // d1-fail/runtime/registry.rs is the pre-fix shape of the real
    // rust/src/runtime/registry.rs (HashMap iterated for display order):
    // reverting that satellite fix must trip exactly these two findings.
    assert_eq!(
        hits("d1-fail"),
        vec![
            ("runtime/registry.rs".to_string(), 12, Rule::HashIteration),
            ("runtime/registry.rs".to_string(), 19, Rule::HashIteration),
        ]
    );
    // Keyed HashMap lookups and BTreeMap iteration are both fine.
    expect_clean("d1-pass");
}

#[test]
fn d2_unordered_reduction() {
    assert_eq!(
        hits("d2-fail"),
        vec![
            ("tensor/ops.rs".to_string(), 2, Rule::UnorderedReduction),
            ("tensor/ops.rs".to_string(), 6, Rule::UnorderedReduction),
        ]
    );
    // The min/max fold seeded with NEG_INFINITY in d2-fail is exempt by
    // construction (order-independent), hence no line-10 finding above.
    expect_clean("d2-pass");
}

#[test]
fn d2b_int_accum_order() {
    // Both recognized idioms trip: the widening `+= .. as i32` MAC and
    // rowsum loops (lines 4, 12), and the integer-SIMD accumulate
    // intrinsics (lines 19, 20).
    assert_eq!(
        hits("int-accum-fail"),
        vec![
            ("tensor/mac.rs".to_string(), 4, Rule::IntAccumOrder),
            ("tensor/mac.rs".to_string(), 12, Rule::IntAccumOrder),
            ("tensor/mac.rs".to_string(), 19, Rule::IntAccumOrder),
            ("tensor/mac.rs".to_string(), 20, Rule::IntAccumOrder),
        ]
    );
    // Scope precision: the identical accumulation in engine/ is outside
    // the kernel scope and must not be flagged.
    assert!(
        !hits("int-accum-fail").iter().any(|(p, _, _)| p == "engine/mix.rs"),
        "engine/ is outside the int-accum-order scope"
    );
    // Marked fns (fn-level and statement-level markers), float and usize
    // accumulators, all clean — and no stale-marker findings either.
    expect_clean("int-accum-pass");
}

#[test]
fn d3_panic_in_serve() {
    assert_eq!(
        hits("d3-fail"),
        vec![
            ("engine/lifecycle.rs".to_string(), 2, Rule::PanicInServe),
            ("engine/scheduler.rs".to_string(), 2, Rule::PanicInServe),
            ("serve/mod.rs".to_string(), 2, Rule::PanicInServe),
            ("serve/mod.rs".to_string(), 4, Rule::PanicInServe),
            ("serve/mod.rs".to_string(), 6, Rule::PanicInServe),
        ]
    );
    // Scope precision: d3-fail/engine/mod.rs also calls unwrap(), but only
    // engine/scheduler.rs and engine/lifecycle.rs (not the rest of
    // engine/) are in the serving path.
    assert!(
        !hits("d3-fail").iter().any(|(p, _, _)| p == "engine/mod.rs"),
        "engine/mod.rs is outside the D3 scope and must not be flagged"
    );
    expect_clean("d3-pass");
}

#[test]
fn s1_missing_safety() {
    assert_eq!(
        hits("s1-fail"),
        vec![
            ("util/raw.rs".to_string(), 2, Rule::MissingSafety),
            ("util/raw.rs".to_string(), 7, Rule::MissingSafety),
        ]
    );
    // Same code with `// SAFETY:` comments, plus an `unsafe fn` declaration
    // (caller-side contract, no comment required) lints clean.
    expect_clean("s1-pass");
}

#[test]
fn s2_time_or_env() {
    assert_eq!(
        hits("s2-fail"),
        vec![
            ("tensor/clock.rs".to_string(), 1, Rule::TimeOrEnv),
            ("tensor/clock.rs".to_string(), 6, Rule::TimeOrEnv),
        ]
    );
    // Instant in serve/ (out of S2 scope) and an allow-marked env read in
    // tensor/ are both acceptable.
    expect_clean("s2-pass");
}

#[test]
fn clk_untracked_clock() {
    assert_eq!(
        hits("untracked-clock-fail"),
        vec![
            ("engine/stamp.rs".to_string(), 4, Rule::UntrackedClock),
            ("serve/timer.rs".to_string(), 1, Rule::UntrackedClock),
            ("serve/timer.rs".to_string(), 3, Rule::UntrackedClock),
            ("serve/timer.rs".to_string(), 4, Rule::UntrackedClock),
        ]
    );
    // Scope precision: coordinator/heartbeat.rs in the same tree reads
    // the wall clock directly, and that is the coordinator's job.
    assert!(
        !hits("untracked-clock-fail")
            .iter()
            .any(|(p, _, _)| p == "coordinator/heartbeat.rs"),
        "coordinator/ is outside the untracked-clock scope"
    );
    // Storing/diffing Instants and audited allow-marked reads are fine.
    expect_clean("untracked-clock-pass");
}

#[test]
fn cfg_test_code_is_exempt() {
    // testmask-pass/tensor/sums.rs commits every sin — `.sum()`, hash
    // iteration, `unwrap()` — but only inside `#[cfg(test)]`.
    expect_clean("testmask-pass");
}

#[test]
fn unused_allow_is_flagged() {
    assert_eq!(
        hits("unused-fail"),
        vec![("tensor/noop.rs".to_string(), 1, Rule::UnusedAllow)]
    );
}

#[test]
fn canary_tree_trips_every_rule() {
    // CI runs the faq-lint binary over this tree and asserts a nonzero
    // exit, so a linter that silently stops finding anything cannot green
    // the pipeline. Keep this assertion in lockstep with that job.
    assert_eq!(
        hits("canary-tree"),
        vec![
            ("runtime/registry.rs".to_string(), 7, Rule::HashIteration),
            ("serve/mod.rs".to_string(), 2, Rule::PanicInServe),
            ("serve/mod.rs".to_string(), 5, Rule::UntrackedClock),
            ("tensor/intmac.rs".to_string(), 4, Rule::IntAccumOrder),
            ("tensor/kernel.rs".to_string(), 2, Rule::UnorderedReduction),
            ("tensor/kernel.rs".to_string(), 5, Rule::TimeOrEnv),
            ("tensor/kernel.rs".to_string(), 6, Rule::TimeOrEnv),
            ("tensor/kernel.rs".to_string(), 9, Rule::UnusedAllow),
            ("util/raw.rs".to_string(), 2, Rule::MissingSafety),
        ]
    );
}
