//! The linter's own acceptance gate: the tree it guards must be clean.
//!
//! This is the test-shaped twin of the CI `lint` job — it keeps
//! `cargo test` sufficient to catch a regression without the workflow.

use std::path::PathBuf;

#[test]
fn rust_src_lints_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../../..")
        .join("rust/src");
    let root = root
        .canonicalize()
        .expect("repo layout: rust/tools/faq-lint sits three levels below the root");
    let findings = faq_lint::lint_tree(&root).expect("lint rust/src");
    assert!(
        findings.is_empty(),
        "faq-lint found {} issue(s) in rust/src — fix them or add an \
         audited `// faq-lint: allow(<rule>)` marker:\n{}",
        findings.len(),
        findings
            .iter()
            .map(ToString::to_string)
            .collect::<Vec<_>>()
            .join("\n")
    );
}
