// Fixture: the reverted satellite — a hash-ordered manifest map whose
// iteration order leaks into the validation report (D1 must flag it).
use std::collections::HashMap;

pub struct Manifest {
    configs: HashMap<String, u32>,
}

impl Manifest {
    pub fn validate(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (name, cfg) in &self.configs {
            out.push(format!("{name}: {cfg}"));
        }
        out
    }

    pub fn names(&self) -> Vec<String> {
        self.configs.keys().cloned().collect()
    }
}
