pub fn read_first(v: &[u8]) -> u8 {
    assert!(!v.is_empty());
    // SAFETY: the assert above guarantees at least one element, so the
    // pointer read stays in bounds.
    unsafe { *v.as_ptr() }
}

pub struct Wrapper(*const u8);

// SAFETY: the pointer is never dereferenced off its owning thread.
unsafe impl Send for Wrapper {}

/// An `unsafe fn` declaration documents its contract at call sites;
/// S1 only binds blocks and impls.
pub unsafe fn untracked(p: *const u8) -> u8 {
    // SAFETY: caller upholds validity per this fn's contract.
    unsafe { *p }
}
