// S2 binds kernel modules only: wall-clock reads are the coordinator
// layer's job, and serve/ IS that layer.
pub fn elapsed_secs(t0: std::time::Instant) -> f32 {
    t0.elapsed().as_secs_f32()
}
