// faq-lint: allow(time-or-env) — debug override; the default path
// never reads the environment.
pub fn threads() -> usize {
    match std::env::var("THREADS") {
        Ok(v) => v.parse().unwrap_or(1),
        Err(_) => 1,
    }
}
