// faq-lint: allow(unordered-reduction) — nothing here reduces
pub fn id(x: f32) -> f32 {
    x
}
