pub fn unmarked_mac(xq: &[i8], codes: &[u8]) -> i32 {
    let mut acc = 0i32;
    for (x, b) in xq.iter().zip(codes) {
        acc += (*x as i32) * ((*b & 0xF) as i32);
    }
    acc
}
