pub fn total(xs: &[f32]) -> f32 {
    xs.iter().sum()
}

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}

// faq-lint: allow(unordered-reduction) — covers nothing, must trip unused-allow
pub fn id(x: f32) -> f32 {
    x
}
