pub fn first(v: &[u32]) -> u32 {
    v.first().copied().unwrap()
}
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
