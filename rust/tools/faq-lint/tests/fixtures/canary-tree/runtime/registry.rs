// CI canary: this tree MUST fail faq-lint (one violation per rule).
// The lint job runs the tool here and asserts a nonzero exit, so a
// silently broken linter cannot green the pipeline.
use std::collections::HashMap;

pub fn dump(stats: &HashMap<String, u32>) -> Vec<String> {
    stats.iter().map(|(k, v)| format!("{k}={v}")).collect()
}
