pub fn head(queue: &[u32]) -> u32 {
    *queue.first().expect("queue is never empty")
}
