pub fn resolve(deadline: Option<u64>, now: u64) -> u64 {
    now + deadline.expect("deadline must be set")
}
