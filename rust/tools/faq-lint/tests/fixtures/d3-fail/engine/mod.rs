// D3 covers serve/ and engine/scheduler.rs only: an unwrap here (an
// engine-internal module, not the serving path) must NOT be flagged.
pub fn pick(v: &[u32]) -> u32 {
    *v.first().unwrap()
}
