// D3 covers serve/, engine/scheduler.rs, and engine/lifecycle.rs only:
// an unwrap here (an engine-internal module, not the serving path) must
// NOT be flagged.
pub fn pick(v: &[u32]) -> u32 {
    *v.first().unwrap()
}
