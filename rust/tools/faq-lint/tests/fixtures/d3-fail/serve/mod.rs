pub fn respond(outs: &[Vec<f32>], idx: usize) -> Vec<f32> {
    let row = &outs[idx];
    if row.is_empty() {
        unreachable!("rows are never empty");
    }
    row.first().map(|_| row.clone()).unwrap()
}
