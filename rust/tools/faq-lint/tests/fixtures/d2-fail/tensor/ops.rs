pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn total(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0, |acc, v| acc + v)
}

pub fn peak(xs: &[f32]) -> f32 {
    // A fold seeded with f32::NEG_INFINITY is a per-element max scan,
    // not an accumulation: exempt from D2.
    xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v))
}
