// faq-lint: allow(unordered-reduction) — strictly in-order slice walk
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn mean(xs: &[f32]) -> f32 {
    let total: f32 = xs.iter().sum(); // faq-lint: allow(unordered-reduction) — in-order
    total / xs.len() as f32
}
