use std::fmt;

#[derive(Debug)]
pub struct EmptyRow;

impl fmt::Display for EmptyRow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("empty logits row")
    }
}

pub fn respond(outs: &[Vec<f32>], idx: usize) -> Result<Vec<f32>, EmptyRow> {
    let row = outs.get(idx).ok_or(EmptyRow)?;
    match row.first() {
        Some(_) => Ok(row.clone()),
        None => Err(EmptyRow),
    }
}
