// The clock seam itself: the one sanctioned wall-clock read, carrying
// the audited allow marker. Everything else only stores or diffs the
// Instants it is handed.
pub struct Seam {
    t0: std::time::Instant,
}

impl Seam {
    pub fn new() -> Self {
        // faq-lint: allow(untracked-clock) — the seam anchors its epoch
        Self { t0: std::time::Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f32 {
        self.t0.elapsed().as_secs_f32()
    }
}
