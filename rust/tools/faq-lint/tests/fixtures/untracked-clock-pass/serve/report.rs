// Report-only stamps are fine when audited with a trailing marker.
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now() // faq-lint: allow(untracked-clock) — report wall time
}

pub fn wait_secs(queued: std::time::Instant, now: std::time::Instant) -> f32 {
    now.duration_since(queued).as_secs_f32()
}
