// Fixture: ordered iteration (BTreeMap) plus keyed-only HashMap use —
// both fine under D1.
use std::collections::{BTreeMap, HashMap};

pub struct Manifest {
    configs: BTreeMap<String, u32>,
    cache: HashMap<u64, u32>,
}

impl Manifest {
    pub fn validate(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (name, cfg) in &self.configs {
            out.push(format!("{name}: {cfg}"));
        }
        out
    }

    pub fn lookup(&self, key: u64) -> Option<u32> {
        self.cache.get(&key).copied()
    }
}
