pub fn elapsed_secs(t0: std::time::Instant) -> f32 {
    t0.elapsed().as_secs_f32()
}

pub fn threads() -> usize {
    match std::env::var("THREADS") {
        Ok(v) => v.parse().unwrap_or(1),
        Err(_) => 1,
    }
}
