pub fn add(a: f32, b: f32) -> f32 {
    a + b
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn sums_and_maps() {
        // Tests are exempt from every rule: ad-hoc sums, hash
        // iteration, and unwraps are all fine here.
        let v = [1.0f32, 2.0];
        let s: f32 = v.iter().sum();
        let mut m: HashMap<u32, f32> = HashMap::new();
        m.insert(1, s);
        for (k, val) in m.iter() {
            assert!(*k == 1 && *val == 3.0);
        }
        assert_eq!(v.first().copied().unwrap(), 1.0);
    }
}
