pub fn read_first(v: &[u8]) -> u8 {
    unsafe { *v.as_ptr() }
}

pub struct Wrapper(*const u8);

unsafe impl Send for Wrapper {}
