use std::time::Instant;

pub fn tick_stamp() -> Instant {
    Instant::now()
}
