// The coordinator layer owns wall time: outside the untracked-clock
// scope, a direct read is legitimate and must NOT be flagged.
pub fn heartbeat_secs(t0: std::time::Instant) -> f32 {
    let now = std::time::Instant::now();
    now.duration_since(t0).as_secs_f32()
}
