use std::time::SystemTime;

pub fn now_unix(epoch: SystemTime) -> u64 {
    SystemTime::now()
        .duration_since(epoch)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}
