pub fn histogram_total(counts: &[u8]) -> i32 {
    let mut total = 0i32;
    for &c in counts {
        total += c as i32;
    }
    total
}
