pub fn dot_q(xq: &[i8], codes: &[u8]) -> i32 {
    let mut acc = 0i32;
    for (x, b) in xq.iter().zip(codes) {
        acc += (*x as i32) * ((*b & 0xF) as i32);
    }
    acc
}

pub fn rowsum(xq: &[i8]) -> i32 {
    let mut s = 0i32;
    for &q in xq {
        s += q as i32;
    }
    s
}

pub unsafe fn accum_lane(acc: *mut i32) {
    // SAFETY: fixture; the intrinsic name alone is what the rule sees.
    let av = _mm256_add_epi32(acc, acc);
    let bv = vmlaq_n_s32(av, av, 2);
    drop(bv);
}
