// faq-lint: accum(ascending-k) — exact i32 MAC, traversal pinned ascending.
pub fn dot_q(xq: &[i8], codes: &[u8]) -> i32 {
    let mut acc = 0i32;
    for (x, b) in xq.iter().zip(codes) {
        acc += (*x as i32) * ((*b & 0xF) as i32);
    }
    acc
}

pub fn rowsum(xq: &[i8]) -> i32 {
    let mut s = 0i32;
    for &q in xq {
        // faq-lint: accum(ascending-k) — exact i32 sum in slice order.
        s += q as i32;
    }
    s
}

// faq-lint: accum(ascending-k) — same integers as the scalar lane.
pub unsafe fn accum_lane(acc: *mut i32) {
    // SAFETY: fixture; the intrinsic name alone is what the rule sees.
    let av = _mm256_add_epi32(acc, acc);
    drop(av);
}

pub fn float_and_index_accum(xs: &[f32]) -> (f32, usize) {
    let mut total = 0.0f32;
    let mut steps = 0usize;
    for &x in xs {
        total += x * 2.0;
        steps += 1;
    }
    (total, steps)
}
