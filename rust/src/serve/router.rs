//! Supervised multi-engine router: crash-isolated sharded serving with
//! prefix-affinity routing and deterministic failover (DESIGN.md §16).
//!
//! The router owns `N` engine workers, each on its own thread with its
//! own [`Engine`] (own KV pool, prefix cache, samplers). A supervisor
//! (the [`Router`], driven through the [`Stepper`] trait by the generic
//! serve loop) routes admitted requests, watches worker health, and
//! re-executes the in-flight work of a crashed or wedged worker on a
//! healthy one.
//!
//! **Why failover is sound.** The engine's bit-identity contract plus
//! samplers keyed by `(seed, request id)` make a request's token stream
//! a pure function of `(prompt, gen seed, id, sampling params)` — never
//! of which worker ran it, what else was batched with it, or how far a
//! dead worker got before dying. Re-executing a request from scratch on
//! another worker therefore reproduces the exact stream the crashed
//! worker would have produced, and the router-level fault harness pins
//! that bitwise (`testutil::router_faults`).
//!
//! **Exactly-once answers.** Every dispatch carries the worker's epoch;
//! outputs are matched against the inflight entry's recorded
//! `(worker, epoch)`. A worker that stalls, is quarantined, and later
//! wakes up can only emit stale-epoch outputs, which the router drops —
//! the failover copy's output is the only one that counts. Workers
//! likewise drop stale-epoch dispatches after a restart.
//!
//! **Stall detection without a clock.** Each worker bumps a
//! [`Heartbeat`] after every completed step. The supervisor counts its
//! own *idle rounds* — event-pump rounds in which nothing arrived — and
//! quarantines a worker whose heartbeat stays flat across
//! `stall_rounds` such rounds while it holds queued work. No wall-time
//! read is involved (the `untracked-clock` lint stays clean), and a
//! false positive only triggers a harmless deterministic re-execution:
//! the quarantined worker's late outputs are stale-epoch and dropped.
//!
//! **Drain.** Worker engines never enter engine-level drain — a drained
//! engine would reject the very re-dispatches failover depends on.
//! Draining is enforced at router admission; `Drain` asks each worker
//! to report back once idle with its final [`GenReport`], latency
//! histograms, and a pool-leak check (`flush_prefix_cache` →
//! `check_paged_invariants` → `assert_pool_all_free`).

use crate::config::ModelConfig;
use crate::engine::{
    Engine, FinishReason, GenConfig, GenOutput, GenReport, GenRequest, Heartbeat, RejectCounts,
    RejectReason, DEFAULT_BLOCK_TOKENS,
};
use crate::model::Params;
use crate::obs::{Hist, LatencyStats, Metrics, Trace, TraceEvent, TraceRecord};
use crate::quant::QuantizedModel;
use crate::runtime::Runtime;
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use super::Stepper;

/// How long an idle worker blocks on its mailbox per round.
const IDLE_WAIT: Duration = Duration::from_millis(1);
/// How long the router blocks for worker events when it has in-flight
/// work but nothing to do.
const EVENT_WAIT: Duration = Duration::from_millis(1);
/// Startup barrier bound: rounds waited for every worker to report Up
/// or Down before routing begins (engine construction may prepare
/// weights, so this is generous; each idle round waits [`EVENT_WAIT`]).
const STARTUP_ROUNDS: usize = 120_000;
/// Shutdown bounds: idle rounds stepping a draining router, and rounds
/// waiting for per-worker drained reports.
const FINISH_ROUNDS: usize = 60_000;
const DRAIN_COLLECT_ROUNDS: usize = 30_000;

/// Leading prompt blocks hashed for prefix-affinity routing. Shared
/// system prompts dominate the first few blocks; hashing more would
/// spread requests that share a long prefix across workers and defeat
/// the point.
pub const AFFINITY_BLOCKS: usize = 4;

/// Prefix-affinity routing: hash the prompt's leading complete blocks
/// (up to [`AFFINITY_BLOCKS`] of `block_tokens` tokens each) to a
/// worker index, so traffic sharing a system prompt lands on the worker
/// whose radix tree already caches it.
///
/// Pure function of `(prompt, block_tokens, workers)` — a pinned
/// property test holds it to that. Returns `None` when no complete
/// block exists (or `workers`/`block_tokens` is zero): such prompts
/// cannot hit the prefix cache anyway, so they fall back to
/// least-loaded placement.
pub fn route_affinity(prompt: &[i32], block_tokens: usize, workers: usize) -> Option<usize> {
    if workers == 0 || block_tokens == 0 {
        return None;
    }
    let blocks = (prompt.len() / block_tokens).min(AFFINITY_BLOCKS);
    if blocks == 0 {
        return None;
    }
    // FNV-1a over the little-endian bytes of the hashed tokens: stable
    // across platforms, cheap, and with no dependency on the std
    // hasher's per-process seed.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for t in prompt.iter().take(blocks * block_tokens) {
        for b in (*t as u32).to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    Some((h % workers as u64) as usize)
}

/// Fault seam at the worker boundary, called immediately before every
/// step attempt with a cumulative attempt counter (monotone across
/// restarts, so a plan keyed on attempt numbers fires each fault
/// exactly once). Returning `true` simulates a wedge: the worker stops
/// making progress — heartbeat flat, mailbox ignored except Shutdown —
/// until the supervisor quarantines it. A hook may also panic to
/// simulate a crash; the worker's `catch_unwind` absorbs it.
///
/// Implementations live in `testutil::router_faults`; production
/// routers carry no hook and pay one `Option` check per step.
pub trait WorkerFaultHook: Send {
    fn before_step(&mut self, worker: usize, epoch: usize, attempt: u64) -> bool;
}

/// Per-worker hook factory (worker index → hook), so a fault plan can
/// target one worker and leave the rest clean.
pub type HookFactory = Arc<dyn Fn(usize) -> Option<Box<dyn WorkerFaultHook>> + Send + Sync>;

/// Sharded-router configuration. `Default` is production-shaped: two
/// workers, affinity on, no fault hook.
#[derive(Clone)]
pub struct RouterConfig {
    /// Worker (engine) count; 0 is treated as 1.
    pub workers: usize,
    /// Prefix-affinity routing ([`route_affinity`]); when off, every
    /// request goes to the least-loaded eligible worker.
    pub affinity: bool,
    /// Global admission bound on pending + in-flight requests
    /// (0 = unbounded). Overflow rejects with [`RejectReason::QueueFull`].
    pub max_queue: usize,
    /// Per-worker dispatch bound (backpressure): a worker holding this
    /// many in-flight requests is ineligible for more until one
    /// completes. 0 resolves to 2 × engine slots.
    pub worker_queue: usize,
    /// Supervisor idle rounds with a flat heartbeat (while holding
    /// queued work) before a worker is presumed wedged and quarantined.
    /// 0 disables stall detection.
    pub stall_rounds: usize,
    /// Sleep between a worker crash and its restart attempt.
    pub restart_backoff: Duration,
    /// Restarts allowed per worker before it is marked permanently
    /// down (so `max_restarts + 1` engine lifetimes).
    pub max_restarts: usize,
    /// Record router trace events (worker_up / route / worker_crash /
    /// failover) into the report.
    pub trace: bool,
    /// Virtual trace-stamp step (see `obs::Trace::virtual_clock`);
    /// `None` stamps wall time.
    pub virtual_step: Option<Duration>,
    /// Fault-injection seam for the deterministic failover harness.
    pub hook: Option<HookFactory>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            affinity: true,
            max_queue: 0,
            worker_queue: 0,
            stall_rounds: 200,
            restart_backoff: Duration::from_millis(10),
            max_restarts: 4,
            trace: false,
            virtual_step: None,
            hook: None,
        }
    }
}

impl fmt::Debug for RouterConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RouterConfig")
            .field("workers", &self.workers)
            .field("affinity", &self.affinity)
            .field("max_queue", &self.max_queue)
            .field("worker_queue", &self.worker_queue)
            .field("stall_rounds", &self.stall_rounds)
            .field("restart_backoff", &self.restart_backoff)
            .field("max_restarts", &self.max_restarts)
            .field("trace", &self.trace)
            .field("virtual_step", &self.virtual_step)
            .field("hook", &self.hook.as_ref().map(|_| "<factory>"))
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// State shared between a worker thread and the supervisor.
#[derive(Debug, Default)]
struct WorkerShared {
    heartbeat: Heartbeat,
    /// Set by the supervisor to quarantine a presumed-wedged worker;
    /// the worker consumes it (`swap(false)`) and restarts its engine.
    quarantined: AtomicBool,
}

enum WorkerMsg {
    /// A request routed at the given worker epoch. A worker that has
    /// since restarted drops stale-epoch dispatches — the router
    /// already failed them over.
    Dispatch(GenRequest, usize),
    /// Router-level drain: report back (once idle) with the engine
    /// report, latency histograms, and a pool-leak check. Deliberately
    /// NOT engine-level drain — a drained engine would reject the
    /// re-dispatches failover depends on.
    Drain,
    Shutdown,
}

/// A worker's final accounting, sent on drain.
#[derive(Clone, Debug)]
struct DrainedInfo {
    report: GenReport,
    ttft: Hist,
    per_token: Hist,
    queue_wait: Hist,
    /// `Some(description)` when the post-drain pool check failed.
    leak: Option<String>,
}

enum WorkerEvent {
    Up {
        worker: usize,
        epoch: usize,
    },
    Out {
        worker: usize,
        epoch: usize,
        out: GenOutput,
    },
    Crash {
        worker: usize,
        epoch: usize,
        cause: &'static str,
        detail: String,
    },
    Drained {
        worker: usize,
        info: Box<DrainedInfo>,
    },
    /// Permanently down: restart budget exhausted or the engine could
    /// not be constructed.
    Down {
        worker: usize,
        detail: String,
    },
}

enum EpochEnd {
    Shutdown,
    Crashed,
}

enum Applied {
    Continue,
    Shutdown,
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker: usize,
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    qm: &QuantizedModel,
    gen: GenConfig,
    backoff: Duration,
    max_restarts: usize,
    shared: Arc<WorkerShared>,
    rx: mpsc::Receiver<WorkerMsg>,
    tx: mpsc::Sender<WorkerEvent>,
    mut hook: Option<Box<dyn WorkerFaultHook>>,
) {
    // Cumulative across epochs so an attempt-keyed fault plan passes
    // each attempt number exactly once (no re-firing after restart).
    let mut attempt: u64 = 0;
    let mut epoch = 0usize;
    loop {
        if epoch > max_restarts {
            let _ = tx.send(WorkerEvent::Down {
                worker,
                detail: format!("restart budget exhausted after {epoch} engine lifetimes"),
            });
            wait_for_shutdown(&rx);
            return;
        }
        let mut engine = match Engine::new(rt, cfg, params, qm, gen.clone()) {
            Ok(e) => e,
            Err(e) => {
                let _ = tx.send(WorkerEvent::Down {
                    worker,
                    detail: format!("engine construction failed: {e:#}"),
                });
                wait_for_shutdown(&rx);
                return;
            }
        };
        if tx.send(WorkerEvent::Up { worker, epoch }).is_err() {
            return;
        }
        match serve_epoch(
            worker,
            epoch,
            &mut engine,
            &shared,
            &rx,
            &tx,
            &mut hook,
            &mut attempt,
        ) {
            EpochEnd::Shutdown => return,
            EpochEnd::Crashed => {
                // Free the dead epoch's engine (KV pool, caches) before
                // backing off; the replacement gets a fresh one.
                drop(engine);
                std::thread::sleep(backoff);
                epoch += 1;
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_epoch(
    worker: usize,
    epoch: usize,
    engine: &mut Engine<'_>,
    shared: &WorkerShared,
    rx: &mpsc::Receiver<WorkerMsg>,
    tx: &mpsc::Sender<WorkerEvent>,
    hook: &mut Option<Box<dyn WorkerFaultHook>>,
    attempt: &mut u64,
) -> EpochEnd {
    let mut drain_requested = false;
    let mut drained_sent = false;
    loop {
        if shared.quarantined.swap(false, Ordering::SeqCst) {
            // Supervisor presumed us wedged (a false positive is safe —
            // our in-flight work was already failed over; anything this
            // epoch might still emit is stale and dropped).
            return EpochEnd::Crashed;
        }
        // Drain the mailbox without blocking.
        loop {
            match rx.try_recv() {
                Ok(msg) => match apply_msg(
                    msg,
                    worker,
                    epoch,
                    engine,
                    tx,
                    &mut drain_requested,
                    &mut drained_sent,
                ) {
                    Applied::Continue => {}
                    Applied::Shutdown => return EpochEnd::Shutdown,
                },
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => return EpochEnd::Shutdown,
            }
        }
        if engine.has_work() {
            // Count the attempt BEFORE trying it, so a plan crash at
            // attempt k fires exactly once: the re-execution after
            // restart runs under later attempt numbers.
            *attempt += 1;
            let this_attempt = *attempt;
            let stepped = catch_unwind(AssertUnwindSafe(|| {
                if let Some(h) = hook.as_deref_mut() {
                    if h.before_step(worker, epoch, this_attempt) {
                        return Ok(None);
                    }
                }
                engine.step().map(Some)
            }));
            match stepped {
                Ok(Ok(Some(outs))) => {
                    shared.heartbeat.beat();
                    for out in outs {
                        if tx.send(WorkerEvent::Out { worker, epoch, out }).is_err() {
                            return EpochEnd::Shutdown;
                        }
                    }
                }
                Ok(Ok(None)) => return park_stalled(shared, rx),
                Ok(Err(e)) => {
                    let _ = tx.send(WorkerEvent::Crash {
                        worker,
                        epoch,
                        cause: "step_error",
                        detail: format!("{e:#}"),
                    });
                    return EpochEnd::Crashed;
                }
                Err(payload) => {
                    let _ = tx.send(WorkerEvent::Crash {
                        worker,
                        epoch,
                        cause: "panic",
                        detail: panic_detail(payload),
                    });
                    return EpochEnd::Crashed;
                }
            }
        } else {
            if drain_requested && !drained_sent {
                let info = drain_check(engine);
                if tx
                    .send(WorkerEvent::Drained {
                        worker,
                        info: Box::new(info),
                    })
                    .is_err()
                {
                    return EpochEnd::Shutdown;
                }
                drained_sent = true;
            }
            // Idle: block briefly for the next message.
            match rx.recv_timeout(IDLE_WAIT) {
                Ok(msg) => match apply_msg(
                    msg,
                    worker,
                    epoch,
                    engine,
                    tx,
                    &mut drain_requested,
                    &mut drained_sent,
                ) {
                    Applied::Continue => {}
                    Applied::Shutdown => return EpochEnd::Shutdown,
                },
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return EpochEnd::Shutdown,
            }
        }
    }
}

fn apply_msg(
    msg: WorkerMsg,
    worker: usize,
    epoch: usize,
    engine: &mut Engine<'_>,
    tx: &mpsc::Sender<WorkerEvent>,
    drain_requested: &mut bool,
    drained_sent: &mut bool,
) -> Applied {
    match msg {
        WorkerMsg::Dispatch(req, for_epoch) => {
            if for_epoch != epoch {
                // Routed at a previous epoch of this worker; the router
                // has already failed it over. Running it here would
                // double-execute the request.
                return Applied::Continue;
            }
            // New work arriving during a drain (failover re-dispatch)
            // invalidates any drained report we already sent; we will
            // re-send one when idle again, and the router keeps the
            // latest.
            *drained_sent = false;
            if let Some(out) = engine.submit(req) {
                // Immediate rejection: surfaces through the normal
                // output path with the epoch tag.
                let _ = tx.send(WorkerEvent::Out { worker, epoch, out });
            }
        }
        WorkerMsg::Drain => *drain_requested = true,
        WorkerMsg::Shutdown => return Applied::Shutdown,
    }
    Applied::Continue
}

/// Cooperative-stall parking (fault hook returned `true`): make no
/// progress — heartbeat flat, dispatches ignored — until the
/// supervisor's quarantine flag arrives or the router shuts down.
/// Models a wedged worker faithfully: work dispatched to it is simply
/// lost until failover.
fn park_stalled(shared: &WorkerShared, rx: &mpsc::Receiver<WorkerMsg>) -> EpochEnd {
    loop {
        if shared.quarantined.swap(false, Ordering::SeqCst) {
            return EpochEnd::Crashed;
        }
        match rx.recv_timeout(IDLE_WAIT) {
            Ok(WorkerMsg::Shutdown) => return EpochEnd::Shutdown,
            Ok(_) => {}
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => return EpochEnd::Shutdown,
        }
    }
}

/// The post-drain leak check + final accounting for one worker engine.
fn drain_check(engine: &mut Engine<'_>) -> DrainedInfo {
    let leak = verify_pool_clean(engine).err().map(|e| format!("{e:#}"));
    let report = engine.report();
    let m = engine.metrics();
    DrainedInfo {
        report,
        ttft: m.hist("ttft_us").cloned().unwrap_or_else(Hist::new),
        per_token: m.hist("per_token_us").cloned().unwrap_or_else(Hist::new),
        queue_wait: m.hist("queue_wait_us").cloned().unwrap_or_else(Hist::new),
        leak,
    }
}

/// Same leak discipline as the engine fault harness: drop the prefix
/// cache's block references, re-check the paged invariants, and require
/// the pool fully free.
fn verify_pool_clean(engine: &mut Engine<'_>) -> Result<()> {
    engine.flush_prefix_cache()?;
    engine.check_paged_invariants()?;
    engine.assert_pool_all_free()?;
    Ok(())
}

fn wait_for_shutdown(rx: &mpsc::Receiver<WorkerMsg>) {
    loop {
        match rx.recv() {
            Ok(WorkerMsg::Shutdown) | Err(_) => return,
            Ok(_) => {}
        }
    }
}

fn panic_detail(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// Supervisor / router side
// ---------------------------------------------------------------------------

/// Per-worker metric names are static (the [`Metrics`] registry keys on
/// `&'static str`); workers beyond the table share the last slot.
const QUEUE_PEAK_GAUGES: [&str; 8] = [
    "router_w0_queue_peak",
    "router_w1_queue_peak",
    "router_w2_queue_peak",
    "router_w3_queue_peak",
    "router_w4_queue_peak",
    "router_w5_queue_peak",
    "router_w6_queue_peak",
    "router_w7_queue_peak",
];
const RESTART_COUNTERS: [&str; 8] = [
    "router_w0_restarts",
    "router_w1_restarts",
    "router_w2_restarts",
    "router_w3_restarts",
    "router_w4_restarts",
    "router_w5_restarts",
    "router_w6_restarts",
    "router_w7_restarts",
];

fn worker_metric(names: &'static [&'static str; 8], w: usize) -> &'static str {
    let i = w.min(names.len() - 1);
    names.get(i).copied().unwrap_or("router_w7_overflow")
}

struct WorkerHandle {
    tx: mpsc::Sender<WorkerMsg>,
    shared: Arc<WorkerShared>,
    epoch: usize,
    serving: bool,
    down: bool,
    /// Dispatched-but-unanswered requests (router-side view).
    queued: usize,
    peak_queued: usize,
    completed: usize,
    crashes: usize,
    stalls: usize,
    restarts: usize,
    last_beat: u64,
    /// Consecutive supervisor idle rounds with a flat heartbeat while
    /// holding queued work.
    idle_flat: usize,
    drained: Option<DrainedInfo>,
}

impl WorkerHandle {
    fn new(tx: mpsc::Sender<WorkerMsg>, shared: Arc<WorkerShared>) -> Self {
        Self {
            tx,
            shared,
            epoch: 0,
            serving: false,
            down: false,
            queued: 0,
            peak_queued: 0,
            completed: 0,
            crashes: 0,
            stalls: 0,
            restarts: 0,
            last_beat: 0,
            idle_flat: 0,
            drained: None,
        }
    }
}

struct Inflight {
    /// Kept for failover re-execution (the cancel token is shared with
    /// the copy, so a client cancel still lands after a reroute).
    req: GenRequest,
    worker: usize,
    epoch: usize,
}

/// The supervisor: owns the worker fleet, routes requests, and
/// implements [`Stepper`] so the generic serve loop (and the fault
/// harness) can drive it exactly like a single engine.
pub struct Router {
    workers: Vec<WorkerHandle>,
    events: mpsc::Receiver<WorkerEvent>,
    pending: VecDeque<GenRequest>,
    inflight: BTreeMap<usize, Inflight>,
    ready: Vec<GenOutput>,
    affinity: bool,
    block_tokens: usize,
    max_queue: usize,
    worker_queue: usize,
    stall_rounds: usize,
    draining: bool,
    tick: u64,
    completed: usize,
    rerouted: usize,
    crashes: usize,
    stalls: usize,
    dispatches: usize,
    affinity_routed: usize,
    orphaned: usize,
    /// Most recent crashed/stalled worker — named by terminal
    /// [`RejectReason::WorkerCrashed`] rejections when the whole fleet
    /// is down.
    last_crashed: usize,
    down_details: Vec<String>,
    reject_counts: RejectCounts,
    trace: Trace,
    metrics: Metrics,
}

impl Stepper for Router {
    fn submit(&mut self, req: GenRequest) -> Option<GenOutput> {
        self.submit_inner(req)
    }

    fn step(&mut self) -> Result<Vec<GenOutput>> {
        Ok(self.step_inner())
    }

    fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.inflight.is_empty() || !self.ready.is_empty()
    }

    fn begin_drain(&mut self) {
        self.begin_drain_inner();
    }

    fn draining(&self) -> bool {
        self.draining
    }
}

impl Router {
    fn submit_inner(&mut self, req: GenRequest) -> Option<GenOutput> {
        self.trace.emit(self.tick, TraceEvent::Submit { id: req.id });
        let reason = if self.draining {
            Some(RejectReason::Draining)
        } else if self.max_queue > 0 && self.pending.len() + self.inflight.len() >= self.max_queue
        {
            Some(RejectReason::QueueFull {
                limit: self.max_queue,
            })
        } else {
            None
        };
        if let Some(reason) = reason {
            return Some(self.reject(req, reason));
        }
        self.pending.push_back(req);
        // Keep the worker view fresh so routing sees completions that
        // already happened, then try to place immediately.
        self.pump_events();
        self.flush_pending();
        None
    }

    fn reject(&mut self, req: GenRequest, reason: RejectReason) -> GenOutput {
        self.reject_counts.note(&reason);
        self.metrics.inc("router_rejected", 1);
        self.trace.emit(
            self.tick,
            TraceEvent::Reject {
                id: req.id,
                cause: reason.cause(),
            },
        );
        GenOutput {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens: Vec::new(),
            finish: FinishReason::Rejected(reason),
        }
    }

    fn step_inner(&mut self) -> Vec<GenOutput> {
        self.tick = self.tick.saturating_add(1);
        let mut progressed = self.pump_events();
        self.flush_pending();
        if !progressed && self.ready.is_empty() && !self.inflight.is_empty() {
            // Nothing surfaced and callers expect progress: block
            // briefly for the free-running workers.
            progressed = self.wait_events();
            if progressed {
                self.pump_events();
            }
            self.flush_pending();
        }
        self.supervise(!progressed);
        // Supervision may have requeued a quarantined worker's work.
        self.flush_pending();
        std::mem::take(&mut self.ready)
    }

    fn begin_drain_inner(&mut self) {
        if self.draining {
            return;
        }
        self.draining = true;
        self.trace.emit(self.tick, TraceEvent::Drain);
        for ws in &self.workers {
            if !ws.down {
                let _ = ws.tx.send(WorkerMsg::Drain);
            }
        }
    }

    /// Non-blocking event pump; returns whether anything arrived.
    fn pump_events(&mut self) -> bool {
        let mut any = false;
        loop {
            match self.events.try_recv() {
                Ok(ev) => {
                    any = true;
                    self.handle_event(ev);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    self.handle_fleet_gone();
                    break;
                }
            }
        }
        any
    }

    /// Blocking (bounded) wait for one event; returns whether one came.
    fn wait_events(&mut self) -> bool {
        match self.events.recv_timeout(EVENT_WAIT) {
            Ok(ev) => {
                self.handle_event(ev);
                true
            }
            Err(mpsc::RecvTimeoutError::Timeout) => false,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.handle_fleet_gone();
                false
            }
        }
    }

    fn handle_event(&mut self, ev: WorkerEvent) {
        match ev {
            WorkerEvent::Up { worker, epoch } => {
                let draining = self.draining;
                let Some(ws) = self.workers.get_mut(worker) else {
                    return;
                };
                ws.epoch = epoch;
                ws.serving = true;
                ws.idle_flat = 0;
                ws.last_beat = ws.shared.heartbeat.snapshot();
                if epoch > 0 {
                    ws.restarts += 1;
                    self.metrics.inc(worker_metric(&RESTART_COUNTERS, worker), 1);
                    self.metrics.inc("router_restarts", 1);
                }
                self.trace.emit(self.tick, TraceEvent::WorkerUp { worker, epoch });
                if draining {
                    // The drain request died with the old epoch; the
                    // replacement must also report a drained engine.
                    if let Some(ws) = self.workers.get(worker) {
                        let _ = ws.tx.send(WorkerMsg::Drain);
                    }
                }
            }
            WorkerEvent::Out { worker, epoch, out } => {
                let current = self
                    .inflight
                    .get(&out.id)
                    .is_some_and(|e| e.worker == worker && e.epoch == epoch);
                if !current {
                    // Stale epoch (output raced a quarantine and the
                    // request was failed over) or an id we already
                    // answered: drop. This is what makes failover
                    // exactly-once.
                    return;
                }
                self.inflight.remove(&out.id);
                if let Some(ws) = self.workers.get_mut(worker) {
                    ws.queued = ws.queued.saturating_sub(1);
                    ws.completed += 1;
                }
                self.completed += 1;
                self.ready.push(out);
            }
            WorkerEvent::Crash {
                worker,
                epoch,
                cause,
                detail: _,
            } => {
                let current = self
                    .workers
                    .get(worker)
                    .is_some_and(|ws| ws.serving && !ws.down && ws.epoch == epoch);
                if current {
                    self.fail_worker(worker, epoch, cause, false);
                }
            }
            WorkerEvent::Drained { worker, info } => {
                if let Some(ws) = self.workers.get_mut(worker) {
                    // Latest wins: a failover re-dispatch after an
                    // earlier report invalidates it and the worker
                    // re-sends once idle again.
                    ws.drained = Some(*info);
                }
            }
            WorkerEvent::Down { worker, detail } => {
                let Some(ws) = self.workers.get_mut(worker) else {
                    return;
                };
                if ws.down {
                    return;
                }
                let epoch = ws.epoch;
                ws.down = true;
                ws.serving = false;
                ws.queued = 0;
                self.down_details.push(format!("worker {worker}: {detail}"));
                // Usually empty (a Crash at the same epoch already
                // requeued), but covers construction failures mid-run.
                self.requeue_lost(worker, epoch);
            }
        }
    }

    /// The events channel can only disconnect when every worker thread
    /// has exited (each holds a sender clone) — shutdown, or something
    /// catastrophic. Requeue everything so accounting stays honest.
    fn handle_fleet_gone(&mut self) {
        for w in 0..self.workers.len() {
            let Some(ws) = self.workers.get_mut(w) else {
                continue;
            };
            if ws.down {
                continue;
            }
            let epoch = ws.epoch;
            ws.down = true;
            ws.serving = false;
            ws.queued = 0;
            self.requeue_lost(w, epoch);
        }
    }

    /// Quarantine a crashed or stalled worker and fail its in-flight
    /// work over (deterministic re-execution; see module docs).
    fn fail_worker(&mut self, worker: usize, epoch: usize, cause: &'static str, stall: bool) {
        if let Some(ws) = self.workers.get_mut(worker) {
            ws.serving = false;
            ws.queued = 0;
            ws.idle_flat = 0;
            if stall {
                ws.stalls += 1;
                self.stalls += 1;
                self.metrics.inc("router_stalls", 1);
                // The worker consumes this flag and restarts with a
                // fresh engine at the next epoch.
                ws.shared.quarantined.store(true, Ordering::SeqCst);
            } else {
                ws.crashes += 1;
                self.crashes += 1;
                self.metrics.inc("router_crashes", 1);
            }
        }
        self.last_crashed = worker;
        self.trace
            .emit(self.tick, TraceEvent::WorkerCrash { worker, epoch, cause });
        self.requeue_lost(worker, epoch);
    }

    /// Move the given `(worker, epoch)`'s in-flight requests back to
    /// the FRONT of the pending queue in ascending-id order, so
    /// rerouted work is re-placed before newer admissions and in a
    /// deterministic order.
    fn requeue_lost(&mut self, worker: usize, epoch: usize) {
        let lost: Vec<usize> = self
            .inflight
            .iter()
            .filter(|(_, e)| e.worker == worker && e.epoch == epoch)
            .map(|(id, _)| *id)
            .collect();
        for id in lost.iter().rev() {
            if let Some(entry) = self.inflight.remove(id) {
                self.rerouted += 1;
                self.metrics.inc("router_rerouted", 1);
                self.trace.emit(
                    self.tick,
                    TraceEvent::Failover {
                        id: *id,
                        from: worker,
                        epoch,
                    },
                );
                self.pending.push_front(entry.req);
            }
        }
    }

    /// Clock-free stall supervision (see module docs): count only the
    /// router's own idle rounds, and only against workers that hold
    /// queued work with a flat heartbeat.
    fn supervise(&mut self, idle_round: bool) {
        if self.stall_rounds == 0 {
            return;
        }
        for w in 0..self.workers.len() {
            let stalled_epoch = {
                let Some(ws) = self.workers.get_mut(w) else {
                    continue;
                };
                if ws.down || !ws.serving || ws.queued == 0 {
                    ws.idle_flat = 0;
                    continue;
                }
                let beat = ws.shared.heartbeat.snapshot();
                if beat != ws.last_beat {
                    ws.last_beat = beat;
                    ws.idle_flat = 0;
                    continue;
                }
                if !idle_round {
                    continue;
                }
                ws.idle_flat += 1;
                if ws.idle_flat >= self.stall_rounds {
                    Some(ws.epoch)
                } else {
                    None
                }
            };
            if let Some(epoch) = stalled_epoch {
                self.fail_worker(w, epoch, "stall", true);
            }
        }
    }

    fn eligible(&self, w: usize) -> bool {
        self.workers
            .get(w)
            .is_some_and(|ws| ws.serving && !ws.down && ws.queued < self.worker_queue)
    }

    /// Routing decision for a prompt: affinity target when eligible,
    /// else least-loaded eligible worker (ties to the lowest index, so
    /// placement is deterministic given the worker view).
    fn route(&self, prompt: &[i32]) -> Option<(usize, bool)> {
        if self.affinity {
            if let Some(w) = route_affinity(prompt, self.block_tokens, self.workers.len()) {
                if self.eligible(w) {
                    return Some((w, true));
                }
            }
        }
        let mut best: Option<(usize, usize)> = None; // (queued, worker)
        for (w, ws) in self.workers.iter().enumerate() {
            if !self.eligible(w) {
                continue;
            }
            let better = match best {
                None => true,
                Some((q, _)) => ws.queued < q,
            };
            if better {
                best = Some((ws.queued, w));
            }
        }
        best.map(|(_, w)| (w, false))
    }

    /// Head-of-line dispatch: place pending requests until the head
    /// has no eligible worker (backpressure keeps FIFO order — no
    /// overtaking based on which worker happens to have room).
    fn flush_pending(&mut self) {
        loop {
            let decision = match self.pending.front() {
                None => break,
                Some(req) => self.route(&req.prompt),
            };
            match decision {
                Some((w, aff)) => {
                    let Some(req) = self.pending.pop_front() else {
                        break;
                    };
                    let id = req.id;
                    let Some(ws) = self.workers.get_mut(w) else {
                        self.pending.push_front(req);
                        break;
                    };
                    let epoch = ws.epoch;
                    if ws.tx.send(WorkerMsg::Dispatch(req.clone(), epoch)).is_err() {
                        // Worker thread died without a Down event:
                        // mark it and retry routing elsewhere.
                        ws.down = true;
                        ws.serving = false;
                        ws.queued = 0;
                        self.pending.push_front(req);
                        continue;
                    }
                    ws.queued += 1;
                    if ws.queued > ws.peak_queued {
                        ws.peak_queued = ws.queued;
                    }
                    let depth = ws.queued as u64;
                    self.metrics
                        .max_gauge(worker_metric(&QUEUE_PEAK_GAUGES, w), depth);
                    self.dispatches += 1;
                    self.metrics.inc("router_dispatches", 1);
                    if aff {
                        self.affinity_routed += 1;
                        self.metrics.inc("router_affinity_routed", 1);
                    }
                    self.trace.emit(
                        self.tick,
                        TraceEvent::Route {
                            id,
                            worker: w,
                            affinity: aff,
                        },
                    );
                    self.inflight.insert(id, Inflight { req, worker: w, epoch });
                }
                None => {
                    if self.workers.iter().all(|ws| ws.down) {
                        // No worker will ever come back: answer the
                        // whole backlog with the terminal cause.
                        if let Some(req) = self.pending.pop_front() {
                            let worker = self.last_crashed;
                            let out = self.reject(req, RejectReason::WorkerCrashed { worker });
                            self.ready.push(out);
                            continue;
                        }
                    }
                    break;
                }
            }
        }
    }

    /// Startup barrier: wait (bounded) until every worker reported Up
    /// or Down. Routing against a fully-started fleet makes affinity
    /// placement independent of construction timing — and guarantees a
    /// fault plan's target worker actually receives its dispatches.
    fn await_fleet_up(&mut self) {
        let mut rounds = 0usize;
        while rounds < STARTUP_ROUNDS {
            if self.workers.iter().all(|ws| ws.serving || ws.down) {
                return;
            }
            if !self.pump_events() && !self.wait_events() {
                rounds += 1;
            }
        }
    }

    /// Drain, collect per-worker reports, shut the fleet down, and
    /// build the run report. Called exactly once by [`run_router`] —
    /// also on the error path, since the worker threads are scoped and
    /// must be released before the scope can join.
    fn finish(&mut self) -> RouterReport {
        self.begin_drain_inner();
        let mut idle = 0usize;
        while Stepper::has_work(self) && idle < FINISH_ROUNDS {
            let outs = self.step_inner();
            if outs.is_empty() {
                idle += 1;
            } else {
                idle = 0;
                // Outputs surfacing after the driving loop stopped
                // stepping were admitted but never delivered.
                self.orphaned += outs.len();
            }
        }
        self.orphaned += self.pending.len() + self.inflight.len();
        self.pending.clear();
        self.inflight.clear();
        let mut rounds = 0usize;
        while rounds < DRAIN_COLLECT_ROUNDS {
            if self
                .workers
                .iter()
                .all(|ws| ws.down || ws.drained.is_some())
            {
                break;
            }
            if !self.pump_events() && !self.wait_events() {
                rounds += 1;
            }
        }
        for ws in &self.workers {
            let _ = ws.tx.send(WorkerMsg::Shutdown);
        }
        self.build_report()
    }

    fn build_report(&mut self) -> RouterReport {
        let mut per = Vec::with_capacity(self.workers.len());
        let mut leaks = Vec::new();
        let mut ttft = Hist::new();
        let mut per_token = Hist::new();
        let mut queue_wait = Hist::new();
        for (w, ws) in self.workers.iter_mut().enumerate() {
            let mut drained_clean = false;
            let mut report = None;
            match ws.drained.take() {
                Some(info) => {
                    drained_clean = info.leak.is_none();
                    if let Some(l) = info.leak {
                        leaks.push(format!("worker {w}: {l}"));
                    }
                    ttft.merge(&info.ttft);
                    per_token.merge(&info.per_token);
                    queue_wait.merge(&info.queue_wait);
                    report = Some(info.report);
                }
                None => {
                    if !ws.down {
                        leaks.push(format!("worker {w} never reported a drained engine"));
                    }
                }
            }
            per.push(RouterWorkerReport {
                worker: w,
                completed: ws.completed,
                crashes: ws.crashes,
                stalls: ws.stalls,
                restarts: ws.restarts,
                peak_queue: ws.peak_queued,
                drained_clean,
                report,
            });
        }
        let latency = LatencyStats::from_hists(&ttft, &per_token, &queue_wait);
        let engine = aggregate_engine(&per, latency.clone());
        let mut reject_counts = self.reject_counts.clone();
        reject_counts.merge(&engine.reject_counts);
        let rejected = reject_counts.total();
        RouterReport {
            workers: self.workers.len(),
            completed: self.completed,
            dispatches: self.dispatches,
            affinity_routed: self.affinity_routed,
            rerouted: self.rerouted,
            crashes: self.crashes,
            stalls: self.stalls,
            restarts: per.iter().map(|p| p.restarts).sum(),
            rejected,
            reject_counts,
            orphaned: self.orphaned,
            leaks,
            down: std::mem::take(&mut self.down_details),
            latency,
            engine,
            per_worker: per,
            trace: self.trace.snapshot(),
            trace_dropped: self.trace.dropped(),
            metrics_text: self.metrics.render_text(),
        }
    }
}

/// Fold the surviving workers' final engine reports into one fleet
/// view. Counts from engine lifetimes lost to crashes are not in here
/// (the engine died with them) — router-side counters (`completed`,
/// `rerouted`, `crashes`) track the fleet truth for those.
fn aggregate_engine(per: &[RouterWorkerReport], latency: LatencyStats) -> GenReport {
    let mut agg = GenReport::default();
    let mut occ = 0f32;
    for wr in per {
        let Some(r) = &wr.report else { continue };
        agg.sequences += r.sequences;
        agg.rejected += r.rejected;
        agg.reject_counts.merge(&r.reject_counts);
        agg.steps += r.steps;
        agg.prefill_tokens += r.prefill_tokens;
        agg.decode_tokens += r.decode_tokens;
        agg.prefill_secs += r.prefill_secs;
        agg.decode_secs += r.decode_secs;
        occ += r.mean_slot_occupancy * r.steps as f32;
        agg.prefix_hit_tokens += r.prefix_hit_tokens;
        agg.peak_blocks_in_use += r.peak_blocks_in_use;
        agg.pool_blocks += r.pool_blocks;
        agg.block_tokens = agg.block_tokens.max(r.block_tokens);
        agg.evicted_blocks += r.evicted_blocks;
        agg.cancelled += r.cancelled;
        agg.deadline_exceeded += r.deadline_exceeded;
        agg.quarantined += r.quarantined;
        agg.step_faults += r.step_faults;
        agg.step_retried += r.step_retried;
    }
    if agg.steps > 0 {
        agg.mean_slot_occupancy = occ / agg.steps as f32;
    }
    agg.latency = latency;
    agg
}

/// Per-worker slice of a [`RouterReport`].
#[derive(Clone, Debug)]
pub struct RouterWorkerReport {
    pub worker: usize,
    /// Requests this worker answered (completions and rejections).
    pub completed: usize,
    pub crashes: usize,
    pub stalls: usize,
    pub restarts: usize,
    /// High-water mark of dispatched-but-unanswered requests.
    pub peak_queue: usize,
    /// Whether the final engine drained with a clean pool check.
    pub drained_clean: bool,
    /// The final engine lifetime's report (`None` if permanently down
    /// before drain).
    pub report: Option<GenReport>,
}

/// Fleet-level summary of a sharded router run.
#[derive(Clone, Debug)]
pub struct RouterReport {
    pub workers: usize,
    /// Requests answered by workers (completions and worker-validated
    /// rejections; router-level rejections are only in `rejected`).
    pub completed: usize,
    /// Dispatches sent to workers (failover re-dispatches included).
    pub dispatches: usize,
    /// Dispatches placed by prefix affinity (vs least-loaded).
    pub affinity_routed: usize,
    /// Requests re-executed on another worker after a crash or stall.
    pub rerouted: usize,
    pub crashes: usize,
    pub stalls: usize,
    pub restarts: usize,
    /// Total rejections (router admission + worker validation).
    pub rejected: usize,
    pub reject_counts: RejectCounts,
    /// Requests that were admitted but never delivered to the caller —
    /// always 0 when the driving loop runs the router to completion.
    pub orphaned: usize,
    /// Pool-leak findings from per-worker drain checks (empty = clean).
    pub leaks: Vec<String>,
    /// Workers that went permanently down, with cause.
    pub down: Vec<String>,
    /// Fleet latency percentiles (exact: per-worker histograms share
    /// compiled-in buckets and merge by addition).
    pub latency: LatencyStats,
    /// Merged engine accounting across surviving workers.
    pub engine: GenReport,
    pub per_worker: Vec<RouterWorkerReport>,
    pub trace: Vec<TraceRecord>,
    pub trace_dropped: u64,
    pub metrics_text: String,
}

impl RouterReport {
    /// One-line fleet + per-worker occupancy/restart summary (printed
    /// by the CLI; format pinned by a test).
    pub fn summary_line(&self) -> String {
        let mut s = format!(
            "router: {} workers | {} done, {} rerouted, {} crashes, {} stalls, {} restarts, {} affinity-routed",
            self.workers,
            self.completed,
            self.rerouted,
            self.crashes,
            self.stalls,
            self.restarts,
            self.affinity_routed
        );
        for w in &self.per_worker {
            let occ = w
                .report
                .as_ref()
                .map(|r| r.mean_slot_occupancy)
                .unwrap_or(0.0);
            let _ = std::fmt::Write::write_fmt(
                &mut s,
                format_args!(
                    " | w{}: {} done, occ {:.2}, peak q {}, {} restarts",
                    w.worker, w.completed, occ, w.peak_queue, w.restarts
                ),
            );
        }
        s
    }
}

/// Run a worker fleet, hand the supervising [`Router`] to `f` (the
/// serve loop, the bench driver, or the fault harness), then always
/// drain, leak-check, and join the fleet — even when `f` errs, since
/// the workers are scoped threads and must be released first.
#[allow(clippy::too_many_arguments)]
pub fn run_router<R>(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    qm: &QuantizedModel,
    gen: GenConfig,
    rcfg: RouterConfig,
    f: impl FnOnce(&mut Router) -> Result<R>,
) -> Result<(R, RouterReport)> {
    let n = rcfg.workers.max(1);
    let slots = if gen.slots == 0 { cfg.batch } else { gen.slots };
    let worker_queue = if rcfg.worker_queue == 0 {
        slots.saturating_mul(2).max(1)
    } else {
        rcfg.worker_queue
    };
    let block_tokens = if gen.block_tokens == 0 {
        DEFAULT_BLOCK_TOKENS
    } else {
        gen.block_tokens
    };
    let trace = if rcfg.trace {
        match rcfg.virtual_step {
            Some(step) => {
                Trace::virtual_clock(u64::try_from(step.as_micros()).unwrap_or(u64::MAX))
            }
            None => Trace::wall_clock(),
        }
    } else {
        Trace::disabled()
    };
    let (etx, erx) = mpsc::channel();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n);
        for w in 0..n {
            let (wtx, wrx) = mpsc::channel::<WorkerMsg>();
            let shared = Arc::new(WorkerShared::default());
            let hook = rcfg.hook.as_ref().and_then(|mk| mk(w));
            let tx = etx.clone();
            let worker_shared = Arc::clone(&shared);
            let wgen = gen.clone();
            let backoff = rcfg.restart_backoff;
            let max_restarts = rcfg.max_restarts;
            scope.spawn(move || {
                worker_loop(
                    w,
                    rt,
                    cfg,
                    params,
                    qm,
                    wgen,
                    backoff,
                    max_restarts,
                    worker_shared,
                    wrx,
                    tx,
                    hook,
                );
            });
            handles.push(WorkerHandle::new(wtx, shared));
        }
        drop(etx);
        let mut router = Router {
            workers: handles,
            events: erx,
            pending: VecDeque::new(),
            inflight: BTreeMap::new(),
            ready: Vec::new(),
            affinity: rcfg.affinity,
            block_tokens,
            max_queue: rcfg.max_queue,
            worker_queue,
            stall_rounds: rcfg.stall_rounds,
            draining: false,
            tick: 0,
            completed: 0,
            rerouted: 0,
            crashes: 0,
            stalls: 0,
            dispatches: 0,
            affinity_routed: 0,
            orphaned: 0,
            last_crashed: 0,
            down_details: Vec::new(),
            reject_counts: RejectCounts::default(),
            trace,
            metrics: Metrics::new(),
        };
        router.await_fleet_up();
        let out = f(&mut router);
        // ALWAYS finish — the scoped workers block the scope's join
        // until they see Shutdown (or their channels close).
        let report = router.finish();
        Ok((out?, report))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affinity_is_deterministic_and_in_range() {
        for seed in 0..64i32 {
            let prompt: Vec<i32> = (0..40).map(|i| (i * 7 + seed) % 97).collect();
            for workers in 1..=8 {
                let a = route_affinity(&prompt, 4, workers);
                let b = route_affinity(&prompt, 4, workers);
                assert_eq!(a, b);
                let w = a.expect("prompt has complete blocks");
                assert!(w < workers);
            }
        }
    }

    #[test]
    fn affinity_ignores_tokens_beyond_hashed_blocks() {
        // 4 blocks of 4 tokens are hashed; everything after token 16
        // must not move the placement.
        let base: Vec<i32> = (0..16).collect();
        let mut longer = base.clone();
        longer.extend([99, -5, 1234, 7, 0, 42]);
        for workers in 1..=8 {
            assert_eq!(
                route_affinity(&base, 4, workers),
                route_affinity(&longer, 4, workers)
            );
        }
    }

    #[test]
    fn affinity_declines_without_a_complete_block() {
        assert_eq!(route_affinity(&[1, 2, 3], 4, 4), None);
        assert_eq!(route_affinity(&[], 4, 4), None);
        assert_eq!(route_affinity(&[1, 2, 3, 4], 0, 4), None);
        assert_eq!(route_affinity(&[1, 2, 3, 4], 4, 0), None);
        // Exactly one complete block is enough.
        assert!(route_affinity(&[1, 2, 3, 4], 4, 4).is_some());
    }

    #[test]
    fn router_config_defaults_are_production_shaped() {
        let c = RouterConfig::default();
        assert_eq!(c.workers, 2);
        assert!(c.affinity);
        assert_eq!(c.max_queue, 0);
        assert_eq!(c.worker_queue, 0);
        assert_eq!(c.stall_rounds, 200);
        assert_eq!(c.max_restarts, 4);
        assert!(!c.trace);
        assert!(c.hook.is_none());
        // Debug must not choke on the non-Debug hook field.
        let dbg = format!("{c:?}");
        assert!(dbg.contains("workers: 2"));
    }

    #[test]
    fn worker_metric_names_are_static_and_bounded() {
        assert_eq!(worker_metric(&QUEUE_PEAK_GAUGES, 0), "router_w0_queue_peak");
        assert_eq!(worker_metric(&RESTART_COUNTERS, 7), "router_w7_restarts");
        // Workers beyond the table share the last slot instead of
        // panicking.
        assert_eq!(worker_metric(&QUEUE_PEAK_GAUGES, 64), "router_w7_queue_peak");
    }

    #[test]
    fn summary_line_format_is_pinned() {
        let report = RouterReport {
            workers: 2,
            completed: 10,
            dispatches: 12,
            affinity_routed: 7,
            rerouted: 2,
            crashes: 1,
            stalls: 0,
            restarts: 1,
            rejected: 0,
            reject_counts: RejectCounts::default(),
            orphaned: 0,
            leaks: vec![],
            down: vec![],
            latency: LatencyStats::default(),
            engine: GenReport::default(),
            per_worker: vec![
                RouterWorkerReport {
                    worker: 0,
                    completed: 6,
                    crashes: 1,
                    stalls: 0,
                    restarts: 1,
                    peak_queue: 3,
                    drained_clean: true,
                    report: Some(GenReport {
                        mean_slot_occupancy: 0.5,
                        ..GenReport::default()
                    }),
                },
                RouterWorkerReport {
                    worker: 1,
                    completed: 4,
                    crashes: 0,
                    stalls: 0,
                    restarts: 0,
                    peak_queue: 2,
                    drained_clean: true,
                    report: None,
                },
            ],
            trace: vec![],
            trace_dropped: 0,
            metrics_text: String::new(),
        };
        assert_eq!(
            report.summary_line(),
            "router: 2 workers | 10 done, 2 rerouted, 1 crashes, 0 stalls, 1 restarts, \
             7 affinity-routed | w0: 6 done, occ 0.50, peak q 3, 1 restarts \
             | w1: 4 done, occ 0.00, peak q 2, 0 restarts"
        );
    }
}
