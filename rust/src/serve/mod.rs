//! Edge-serving demo (S12): batched inference over the quantized
//! deployment artifact (`fwd_logits_q`) with a request queue, a timeout
//! batcher, and latency accounting.
//!
//! The server owns the runtime on a dedicated executor thread (one
//! upload of the weight set, simple lifecycle — the runtime itself is
//! `Sync` since the parallel compute core landed); clients talk over
//! mpsc channels. The batcher collects
//! up to `batch` requests or flushes after `max_wait`; partial batches are
//! padded (fixed-shape artifacts) and pad rows discarded. Malformed
//! requests (wrong sequence length or out-of-range token ids) are
//! rejected individually — their response channel is dropped so the
//! client observes a disconnect — and never abort the serving loop for
//! the well-formed traffic behind them.

use crate::config::ModelConfig;
use crate::model::{Params, ROLES};
use crate::quant::QuantizedModel;
use crate::runtime::{lit_f32, tensor_f32, Buffer, Runtime, Value};
use crate::tensor::{percentile, Tensor, TensorI32};
use anyhow::Result;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One inference request: a full token sequence; the response carries the
/// logits of the final position (next-token distribution).
pub struct Request {
    pub tokens: Vec<i32>,
    pub respond: mpsc::Sender<Response>,
}

pub struct Response {
    pub next_logits: Vec<f32>,
    pub queued_at: Instant,
    pub done_at: Instant,
}

/// Latency/throughput summary of a serving run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    /// Malformed requests dropped without aborting the loop.
    pub rejected: usize,
    pub batches: usize,
    pub mean_batch_fill: f32,
    pub p50_ms: f32,
    pub p95_ms: f32,
    pub throughput_rps: f32,
}

/// Build the flat argument prefix for `fwd_logits_q` from a quantized
/// model (everything except the trailing tokens tensor).
///
/// Arg order (must mirror python model.fwd_logits_q): tok_emb, pos_emb,
/// per block [ln1, qkv{q,d,z,inv}, o{...}, ln2, up{...}, down{...}],
/// lnf_g, w_head.
pub fn qmodel_literals(params: &Params, qm: &QuantizedModel) -> Result<Vec<Value>> {
    let cfg = &qm.cfg;
    let mut lits = Vec::new();
    lits.push(lit_f32(params.get("tok_emb")?)?);
    lits.push(lit_f32(params.get("pos_emb")?)?);
    for b in 0..cfg.n_layer {
        lits.push(lit_f32(params.get(&format!("blk{b}.ln1_g"))?)?);
        for role in ["qkv", "o"] {
            push_linear(&mut lits, qm, b, role)?;
        }
        lits.push(lit_f32(params.get(&format!("blk{b}.ln2_g"))?)?);
        for role in ["up", "down"] {
            push_linear(&mut lits, qm, b, role)?;
        }
    }
    lits.push(lit_f32(params.get("lnf_g")?)?);
    lits.push(lit_f32(params.get("w_head")?)?);
    Ok(lits)
}

/// Upload a value bundle to reusable buffers.
fn upload_literals(rt: &Runtime, lits: &[Value]) -> Result<Vec<Buffer>> {
    lits.iter().map(|l| rt.upload_literal(l)).collect()
}

fn push_linear(
    lits: &mut Vec<Value>,
    qm: &QuantizedModel,
    block: usize,
    role: &str,
) -> Result<()> {
    let lq = qm
        .linear(block, role)
        .ok_or_else(|| anyhow::anyhow!("missing linear blk{block}.{role}"))?;
    debug_assert!(ROLES.contains(&role));
    let ints = &lq.ints;
    let ng = ints.n / ints.group;
    // Codes travel as f32 (qmatmul kernel contract; see kernels/qmatmul.py).
    let q_f32: Vec<f32> = ints.q.iter().map(|&c| c as f32).collect();
    lits.push(lit_f32(&Tensor::from_vec(&[ints.n, ints.m], q_f32)?)?);
    lits.push(lit_f32(&Tensor::from_vec(&[ng, ints.m], ints.delta.clone())?)?);
    lits.push(lit_f32(&Tensor::from_vec(&[ng, ints.m], ints.zero.clone())?)?);
    lits.push(lit_f32(&Tensor::from_vec(&[ints.n], lq.inv_s.clone())?)?);
    Ok(())
}

/// Run the serving loop over a closed set of requests (demo/benchmark
/// mode): consumes the receiver until disconnect, returns the report.
pub fn serve_requests(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    qm: &QuantizedModel,
    rx: mpsc::Receiver<Request>,
    max_wait: Duration,
) -> Result<ServeReport> {
    // §Perf: the INT-code weight bundle lives on-device for the whole
    // serving session; only token batches cross the host boundary.
    let weight_lits = qmodel_literals(params, qm)?;
    let weight_bufs = upload_literals(rt, &weight_lits)?;
    let (b, t, v) = (cfg.batch, cfg.seq, cfg.vocab);
    let mut latencies_ms: Vec<f32> = Vec::new();
    let mut fills: Vec<f32> = Vec::new();
    let mut batches = 0usize;
    let mut rejected = 0usize;
    let started = Instant::now();
    let mut pending: Vec<(Request, Instant)> = Vec::new();
    let mut done = false;

    while !done || !pending.is_empty() {
        // Fill the batch window, rejecting malformed requests at intake:
        // dropping the request closes its response channel (the client
        // sees a disconnect) while the rest of the queue keeps serving.
        let deadline = Instant::now() + max_wait;
        while pending.len() < b && !done {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                // Wrong length would corrupt the fixed-shape batch; an
                // out-of-range token id would make the embedding gather
                // fail mid-batch and take the whole loop down with it.
                Ok(req)
                    if req.tokens.len() != t
                        || req.tokens.iter().any(|&id| id < 0 || id as usize >= v) =>
                {
                    rejected += 1
                }
                Ok(req) => pending.push((req, Instant::now())),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => done = true,
            }
        }
        if pending.is_empty() {
            continue;
        }
        let take = pending.len().min(b);
        let group: Vec<(Request, Instant)> = pending.drain(..take).collect();
        fills.push(take as f32 / b as f32);

        // Assemble the fixed-shape batch, padding with the last row.
        let mut data = Vec::with_capacity(b * t);
        for i in 0..b {
            let (req, _) = &group[i.min(take - 1)];
            debug_assert_eq!(req.tokens.len(), t, "validated at intake");
            data.extend_from_slice(&req.tokens);
        }
        let batch = TensorI32::from_vec(&[b, t], data)?;
        let tok_buf = rt.upload_i32(&batch)?;
        let mut args: Vec<&Buffer> = weight_bufs.iter().collect();
        args.push(&tok_buf);
        let outs = rt.exec_b(&cfg.name, "fwd_logits_q", &args)?;
        let logits = tensor_f32(&outs[0])?; // [B, T, V]
        let now = Instant::now();
        batches += 1;

        for (i, (req, queued)) in group.into_iter().enumerate() {
            let base = (i * t + (t - 1)) * v;
            let next = logits.data()[base..base + v].to_vec();
            latencies_ms.push(now.duration_since(queued).as_secs_f32() * 1e3);
            // Receiver may have hung up; that's the client's business.
            let _ = req.respond.send(Response {
                next_logits: next,
                queued_at: queued,
                done_at: now,
            });
        }
    }

    let total = started.elapsed().as_secs_f32();
    let n = latencies_ms.len();
    Ok(ServeReport {
        requests: n,
        rejected,
        batches,
        mean_batch_fill: if fills.is_empty() {
            0.0
        } else {
            fills.iter().sum::<f32>() / fills.len() as f32
        },
        p50_ms: percentile(&latencies_ms, 50.0),
        p95_ms: percentile(&latencies_ms, 95.0),
        throughput_rps: if total > 0.0 { n as f32 / total } else { 0.0 },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_fields_sane() {
        let r = ServeReport {
            requests: 10,
            rejected: 1,
            batches: 3,
            mean_batch_fill: 0.83,
            p50_ms: 5.0,
            p95_ms: 9.0,
            throughput_rps: 100.0,
        };
        assert!(r.p95_ms >= r.p50_ms);
        assert!(r.mean_batch_fill <= 1.0);
    }
}
