//! Edge-serving demo (S12): batched inference over the quantized
//! deployment artifact, with a request queue, a timeout batcher, and
//! latency accounting.
//!
//! Two request flavors share the uploaded INT-code weight bundle:
//!
//! - **one-shot scoring** ([`serve_requests`]): a full fixed-length token
//!   sequence in, the final position's next-token logits out
//!   (`fwd_logits_q`, the original path);
//! - **generation** ([`serve_generate`]): a prompt + sampling budget in,
//!   generated tokens out, served by the continuous-batching
//!   [`crate::engine::Engine`] — in-flight sequences of different
//!   lengths share each batched decode step. The engine's KV store is
//!   block-paged with radix prefix sharing by default (DESIGN.md §12),
//!   so requests repeating a cached prompt prefix skip that prefill;
//!   the report's embedded [`GenReport`] carries the prefix-hit token
//!   count and block-pool occupancy alongside the throughput split.
//!
//! Malformed requests are rejected individually with a structured
//! [`RejectReason`] sent back on the response channel (never a silent
//! disconnect), counted per cause in the reports, and never abort the
//! serving loop for the well-formed traffic behind them.
//!
//! **Failure model (DESIGN.md §14):** responses travel on drop-aware
//! [`oneshot`] channels, so both loops observe client hang-ups — the
//! one-shot batcher skips dead requests at dispatch (counted under
//! [`RejectReason::Disconnected`]) and the generation loop cancels
//! their sequences mid-flight. An optional shutdown [`CancelToken`]
//! drains both loops gracefully: admission stops (late arrivals are
//! answered [`RejectReason::Draining`]), in-flight work finishes, and
//! the complete report is returned.
//!
//! **Sharding (DESIGN.md §16):** the generation loop is generic over
//! the [`Stepper`] trait — the driving surface a serving back end
//! exposes. The single [`Engine`] implements it directly
//! ([`serve_generate`]); [`router::Router`] implements it over N
//! crash-isolated engine workers with prefix-affinity routing and
//! deterministic failover ([`serve_generate_sharded`]). One loop, two
//! back ends.

use crate::config::ModelConfig;
use crate::engine::{
    CancelToken, Engine, FinishReason, GenConfig, GenOutput, GenReport, GenRequest,
};
use crate::model::{Params, ROLES};
use crate::obs::{Hist, TraceRecord};
use crate::quant::QuantizedModel;
use crate::runtime::{lit_f32, tensor_f32, Buffer, Runtime, Value};
use crate::tensor::{Tensor, TensorI32};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

pub mod oneshot;
pub mod router;

pub use crate::engine::{RejectCounts, RejectReason};
pub use oneshot::{oneshot_channel, OneshotReceiver, OneshotSender, RecvError};
pub use router::{route_affinity, RouterConfig, RouterReport, WorkerFaultHook};

/// The uniform driving surface of a generation back end. The single
/// [`Engine`] implements it directly; the sharded [`router::Router`]
/// implements it over N crash-isolated workers. The generic serve loop
/// ([`serve_on`]), the bench driver, and the router fault harness all
/// drive these five calls and nothing else, so a back end swap never
/// touches the loop (ROADMAP item 2's suggested refactor).
pub trait Stepper {
    /// Submit a request. `Some` is an immediate admission answer (today
    /// always a rejection); `None` means the request is in flight and
    /// its output will arrive from a later [`Stepper::step`]. Back ends
    /// key sampler streams by `(seed, request id)`, so callers must
    /// keep ids unique among in-flight requests.
    fn submit(&mut self, req: GenRequest) -> Option<GenOutput>;
    /// Advance the back end one scheduling round; returns whatever
    /// finished (possibly empty — a sharded back end's workers run
    /// free, so outputs arrive when they arrive).
    fn step(&mut self) -> Result<Vec<GenOutput>>;
    /// Whether queued or in-flight work remains.
    fn has_work(&self) -> bool;
    /// Stop admitting: fresh submits answer [`RejectReason::Draining`];
    /// everything already accepted runs to completion.
    fn begin_drain(&mut self);
    fn draining(&self) -> bool;
}

impl Stepper for Engine<'_> {
    fn submit(&mut self, req: GenRequest) -> Option<GenOutput> {
        Engine::submit(self, req)
    }

    fn step(&mut self) -> Result<Vec<GenOutput>> {
        Engine::step(self)
    }

    fn has_work(&self) -> bool {
        Engine::has_work(self)
    }

    fn begin_drain(&mut self) {
        Engine::begin_drain(self)
    }

    fn draining(&self) -> bool {
        Engine::draining(self)
    }
}

/// One scoring request: a full token sequence; the response carries the
/// logits of the final position (next-token distribution).
pub struct Request {
    pub tokens: Vec<i32>,
    pub respond: OneshotSender<Response>,
}

/// A successful scoring response.
pub struct Completion {
    pub next_logits: Vec<f32>,
    pub queued_at: Instant,
    pub done_at: Instant,
    /// Worker shard whose loop executed the batch (0 for the default
    /// single-shard [`serve_requests`]).
    pub served_by: usize,
}

/// What a scoring client hears back: logits, or a structured reason.
pub enum Response {
    Done(Completion),
    Rejected(RejectReason),
}

impl Response {
    pub fn completion(&self) -> Option<&Completion> {
        match self {
            Response::Done(c) => Some(c),
            Response::Rejected(_) => None,
        }
    }

    pub fn rejection(&self) -> Option<&RejectReason> {
        match self {
            Response::Done(_) => None,
            Response::Rejected(r) => Some(r),
        }
    }
}

/// Latency/throughput summary of a one-shot serving run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    /// Malformed requests rejected without aborting the loop.
    pub rejected: usize,
    /// The same rejections, broken down by cause.
    pub reject_counts: RejectCounts,
    pub batches: usize,
    pub mean_batch_fill: f32,
    /// Queue-side latency percentiles from the deterministic
    /// fixed-bucket histogram ([`Hist`], DESIGN.md §15) — values are
    /// bucket upper bounds, not interpolated.
    pub p50_ms: f32,
    pub p95_ms: f32,
    pub p99_ms: f32,
    pub throughput_rps: f32,
    /// Worker shard this loop ran as ([`serve_requests_as`]).
    pub worker: usize,
    /// Worker shard that served each dispatched batch, in dispatch
    /// order (`batch_workers.len() == batches`).
    pub batch_workers: Vec<usize>,
}

/// One generation request over the serving queue.
pub struct GenServeRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub stop_id: Option<i32>,
    /// Optional per-request wall-clock budget, measured from engine
    /// submission ([`crate::engine::FinishReason::DeadlineExceeded`]).
    pub deadline: Option<Duration>,
    /// Optional cooperative cancel token. The serving loop registers
    /// one itself when absent (it needs one to convert a client
    /// disconnect into a cancel), so passing `None` costs nothing.
    pub cancel: Option<CancelToken>,
    pub respond: OneshotSender<GenServeResponse>,
}

/// What a generation client hears back.
pub enum GenServeResponse {
    Done {
        /// Generated tokens (prompt excluded).
        tokens: Vec<i32>,
        finish: FinishReason,
        queued_at: Instant,
        done_at: Instant,
    },
    Rejected(RejectReason),
}

/// Summary of a generation serving run: engine throughput + queue-side
/// latency percentiles.
#[derive(Clone, Debug)]
pub struct GenServeReport {
    pub engine: GenReport,
    /// Requests seen on the queue: completed + rejected (quarantined
    /// included) + cancelled + deadline-expired.
    pub requests: usize,
    /// Queue-side latency percentiles ([`Hist`] bucket upper bounds).
    pub p50_ms: f32,
    pub p95_ms: f32,
    pub p99_ms: f32,
    /// The engine's structured trace (empty unless `GenConfig::trace`);
    /// export with [`crate::obs::chrome_trace_json`] / [`crate::obs::text_dump`].
    pub trace: Vec<TraceRecord>,
    /// Ring-buffer overflow: oldest trace events overwritten.
    pub trace_dropped: u64,
}

/// Build the flat argument prefix for `fwd_logits_q`/`decode_step_q`
/// from a quantized model (everything except the trailing tensors).
///
/// Arg order (must mirror python model.fwd_logits_q): tok_emb, pos_emb,
/// per block [ln1, qkv{q,d,z,inv}, o{...}, ln2, up{...}, down{...}],
/// lnf_g, w_head.
pub fn qmodel_literals(params: &Params, qm: &QuantizedModel) -> Result<Vec<Value>> {
    let cfg = &qm.cfg;
    let mut lits = Vec::new();
    lits.push(lit_f32(params.get("tok_emb")?)?);
    lits.push(lit_f32(params.get("pos_emb")?)?);
    for b in 0..cfg.n_layer {
        lits.push(lit_f32(params.get(&format!("blk{b}.ln1_g"))?)?);
        for role in ["qkv", "o"] {
            push_linear(&mut lits, qm, b, role)?;
        }
        lits.push(lit_f32(params.get(&format!("blk{b}.ln2_g"))?)?);
        for role in ["up", "down"] {
            push_linear(&mut lits, qm, b, role)?;
        }
    }
    lits.push(lit_f32(params.get("lnf_g")?)?);
    lits.push(lit_f32(params.get("w_head")?)?);
    Ok(lits)
}

fn push_linear(
    lits: &mut Vec<Value>,
    qm: &QuantizedModel,
    block: usize,
    role: &str,
) -> Result<()> {
    let lq = qm
        .linear(block, role)
        .ok_or_else(|| anyhow::anyhow!("missing linear blk{block}.{role}"))?;
    debug_assert!(ROLES.contains(&role));
    let ints = &lq.ints;
    let ng = ints.n / ints.group;
    // Codes travel as f32 (qmatmul kernel contract; see kernels/qmatmul.py).
    let q_f32: Vec<f32> = ints.q.iter().map(|&c| c as f32).collect();
    lits.push(lit_f32(&Tensor::from_vec(&[ints.n, ints.m], q_f32)?)?);
    lits.push(lit_f32(&Tensor::from_vec(&[ng, ints.m], ints.delta.clone())?)?);
    lits.push(lit_f32(&Tensor::from_vec(&[ng, ints.m], ints.zero.clone())?)?);
    lits.push(lit_f32(&Tensor::from_vec(&[ints.n], lq.inv_s.clone())?)?);
    Ok(())
}

/// Integer microseconds of a duration (saturating), for [`Hist`].
fn duration_us(d: Duration) -> u64 {
    u64::try_from(d.as_micros()).unwrap_or(u64::MAX)
}

/// A histogram percentile in milliseconds (bucket upper bound).
fn hist_ms(h: &Hist, p: u64) -> f32 {
    h.percentile(p) as f32 / 1000.0
}

/// Why a one-shot scoring request cannot join a batch, if anything.
fn validate_oneshot(tokens: &[i32], want_len: usize, vocab: usize) -> Option<RejectReason> {
    if tokens.len() != want_len {
        return Some(RejectReason::WrongLength {
            got: tokens.len(),
            want: want_len,
        });
    }
    for (index, &id) in tokens.iter().enumerate() {
        if id < 0 || id as usize >= vocab {
            return Some(RejectReason::TokenOutOfRange { index, id });
        }
    }
    None
}

/// Run the one-shot serving loop over a closed set of requests
/// (demo/benchmark mode): consumes the receiver until disconnect — or
/// until `shutdown` fires, which stops admission (late arrivals are
/// answered [`RejectReason::Draining`]) while already-accepted requests
/// still execute — and returns the report. Serves as worker shard 0;
/// use [`serve_requests_as`] to label another shard.
pub fn serve_requests(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    qm: &QuantizedModel,
    rx: mpsc::Receiver<Request>,
    max_wait: Duration,
    shutdown: Option<CancelToken>,
) -> Result<ServeReport> {
    serve_requests_as(0, rt, cfg, params, qm, rx, max_wait, shutdown)
}

/// [`serve_requests`] running as a named worker shard: completions
/// carry `served_by = worker` and the report records which shard served
/// each batch, so a sharded one-shot deployment can attribute every
/// batch to the loop that executed it.
#[allow(clippy::too_many_arguments)]
pub fn serve_requests_as(
    worker: usize,
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    qm: &QuantizedModel,
    rx: mpsc::Receiver<Request>,
    max_wait: Duration,
    shutdown: Option<CancelToken>,
) -> Result<ServeReport> {
    // §Perf: the weight bundle is prepared once through the runtime's
    // prepared-state map (dequantize-once packed panels on the native
    // backend, DESIGN.md §11) and reused for the whole serving session;
    // only token batches cross the host boundary per batch.
    let weight_lits = qmodel_literals(params, qm)?;
    let weight_bufs = rt.prepare_qweights(&cfg.name, &weight_lits)?;
    let (b, t, v) = (cfg.batch, cfg.seq, cfg.vocab);
    let mut lat = Hist::new();
    let mut fills: Vec<f32> = Vec::new();
    let mut batches = 0usize;
    let mut batch_workers: Vec<usize> = Vec::new();
    let mut reject_counts = RejectCounts::default();
    let started = Instant::now(); // faq-lint: allow(untracked-clock) — report wall time
    let mut pending: Vec<(Request, Instant)> = Vec::new();
    let mut done = false;

    while !done || !pending.is_empty() {
        if !done && shutdown.as_ref().is_some_and(|s| s.is_cancelled()) {
            // Graceful drain: stop admission. Whatever is already
            // sitting in the intake queue is answered `Draining`
            // (never silently dropped); accepted requests in `pending`
            // still execute below.
            loop {
                match rx.try_recv() {
                    Ok(req) => {
                        reject_counts.note(&RejectReason::Draining);
                        let _ = req.respond.send(Response::Rejected(RejectReason::Draining));
                    }
                    Err(mpsc::TryRecvError::Empty | mpsc::TryRecvError::Disconnected) => break,
                }
            }
            done = true;
        }
        // Fill the batch window, rejecting malformed requests at intake
        // with a structured reason (a wrong length would corrupt the
        // fixed-shape batch; an out-of-range token id would make the
        // embedding gather fail mid-batch and take the whole loop down).
        let deadline = Instant::now() + max_wait; // faq-lint: allow(untracked-clock) — batch window
        while pending.len() < b && !done {
            let timeout = deadline.saturating_duration_since(Instant::now()); // faq-lint: allow(untracked-clock) — batch window
            match rx.recv_timeout(timeout) {
                Ok(req) => match validate_oneshot(&req.tokens, t, v) {
                    Some(reason) => {
                        reject_counts.note(&reason);
                        // Receiver may have hung up; that's their business.
                        let _ = req.respond.send(Response::Rejected(reason));
                    }
                    None => pending.push((req, Instant::now())), // faq-lint: allow(untracked-clock) — queue stamp
                },
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => done = true,
            }
        }
        // Dispatch-time disconnect check: a client that dropped its
        // receiver while queued would waste a batch slot (its logits
        // computed for nobody) — skip it and count the dead request.
        pending.retain(|(req, _)| {
            if req.respond.is_disconnected() {
                reject_counts.note(&RejectReason::Disconnected);
                false
            } else {
                true
            }
        });
        if pending.is_empty() {
            continue;
        }
        let take = pending.len().min(b);
        let group: Vec<(Request, Instant)> = pending.drain(..take).collect();
        fills.push(take as f32 / b as f32);

        // Assemble the fixed-shape batch, padding with the last row.
        let mut data = Vec::with_capacity(b * t);
        for (req, _) in &group {
            debug_assert_eq!(req.tokens.len(), t, "validated at intake");
            data.extend_from_slice(&req.tokens);
        }
        if let Some((last, _)) = group.last() {
            for _ in group.len()..b {
                data.extend_from_slice(&last.tokens);
            }
        }
        let batch = TensorI32::from_vec(&[b, t], data)?;
        let tok_buf = rt.upload_i32(&batch)?;
        let mut args: Vec<&Buffer> = weight_bufs.iter().collect();
        args.push(&tok_buf);
        let outs = rt.exec_b(&cfg.name, "fwd_logits_q", &args)?;
        let first = outs
            .first()
            .ok_or_else(|| anyhow!("fwd_logits_q returned no outputs"))?;
        let logits = tensor_f32(first)?; // [B, T, V]
        let now = Instant::now(); // faq-lint: allow(untracked-clock) — latency stamp
        batches += 1;
        batch_workers.push(worker);

        for (i, (req, queued)) in group.into_iter().enumerate() {
            let base = (i * t + (t - 1)) * v;
            let next = logits
                .data()
                .get(base..base + v)
                .ok_or_else(|| anyhow!("logits row {i} out of range"))?
                .to_vec();
            lat.record(duration_us(now.duration_since(queued)));
            let _ = req.respond.send(Response::Done(Completion {
                next_logits: next,
                queued_at: queued,
                done_at: now,
                served_by: worker,
            }));
        }
    }

    let total = started.elapsed().as_secs_f32();
    let n = usize::try_from(lat.count()).unwrap_or(usize::MAX);
    Ok(ServeReport {
        requests: n,
        rejected: reject_counts.total(),
        reject_counts,
        batches,
        mean_batch_fill: if fills.is_empty() {
            0.0
        } else {
            fills.iter().sum::<f32>() / fills.len() as f32
        },
        p50_ms: hist_ms(&lat, 50),
        p95_ms: hist_ms(&lat, 95),
        p99_ms: hist_ms(&lat, 99),
        throughput_rps: if total > 0.0 { n as f32 / total } else { 0.0 },
        worker,
        batch_workers,
    })
}

/// One admitted generation request waiting for its engine output.
struct InflightEntry {
    respond: OneshotSender<GenServeResponse>,
    queued_at: Instant,
    /// The sequence's cancel token (the client's, or one the loop
    /// registered) — fired when the client's receiver is found dropped.
    cancel: CancelToken,
}

/// Submit one queue request to the back end; rejections answer
/// immediately, admissions wait in `inflight` for their output.
fn admit<S: Stepper>(
    stepper: &mut S,
    inflight: &mut BTreeMap<usize, InflightEntry>,
    next_id: &mut usize,
    req: GenServeRequest,
) {
    let id = *next_id;
    *next_id += 1;
    // Always register a token: the loop needs one to convert a
    // client disconnect into a cancel, whether or not the client
    // kept a handle for itself.
    let cancel = req.cancel.unwrap_or_default();
    let out = stepper.submit(GenRequest {
        id,
        prompt: req.prompt,
        max_new: req.max_new,
        stop_id: req.stop_id,
        deadline: req.deadline,
        cancel: Some(cancel.clone()),
    });
    match out {
        Some(immediate) => {
            let now = Instant::now(); // faq-lint: allow(untracked-clock) — response stamp
            let resp = match immediate.finish {
                FinishReason::Rejected(reason) => GenServeResponse::Rejected(reason),
                // `submit` only answers immediately with rejections
                // today; if that ever changes, a completed (if empty)
                // generation must not take the serving loop down.
                finish => GenServeResponse::Done {
                    tokens: immediate.tokens,
                    finish,
                    queued_at: now,
                    done_at: now,
                },
            };
            let _ = req.respond.send(resp);
        }
        None => {
            inflight.insert(
                id,
                InflightEntry {
                    respond: req.respond,
                    queued_at: Instant::now(), // faq-lint: allow(untracked-clock) — queue stamp
                    cancel,
                },
            );
        }
    }
}

/// The generic generation serve loop: drive any [`Stepper`] through the
/// request queue until the sender disconnects and all in-flight
/// sequences drain — or until `shutdown` fires, which puts the back end
/// into drain mode. Returns the queue-side latency histogram and the
/// number of requests answered (completions and rejections alike).
///
/// Note one asymmetry between back ends: a request the single engine
/// rejects at `submit` answers [`GenServeResponse::Rejected`], while a
/// sharded back end validates on the worker — the same rejection then
/// arrives from [`Stepper::step`] and answers `Done { finish:
/// Rejected(..), .. }` with empty tokens. The cause accounting is
/// identical either way.
fn serve_on<S: Stepper>(
    stepper: &mut S,
    rx: &mpsc::Receiver<GenServeRequest>,
    max_wait: Duration,
    shutdown: Option<CancelToken>,
) -> Result<(Hist, usize)> {
    let mut inflight: BTreeMap<usize, InflightEntry> = BTreeMap::new();
    let mut lat = Hist::new();
    let mut next_id = 0usize;
    let mut answered = 0usize;
    let mut done = false;

    loop {
        if !stepper.draining() && shutdown.as_ref().is_some_and(|s| s.is_cancelled()) {
            // Graceful drain: the back end rejects fresh submits with
            // `Draining` (clients get answered, not ignored) while
            // everything already admitted runs to completion.
            stepper.begin_drain();
        }
        // Drain whatever is immediately available (never blocks).
        loop {
            match rx.try_recv() {
                Ok(r) => {
                    admit(stepper, &mut inflight, &mut next_id, r);
                    answered += 1;
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    done = true;
                    break;
                }
            }
        }
        // Mid-flight disconnect sweep: a client that dropped its
        // receiver gets its sequence cancelled (the back end observes
        // the token at its next lifecycle sweep) instead of burning
        // decode steps on tokens nobody will read.
        for entry in inflight.values() {
            if !entry.cancel.is_cancelled() && entry.respond.is_disconnected() {
                entry.cancel.cancel();
            }
        }
        if !stepper.has_work() {
            if done || stepper.draining() {
                break;
            }
            // Idle: wait for the next request (or the disconnect).
            match rx.recv_timeout(max_wait) {
                Ok(r) => {
                    admit(stepper, &mut inflight, &mut next_id, r);
                    answered += 1;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => done = true,
            }
            continue;
        }
        for out in stepper.step()? {
            let now = Instant::now(); // faq-lint: allow(untracked-clock) — response stamp
            if let Some(entry) = inflight.remove(&out.id) {
                lat.record(duration_us(now.duration_since(entry.queued_at)));
                let _ = entry.respond.send(GenServeResponse::Done {
                    tokens: out.tokens,
                    finish: out.finish,
                    queued_at: entry.queued_at,
                    done_at: now,
                });
            }
        }
    }
    // `answered` counted admissions; in-flight entries whose clients
    // vanished still got a terminal output above, so every admission
    // was answered (or its client hung up — same count either way).
    Ok((lat, answered))
}

/// Run the generation serving loop on a single [`Engine`] until the
/// sender disconnects and all in-flight sequences drain — or until
/// `shutdown` fires, which puts the engine into drain mode: fresh
/// requests are answered [`RejectReason::Draining`] while in-flight
/// sequences run to completion, and the full report is still returned.
///
/// Requests are admitted into the engine's slot queue as they arrive —
/// between decode steps, so a request that shows up while long sequences
/// are mid-generation starts as soon as any slot frees (continuous
/// batching). Invalid requests are answered immediately with their
/// [`RejectReason`] and counted per cause in `report.engine`. A client
/// that drops its response receiver mid-generation has its sequence
/// cancelled ([`FinishReason::Cancelled`]) instead of decoding tokens
/// nobody will read; abnormal completions (cancel, deadline expiry,
/// quarantine) still answer with `Done { finish, .. }` carrying the
/// partial tokens.
#[allow(clippy::too_many_arguments)]
pub fn serve_generate(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    qm: &QuantizedModel,
    gen: GenConfig,
    rx: mpsc::Receiver<GenServeRequest>,
    max_wait: Duration,
    shutdown: Option<CancelToken>,
) -> Result<GenServeReport> {
    let mut engine = Engine::new(rt, cfg, params, qm, gen)?;
    let (lat, _answered) = serve_on(&mut engine, &rx, max_wait, shutdown)?;

    let engine_report = engine.report();
    let trace = engine.trace().snapshot();
    let trace_dropped = engine.trace().dropped();
    Ok(GenServeReport {
        requests: engine_report.sequences
            + engine_report.rejected
            + engine_report.cancelled
            + engine_report.deadline_exceeded,
        engine: engine_report,
        p50_ms: hist_ms(&lat, 50),
        p95_ms: hist_ms(&lat, 95),
        p99_ms: hist_ms(&lat, 99),
        trace,
        trace_dropped,
    })
}

/// Summary of a sharded generation serving run: fleet-level router
/// report (crashes, failovers, per-worker occupancy, merged engine
/// accounting) plus the queue-side latency percentiles.
#[derive(Clone, Debug)]
pub struct ShardedServeReport {
    pub router: RouterReport,
    /// Requests answered on the queue (completions + rejections).
    pub requests: usize,
    /// Queue-side latency percentiles ([`Hist`] bucket upper bounds).
    pub p50_ms: f32,
    pub p95_ms: f32,
    pub p99_ms: f32,
}

/// [`serve_generate`] over the crash-isolated sharded router: the same
/// generic loop drives a [`router::Router`] owning `rcfg.workers`
/// engine workers with prefix-affinity routing; a worker panic or
/// stall is absorbed by quarantine + deterministic re-execution
/// instead of taking the serving loop down (DESIGN.md §16).
#[allow(clippy::too_many_arguments)]
pub fn serve_generate_sharded(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    qm: &QuantizedModel,
    gen: GenConfig,
    rcfg: RouterConfig,
    rx: mpsc::Receiver<GenServeRequest>,
    max_wait: Duration,
    shutdown: Option<CancelToken>,
) -> Result<ShardedServeReport> {
    let ((lat, answered), report) = router::run_router(rt, cfg, params, qm, gen, rcfg, |r| {
        serve_on(r, &rx, max_wait, shutdown)
    })?;
    Ok(ShardedServeReport {
        router: report,
        requests: answered,
        p50_ms: hist_ms(&lat, 50),
        p95_ms: hist_ms(&lat, 95),
        p99_ms: hist_ms(&lat, 99),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_fields_sane() {
        let mut rc = RejectCounts::default();
        rc.note(&RejectReason::WrongLength { got: 2, want: 4 });
        let r = ServeReport {
            requests: 10,
            rejected: rc.total(),
            reject_counts: rc,
            batches: 3,
            mean_batch_fill: 0.83,
            p50_ms: 5.0,
            p95_ms: 9.0,
            p99_ms: 10.0,
            throughput_rps: 100.0,
            worker: 2,
            batch_workers: vec![2, 2, 2],
        };
        assert!(r.p95_ms >= r.p50_ms);
        assert!(r.p99_ms >= r.p95_ms);
        assert!(r.mean_batch_fill <= 1.0);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.reject_counts.wrong_length, 1);
        assert_eq!(r.batch_workers.len(), r.batches);
        assert!(r.batch_workers.iter().all(|&w| w == r.worker));
    }

    #[test]
    fn hist_ms_converts_bucket_bounds() {
        let mut h = Hist::new();
        h.record(duration_us(Duration::from_millis(3)));
        // 3 ms lands in the (2ms, 5ms] bucket: upper bound 5 ms.
        assert_eq!(hist_ms(&h, 50), 5.0);
        assert_eq!(hist_ms(&Hist::new(), 95), 0.0);
    }

    #[test]
    fn oneshot_validation_reasons() {
        assert!(validate_oneshot(&[1, 2, 3], 3, 8).is_none());
        assert_eq!(
            validate_oneshot(&[1, 2], 3, 8),
            Some(RejectReason::WrongLength { got: 2, want: 3 })
        );
        assert_eq!(
            validate_oneshot(&[1, 9, 3], 3, 8),
            Some(RejectReason::TokenOutOfRange { index: 1, id: 9 })
        );
        assert_eq!(
            validate_oneshot(&[1, -1, 3], 3, 8),
            Some(RejectReason::TokenOutOfRange { index: 1, id: -1 })
        );
    }

    #[test]
    fn response_accessors() {
        let r = Response::Rejected(RejectReason::EmptyPrompt);
        assert!(r.completion().is_none());
        assert_eq!(r.rejection().unwrap().cause(), "empty_prompt");
    }
}
