//! Drop-aware one-shot response channels for the serving loops.
//!
//! `std::sync::mpsc` cannot answer "is the other side still there?"
//! without actually sending, but the fault-tolerant serve paths need
//! exactly that: the one-shot batcher skips requests whose client hung
//! up before dispatch (counted under
//! [`crate::engine::RejectReason::Disconnected`]), and the generation
//! loop converts a mid-flight disconnect into a cancel instead of
//! decoding tokens nobody will read. This channel keeps both sides'
//! liveness flags under the same mutex as the value, so a
//! `send`/`is_disconnected` check can never race a hang-up: whichever
//! happens first is the one the other observes.
//!
//! Poisoned locks are recovered with `into_inner()` — the state is a
//! plain value + two booleans, valid after any panic mid-update, and a
//! response channel must keep working even if some client thread died
//! (the PR 6 pool-recovery argument, applied to serving).

use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

struct State<T> {
    value: Option<T>,
    sender_alive: bool,
    receiver_alive: bool,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
}

impl<T> Shared<T> {
    fn lock(&self) -> MutexGuard<'_, State<T>> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Sending half: consumed by [`OneshotSender::send`]; dropping it
/// unsent wakes the receiver with [`RecvError::Disconnected`].
pub struct OneshotSender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half: dropping it makes the sender observe
/// [`OneshotSender::is_disconnected`] and future sends fail.
pub struct OneshotReceiver<T> {
    shared: Arc<Shared<T>>,
}

/// Why a receive returned no value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvError {
    /// The sender was dropped without sending.
    Disconnected,
    /// No value arrived within the timeout (the sender may still send).
    Timeout,
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Disconnected => write!(f, "sender dropped without responding"),
            RecvError::Timeout => write!(f, "no response within the timeout"),
        }
    }
}

impl std::error::Error for RecvError {}

/// Create a connected one-shot channel pair.
pub fn oneshot_channel<T>() -> (OneshotSender<T>, OneshotReceiver<T>) {
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            value: None,
            sender_alive: true,
            receiver_alive: true,
        }),
        cv: Condvar::new(),
    });
    (
        OneshotSender {
            shared: Arc::clone(&shared),
        },
        OneshotReceiver { shared },
    )
}

impl<T> OneshotSender<T> {
    /// Deliver the value. Returns it back when the receiver already
    /// hung up (so the caller can account for the dead client).
    pub fn send(self, value: T) -> Result<(), T> {
        let mut st = self.shared.lock();
        if !st.receiver_alive {
            return Err(value);
        }
        st.value = Some(value);
        drop(st);
        self.shared.cv.notify_all();
        Ok(())
    }

    /// Whether the receiving half has been dropped. Checked under the
    /// same lock a `send` takes, so a `false` here means a send started
    /// right now would be delivered.
    pub fn is_disconnected(&self) -> bool {
        !self.shared.lock().receiver_alive
    }
}

impl<T> Drop for OneshotSender<T> {
    fn drop(&mut self) {
        // After a successful `send` the value is already in the state;
        // clearing `sender_alive` then changes nothing the receiver can
        // observe (it always takes the value first).
        self.shared.lock().sender_alive = false;
        self.shared.cv.notify_all();
    }
}

impl<T> OneshotReceiver<T> {
    /// Block until the value arrives or the sender is dropped.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.shared.lock();
        loop {
            if let Some(v) = st.value.take() {
                return Ok(v);
            }
            if !st.sender_alive {
                return Err(RecvError::Disconnected);
            }
            st = self
                .shared
                .cv
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Block until the value arrives, the sender is dropped, or
    /// `timeout` elapses.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvError> {
        // faq-lint: allow(untracked-clock) — client-side wait primitive:
        // bounds a condvar wait against real time; never reaches the
        // engine's scheduling decisions.
        let deadline = Instant::now().checked_add(timeout);
        let mut st = self.shared.lock();
        loop {
            if let Some(v) = st.value.take() {
                return Ok(v);
            }
            if !st.sender_alive {
                return Err(RecvError::Disconnected);
            }
            let left = deadline
                .map(|d| d.saturating_duration_since(Instant::now())) // faq-lint: allow(untracked-clock) — client-side wait
                .unwrap_or(Duration::MAX);
            if left.is_zero() {
                return Err(RecvError::Timeout);
            }
            let (guard, _timed_out) = self
                .shared
                .cv
                .wait_timeout(st, left)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
        }
    }
}

impl<T> Drop for OneshotReceiver<T> {
    fn drop(&mut self) {
        self.shared.lock().receiver_alive = false;
        self.shared.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_then_recv_delivers() {
        let (tx, rx) = oneshot_channel();
        assert!(!tx.is_disconnected());
        assert!(tx.send(42).is_ok());
        assert_eq!(rx.recv(), Ok(42));
        // A second recv sees the (now value-less, sender-dropped) state.
        assert_eq!(rx.recv(), Err(RecvError::Disconnected));
    }

    #[test]
    fn dropped_receiver_is_observed_and_fails_send() {
        let (tx, rx) = oneshot_channel();
        drop(rx);
        assert!(tx.is_disconnected());
        assert_eq!(tx.send(7), Err(7));
    }

    #[test]
    fn dropped_sender_wakes_recv_with_disconnected() {
        let (tx, rx) = oneshot_channel::<i32>();
        drop(tx);
        assert_eq!(rx.recv(), Err(RecvError::Disconnected));
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(5)),
            Err(RecvError::Disconnected)
        );
    }

    #[test]
    fn recv_timeout_times_out_while_sender_lives() {
        let (tx, rx) = oneshot_channel::<i32>();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(5)),
            Err(RecvError::Timeout)
        );
        // Still connected: the send after a timeout is delivered.
        assert!(tx.send(9).is_ok());
        assert_eq!(rx.recv_timeout(Duration::from_millis(50)), Ok(9));
    }

    #[test]
    fn cross_thread_roundtrip() {
        let (tx, rx) = oneshot_channel();
        let h = std::thread::spawn(move || {
            let _ = tx.send(1234);
        });
        assert_eq!(rx.recv(), Ok(1234));
        assert!(h.join().is_ok());
    }

    #[test]
    fn send_delivered_before_sender_drop_is_not_lost() {
        let (tx, rx) = oneshot_channel();
        assert!(tx.send(5).is_ok());
        // Sender is gone (consumed by send) but the value was stored
        // first; the receiver must get it, not Disconnected.
        assert_eq!(rx.recv_timeout(Duration::from_millis(50)), Ok(5));
    }
}
