//! Progress/metrics reporting for long pipeline runs.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// Stamped, optionally-silenced progress logger.
pub struct Progress {
    start: Instant,
    quiet: AtomicBool,
}

impl Default for Progress {
    fn default() -> Self {
        Self {
            start: Instant::now(),
            quiet: AtomicBool::new(std::env::var("FAQUANT_QUIET").is_ok()),
        }
    }
}

impl Progress {
    pub fn quiet() -> Self {
        let p = Self::default();
        p.quiet.store(true, Ordering::Relaxed);
        p
    }

    pub fn log(&self, msg: &str) {
        if !self.quiet.load(Ordering::Relaxed) {
            eprintln!("[{:8.2}s] {msg}", self.start.elapsed().as_secs_f32());
        }
    }

    pub fn elapsed_secs(&self) -> f32 {
        self.start.elapsed().as_secs_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotone() {
        let p = Progress::quiet();
        let a = p.elapsed_secs();
        let b = p.elapsed_secs();
        assert!(b >= a);
        p.log("silenced");
    }
}
