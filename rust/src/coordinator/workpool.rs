//! Bounded worker pool over std::thread (no tokio offline).
//!
//! Used for coarse CPU-side job fan-out with per-call worker threads
//! (task-suite construction, packing). The *compute* hot path — matmul
//! kernels, attention, Phase B — uses the persistent deterministic pool
//! in [`crate::tensor::par`] instead; see runtime/mod.rs.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `jobs` closures on `workers` threads; results return in job order.
pub fn run_jobs<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let workers = workers.max(1).min(jobs.len().max(1));
    let queue: Arc<Mutex<Vec<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(thread::spawn(move || loop {
            let job = queue.lock().unwrap().pop();
            match job {
                Some((idx, f)) => {
                    // A send failure means the receiver is gone; stop.
                    if tx.send((idx, f())).is_err() {
                        break;
                    }
                }
                None => break,
            }
        }));
    }
    drop(tx);
    let mut results: Vec<Option<T>> = Vec::new();
    for (idx, val) in rx {
        if results.len() <= idx {
            results.resize_with(idx + 1, || None);
        }
        results[idx] = Some(val);
    }
    for h in handles {
        h.join().expect("worker panicked");
    }
    results.into_iter().map(|r| r.expect("job lost")).collect()
}

/// Simple reusable pool facade (keeps a worker count).
pub struct WorkPool {
    pub workers: usize,
}

impl WorkPool {
    pub fn new(workers: usize) -> Self {
        Self {
            workers: workers.max(1),
        }
    }

    pub fn auto() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(n)
    }

    pub fn map<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        run_jobs(self.workers, jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_job_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..20)
            .map(|i| {
                Box::new(move || {
                    // Vary work so completion order differs from job order.
                    let mut acc = 0usize;
                    for k in 0..((20 - i) * 1000) {
                        acc = acc.wrapping_add(k);
                    }
                    std::hint::black_box(acc);
                    i * 2
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = run_jobs(4, jobs);
        assert_eq!(out, (0..20).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        let out: Vec<usize> = run_jobs(1, vec![|| 7usize]);
        assert_eq!(out, vec![7]);
        let empty: Vec<usize> = run_jobs(4, Vec::<fn() -> usize>::new());
        assert!(empty.is_empty());
    }

    #[test]
    fn pool_facade() {
        let pool = WorkPool::new(2);
        let out = pool.map((0..5).map(|i| move || i + 1).collect::<Vec<_>>());
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
        assert!(WorkPool::auto().workers >= 1);
    }
}
