//! The end-to-end PTQ pipeline (S9): checkpoint -> calibration capture ->
//! per-linear scale search -> quantize + pack -> evaluate -> report.
//!
//! This is the L3 "coordination" layer: it owns artifact scheduling (the
//! FAQ preview's future-layer dependency is resolved by the two-phase
//! capture-then-search schedule, DESIGN.md §2), progress reporting, and
//! run metrics. The compute itself always happens inside HLO artifacts.

mod progress;
mod workpool;

pub use progress::Progress;
pub use workpool::WorkPool;

use crate::calib::{capture, CalibStats};
use crate::config::{Method, QuantConfig, RunConfig};
use crate::corpus::Batcher;
use crate::eval::{calib_ids, canonical_tokenizer, eval_all, EvalRow};
use crate::model::Params;
use crate::quant::{quantize_model, QuantizedModel};
use crate::runtime::Runtime;
use crate::train::ensure_checkpoint;
use anyhow::Result;
use std::time::Instant;

/// Everything a pipeline run produces.
pub struct PipelineOutcome {
    pub params: Params,
    pub calib: Option<CalibStats>,
    pub quantized: Option<QuantizedModel>,
    pub eval: Option<EvalRow>,
    pub timings: Timings,
}

#[derive(Clone, Debug, Default)]
pub struct Timings {
    pub train_secs: f32,
    pub capture_secs: f32,
    pub search_secs: f32,
    pub eval_secs: f32,
}

/// The pipeline driver. Construct once per run configuration; stages can
/// be invoked individually (benches) or end-to-end via [`Pipeline::run`].
pub struct Pipeline<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: RunConfig,
    pub progress: Progress,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: RunConfig) -> Self {
        Self {
            rt,
            cfg,
            progress: Progress::default(),
        }
    }

    /// Stage 1: trained checkpoint (cached under runs/).
    pub fn checkpoint(&self) -> Result<(Params, f32)> {
        let t0 = Instant::now();
        let out = ensure_checkpoint(
            self.rt,
            &self.cfg.model,
            &self.cfg.runs_dir,
            self.cfg.train_steps,
            17,
        )?;
        if out.cached {
            self.progress.log(&format!(
                "checkpoint: cached ({} params)",
                out.params.param_count()
            ));
        } else {
            let first = out.curve.first().map(|c| c.1).unwrap_or(f32::NAN);
            let last = out.curve.last().map(|c| c.1).unwrap_or(f32::NAN);
            self.progress.log(&format!(
                "checkpoint: trained {} steps, loss {first:.3} -> {last:.3}",
                self.cfg.train_steps
            ));
        }
        Ok((out.params, t0.elapsed().as_secs_f32()))
    }

    /// Stage 2 (phase A): calibration capture over N sequences.
    pub fn calibrate(&self, params: &Params) -> Result<(CalibStats, f32)> {
        let t0 = Instant::now();
        let tok = canonical_tokenizer(&self.cfg.model);
        let ids = calib_ids(&self.cfg.model, &tok, self.cfg.calib_seqs, self.cfg.calib_seed);
        let batcher = Batcher::new(self.cfg.model.batch, self.cfg.model.seq);
        let mut batches = batcher.eval_batches(&ids)?;
        batches.truncate(self.cfg.calib_seqs.div_ceil(self.cfg.model.batch));
        let stats = capture(self.rt, &self.cfg.model, params, &batches, self.cfg.calib_seed)?;
        self.progress.log(&format!(
            "calibration: {} batches captured (N={} seqs)",
            stats.n_batches, self.cfg.calib_seqs
        ));
        Ok((stats, t0.elapsed().as_secs_f32()))
    }

    /// Stage 3 (phase B): per-linear search + quantize + pack.
    pub fn quantize(
        &self,
        params: &Params,
        calib: Option<&CalibStats>,
    ) -> Result<(QuantizedModel, f32)> {
        let t0 = Instant::now();
        let qm = quantize_model(self.rt, &self.cfg.quant, params, calib)?;
        let (packed, fp) = qm.compression();
        self.progress.log(&format!(
            "quantize[{} b{}]: mean recon loss {:.5e}, packed {packed} B vs fp {fp} B ({:.2}x)",
            self.cfg.quant.method.name(),
            self.cfg.quant.bits,
            qm.mean_loss(),
            fp as f32 / packed as f32
        ));
        Ok((qm, t0.elapsed().as_secs_f32()))
    }

    /// Stage 4: full Table-1 metric row for a parameter set.
    pub fn evaluate(&self, params: &Params) -> Result<(EvalRow, f32)> {
        let t0 = Instant::now();
        let tok = canonical_tokenizer(&self.cfg.model);
        let row = eval_all(
            self.rt,
            &self.cfg.model,
            params,
            &tok,
            self.cfg.eval_seqs,
            self.cfg.task_items,
        )?;
        self.progress.log(&format!(
            "eval: ppl wiki {:.4} / c4 {:.4}",
            row.ppl_wiki, row.ppl_c4
        ));
        Ok((row, t0.elapsed().as_secs_f32()))
    }

    /// End-to-end: checkpoint -> (calibrate) -> (quantize) -> evaluate.
    ///
    /// `Method::Fp` skips calibration/quantization and evaluates the
    /// full-precision checkpoint (Table 1's FP16 row).
    pub fn run(&self) -> Result<PipelineOutcome> {
        let mut timings = Timings::default();
        let (params, t) = self.checkpoint()?;
        timings.train_secs = t;

        let method = self.cfg.quant.method;
        let needs_calib = matches!(method, Method::Awq | Method::Faq)
            || (method == Method::Rtn && self.cfg.quant.full_search);
        let calib = if needs_calib || method == Method::Rtn {
            // RTN also captures so its recon loss is measurable.
            let (c, t) = self.calibrate(&params)?;
            timings.capture_secs = t;
            Some(c)
        } else {
            None
        };

        let (quantized, eval_params) = if method == Method::Fp {
            (None, params.clone())
        } else {
            let (qm, t) = self.quantize(&params, calib.as_ref())?;
            timings.search_secs = t;
            let p = qm.fq_params.clone();
            (Some(qm), p)
        };

        let (eval, t) = self.evaluate(&eval_params)?;
        timings.eval_secs = t;

        Ok(PipelineOutcome {
            params,
            calib,
            quantized,
            eval: Some(eval),
            timings,
        })
    }
}

/// Convenience: quantize-only run for a given method, reusing an existing
/// checkpoint + calibration (the benches sweep methods this way).
pub fn quantize_with_method(
    rt: &Runtime,
    base: &RunConfig,
    method: Method,
    params: &Params,
    calib: &CalibStats,
) -> Result<QuantizedModel> {
    let mut qcfg = QuantConfig {
        method,
        ..base.quant.clone()
    };
    qcfg.method = method;
    quantize_model(rt, &qcfg, params, Some(calib))
}
