//! Mini-criterion (S15): timing harness + table reporter.
//!
//! criterion is not in the offline registry, so `cargo bench` targets use
//! this: warmup, fixed-iteration timing, mean/std/p50/p95, and a markdown
//! table printer used by every paper-table bench to emit rows in the same
//! format the paper reports.

#[cfg(feature = "alloc-count")]
pub mod alloc;

use crate::tensor::{mean_std, percentile};
use std::time::Instant;

/// Timing summary of one benchmark case (all times in seconds).
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub mean: f32,
    pub std: f32,
    pub p50: f32,
    pub p95: f32,
    pub min: f32,
}

impl Sample {
    pub fn throughput(&self, units_per_iter: f32) -> f32 {
        if self.mean <= 0.0 {
            return 0.0;
        }
        units_per_iter / self.mean
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f32());
    }
    let (mean, std) = mean_std(&times);
    Sample {
        name: name.to_string(),
        iters: iters.max(1),
        mean,
        std,
        p50: percentile(&times, 50.0),
        p95: percentile(&times, 95.0),
        min: times.iter().copied().fold(f32::INFINITY, f32::min),
    }
}

/// Render a bench sample as a one-line report.
pub fn report(s: &Sample) -> String {
    format!(
        "{:<40} {:>10.4}s ±{:>8.4} (p50 {:.4}s, p95 {:.4}s, n={})",
        s.name, s.mean, s.std, s.p50, s.p95, s.iters
    )
}

/// Markdown table builder for paper-style result grids.
#[derive(Default, Debug)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Format as GitHub markdown (printed by benches, pasted into
    /// EXPERIMENTS.md).
    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |", cols.join(" | "))
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n### {}\n\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a float like the paper (4 decimal places).
pub fn f4(x: f32) -> String {
    format!("{x:.4}")
}

// ---------------------------------------------------------------- JSON
// serde is not in the offline registry, so the machine-readable bench
// output (BENCH_perf.json, tracked across PRs) uses this minimal writer.

/// JSON-escape a string (quotes, backslashes, control characters).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A float as a JSON value (`null` for non-finite — JSON has no NaN/Inf).
fn json_f32(x: f32) -> String {
    if x.is_finite() {
        format!("{x:.6e}")
    } else {
        "null".to_string()
    }
}

impl Sample {
    /// One stage as a JSON object (all times in seconds).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"name\": \"{}\", \"iters\": {}, \"mean_s\": {}, \"std_s\": {}, \
             \"p50_s\": {}, \"p95_s\": {}, \"min_s\": {}}}",
            json_escape(&self.name),
            self.iters,
            json_f32(self.mean),
            json_f32(self.std),
            json_f32(self.p50),
            json_f32(self.p95),
            json_f32(self.min),
        )
    }
}

/// Machine-readable perf-bench report: per-stage timings plus the
/// threading headline (end-to-end quantize at 1 vs N threads). Written
/// by `benches/perf_hotpath.rs` as `BENCH_perf.json` and committed, so
/// the perf trajectory is tracked across PRs.
#[derive(Clone, Debug)]
pub struct PerfReport {
    /// Model preset the bench ran (e.g. "nano"; "pico" in CI smoke).
    pub preset: String,
    /// Effective worker count for the N-thread runs (FAQUANT_THREADS).
    pub threads: usize,
    /// Hardware parallelism of the runner (context for the speedup).
    pub cores: usize,
    pub stages: Vec<Sample>,
    /// End-to-end Phase-B quantize wall seconds, 1 thread.
    pub quantize_secs_1t: f32,
    /// End-to-end Phase-B quantize wall seconds, `threads` threads.
    pub quantize_secs_nt: f32,
    /// quantize_secs_1t / quantize_secs_nt.
    pub speedup: f32,
    /// Fraction of steady-state wall time spent outside backend
    /// execution (DESIGN §9; measured single-threaded so the sum of
    /// per-entry exec times is comparable to wall time).
    pub coordinator_overhead: f32,
    /// KV-cached generation engine: prompt tokens per second (prefill,
    /// unprepared seed path — the baseline).
    pub prefill_tps: f32,
    /// KV-cached generation engine: generated tokens per second (decode,
    /// unprepared seed path — the baseline).
    pub decode_tps: f32,
    /// One-time cost of preparing the quantized weight bundle
    /// (dequantize-once panel pack, DESIGN.md §11), seconds.
    pub prepare_secs: f32,
    /// Decode tokens per second over the prepared weight bundle (the
    /// serving-throughput headline from this PR on).
    pub decode_prepared_tps: f32,
    /// Shared-prefix generate stage: fraction of prompt tokens the paged
    /// engine's radix prefix cache skipped (prefill work saved, 0..1).
    pub prefix_hit_prefill_savings: f32,
    /// Peak KV bytes actually in use by the paged engine on the
    /// many-short-sequences stage (peak blocks x block bytes).
    pub paged_peak_kv_bytes: f32,
    /// The dense engine's slab for the same stage: `slots x T_max` rows,
    /// resident for the whole run regardless of sequence lengths.
    pub dense_kv_slab_bytes: f32,
    /// Time-to-first-token percentiles from the engine's deterministic
    /// histogram (Hist bucket upper bounds, converted to seconds).
    pub ttft_p50: f32,
    pub ttft_p95: f32,
    pub ttft_p99: f32,
    /// Per-decode-token latency percentiles (seconds).
    pub per_token_p50: f32,
    pub per_token_p95: f32,
    pub per_token_p99: f32,
    /// Queue-wait (submit -> admission) p95, seconds.
    pub queue_wait_p95: f32,
    /// Worker count of the sharded-router stage (`serve bench` and the
    /// perf bench's router stage; 0 when the stage didn't run).
    pub router_workers: usize,
    /// TTFT percentiles of the same generation workload fanned out over
    /// the crash-isolated sharded router (fleet-merged deterministic
    /// histograms from the router report), seconds.
    pub router_ttft_p50: f32,
    pub router_ttft_p95: f32,
    pub router_ttft_p99: f32,
    /// Per-decode-token latency percentiles over the sharded router,
    /// seconds.
    pub router_per_token_p50: f32,
    pub router_per_token_p95: f32,
    pub router_per_token_p99: f32,
    /// Decode tokens per second on the integer W4A8 path (int8
    /// activations x stored int4 codes, DESIGN.md §17) over the same
    /// prepared bundle as `decode_prepared_tps` — the two rows are
    /// directly comparable. 0 when the stage didn't run (codes wider
    /// than int4).
    pub decode_int_tps: f32,
    /// Which int kernel lane ran ("scalar", "avx2", "neon"; "" when the
    /// int stage didn't run).
    pub int_kernel: String,
    /// Weight bytes one full block-linear pass reads per token on the
    /// f32 prepared path (dequantized panels; excludes the head, which
    /// both paths share — see `head_bytes`).
    pub weight_bytes_f32: f32,
    /// Same pass on the int path: packed int4 codes + dequant params.
    /// The f32/int ratio is the memory-traffic headroom the int kernel
    /// has on bandwidth-bound decode.
    pub weight_bytes_int: f32,
}

impl PerfReport {
    pub fn to_json(&self) -> String {
        let stages: Vec<String> = self.stages.iter().map(|s| s.to_json()).collect();
        format!(
            "{{\n  \"schema\": \"faquant-perf-v1\",\n  \"preset\": \"{}\",\n  \
             \"threads\": {},\n  \"cores\": {},\n  \"stages\": [\n    {}\n  ],\n  \
             \"quantize_secs_1t\": {},\n  \"quantize_secs_nt\": {},\n  \
             \"speedup_vs_1t\": {},\n  \"coordinator_overhead\": {},\n  \
             \"prefill_tokens_per_sec\": {},\n  \"decode_tokens_per_sec\": {},\n  \
             \"prepare_secs\": {},\n  \"decode_prepared_tokens_per_sec\": {},\n  \
             \"prefix_hit_prefill_savings\": {},\n  \"paged_peak_kv_bytes\": {},\n  \
             \"dense_kv_slab_bytes\": {},\n  \
             \"ttft_p50\": {},\n  \"ttft_p95\": {},\n  \"ttft_p99\": {},\n  \
             \"per_token_p50\": {},\n  \"per_token_p95\": {},\n  \"per_token_p99\": {},\n  \
             \"queue_wait_p95\": {},\n  \"router_workers\": {},\n  \
             \"router_ttft_p50\": {},\n  \"router_ttft_p95\": {},\n  \
             \"router_ttft_p99\": {},\n  \"router_per_token_p50\": {},\n  \
             \"router_per_token_p95\": {},\n  \"router_per_token_p99\": {},\n  \
             \"decode_int_tokens_per_sec\": {},\n  \"int_kernel\": \"{}\",\n  \
             \"weight_read_bytes_f32\": {},\n  \"weight_read_bytes_int\": {}\n}}\n",
            json_escape(&self.preset),
            self.threads,
            self.cores,
            stages.join(",\n    "),
            json_f32(self.quantize_secs_1t),
            json_f32(self.quantize_secs_nt),
            json_f32(self.speedup),
            json_f32(self.coordinator_overhead),
            json_f32(self.prefill_tps),
            json_f32(self.decode_tps),
            json_f32(self.prepare_secs),
            json_f32(self.decode_prepared_tps),
            json_f32(self.prefix_hit_prefill_savings),
            json_f32(self.paged_peak_kv_bytes),
            json_f32(self.dense_kv_slab_bytes),
            json_f32(self.ttft_p50),
            json_f32(self.ttft_p95),
            json_f32(self.ttft_p99),
            json_f32(self.per_token_p50),
            json_f32(self.per_token_p95),
            json_f32(self.per_token_p99),
            json_f32(self.queue_wait_p95),
            self.router_workers,
            json_f32(self.router_ttft_p50),
            json_f32(self.router_ttft_p95),
            json_f32(self.router_ttft_p99),
            json_f32(self.router_per_token_p50),
            json_f32(self.router_per_token_p95),
            json_f32(self.router_per_token_p99),
            json_f32(self.decode_int_tps),
            json_escape(&self.int_kernel),
            json_f32(self.weight_bytes_f32),
            json_f32(self.weight_bytes_int),
        )
    }

    /// Synthesize a per-token stage Sample from a (tokens, seconds)
    /// aggregate, so tokens/sec work appears in the `stages` list next to
    /// the timed stages (`mean_s` = seconds per token).
    pub fn per_token_stage(name: &str, tokens: usize, secs: f32) -> Sample {
        let per = if tokens > 0 {
            secs / tokens as f32
        } else {
            0.0
        };
        Sample {
            name: name.to_string(),
            iters: tokens.max(1),
            mean: per,
            std: 0.0,
            p50: per,
            p95: per,
            min: per,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut count = 0;
        let s = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
        assert!(s.mean >= 0.0 && s.min <= s.p95);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Demo", &["model", "ppl"]);
        t.row(vec!["pico".into(), f4(12.3456)]);
        let md = t.markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| pico"));
        assert!(md.contains("12.3456"));
        assert_eq!(md.lines().filter(|l| l.starts_with('|')).count(), 3);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_escaping_and_nonfinite() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_f32(f32::NAN), "null");
        assert_eq!(json_f32(f32::INFINITY), "null");
        assert!(json_f32(0.5).starts_with("5.0"));
    }

    #[test]
    fn perf_report_json_shape() {
        let s = Sample {
            name: "stage \"x\"".into(),
            iters: 3,
            mean: 0.25,
            std: 0.0,
            p50: 0.25,
            p95: 0.3,
            min: 0.2,
        };
        let r = PerfReport {
            preset: "pico".into(),
            threads: 2,
            cores: 2,
            stages: vec![s.clone(), s],
            quantize_secs_1t: 1.0,
            quantize_secs_nt: 0.5,
            speedup: 2.0,
            coordinator_overhead: 0.01,
            prefill_tps: 1000.0,
            decode_tps: 250.0,
            prepare_secs: 0.02,
            decode_prepared_tps: 900.0,
            prefix_hit_prefill_savings: 0.4,
            paged_peak_kv_bytes: 65536.0,
            dense_kv_slab_bytes: 262144.0,
            ttft_p50: 0.002,
            ttft_p95: 0.005,
            ttft_p99: 0.01,
            per_token_p50: 0.001,
            per_token_p95: 0.002,
            per_token_p99: 0.002,
            queue_wait_p95: 0.0005,
            router_workers: 2,
            router_ttft_p50: 0.003,
            router_ttft_p95: 0.006,
            router_ttft_p99: 0.012,
            router_per_token_p50: 0.001,
            router_per_token_p95: 0.002,
            router_per_token_p99: 0.003,
            decode_int_tps: 1100.0,
            int_kernel: "avx2".into(),
            weight_bytes_f32: 4096.0,
            weight_bytes_int: 640.0,
        };
        let j = r.to_json();
        assert!(j.contains("\"schema\": \"faquant-perf-v1\""));
        assert!(j.contains("\"preset\": \"pico\""));
        assert!(j.contains("\"speedup_vs_1t\""));
        assert!(j.contains("\"prefill_tokens_per_sec\""));
        assert!(j.contains("\"decode_tokens_per_sec\""));
        assert!(j.contains("\"prepare_secs\""));
        assert!(j.contains("\"decode_prepared_tokens_per_sec\""));
        assert!(j.contains("\"prefix_hit_prefill_savings\""));
        assert!(j.contains("\"paged_peak_kv_bytes\""));
        assert!(j.contains("\"dense_kv_slab_bytes\""));
        assert!(j.contains("\"ttft_p50\""));
        assert!(j.contains("\"ttft_p99\""));
        assert!(j.contains("\"per_token_p50\""));
        assert!(j.contains("\"per_token_p99\""));
        assert!(j.contains("\"queue_wait_p95\""));
        assert!(j.contains("\"router_workers\": 2"));
        assert!(j.contains("\"router_ttft_p50\""));
        assert!(j.contains("\"router_ttft_p99\""));
        assert!(j.contains("\"router_per_token_p50\""));
        assert!(j.contains("\"router_per_token_p99\""));
        assert!(j.contains("\"decode_int_tokens_per_sec\""));
        assert!(j.contains("\"int_kernel\": \"avx2\""));
        assert!(j.contains("\"weight_read_bytes_f32\""));
        assert!(j.contains("\"weight_read_bytes_int\""));
        assert!(j.contains("stage \\\"x\\\""));
        assert_eq!(j.matches("\"mean_s\"").count(), 2);
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn per_token_stage_inverts_tps() {
        let s = PerfReport::per_token_stage("decode_tokens_per_sec", 40, 2.0);
        assert_eq!(s.iters, 40);
        assert!((s.mean - 0.05).abs() < 1e-7);
        assert_eq!(s.throughput(1.0), 20.0);
        let z = PerfReport::per_token_stage("empty", 0, 1.0);
        assert_eq!(z.mean, 0.0);
    }

    #[test]
    fn throughput_math() {
        let s = Sample {
            name: "t".into(),
            iters: 1,
            mean: 0.5,
            std: 0.0,
            p50: 0.5,
            p95: 0.5,
            min: 0.5,
        };
        assert_eq!(s.throughput(10.0), 20.0);
    }
}
