//! Global-allocation counter (bench-only, behind the `alloc-count`
//! feature).
//!
//! `benches/alloc_probe.rs` asserts the DESIGN.md §11 contract — a
//! steady-state decode step performs **zero** heap allocations in the
//! quantized-linear path — by installing [`CountingAllocator`] as the
//! global allocator (see `lib.rs`) and reading the counters around the
//! probed region. Counting is a pair of relaxed atomic increments per
//! allocation; never enabled in default builds.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOCS: AtomicUsize = AtomicUsize::new(0);
static BYTES: AtomicUsize = AtomicUsize::new(0);

/// System allocator wrapper that counts allocation events and bytes
/// (allocs, reallocs, and zeroed allocs; deallocations are free).
pub struct CountingAllocator;

// SAFETY: pure pass-through to `System` — every method forwards the
// exact layout/pointer it received, so `System`'s GlobalAlloc contract
// (valid layouts in, valid blocks out) carries over unchanged; the
// counters are relaxed atomics with no effect on the returned memory.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Cumulative allocation events since process start (all threads).
pub fn allocations() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

/// Cumulative requested bytes since process start (all threads).
pub fn allocated_bytes() -> usize {
    BYTES.load(Ordering::Relaxed)
}

/// Snapshot of both counters: `(allocations, bytes)`.
pub fn snapshot() -> (usize, usize) {
    (allocations(), allocated_bytes())
}
