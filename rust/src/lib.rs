//! # faquant — Future-Aware Quantization
//!
//! Rust + JAX + Pallas reproduction of *"Enhancing Post-Training
//! Quantization via Future Activation Awareness"* (Lv et al., 2026).
//!
//! The crate is the Layer-3 coordinator of the three-layer architecture
//! (see DESIGN.md): all request-path work — training loops, calibration,
//! the AWQ/FAQ scale search, quantization, packing, evaluation, serving —
//! runs in rust against a pluggable execution backend. The default
//! native backend executes every artifact entrypoint in-process on host
//! tensors (no python, no artifacts directory); the optional `pjrt`
//! feature swaps in the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py`, executed through the PJRT CPU client.
//!
//! Public API tour:
//! - [`config`] — run/model/quant configuration (TOML-lite, presets)
//! - [`tensor`] — host tensor math + deterministic PRNG
//! - [`store`] — `.fqt` binary tensor checkpoints
//! - [`corpus`] — synthetic corpora, tokenizer, batcher
//! - [`model`] — transformer parameter layout and checkpoints
//! - [`runtime`] — artifact registry + pluggable execution backends
//! - [`train`] — training driver over the `train_step` artifact
//! - [`calib`] — calibration capture and the FAQ preview window
//! - [`quant`] — RTN / AWQ / FAQ quantizers, grid search, bit-packing
//! - [`coordinator`] — the end-to-end PTQ pipeline
//! - [`engine`] — KV-cached decode: continuous batching + sampling
//! - [`obs`] — deterministic tracing, metrics, Chrome-trace export
//! - [`eval`] — perplexity and synthetic zero-shot suites
//! - [`serve`] — batched quantized-model serving demo
//! - [`benchkit`] / [`testutil`] — in-repo bench + property-test kits

pub mod benchkit;
pub mod calib;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod engine;
pub mod eval;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tensor;
pub mod testutil;
pub mod train;

/// Crate-wide result alias (anyhow is the only error dependency offline).
pub type Result<T> = anyhow::Result<T>;

/// With the bench-only `alloc-count` feature, every heap allocation in
/// the process goes through the counting allocator so
/// `benches/alloc_probe.rs` can assert the decode hot path's
/// zero-allocation contract (DESIGN.md §11). Default builds use the
/// system allocator untouched.
#[cfg(feature = "alloc-count")]
#[global_allocator]
static COUNTING_ALLOCATOR: benchkit::alloc::CountingAllocator = benchkit::alloc::CountingAllocator;
