//! Request-lifecycle primitives: cooperative cancellation, the engine
//! clock (real or virtual), and the fault-injection seam.
//!
//! A production serving loop needs more than a happy path: requests get
//! cancelled, deadlines expire, clients hang up, and a compute step can
//! fail. This module holds the small, panic-free building blocks the
//! scheduler composes into that lifecycle (DESIGN.md §14):
//!
//! - [`CancelToken`] — a cloneable atomic flag checked between decode
//!   steps. The serve loops also fire it when a client's response
//!   channel is found disconnected mid-generation, and reuse it as the
//!   graceful-shutdown signal.
//! - [`EngineClock`] — the engine's single source of "now". In
//!   production it is the wall clock; under the fault-injection harness
//!   it advances a fixed [`std::time::Duration`] per engine tick, so
//!   deadline expiry depends only on tick counts and is bitwise
//!   reproducible across machines and thread counts.
//! - [`Heartbeat`] — a monotone progress counter the sharded router's
//!   supervisor polls to tell a busy worker from a wedged one without
//!   reading wall time (DESIGN.md §16).
//! - [`FaultInjector`] — the seam the deterministic harness
//!   (`testutil::faults`) plugs into: it can fail a compute attempt
//!   (before any state changes — failed steps are retryable) or stall
//!   admission as if the block pool were exhausted. Production engines
//!   carry no injector and pay one `Option` check per step.

use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Cooperative cancellation flag. Clones share one flag: any clone's
/// [`CancelToken::cancel`] is observed by every holder. The scheduler
/// checks it between steps, so cancellation is prompt (one step's
/// latency) but never tears a step mid-flight.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fire the token. Idempotent; never blocks.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

/// Monotone progress counter for stall supervision: a worker thread
/// bumps it after each completed engine step, and the router's
/// supervisor compares snapshots across its own idle rounds. A worker
/// that holds queued work while its heartbeat stays flat is presumed
/// wedged and quarantined (DESIGN.md §16). Counting *completed work*
/// rather than reading a clock keeps stall detection free of wall-time
/// reads on the supervision path — and a false positive is safe, since
/// quarantine only triggers deterministic re-execution.
#[derive(Debug, Default)]
pub struct Heartbeat {
    beats: AtomicU64,
}

impl Heartbeat {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one unit of completed work. Relaxed ordering suffices:
    /// the supervisor only compares counts for equality over time.
    pub fn beat(&self) {
        self.beats.fetch_add(1, Ordering::Relaxed);
    }

    /// Current beat count (compared against a previous snapshot).
    pub fn snapshot(&self) -> u64 {
        self.beats.load(Ordering::Relaxed)
    }
}

/// The engine's clock: wall time by default, or a deterministic virtual
/// clock that advances `virtual_step` per engine tick (used by the
/// fault-injection harness so deadline storms replay bit-for-bit).
#[derive(Clone, Debug)]
pub struct EngineClock {
    t0: Instant,
    virtual_step: Option<Duration>,
}

impl EngineClock {
    pub fn new(virtual_step: Option<Duration>) -> Self {
        Self {
            // faq-lint: allow(untracked-clock) — EngineClock IS the
            // sanctioned clock seam; this anchors its epoch.
            t0: Instant::now(),
            virtual_step,
        }
    }

    /// Current time. Virtual mode returns `t0 + ticks * virtual_step`
    /// (saturating — a clock must never fail), so two runs that execute
    /// the same tick sequence observe identical deadline decisions.
    pub fn now(&self, ticks: usize) -> Instant {
        match self.virtual_step {
            // faq-lint: allow(untracked-clock) — the wall arm of the
            // sanctioned clock seam itself.
            None => Instant::now(),
            Some(step) => {
                let n = u32::try_from(ticks).unwrap_or(u32::MAX);
                self.t0.checked_add(step.saturating_mul(n)).unwrap_or(self.t0)
            }
        }
    }

    /// Whether this clock is virtual (tick-driven).
    pub fn is_virtual(&self) -> bool {
        self.virtual_step.is_some()
    }
}

/// Fault-injection seam at the engine boundary. Implementations decide,
/// from deterministic inputs only (tick counter, attempt index, the fed
/// request ids), whether a compute attempt fails or admission stalls —
/// never from wall time or ambient randomness, so an injected fault
/// schedule replays exactly (DESIGN.md §14).
pub trait FaultInjector: Send {
    /// Called immediately before every compute attempt (initial try,
    /// bounded retries, and quarantine-bisection probes all count).
    /// Returning an error makes the attempt fail before any KV append
    /// or sampler draw, exactly like a backend error at that point.
    fn before_attempt(&mut self, tick: usize, attempt: usize, fed_ids: &[usize]) -> Result<()>;

    /// When true, admission treats the store as having no free capacity
    /// this tick (queued requests keep waiting — forced pool
    /// exhaustion). Default: never stall.
    fn stall_admission(&mut self, tick: usize) -> bool {
        let _ = tick;
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_is_shared_across_clones() {
        let t = CancelToken::new();
        let c = t.clone();
        assert!(!t.is_cancelled() && !c.is_cancelled());
        c.cancel();
        assert!(t.is_cancelled() && c.is_cancelled());
        // Idempotent.
        t.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn virtual_clock_is_tick_driven_and_monotone() {
        let clk = EngineClock::new(Some(Duration::from_millis(2)));
        assert!(clk.is_virtual());
        let a = clk.now(0);
        let b = clk.now(5);
        assert_eq!(b.duration_since(a), Duration::from_millis(10));
        // Same tick => same instant, regardless of real elapsed time.
        assert_eq!(clk.now(5), b);
        // Saturation: absurd tick counts must not panic.
        let far = clk.now(usize::MAX);
        assert!(far >= a);
    }

    #[test]
    fn heartbeat_counts_monotonically_across_threads() {
        let hb = Arc::new(Heartbeat::new());
        assert_eq!(hb.snapshot(), 0);
        let worker = Arc::clone(&hb);
        let h = std::thread::spawn(move || {
            for _ in 0..100 {
                worker.beat();
            }
        });
        assert!(h.join().is_ok());
        assert_eq!(hb.snapshot(), 100);
        hb.beat();
        assert_eq!(hb.snapshot(), 101);
    }

    #[test]
    fn real_clock_advances() {
        let clk = EngineClock::new(None);
        assert!(!clk.is_virtual());
        let a = clk.now(0);
        let b = clk.now(0);
        assert!(b >= a);
    }
}
