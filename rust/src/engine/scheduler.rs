//! Continuous-batching generation scheduler.
//!
//! [`Engine`] owns a fixed number of *slots* (default: the preset's batch
//! size), a [`KvCache`] sized `[L, slots, seq, d]`, and the uploaded
//! quantized weight bundle. Every [`Engine::step`] runs ONE batched
//! `decode_step_q` over all occupied slots — sequences at completely
//! different phases (prompt prefill, mid-decode) share the same
//! execution, each at its own cache position. Finished sequences free
//! their slot immediately and the queue backfills it on the next step,
//! so short requests never wait for long ones to drain (continuous
//! batching, the vLLM scheduling model at slot granularity).
//!
//! Prefill feeds prompt tokens one position per step through the same
//! entry as decode: there is exactly one compute path, which is what
//! makes the bit-identity contract (module docs in [`super`]) hold by
//! construction. The [`GenReport`] splits wall time between prefill and
//! decode by each step's feed mix.

use super::{
    FinishReason, GenOutput, GenReport, GenRequest, KvCache, RejectCounts, RejectReason, Sampler,
};
use crate::config::ModelConfig;
use crate::model::Params;
use crate::quant::QuantizedModel;
use crate::runtime::{Buffer, Runtime, Value};
use crate::serve::qmodel_literals;
use crate::tensor::TensorI32;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// Generation settings shared by every sequence of an engine.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// <= 0 is greedy; otherwise softmax temperature.
    pub temperature: f32,
    /// 0 = unrestricted; otherwise sample among the k highest logits.
    pub top_k: usize,
    /// Base seed; each sequence forks its own stream keyed by request id.
    pub seed: u64,
    /// Batch slots (0 = the model preset's batch size).
    pub slots: usize,
    /// Use the runtime's prepared weight bundle (dequantize-once packed
    /// panels, DESIGN.md §11; bit-identical logits). `false` keeps the
    /// per-step dequantizing seed path — the perf bench's baseline.
    pub prepared: bool,
}

impl Default for GenConfig {
    fn default() -> Self {
        Self {
            temperature: 0.0,
            top_k: 0,
            seed: 7,
            slots: 0,
            prepared: true,
        }
    }
}

/// One in-flight sequence.
struct SeqState {
    id: usize,
    prompt_len: usize,
    /// Prompt followed by generated tokens.
    tokens: Vec<i32>,
    /// Tokens fed through the cache so far (== cache len for the slot).
    cursor: usize,
    max_new: usize,
    stop_id: Option<i32>,
    sampler: Sampler,
}

/// The KV-cached continuous-batching generation engine.
pub struct Engine<'rt> {
    rt: &'rt Runtime,
    cfg: ModelConfig,
    gen: GenConfig,
    weight_bufs: std::sync::Arc<Vec<Buffer>>,
    cache: KvCache,
    slots: Vec<Option<SeqState>>,
    queue: VecDeque<SeqState>,
    // Accumulated report state (across generate calls).
    steps: usize,
    prefill_tokens: usize,
    decode_tokens: usize,
    prefill_secs: f32,
    decode_secs: f32,
    occupancy_sum: f32,
    completed: usize,
    rejected: usize,
    reject_counts: RejectCounts,
}

impl<'rt> Engine<'rt> {
    /// Build an engine over a quantized model: prepares the weight
    /// bundle once — by default through the runtime's prepared-state map
    /// (dequantize-once packed panels on the native backend, DESIGN.md
    /// §11; shared across engines over the same artifact) — and sizes
    /// the cache to `[L, slots, seq, d]`.
    pub fn new(
        rt: &'rt Runtime,
        cfg: &ModelConfig,
        params: &Params,
        qm: &QuantizedModel,
        gen: GenConfig,
    ) -> Result<Self> {
        let slots = match gen.slots {
            0 => cfg.batch,
            n => n,
        };
        let lits = qmodel_literals(params, qm)?;
        let weight_bufs = if gen.prepared {
            rt.prepare_qweights(&cfg.name, &lits)?
        } else {
            std::sync::Arc::new(
                lits.iter()
                    .map(|l| rt.upload_literal(l))
                    .collect::<Result<Vec<_>>>()?,
            )
        };
        let cache = KvCache::new(cfg.n_layer, slots, cfg.seq, cfg.d_model);
        Ok(Self {
            rt,
            cfg: cfg.clone(),
            gen,
            weight_bufs,
            cache,
            slots: (0..slots).map(|_| None).collect(),
            queue: VecDeque::new(),
            steps: 0,
            prefill_tokens: 0,
            decode_tokens: 0,
            prefill_secs: 0.0,
            decode_secs: 0.0,
            occupancy_sum: 0.0,
            completed: 0,
            rejected: 0,
            reject_counts: RejectCounts::default(),
        })
    }

    /// Why a request cannot be admitted, if anything.
    pub fn validate(&self, req: &GenRequest) -> Option<RejectReason> {
        if req.prompt.is_empty() {
            return Some(RejectReason::EmptyPrompt);
        }
        if req.max_new == 0 {
            return Some(RejectReason::ZeroMaxNew);
        }
        for (index, &id) in req.prompt.iter().enumerate() {
            if id < 0 || id as usize >= self.cfg.vocab {
                return Some(RejectReason::TokenOutOfRange { index, id });
            }
        }
        let cap = self.cache.t_max();
        if req.prompt.len() + req.max_new > cap {
            return Some(RejectReason::TooLong {
                prompt: req.prompt.len(),
                max_new: req.max_new,
                cap,
            });
        }
        None
    }

    /// Enqueue a request. Returns `Some(rejected output)` immediately
    /// when the request cannot be admitted; `None` means it is queued and
    /// will surface from a later [`Engine::step`].
    pub fn submit(&mut self, req: GenRequest) -> Option<GenOutput> {
        if let Some(reason) = self.validate(&req) {
            self.rejected += 1;
            self.reject_counts.note(&reason);
            return Some(GenOutput {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                finish: FinishReason::Rejected(reason),
            });
        }
        let sampler =
            Sampler::for_sequence(self.gen.temperature, self.gen.top_k, self.gen.seed, req.id);
        self.queue.push_back(SeqState {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens: req.prompt,
            cursor: 0,
            max_new: req.max_new,
            stop_id: req.stop_id,
            sampler,
        });
        None
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(Option::is_some)
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// Admit queued sequences into free slots, run one batched decode
    /// step, and return the sequences that finished on it.
    pub fn step(&mut self) -> Result<Vec<GenOutput>> {
        for (slot, state) in self.slots.iter_mut().enumerate() {
            if state.is_some() {
                continue;
            }
            if let Some(st) = self.queue.pop_front() {
                self.cache.reset(slot);
                *state = Some(st);
            }
        }
        let b = self.slots.len();
        let vocab = self.cfg.vocab;
        let mut pos = vec![-1i32; b];
        let mut tok = vec![0i32; b];
        let mut prefill_feeds = 0usize;
        let mut decode_feeds = 0usize;
        for (slot, st) in self.slots.iter().enumerate() {
            let Some(st) = st else { continue };
            pos[slot] = st.cursor as i32;
            tok[slot] = st.tokens[st.cursor];
            if st.cursor < st.prompt_len {
                prefill_feeds += 1;
            } else {
                decode_feeds += 1;
            }
        }
        let feeds = prefill_feeds + decode_feeds;
        if feeds == 0 {
            return Ok(Vec::new());
        }

        let t0 = Instant::now();
        let (kt, vt) = self.cache.take()?;
        let k_buf = Buffer::Host(Value::F32(kt));
        let v_buf = Buffer::Host(Value::F32(vt));
        let pos_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b], pos)?));
        let tok_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b], tok)?));
        let outs = {
            let mut args: Vec<&Buffer> = self.weight_bufs.iter().collect();
            args.extend([&k_buf, &v_buf, &pos_buf, &tok_buf]);
            self.rt.exec_b(&self.cfg.name, "decode_step_q", &args)
        };
        // The slabs go back whether or not the step succeeded.
        match (k_buf, v_buf) {
            (Buffer::Host(Value::F32(k)), Buffer::Host(Value::F32(v))) => {
                self.cache.put_back(k, v)?
            }
            _ => bail!("KV slabs must stay host-resident"),
        }
        let outs = outs?;
        let dt = t0.elapsed().as_secs_f32();
        self.steps += 1;
        self.occupancy_sum += feeds as f32 / b as f32;
        self.prefill_secs += dt * prefill_feeds as f32 / feeds as f32;
        self.decode_secs += dt * decode_feeds as f32 / feeds as f32;
        self.prefill_tokens += prefill_feeds;

        let logits = outs[0].as_f32()?;
        let k_new = outs[1].as_f32()?;
        let v_new = outs[2].as_f32()?;
        let mut finished = Vec::new();
        for slot in 0..b {
            let done = {
                let Some(st) = self.slots[slot].as_mut() else { continue };
                self.cache.append(slot, k_new, v_new)?;
                st.cursor += 1;
                let mut fin = None;
                if st.cursor >= st.prompt_len {
                    // This feed's logits predict the next position.
                    let row = &logits.data()[slot * vocab..(slot + 1) * vocab];
                    let next = st.sampler.sample(row) as i32;
                    if st.stop_id == Some(next) {
                        fin = Some(FinishReason::Stop);
                    } else {
                        st.tokens.push(next);
                        self.decode_tokens += 1;
                        if st.tokens.len() - st.prompt_len >= st.max_new {
                            fin = Some(FinishReason::MaxTokens);
                        }
                    }
                }
                fin.map(|finish| GenOutput {
                    id: st.id,
                    prompt_len: st.prompt_len,
                    tokens: st.tokens[st.prompt_len..].to_vec(),
                    finish,
                })
            };
            if let Some(out) = done {
                self.slots[slot] = None;
                self.completed += 1;
                finished.push(out);
            }
        }
        Ok(finished)
    }

    /// Snapshot of the accumulated throughput/occupancy counters.
    pub fn report(&self) -> GenReport {
        GenReport {
            sequences: self.completed,
            rejected: self.rejected,
            reject_counts: self.reject_counts.clone(),
            steps: self.steps,
            prefill_tokens: self.prefill_tokens,
            decode_tokens: self.decode_tokens,
            prefill_secs: self.prefill_secs,
            decode_secs: self.decode_secs,
            mean_slot_occupancy: if self.steps > 0 {
                self.occupancy_sum / self.steps as f32
            } else {
                0.0
            },
        }
    }

    /// Convenience driver: submit everything, step until drained, return
    /// outputs sorted by request id plus the report snapshot.
    pub fn generate(&mut self, reqs: Vec<GenRequest>) -> Result<(Vec<GenOutput>, GenReport)> {
        let mut outs = Vec::with_capacity(reqs.len());
        for r in reqs {
            if let Some(rejected) = self.submit(r) {
                outs.push(rejected);
            }
        }
        while self.has_work() {
            outs.extend(self.step()?);
        }
        outs.sort_by_key(|o| o.id);
        Ok((outs, self.report()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, QuantConfig};
    use crate::quant::quantize_model;

    fn pico_model(rt: &Runtime) -> (ModelConfig, Params, QuantizedModel) {
        let cfg = ModelConfig::preset("pico").unwrap();
        let params = Params::init(&cfg, 11);
        let qcfg = QuantConfig::with_method(Method::Rtn);
        let qm = quantize_model(rt, &qcfg, &params, None).unwrap();
        (cfg, params, qm)
    }

    #[test]
    fn generate_greedy_runs_and_reports() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let reqs: Vec<GenRequest> = (0..6)
            .map(|i| GenRequest {
                id: i,
                prompt: vec![(i as i32 * 3) % cfg.vocab as i32, 1, 2, 5],
                max_new: 4,
                stop_id: None,
            })
            .collect();
        let (outs, rep) = eng.generate(reqs).unwrap();
        assert_eq!(outs.len(), 6);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.id, i);
            assert_eq!(o.finish, FinishReason::MaxTokens);
            assert_eq!(o.tokens.len(), 4);
            assert!(o.tokens.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
        }
        assert_eq!(rep.sequences, 6);
        assert_eq!(rep.rejected, 0);
        // 6 sequences x 4 prompt tokens; decode tokens delivered = 6 x 4.
        assert_eq!(rep.prefill_tokens, 24);
        assert_eq!(rep.decode_tokens, 24);
        assert!(rep.steps >= 7, "6 seqs over 4 slots need two waves");
        assert!(rep.mean_slot_occupancy > 0.0 && rep.mean_slot_occupancy <= 1.0);
    }

    #[test]
    fn prepared_and_unprepared_paths_generate_identical_tokens() {
        // The prepared (dequantize-once packed panels) path is
        // bit-identical to the seed path, so greedy generations match
        // token for token (DESIGN.md §11).
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let reqs = || -> Vec<GenRequest> {
            (0..3)
                .map(|i| GenRequest {
                    id: i,
                    prompt: vec![(i as i32 * 5) % cfg.vocab as i32, 2, 7],
                    max_new: 5,
                    stop_id: None,
                })
                .collect()
        };
        let run = |prepared: bool| -> Vec<Vec<i32>> {
            let gen = GenConfig {
                prepared,
                ..GenConfig::default()
            };
            let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
            let (outs, _) = eng.generate(reqs()).unwrap();
            outs.into_iter().map(|o| o.tokens).collect()
        };
        assert_eq!(run(true), run(false));
        // Both engines over the same artifact shared one prepared state.
        assert_eq!(rt.prepared_qweights(), 1);
    }

    #[test]
    fn rejections_are_immediate_and_counted() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let req = |id: usize, prompt: Vec<i32>, max_new: usize| GenRequest {
            id,
            prompt,
            max_new,
            stop_id: None,
        };
        let bad = vec![
            req(0, vec![], 2),
            req(1, vec![1, -4], 2),
            req(2, vec![1; cfg.seq], 2),
            req(3, vec![1, 2], 0),
            req(4, vec![1, 2], 2),
        ];
        let (outs, rep) = eng.generate(bad).unwrap();
        assert_eq!(outs.len(), 5);
        assert!(matches!(
            outs[0].finish,
            FinishReason::Rejected(RejectReason::EmptyPrompt)
        ));
        assert!(matches!(
            outs[1].finish,
            FinishReason::Rejected(RejectReason::TokenOutOfRange { index: 1, id: -4 })
        ));
        assert!(matches!(
            outs[2].finish,
            FinishReason::Rejected(RejectReason::TooLong { .. })
        ));
        assert!(matches!(
            outs[3].finish,
            FinishReason::Rejected(RejectReason::ZeroMaxNew)
        ));
        assert_eq!(outs[4].finish, FinishReason::MaxTokens);
        assert_eq!(rep.rejected, 4);
        assert_eq!(rep.reject_counts.total(), 4);
        assert_eq!(rep.reject_counts.bad_token, 1);
        assert_eq!(rep.reject_counts.too_long, 1);
        assert_eq!(rep.sequences, 1);
    }

    #[test]
    fn stop_id_ends_generation_without_emitting_it() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        // Learn what greedy emits first, then rerun with that as stop id.
        let req = |id| GenRequest {
            id,
            prompt: vec![3, 1, 4, 1, 5],
            max_new: 3,
            stop_id: None,
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let (outs, _) = eng.generate(vec![req(0)]).unwrap();
        let first = outs[0].tokens[0];

        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let mut r = req(1);
        r.stop_id = Some(first);
        let (outs, rep) = eng.generate(vec![r]).unwrap();
        assert_eq!(outs[0].finish, FinishReason::Stop);
        assert!(outs[0].tokens.is_empty());
        assert_eq!(rep.sequences, 1);
    }
}
