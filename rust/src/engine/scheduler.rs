//! Continuous-batching generation scheduler.
//!
//! [`Engine`] owns a fixed number of *slots* (default: the preset's batch
//! size), a KV store, and the uploaded quantized weight bundle. Every
//! [`Engine::step`] runs ONE batched decode step over all occupied slots —
//! sequences at completely different phases (prompt prefill, mid-decode)
//! share the same execution, each at its own cache position. Finished
//! sequences free their slot immediately and the queue backfills it on
//! the next step, so short requests never wait for long ones to drain
//! (continuous batching, the vLLM scheduling model at slot granularity).
//!
//! Two KV stores exist behind one scheduler:
//!
//! - **Dense** (`GenConfig { paged: false }`): the seed `[L, slots,
//!   T_max, d]` slabs + `decode_step_q`. A slot reserves `T_max` rows
//!   for its whole lifetime. Kept as the reference engine — the
//!   differential fuzz harness (`testutil::fuzz`) pins the paged engine
//!   bitwise against it.
//! - **Paged** (default): a refcounted [`BlockPool`] of fixed
//!   `block_tokens` pages, per-sequence block tables, and a [`RadixTree`]
//!   prefix cache + `decode_step_paged_q`. Admission is by free
//!   *blocks* (worst case `ceil((prompt + max_new - 1) / block_tokens)`,
//!   reserved up front so mid-decode allocation can never fail), a
//!   request whose prompt shares a cached prefix takes references on the
//!   matched full blocks and starts prefill after them (copy-on-write
//!   duplicates a partially-matched tail block), finished sequences
//!   insert their block-aligned prefix into the tree, and admission
//!   pressure evicts least-recently-used cached prefixes (DESIGN.md §12).
//!
//! Prefill feeds prompt tokens one position per step through the same
//! entry as decode: there is exactly one compute path per store, and the
//! paged gather reads bitwise-identical rows in the identical order, so
//! the bit-identity contract (module docs in [`super`]) holds across
//! stores, thread counts, and batch mixes. The [`GenReport`] splits wall
//! time between prefill and decode by each step's feed mix and carries
//! the paged pool/prefix counters.
//!
//! **Request lifecycle (DESIGN.md §14):** every [`Engine::step`] starts
//! with a lifecycle sweep — queued and running sequences whose
//! [`CancelToken`] fired or whose deadline expired finish immediately
//! with [`FinishReason::Cancelled`]/[`FinishReason::DeadlineExceeded`],
//! their slot and blocks released (abnormal exits never cache their
//! prefix). A failed compute attempt changes no engine state (KV
//! appends and sampler draws happen only after success), so the step is
//! retried up to `GenConfig::step_retries` times for transient faults;
//! if the batch still fails, a one-slot-masked bisection identifies the
//! poisoned sequence and evicts it with
//! [`RejectReason::Internal`] — survivors keep decoding the same
//! streams, bit for bit. [`Engine::begin_drain`] stops admission
//! (fresh submits reject with [`RejectReason::Draining`]) while
//! in-flight work runs to completion, and `GenConfig::max_queue` bounds
//! the admission queue ([`RejectReason::QueueFull`] backpressure).

use super::{
    BlockPool, CancelToken, EngineClock, FaultInjector, FinishReason, GenOutput, GenReport,
    GenRequest, KvCache, RadixTree, RejectCounts, RejectReason, Sampler,
};
use crate::config::ModelConfig;
use crate::model::Params;
use crate::obs::{Hist, LatencyStats, Metrics, Trace, TraceEvent};
use crate::quant::QuantizedModel;
use crate::runtime::{Buffer, Runtime, Value};
use crate::serve::qmodel_literals;
use crate::tensor::{Tensor, TensorI32};
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Default KV page size (tokens per block) for the paged engine.
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Generation settings shared by every sequence of an engine.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// <= 0 is greedy; otherwise softmax temperature.
    pub temperature: f32,
    /// 0 = unrestricted; otherwise sample among the k highest logits.
    pub top_k: usize,
    /// Base seed; each sequence forks its own stream keyed by request id.
    pub seed: u64,
    /// Batch slots (0 = the model preset's batch size).
    pub slots: usize,
    /// Use the runtime's prepared weight bundle (dequantize-once packed
    /// panels, DESIGN.md §11; bit-identical logits). `false` keeps the
    /// per-step dequantizing seed path — the perf bench's baseline.
    pub prepared: bool,
    /// Block-paged KV cache + radix prefix sharing (DESIGN.md §12)
    /// instead of the dense `[L, slots, T_max, d]` slabs. Token streams
    /// are bit-identical either way (pinned by `testutil::fuzz`).
    pub paged: bool,
    /// Tokens per KV page (paged only; 0 = [`DEFAULT_BLOCK_TOKENS`]).
    pub block_tokens: usize,
    /// Pool size in blocks (paged only; 0 = `slots * ceil(seq /
    /// block_tokens)`, the dense slab's capacity). Smaller pools trade
    /// admission concurrency for memory; many short sequences need far
    /// fewer blocks than `slots * T_max` rows.
    pub pool_blocks: usize,
    /// Keep finished prompts' KV blocks in the radix prefix cache so
    /// later requests sharing the prefix skip that prefill (paged only).
    pub prefix_cache: bool,
    /// Admission-queue bound: a `submit` that would push the queue past
    /// this rejects with [`RejectReason::QueueFull`] (backpressure
    /// instead of unbounded growth). 0 = unbounded.
    pub max_queue: usize,
    /// Same-batch retries for a failed compute step before the
    /// quarantine bisection starts hunting for a poisoned sequence
    /// (failed attempts change no state, so retrying is always sound).
    pub step_retries: usize,
    /// Deterministic virtual clock: advance this much per engine tick
    /// instead of reading the wall clock (fault-injection harness only;
    /// `None` = real time).
    pub virtual_step: Option<Duration>,
    /// Record structured trace events (DESIGN.md §15). Disabled, the
    /// trace handle is a no-op — no allocation, no clock reads — and
    /// token streams are bitwise identical either way (pinned by
    /// `testutil::fuzz::trace_determinism_case`). Timestamps follow
    /// `virtual_step` when set (deterministic) and wall time otherwise.
    pub trace: bool,
    /// Decode on the integer W4A8 path (DESIGN.md §17): per-row int8
    /// activation quantization feeding the fused int8×int4 kernel on
    /// the stored codes, instead of the dequantized f32 panels. Logits
    /// are *close* (derived per-row bound), not bit-identical, to the
    /// f32 prepared path — greedy token streams agree on well-margined
    /// inputs (pinned seeds in `testutil::fuzz`). Requires `prepared`
    /// and codes that fit int4 (bits <= 4); `Engine::new` fails fast
    /// otherwise.
    pub int_compute: bool,
}

impl Default for GenConfig {
    fn default() -> Self {
        Self {
            temperature: 0.0,
            top_k: 0,
            seed: 7,
            slots: 0,
            prepared: true,
            paged: true,
            block_tokens: 0,
            pool_blocks: 0,
            prefix_cache: true,
            max_queue: 0,
            step_retries: 2,
            virtual_step: None,
            trace: false,
            int_compute: false,
        }
    }
}

/// One in-flight sequence.
struct SeqState {
    id: usize,
    prompt_len: usize,
    /// Prompt followed by generated tokens.
    tokens: Vec<i32>,
    /// Tokens fed through the cache so far (prefix-cache hits start it
    /// past zero: those positions' KV rows are shared, not re-fed).
    cursor: usize,
    max_new: usize,
    stop_id: Option<i32>,
    sampler: Sampler,
    /// Absolute expiry on the engine clock (budget added at submit).
    deadline_at: Option<Instant>,
    cancel: Option<CancelToken>,
    /// Engine-elapsed stamp at submission (µs) — queue-wait and TTFT
    /// observations subtract it at admission / first token.
    queued_us: u64,
}

/// Cancel / deadline check shared by queued and running sequences.
/// Cancellation wins when both fired in the same sweep (the client
/// explicitly asked; the deadline merely ran out).
fn lifecycle_fate(st: &SeqState, now: Instant) -> Option<FinishReason> {
    if st.cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
        return Some(FinishReason::Cancelled);
    }
    if st.deadline_at.is_some_and(|d| now >= d) {
        return Some(FinishReason::DeadlineExceeded);
    }
    None
}

/// The paged KV state: pool + prefix tree + per-slot block tables and
/// worst-case reservations.
struct PagedKv {
    pool: BlockPool,
    tree: RadixTree,
    /// Per-slot block table (parallel to `Engine::slots`).
    tables: Vec<Vec<u32>>,
    /// Per-slot blocks still to allocate (worst case), pre-reserved at
    /// admission so a mid-decode `alloc` can never fail.
    reserved: Vec<usize>,
    reserved_total: usize,
    /// Block-table width: `ceil(t_max / block_tokens)`.
    max_blocks: usize,
    block_tokens: usize,
    t_max: usize,
    prefix_cache: bool,
    /// Monotonic LRU clock (bumped per admission/insert).
    clock: u64,
    prefix_hit_tokens: usize,
    evicted_refs: usize,
    peak_in_use: usize,
    /// Engine trace handle (cheap clone of the engine's; no-op when
    /// tracing is off) + the tick stamped onto paged events.
    trace: Trace,
    tick: u64,
}

impl PagedKv {
    fn new(
        cfg: &ModelConfig,
        slots: usize,
        block_tokens: usize,
        pool_blocks: usize,
        prefix_cache: bool,
        trace: Trace,
    ) -> Self {
        let bt = if block_tokens == 0 {
            DEFAULT_BLOCK_TOKENS
        } else {
            block_tokens
        };
        let max_blocks = cfg.seq.div_ceil(bt);
        let pool_blocks = if pool_blocks == 0 {
            slots * max_blocks
        } else {
            pool_blocks
        };
        let mut pool = BlockPool::new(cfg.n_layer, pool_blocks, bt, cfg.d_model);
        pool.set_trace(trace.clone());
        Self {
            pool,
            tree: RadixTree::new(bt),
            tables: (0..slots).map(|_| Vec::new()).collect(),
            reserved: vec![0; slots],
            reserved_total: 0,
            max_blocks,
            block_tokens: bt,
            t_max: cfg.seq,
            prefix_cache,
            clock: 0,
            prefix_hit_tokens: 0,
            evicted_refs: 0,
            peak_in_use: 0,
            trace,
            tick: 0,
        }
    }

    /// Forward the engine's tick to paged-event stamps (pool included).
    fn set_tick(&mut self, tick: u64) {
        self.tick = tick;
        self.pool.set_tick(tick);
    }

    /// Requests whose `prompt + max_new` exceeds this can never be
    /// admitted (position capacity or worst-case block need > pool).
    fn capacity(&self) -> usize {
        self.t_max.min(self.pool.n_blocks() * self.block_tokens + 1)
    }

    fn note_peak(&mut self) {
        self.peak_in_use = self.peak_in_use.max(self.pool.in_use_blocks());
    }

    /// Evict LRU cached prefixes until `target` blocks are free — but
    /// only if the target is reachable: eviction can free exactly the
    /// blocks whose every reference is the tree's, so when a waiting
    /// head couldn't be admitted anyway (blocks held by live sequences
    /// or admission pins), the cache is left intact instead of being
    /// pointlessly wiped a step at a time. Returns whether `target` is
    /// met.
    fn secure_free(&mut self, target: usize) -> Result<bool> {
        if self.pool.free_blocks() >= target {
            return Ok(true);
        }
        if self.tree.is_empty() {
            // Nothing cached: the missing blocks are held by live
            // sequences; only their completion can free them.
            return Ok(false);
        }
        // Full reachability walk — O(live tree nodes) per blocked
        // admission attempt. Fine at serving scale (a prefix cache
        // holds tens of nodes); revisit with an incremental
        // tree-only-referenced counter if tree sizes grow.
        let tree_refs = self.tree.block_refs();
        let freeable = tree_refs
            .iter()
            .filter(|&(&b, &refs)| self.pool.refcount(b) == refs)
            .count();
        if self.pool.free_blocks() + freeable < target {
            return Ok(false);
        }
        while self.pool.free_blocks() < target {
            let Some(dropped) = self.tree.evict_lru() else {
                break;
            };
            for b in dropped {
                self.evicted_refs += 1;
                self.trace
                    .emit(self.tick, TraceEvent::BlockEvict { block: b as usize });
                self.pool.release(b)?;
            }
        }
        Ok(self.pool.free_blocks() >= target)
    }

    /// Try to admit a sequence into `slot`: prefix lookup, worst-case
    /// block reservation (evicting LRU cached prefixes as needed), and
    /// copy-on-write of a partially matched tail block. Returns the
    /// starting cursor (prefix tokens skipped) or `None` when the pool
    /// cannot cover the request right now.
    fn try_admit(
        &mut self,
        slot: usize,
        tokens: &[i32],
        prompt_len: usize,
        max_new: usize,
    ) -> Result<Option<usize>> {
        self.clock += 1;
        let bt = self.block_tokens;
        let prompt = tokens
            .get(..prompt_len)
            .ok_or_else(|| anyhow!("prompt_len {prompt_len} exceeds the token stream"))?;
        let (mut p, chain) = if self.prefix_cache {
            let (m, c) = self.tree.lookup(prompt, self.clock);
            // The last prompt token is always fed: its logits seed the
            // first sampled token.
            (m.min(prompt_len - 1), c)
        } else {
            (0, Vec::new())
        };
        let nfull = p / bt;
        let partial = p % bt;
        // Worst-case rows this sequence ever caches (the final sampled
        // token is returned, never fed).
        let rows_worst = prompt_len + max_new - 1;
        let need_total = rows_worst.div_ceil(bt);
        debug_assert!(need_total <= self.pool.n_blocks(), "validate() enforces this");
        let new_needed = need_total - nfull;
        // Pin every shared block (and the copy-on-write source) BEFORE
        // evicting, so eviction can only drop the tree's references —
        // never recycle a block this admission is about to read.
        let mut pinned: Vec<u32> = Vec::with_capacity(nfull + 1);
        for &b in chain.iter().take(nfull) {
            self.pool.retain(b)?;
            pinned.push(b);
        }
        let mut cow_src = None;
        if partial > 0 {
            let src = chain
                .get(nfull)
                .copied()
                .ok_or_else(|| anyhow!("lookup chain missing its partial tail block"))?;
            self.pool.retain(src)?;
            cow_src = Some(src);
        }
        // The free list must cover every outstanding reservation plus
        // this sequence's worst case.
        let target = self.reserved_total + new_needed;
        let mut ok = self.secure_free(target)?;
        if !ok && cow_src.is_some() {
            // The partial-tail hit is opportunistic: its pinned COW
            // source can make the target unreachable at exact pool
            // capacity (the source can never free while pinned). Drop
            // the pin, round the hit down to the full-block boundary,
            // and retry — provably admissible whenever an admission
            // with no hit at all would be.
            if let Some(src) = cow_src.take() {
                self.pool.release(src)?;
            }
            p = nfull * bt;
            ok = self.secure_free(target)?;
        }
        if !ok {
            // Not admissible right now: roll the pins back.
            for b in pinned {
                self.pool.release(b)?;
            }
            if let Some(src) = cow_src {
                self.pool.release(src)?;
            }
            return Ok(None);
        }
        let mut table = pinned;
        let mut reserve = new_needed;
        if let Some(src) = cow_src {
            // Copy-on-write: this sequence appends inside the matched
            // tail block, so it gets a private copy of the shared rows.
            let dst = self.pool.alloc()?;
            self.pool.cow_copy(src, dst, partial)?;
            self.trace.emit(
                self.tick,
                TraceEvent::BlockCow {
                    src: src as usize,
                    dst: dst as usize,
                },
            );
            self.pool.release(src)?;
            table.push(dst);
            reserve -= 1;
        }
        *self
            .tables
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))? = table;
        *self
            .reserved
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))? = reserve;
        self.reserved_total += reserve;
        self.prefix_hit_tokens += p;
        self.note_peak();
        Ok(Some(p))
    }

    /// Write one fed token's KV rows at `pos`, allocating the next block
    /// from the reservation when the position crosses a page boundary.
    fn append_row(
        &mut self,
        slot: usize,
        pos: usize,
        k_new: &Tensor,
        v_new: &Tensor,
    ) -> Result<()> {
        let bt = self.block_tokens;
        let bi = pos / bt;
        let Self {
            pool,
            tables,
            reserved,
            reserved_total,
            ..
        } = self;
        let table = tables
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))?;
        let res = reserved
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))?;
        if bi == table.len() {
            if *res == 0 {
                bail!("slot {slot}: paged append at pos {pos} without a reservation");
            }
            let b = pool.alloc()?;
            table.push(b);
            *res -= 1;
            *reserved_total -= 1;
        }
        let block = table
            .get(bi)
            .copied()
            .ok_or_else(|| anyhow!("slot {slot}: append at pos {pos} past its block table"))?;
        if pool.refcount(block) != 1 {
            bail!(
                "slot {slot}: writing block {block} with refcount {} (shared blocks \
                 are read-only; divergence must copy-on-write)",
                pool.refcount(block)
            );
        }
        pool.write_row(block, pos % bt, slot, k_new, v_new)?;
        self.note_peak();
        Ok(())
    }

    /// A sequence finished having fed `fed` tokens of `tokens`: cache its
    /// block-aligned prefix in the radix tree, then drop the sequence's
    /// own references (blocks the tree kept stay live; the rest free).
    fn on_finish(&mut self, slot: usize, fed: usize, tokens: &[i32]) -> Result<()> {
        let bt = self.block_tokens;
        if self.prefix_cache {
            let aligned = (fed / bt) * bt;
            if aligned > 0 {
                self.clock += 1;
                let table = self
                    .tables
                    .get(slot)
                    .ok_or_else(|| anyhow!("slot {slot} out of range"))?;
                let (prefix, chain) = match (tokens.get(..aligned), table.get(..aligned / bt)) {
                    (Some(p), Some(c)) => (p, c),
                    _ => bail!("slot {slot}: fed {fed} tokens but stream/table are shorter"),
                };
                let new_refs = self.tree.insert(prefix, chain, self.clock);
                for b in new_refs {
                    self.pool.retain(b)?;
                }
            }
        }
        self.on_abort(slot)
    }

    /// A sequence is leaving `slot` abnormally (cancel, deadline,
    /// quarantine): drop its block references and reservation WITHOUT
    /// caching its prefix. The rows it wrote are valid, but an abnormal
    /// exit must leave the pool exactly as if the request never ran —
    /// keeping its entries cache-hot would make later prefix-hit
    /// accounting depend on which requests happened to fault.
    fn on_abort(&mut self, slot: usize) -> Result<()> {
        let table = std::mem::take(
            self.tables
                .get_mut(slot)
                .ok_or_else(|| anyhow!("slot {slot} out of range"))?,
        );
        for b in table {
            self.pool.release(b)?;
        }
        let res = self
            .reserved
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))?;
        self.reserved_total -= *res;
        *res = 0;
        Ok(())
    }
}

/// The engine's KV store: dense seed slabs or the paged block pool.
enum KvStore {
    Dense(KvCache),
    Paged(PagedKv),
}

/// One successful batched compute attempt: the kernel's outputs
/// (`[logits, k_new, v_new]`) plus this attempt's feed metrics.
struct StepOut {
    outs: Vec<Value>,
    prefill_feeds: usize,
    decode_feeds: usize,
    feeds: usize,
    secs: f32,
}

/// The KV-cached continuous-batching generation engine.
pub struct Engine<'rt> {
    rt: &'rt Runtime,
    cfg: ModelConfig,
    gen: GenConfig,
    weight_bufs: std::sync::Arc<Vec<Buffer>>,
    store: KvStore,
    slots: Vec<Option<SeqState>>,
    queue: VecDeque<SeqState>,
    /// Engine clock (wall or virtual) for deadline decisions.
    clock: EngineClock,
    /// Step-call counter: bumped at the top of EVERY [`Engine::step`],
    /// successful or not (unlike `steps`, which counts computed steps).
    /// Drives the virtual clock and the fault-injection schedule.
    ticks: usize,
    /// Draining: fresh submits reject, in-flight work runs out.
    draining: bool,
    /// Fault-injection seam (tests only; `None` in production).
    fault: Option<Box<dyn FaultInjector>>,
    /// Structured event trace (no-op handle unless `GenConfig::trace`).
    trace: Trace,
    /// Latency histograms + engine counters/gauges (DESIGN.md §15).
    metrics: Metrics,
    /// Accumulated engine time in µs — the latency-metric timebase.
    /// Virtual clock: `ticks * virtual_step` (advanced at the top of
    /// every step, deterministic). Wall clock: summed measured compute
    /// seconds (no extra `Instant` reads on the engine path).
    elapsed_us: u64,
    // Accumulated report state (across generate calls).
    steps: usize,
    prefill_tokens: usize,
    decode_tokens: usize,
    prefill_secs: f32,
    decode_secs: f32,
    occupancy_sum: f32,
    completed: usize,
    rejected: usize,
    reject_counts: RejectCounts,
    cancelled: usize,
    deadline_exceeded: usize,
    quarantined: usize,
    step_faults: usize,
    step_retried: usize,
}

impl<'rt> Engine<'rt> {
    /// Build an engine over a quantized model: prepares the weight
    /// bundle once — by default through the runtime's prepared-state map
    /// (dequantize-once packed panels on the native backend, DESIGN.md
    /// §11; shared across engines over the same artifact) — and sizes
    /// the KV store (paged block pool by default, dense `[L, slots, seq,
    /// d]` slabs with `paged: false`).
    pub fn new(
        rt: &'rt Runtime,
        cfg: &ModelConfig,
        params: &Params,
        qm: &QuantizedModel,
        gen: GenConfig,
    ) -> Result<Self> {
        let slots = match gen.slots {
            0 => cfg.batch,
            n => n,
        };
        let lits = qmodel_literals(params, qm)?;
        if gen.int_compute && !gen.prepared {
            bail!("int_compute requires prepared weights (GenConfig.prepared)");
        }
        let weight_bufs = if gen.prepared {
            rt.prepare_qweights(&cfg.name, &lits)?
        } else {
            std::sync::Arc::new(
                lits.iter()
                    .map(|l| rt.upload_literal(l))
                    .collect::<Result<Vec<_>>>()?,
            )
        };
        // Fail fast at construction, not mid-step: a bundle whose codes
        // don't fit int4 can never serve the int path.
        if gen.int_compute {
            if let Some(Buffer::PreparedQ(pm)) = weight_bufs.first() {
                if let Some(reason) = pm.int_reason() {
                    bail!("int_compute unavailable for this artifact — {reason}");
                }
            }
        }
        let trace = if gen.trace {
            match gen.virtual_step {
                Some(step) => {
                    Trace::virtual_clock(u64::try_from(step.as_micros()).unwrap_or(u64::MAX))
                }
                None => Trace::wall_clock(),
            }
        } else {
            Trace::disabled()
        };
        let store = if gen.paged {
            KvStore::Paged(PagedKv::new(
                cfg,
                slots,
                gen.block_tokens,
                gen.pool_blocks,
                gen.prefix_cache,
                trace.clone(),
            ))
        } else {
            KvStore::Dense(KvCache::new(cfg.n_layer, slots, cfg.seq, cfg.d_model))
        };
        let clock = EngineClock::new(gen.virtual_step);
        let mut metrics = Metrics::new();
        metrics.register_hist("ttft_us");
        metrics.register_hist("per_token_us");
        metrics.register_hist("queue_wait_us");
        Ok(Self {
            rt,
            cfg: cfg.clone(),
            gen,
            weight_bufs,
            store,
            slots: (0..slots).map(|_| None).collect(),
            queue: VecDeque::new(),
            clock,
            ticks: 0,
            draining: false,
            fault: None,
            trace,
            metrics,
            elapsed_us: 0,
            steps: 0,
            prefill_tokens: 0,
            decode_tokens: 0,
            prefill_secs: 0.0,
            decode_secs: 0.0,
            occupancy_sum: 0.0,
            completed: 0,
            rejected: 0,
            reject_counts: RejectCounts::default(),
            cancelled: 0,
            deadline_exceeded: 0,
            quarantined: 0,
            step_faults: 0,
            step_retried: 0,
        })
    }

    /// Sequence-capacity cap in tokens (`prompt + max_new` must fit).
    fn capacity(&self) -> usize {
        match &self.store {
            KvStore::Dense(cache) => cache.t_max(),
            KvStore::Paged(ps) => ps.capacity(),
        }
    }

    /// Why a request cannot be admitted, if anything.
    pub fn validate(&self, req: &GenRequest) -> Option<RejectReason> {
        if req.prompt.is_empty() {
            return Some(RejectReason::EmptyPrompt);
        }
        if req.max_new == 0 {
            return Some(RejectReason::ZeroMaxNew);
        }
        for (index, &id) in req.prompt.iter().enumerate() {
            if id < 0 || id as usize >= self.cfg.vocab {
                return Some(RejectReason::TokenOutOfRange { index, id });
            }
        }
        let cap = self.capacity();
        if req.prompt.len() + req.max_new > cap {
            return Some(RejectReason::TooLong {
                prompt: req.prompt.len(),
                max_new: req.max_new,
                cap,
            });
        }
        None
    }

    /// Enqueue a request. Returns `Some(rejected output)` immediately
    /// when the request cannot be admitted; `None` means it is queued and
    /// will surface from a later [`Engine::step`].
    pub fn submit(&mut self, req: GenRequest) -> Option<GenOutput> {
        let reason = if self.draining {
            Some(RejectReason::Draining)
        } else if self.gen.max_queue > 0 && self.queue.len() >= self.gen.max_queue {
            Some(RejectReason::QueueFull {
                limit: self.gen.max_queue,
            })
        } else {
            self.validate(&req)
        };
        if let Some(reason) = reason {
            self.rejected += 1;
            self.reject_counts.note(&reason);
            self.metrics.inc("rejected", 1);
            self.trace.emit(
                self.ticks as u64,
                TraceEvent::Reject {
                    id: req.id,
                    cause: reason.cause(),
                },
            );
            return Some(GenOutput {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                finish: FinishReason::Rejected(reason),
            });
        }
        let sampler =
            Sampler::for_sequence(self.gen.temperature, self.gen.top_k, self.gen.seed, req.id);
        // The deadline is a budget relative to submission, resolved to an
        // absolute engine-clock instant here (checked: an absurd budget
        // that overflows the clock simply means "no deadline").
        let deadline_at = req
            .deadline
            .and_then(|budget| self.clock.now(self.ticks).checked_add(budget));
        self.metrics.inc("submitted", 1);
        self.trace
            .emit(self.ticks as u64, TraceEvent::Submit { id: req.id });
        self.queue.push_back(SeqState {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens: req.prompt,
            cursor: 0,
            max_new: req.max_new,
            stop_id: req.stop_id,
            sampler,
            deadline_at,
            cancel: req.cancel,
            queued_us: self.elapsed_us,
        });
        None
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(Option::is_some)
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// Stop admitting new work: every later [`Engine::submit`] rejects
    /// with [`RejectReason::Draining`], while queued and running
    /// sequences run to completion through further [`Engine::step`]
    /// calls. Irreversible for the engine's lifetime (DESIGN.md §14).
    pub fn begin_drain(&mut self) {
        if !self.draining {
            self.trace.emit(self.ticks as u64, TraceEvent::Drain);
        }
        self.draining = true;
    }

    pub fn draining(&self) -> bool {
        self.draining
    }

    /// Install a fault injector (deterministic failure harness,
    /// `testutil::faults`). Production engines never call this.
    pub fn set_fault_injector(&mut self, fault: Box<dyn FaultInjector>) {
        self.fault = Some(fault);
    }

    /// Drop every cached prefix, releasing its block references. Returns
    /// the number of block references released. After a drain this takes
    /// the pool back to fully free (`BlockPool::assert_all_free`) — the
    /// fault harness's leak check.
    pub fn flush_prefix_cache(&mut self) -> Result<usize> {
        let KvStore::Paged(ps) = &mut self.store else {
            return Ok(0);
        };
        let mut dropped = 0usize;
        while let Some(blocks) = ps.tree.evict_lru() {
            for b in blocks {
                ps.pool.release(b)?;
                dropped += 1;
            }
        }
        Ok(dropped)
    }

    /// Count an abnormal completion in the report totals.
    fn note_abnormal_finish(&mut self, finish: &FinishReason) {
        match finish {
            FinishReason::Cancelled => self.cancelled += 1,
            FinishReason::DeadlineExceeded => self.deadline_exceeded += 1,
            FinishReason::Rejected(reason) => {
                self.quarantined += 1;
                self.rejected += 1;
                self.reject_counts.note(reason);
            }
            FinishReason::Stop | FinishReason::MaxTokens => {}
        }
    }

    /// Evict the sequence in `slot` with an abnormal finish: release its
    /// blocks and reservation (never caching its prefix), count it, and
    /// emit whatever tokens it had produced so far (always a bitwise
    /// prefix of the fault-free stream — samplers are keyed by request
    /// id and failed compute attempts change no state).
    fn evict_slot(&mut self, slot: usize, finish: FinishReason) -> Result<Option<GenOutput>> {
        let taken = self
            .slots
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))?
            .take();
        let Some(st) = taken else {
            return Ok(None);
        };
        if let KvStore::Paged(ps) = &mut self.store {
            ps.on_abort(slot)?;
        }
        self.note_abnormal_finish(&finish);
        Ok(Some(GenOutput {
            id: st.id,
            prompt_len: st.prompt_len,
            tokens: st.tokens.get(st.prompt_len..).unwrap_or_default().to_vec(),
            finish,
        }))
    }

    /// Lifecycle sweep: finish every queued or running sequence whose
    /// cancel token fired or whose deadline expired on the engine clock.
    /// Runs at the top of each step, so a cancel is observed within one
    /// step's latency and an expired deadline never feeds another token.
    fn sweep_lifecycle(&mut self) -> Result<Vec<GenOutput>> {
        let now = self.clock.now(self.ticks);
        let mut finished = Vec::new();
        // Queued first (cheap: no store state to release). Keeper order
        // is preserved — admission stays FIFO.
        let queued = std::mem::take(&mut self.queue);
        for st in queued {
            match lifecycle_fate(&st, now) {
                Some(finish) => {
                    self.trace_lifecycle(st.id, &finish);
                    self.note_abnormal_finish(&finish);
                    finished.push(GenOutput {
                        id: st.id,
                        prompt_len: st.prompt_len,
                        tokens: Vec::new(),
                        finish,
                    });
                }
                None => self.queue.push_back(st),
            }
        }
        for slot in 0..self.slots.len() {
            let fate = self
                .slots
                .get(slot)
                .and_then(|s| s.as_ref())
                .and_then(|st| lifecycle_fate(st, now).map(|f| (st.id, f)));
            if let Some((id, finish)) = fate {
                self.trace_lifecycle(id, &finish);
                if let Some(out) = self.evict_slot(slot, finish)? {
                    finished.push(out);
                }
            }
        }
        Ok(finished)
    }

    /// Trace a lifecycle exit (cancel / deadline) for request `id`.
    fn trace_lifecycle(&self, id: usize, finish: &FinishReason) {
        let ev = match finish {
            FinishReason::Cancelled => TraceEvent::Cancel { id },
            FinishReason::DeadlineExceeded => TraceEvent::Deadline { id },
            _ => return,
        };
        self.trace.emit(self.ticks as u64, ev);
    }

    /// Admit queued sequences into free slots. Dense: a free slot is all
    /// it takes. Paged: the head of the queue also needs its worst-case
    /// block reservation (FIFO — a stuck head does not let later
    /// requests starve it of blocks).
    fn admit(&mut self) -> Result<()> {
        // Fault seam: a stalled tick behaves exactly like a pool with no
        // free capacity — queued requests keep waiting, nothing changes.
        let stalled = match self.fault.as_mut() {
            Some(f) => f.stall_admission(self.ticks),
            None => false,
        };
        if stalled {
            return Ok(());
        }
        let tick = self.ticks as u64;
        let elapsed = self.elapsed_us;
        let Self {
            slots,
            store,
            queue,
            trace,
            metrics,
            ..
        } = self;
        for (slot, slot_ref) in slots.iter_mut().enumerate() {
            if slot_ref.is_some() {
                continue;
            }
            let Some(mut head) = queue.pop_front() else {
                break;
            };
            match store {
                KvStore::Dense(cache) => {
                    cache.reset(slot);
                    metrics.observe("queue_wait_us", elapsed.saturating_sub(head.queued_us));
                    trace.emit(
                        tick,
                        TraceEvent::Admit {
                            id: head.id,
                            slot,
                            start: 0,
                        },
                    );
                    trace.emit(
                        tick,
                        TraceEvent::PrefillBegin {
                            id: head.id,
                            slot,
                            tokens: head.prompt_len,
                        },
                    );
                    *slot_ref = Some(head);
                }
                KvStore::Paged(ps) => {
                    let admitted =
                        match ps.try_admit(slot, &head.tokens, head.prompt_len, head.max_new) {
                            Ok(a) => a,
                            Err(e) => {
                                // Keep the request queued; the error is the
                                // caller's to handle.
                                queue.push_front(head);
                                return Err(e);
                            }
                        };
                    match admitted {
                        Some(start) => {
                            head.cursor = start;
                            metrics
                                .observe("queue_wait_us", elapsed.saturating_sub(head.queued_us));
                            if start > 0 {
                                trace.emit(
                                    tick,
                                    TraceEvent::PrefixHit {
                                        id: head.id,
                                        tokens: start,
                                    },
                                );
                            }
                            trace.emit(
                                tick,
                                TraceEvent::Admit {
                                    id: head.id,
                                    slot,
                                    start,
                                },
                            );
                            trace.emit(
                                tick,
                                TraceEvent::PrefillBegin {
                                    id: head.id,
                                    slot,
                                    tokens: head.prompt_len - start,
                                },
                            );
                            *slot_ref = Some(head);
                        }
                        // Head must wait for blocks; keep FIFO order.
                        None => {
                            queue.push_front(head);
                            break;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Run one engine tick: lifecycle sweep (cancellations, expired
    /// deadlines), admission, ONE batched decode step — with bounded
    /// retry and quarantine bisection on compute failure — and return
    /// the sequences that finished on it.
    pub fn step(&mut self) -> Result<Vec<GenOutput>> {
        // Tick first: the counter advances on EVERY call (success or
        // failure), so the virtual clock and fault schedule see a
        // monotone timeline regardless of what this step does.
        self.ticks += 1;
        let tick = self.ticks as u64;
        if let Some(step) = self.gen.virtual_step {
            // Virtual timebase advances per tick (matching EngineClock),
            // computed step or not, so elapsed_us == ticks * step.
            self.elapsed_us = self
                .elapsed_us
                .saturating_add(u64::try_from(step.as_micros()).unwrap_or(u64::MAX));
        }
        if let KvStore::Paged(ps) = &mut self.store {
            ps.set_tick(tick);
        }
        let mut finished = self.sweep_lifecycle()?;
        self.admit()?;

        // Compute with bounded retry, then — if the batch still fails —
        // a one-slot-masked bisection: probe with each occupied slot
        // withheld in turn until an attempt succeeds; the masked slot
        // holds the poisoned sequence. Failed attempts change no engine
        // state (KV appends and sampler draws happen only after
        // success), so every retry and probe re-executes an identical
        // batch and survivors' streams stay bit-for-bit.
        let mut masked: Option<usize> = None;
        let mut attempt = 0usize;
        let mut last_err: Option<anyhow::Error> = None;
        let computed = loop {
            match self.compute_step(masked, attempt) {
                Ok(out) => break out,
                Err(err) => {
                    self.step_faults += 1;
                    self.trace.emit(tick, TraceEvent::StepRetry { attempt });
                    attempt += 1;
                    if masked.is_none() && attempt <= self.gen.step_retries {
                        // Transient budget: same batch, try again.
                        self.step_retried += 1;
                        last_err = Some(err);
                        continue;
                    }
                    let from = match masked {
                        None => 0,
                        Some(m) => m + 1,
                    };
                    last_err = Some(err);
                    let next = self
                        .slots
                        .iter()
                        .enumerate()
                        .skip(from)
                        .find_map(|(i, s)| s.as_ref().map(|_| i));
                    match next {
                        Some(m) => masked = Some(m),
                        None => {
                            // Every occupied slot was probed and the
                            // batch still fails: not one bad request
                            // but a broken backend. Surface it.
                            return Err(
                                last_err.unwrap_or_else(|| anyhow!("decode step failed"))
                            );
                        }
                    }
                }
            }
        };
        if let Some(slot) = masked {
            let detail = match &last_err {
                Some(e) => format!("decode step failed; quarantined after bisection: {e:#}"),
                None => "decode step failed".to_string(),
            };
            let finish = FinishReason::Rejected(RejectReason::Internal { detail });
            if let Some(id) = self.slots.get(slot).and_then(|s| s.as_ref()).map(|st| st.id) {
                self.trace.emit(tick, TraceEvent::Quarantine { id });
            }
            if let Some(out) = self.evict_slot(slot, finish)? {
                finished.push(out);
            }
        }
        let Some(stepd) = computed else {
            return Ok(finished);
        };

        self.steps += 1;
        let b = self.slots.len();
        let vocab = self.cfg.vocab;
        self.occupancy_sum += stepd.feeds as f32 / b as f32;
        self.prefill_secs += stepd.secs * stepd.prefill_feeds as f32 / stepd.feeds as f32;
        self.decode_secs += stepd.secs * stepd.decode_feeds as f32 / stepd.feeds as f32;
        self.prefill_tokens += stepd.prefill_feeds;
        // Metrics timebase: virtual mode already advanced at the top of
        // the step; wall mode accumulates the measured compute time.
        let step_us = match self.gen.virtual_step {
            Some(step) => u64::try_from(step.as_micros()).unwrap_or(u64::MAX),
            None => {
                let us = (f64::from(stepd.secs) * 1e6) as u64;
                self.elapsed_us = self.elapsed_us.saturating_add(us);
                us
            }
        };
        self.trace.emit(
            tick,
            TraceEvent::Step {
                batch: stepd.feeds,
                prefill: stepd.prefill_feeds,
                decode: stepd.decode_feeds,
            },
        );
        self.metrics.inc("steps", 1);
        for _ in 0..stepd.decode_feeds {
            self.metrics.observe("per_token_us", step_us);
        }
        if let KvStore::Paged(ps) = &self.store {
            let in_use = ps.pool.in_use_blocks() as u64;
            let cached = ps.tree.cached_tokens() as u64;
            self.metrics.set_gauge("pool_in_use_blocks", in_use);
            self.metrics.max_gauge("pool_peak_blocks", in_use);
            self.metrics.set_gauge("prefix_cached_tokens", cached);
        }

        let mut outs = stepd.outs.into_iter();
        let (Some(logits_v), Some(k_v), Some(v_v)) = (outs.next(), outs.next(), outs.next())
        else {
            bail!("decode step returned fewer than three outputs");
        };
        let logits = logits_v.as_f32()?;
        let k_new = k_v.as_f32()?;
        let v_new = v_v.as_f32()?;
        let elapsed = self.elapsed_us;
        let Self {
            slots,
            store,
            decode_tokens,
            completed,
            trace,
            metrics,
            ..
        } = self;
        for (slot, slot_ref) in slots.iter_mut().enumerate() {
            let Some(st) = slot_ref.as_mut() else { continue };
            match store {
                KvStore::Dense(cache) => cache.append(slot, k_new, v_new)?,
                KvStore::Paged(ps) => ps.append_row(slot, st.cursor, k_new, v_new)?,
            }
            let was_prefill = st.cursor < st.prompt_len;
            st.cursor += 1;
            if was_prefill && st.cursor >= st.prompt_len {
                // The last prompt position just fed: prefill is over
                // (its logits seed the first sample below).
                trace.emit(tick, TraceEvent::PrefillEnd { id: st.id, slot });
            }
            let mut fin = None;
            if st.cursor >= st.prompt_len {
                // This feed's logits predict the next position.
                let row = logits
                    .data()
                    .get(slot * vocab..(slot + 1) * vocab)
                    .ok_or_else(|| anyhow!("logits row {slot} out of range"))?;
                let next = st.sampler.sample(row) as i32;
                if st.stop_id == Some(next) {
                    fin = Some(FinishReason::Stop);
                } else {
                    st.tokens.push(next);
                    *decode_tokens += 1;
                    if st.tokens.len() == st.prompt_len + 1 {
                        // First generated token: time-to-first-token.
                        metrics.observe("ttft_us", elapsed.saturating_sub(st.queued_us));
                    }
                    if st.tokens.len() - st.prompt_len >= st.max_new {
                        fin = Some(FinishReason::MaxTokens);
                    }
                }
            }
            let Some(finish) = fin else { continue };
            if let KvStore::Paged(ps) = store {
                ps.on_finish(slot, st.cursor, &st.tokens)?;
            }
            let Some(st) = slot_ref.take() else { continue };
            let cause = match finish {
                FinishReason::Stop => "stop",
                _ => "max_tokens",
            };
            trace.emit(
                tick,
                TraceEvent::Finish {
                    id: st.id,
                    slot,
                    tokens: st.tokens.len() - st.prompt_len,
                    cause,
                },
            );
            metrics.inc("completed", 1);
            finished.push(GenOutput {
                id: st.id,
                prompt_len: st.prompt_len,
                tokens: st.tokens.get(st.prompt_len..).unwrap_or_default().to_vec(),
                finish,
            });
            *completed += 1;
        }
        Ok(finished)
    }

    /// Build and execute ONE batched decode attempt, withholding the
    /// `masked` slot (quarantine bisection probe). Returns `Ok(None)`
    /// when nothing would feed. A failed attempt leaves every KV slab,
    /// block table, cursor, and sampler untouched — the caller may
    /// retry or probe again and get the identical batch.
    fn compute_step(&mut self, masked: Option<usize>, attempt: usize) -> Result<Option<StepOut>> {
        let b = self.slots.len();
        let mut pos = vec![-1i32; b];
        let mut tok = vec![0i32; b];
        let mut prefill_feeds = 0usize;
        let mut decode_feeds = 0usize;
        let mut fed_ids = Vec::new();
        for (slot, ((p, t), st)) in pos
            .iter_mut()
            .zip(tok.iter_mut())
            .zip(&self.slots)
            .enumerate()
        {
            let Some(st) = st else { continue };
            if masked == Some(slot) {
                continue;
            }
            *p = st.cursor as i32;
            *t = st
                .tokens
                .get(st.cursor)
                .copied()
                .ok_or_else(|| anyhow!("sequence {}: cursor past its token stream", st.id))?;
            fed_ids.push(st.id);
            if st.cursor < st.prompt_len {
                prefill_feeds += 1;
            } else {
                decode_feeds += 1;
            }
        }
        let feeds = prefill_feeds + decode_feeds;
        if feeds == 0 {
            return Ok(None);
        }
        if let Some(fault) = self.fault.as_mut() {
            fault.before_attempt(self.ticks, attempt, &fed_ids)?;
        }

        // faq-lint: allow(untracked-clock) — measures backend compute
        // time for the report's prefill/decode split; never feeds
        // scheduling decisions (deadlines go through EngineClock).
        let t0 = Instant::now();
        let pos_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b], pos)?));
        let tok_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b], tok)?));
        let outs = match &mut self.store {
            KvStore::Dense(cache) => {
                let (kt, vt) = cache.take()?;
                let k_buf = Buffer::Host(Value::F32(kt));
                let v_buf = Buffer::Host(Value::F32(vt));
                let entry = if self.gen.int_compute {
                    "decode_step_qi"
                } else {
                    "decode_step_q"
                };
                let outs = {
                    let mut args: Vec<&Buffer> = self.weight_bufs.iter().collect();
                    args.extend([&k_buf, &v_buf, &pos_buf, &tok_buf]);
                    self.rt.exec_b(&self.cfg.name, entry, &args)
                };
                // The slabs go back whether or not the step succeeded.
                match (k_buf, v_buf) {
                    (Buffer::Host(Value::F32(k)), Buffer::Host(Value::F32(v))) => {
                        cache.put_back(k, v)?
                    }
                    _ => bail!("KV slabs must stay host-resident"),
                }
                outs
            }
            KvStore::Paged(ps) => {
                let mut tables = vec![-1i32; b * ps.max_blocks];
                for (row, table) in tables.chunks_mut(ps.max_blocks).zip(&ps.tables) {
                    if table.len() > row.len() {
                        bail!("block table wider than {} blocks", ps.max_blocks);
                    }
                    for (cell, &blk) in row.iter_mut().zip(table) {
                        *cell = blk as i32;
                    }
                }
                let tb_buf = Buffer::Host(Value::I32(TensorI32::from_vec(
                    &[b, ps.max_blocks],
                    tables,
                )?));
                let (kt, vt) = ps.pool.take()?;
                let k_buf = Buffer::Host(Value::F32(kt));
                let v_buf = Buffer::Host(Value::F32(vt));
                let entry = if self.gen.int_compute {
                    "decode_step_paged_qi"
                } else {
                    "decode_step_paged_q"
                };
                let outs = {
                    let mut args: Vec<&Buffer> = self.weight_bufs.iter().collect();
                    args.extend([&k_buf, &v_buf, &tb_buf, &pos_buf, &tok_buf]);
                    self.rt.exec_b(&self.cfg.name, entry, &args)
                };
                match (k_buf, v_buf) {
                    (Buffer::Host(Value::F32(k)), Buffer::Host(Value::F32(v))) => {
                        ps.pool.put_back(k, v)?
                    }
                    _ => bail!("KV pool must stay host-resident"),
                }
                outs
            }
        };
        let outs = outs?;
        let secs = t0.elapsed().as_secs_f32();
        Ok(Some(StepOut {
            outs,
            prefill_feeds,
            decode_feeds,
            feeds,
            secs,
        }))
    }

    /// Snapshot of the accumulated throughput/occupancy counters.
    pub fn report(&self) -> GenReport {
        let (prefix_hit_tokens, peak_blocks_in_use, pool_blocks, block_tokens, evicted_blocks) =
            match &self.store {
                KvStore::Dense(_) => (0, 0, 0, 0, 0),
                KvStore::Paged(ps) => (
                    ps.prefix_hit_tokens,
                    ps.peak_in_use,
                    ps.pool.n_blocks(),
                    ps.block_tokens,
                    ps.evicted_refs,
                ),
            };
        GenReport {
            sequences: self.completed,
            rejected: self.rejected,
            reject_counts: self.reject_counts.clone(),
            steps: self.steps,
            prefill_tokens: self.prefill_tokens,
            decode_tokens: self.decode_tokens,
            prefill_secs: self.prefill_secs,
            decode_secs: self.decode_secs,
            mean_slot_occupancy: if self.steps > 0 {
                self.occupancy_sum / self.steps as f32
            } else {
                0.0
            },
            prefix_hit_tokens,
            peak_blocks_in_use,
            pool_blocks,
            block_tokens,
            evicted_blocks,
            cancelled: self.cancelled,
            deadline_exceeded: self.deadline_exceeded,
            quarantined: self.quarantined,
            step_faults: self.step_faults,
            step_retried: self.step_retried,
            latency: self.latency(),
        }
    }

    /// Percentile summary of the engine's latency histograms.
    pub fn latency(&self) -> LatencyStats {
        let empty = Hist::new();
        let h = |name: &str| self.metrics.hist(name).unwrap_or(&empty);
        LatencyStats::from_hists(h("ttft_us"), h("per_token_us"), h("queue_wait_us"))
    }

    /// The engine's trace handle (no-op unless `GenConfig::trace`).
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// The engine's metrics registry (counters, gauges, histograms).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Paged-pool snapshot `(free, in_use, pool_blocks, reserved_total)`;
    /// `None` on the dense engine.
    pub fn pool_stats(&self) -> Option<(usize, usize, usize, usize)> {
        match &self.store {
            KvStore::Dense(_) => None,
            KvStore::Paged(ps) => Some((
                ps.pool.free_blocks(),
                ps.pool.in_use_blocks(),
                ps.pool.n_blocks(),
                ps.reserved_total,
            )),
        }
    }

    /// Assert the paged pool is fully free — the post-drain,
    /// post-[`Engine::flush_prefix_cache`] leak check (names leaked
    /// blocks on failure). No-op on the dense engine.
    pub fn assert_pool_all_free(&self) -> Result<()> {
        match &self.store {
            KvStore::Dense(_) => Ok(()),
            KvStore::Paged(ps) => ps.pool.assert_all_free(),
        }
    }

    /// Live radix-tree node count; `None` on the dense engine.
    pub fn prefix_cache_nodes(&self) -> Option<usize> {
        match &self.store {
            KvStore::Dense(_) => None,
            KvStore::Paged(ps) => Some(ps.tree.node_count()),
        }
    }

    /// Verify every paged-store invariant (no-op on the dense engine).
    /// The differential fuzz harness calls this after every step:
    ///
    /// 1. pool partition: `free + in_use == pool_blocks`, refcount 0
    ///    exactly for free-listed blocks (no underflow can have happened —
    ///    `release` fails loudly instead of wrapping);
    /// 2. refcount accounting: each block's refcount equals its
    ///    references from slot tables plus the radix tree;
    /// 3. reservations are backed: `free >= reserved_total`, and each
    ///    active slot's `table + reserved` covers its worst case;
    /// 4. copy-on-write safety: a block shared by two active sequences
    ///    sits at the same block index and both sequences' tokens agree
    ///    through the shared span (diverged sequences share nothing).
    pub fn check_paged_invariants(&self) -> Result<()> {
        let KvStore::Paged(ps) = &self.store else {
            return Ok(());
        };
        ps.pool.check_invariants()?;
        if ps.pool.free_blocks() < ps.reserved_total {
            bail!(
                "reservations unbacked: {} free < {} reserved",
                ps.pool.free_blocks(),
                ps.reserved_total
            );
        }
        if ps.reserved.iter().sum::<usize>() != ps.reserved_total {
            bail!("reserved_total out of sync with per-slot reservations");
        }
        let mut want = ps.tree.block_refs();
        for table in &ps.tables {
            for &b in table {
                *want.entry(b).or_insert(0) += 1;
            }
        }
        for b in 0..ps.pool.n_blocks() as u32 {
            let rc = ps.pool.refcount(b);
            let w = want.get(&b).copied().unwrap_or(0);
            if rc != w {
                bail!("block {b}: refcount {rc} != {w} (tables + tree)");
            }
        }
        let bt = ps.block_tokens;
        if ps.tables.len() != self.slots.len() || ps.reserved.len() != self.slots.len() {
            bail!("paged per-slot arrays out of sync with the slot count");
        }
        for (slot, ((st, table), &reserved)) in self
            .slots
            .iter()
            .zip(&ps.tables)
            .zip(&ps.reserved)
            .enumerate()
        {
            match st {
                None => {
                    if !table.is_empty() || reserved != 0 {
                        bail!("empty slot {slot} holds blocks or reservations");
                    }
                }
                Some(st) => {
                    if table.len() != st.cursor.div_ceil(bt) {
                        bail!(
                            "slot {slot}: table {} blocks != ceil(cursor {} / {bt})",
                            table.len(),
                            st.cursor
                        );
                    }
                    let need = (st.prompt_len + st.max_new - 1).div_ceil(bt);
                    if table.len() + reserved != need {
                        bail!(
                            "slot {slot}: table {} + reserved {reserved} != worst case {need}",
                            table.len()
                        );
                    }
                }
            }
        }
        for (a, (sa, ta)) in self.slots.iter().zip(&ps.tables).enumerate() {
            for (c, (sc, tc)) in self.slots.iter().zip(&ps.tables).enumerate().skip(a + 1) {
                let (Some(sa), Some(sc)) = (sa, sc) else {
                    continue;
                };
                for (ia, &ba) in ta.iter().enumerate() {
                    for (ic, &bc) in tc.iter().enumerate() {
                        if ba != bc {
                            continue;
                        }
                        if ia != ic {
                            bail!("block {ba} shared at different positions {ia}/{ic}");
                        }
                        let l = ((ia + 1) * bt).min(sa.cursor).min(sc.cursor);
                        let (Some(pa), Some(pc)) = (sa.tokens.get(..l), sc.tokens.get(..l))
                        else {
                            bail!("slots {a}/{c}: cursor past the token stream");
                        };
                        if pa != pc {
                            bail!(
                                "diverged sequences in slots {a}/{c} share block {ba}"
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Convenience driver: submit everything, step until drained, return
    /// outputs sorted by request id plus the report snapshot.
    pub fn generate(&mut self, reqs: Vec<GenRequest>) -> Result<(Vec<GenOutput>, GenReport)> {
        let mut outs = Vec::with_capacity(reqs.len());
        for r in reqs {
            if let Some(rejected) = self.submit(r) {
                outs.push(rejected);
            }
        }
        while self.has_work() {
            outs.extend(self.step()?);
        }
        outs.sort_by_key(|o| o.id);
        Ok((outs, self.report()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::testutil::fixtures;

    fn pico_model(rt: &Runtime) -> (ModelConfig, Params, QuantizedModel) {
        fixtures::quantized_pico(rt, Method::Rtn, 11)
    }

    #[test]
    fn generate_greedy_runs_and_reports() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let reqs: Vec<GenRequest> = (0..6)
            .map(|i| GenRequest {
                id: i,
                prompt: vec![(i as i32 * 3) % cfg.vocab as i32, 1, 2, 5],
                max_new: 4,
                stop_id: None,
                ..Default::default()
            })
            .collect();
        let (outs, rep) = eng.generate(reqs).unwrap();
        assert_eq!(outs.len(), 6);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.id, i);
            assert_eq!(o.finish, FinishReason::MaxTokens);
            assert_eq!(o.tokens.len(), 4);
            assert!(o.tokens.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
        }
        assert_eq!(rep.sequences, 6);
        assert_eq!(rep.rejected, 0);
        // 6 sequences x 4 prompt tokens; decode tokens delivered = 6 x 4.
        assert_eq!(rep.prefill_tokens, 24);
        assert_eq!(rep.decode_tokens, 24);
        assert!(rep.steps >= 7, "6 seqs over 4 slots need two waves");
        assert!(rep.mean_slot_occupancy > 0.0 && rep.mean_slot_occupancy <= 1.0);
        // Default engine is paged; everything is released at drain.
        assert!(rep.pool_blocks > 0 && rep.block_tokens > 0);
        eng.check_paged_invariants().unwrap();
    }

    #[test]
    fn paged_and_dense_generate_identical_tokens() {
        // THE tentpole contract at engine level: the block-paged store
        // (with prefix sharing enabled) produces exactly the dense
        // engine's token streams (DESIGN.md §12; testutil::fuzz sweeps
        // this over random workloads and thread counts).
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let reqs = || -> Vec<GenRequest> {
            (0..6)
                .map(|i| GenRequest {
                    id: i,
                    // Three pairs sharing a prompt: the second of each
                    // pair hits the prefix cache on the paged engine.
                    prompt: (0..10)
                        .map(|k| ((k * 3 + (i / 2) * 17) % cfg.vocab) as i32)
                        .collect(),
                    max_new: 5,
                    stop_id: None,
                    ..Default::default()
                })
                .collect()
        };
        let run = |paged: bool, block_tokens: usize| -> Vec<Vec<i32>> {
            let gen = GenConfig {
                temperature: 0.8,
                top_k: 6,
                seed: 99,
                slots: 3,
                paged,
                block_tokens,
                ..GenConfig::default()
            };
            let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
            let (outs, rep) = eng.generate(reqs()).unwrap();
            eng.check_paged_invariants().unwrap();
            if paged {
                assert!(
                    rep.prefix_hit_tokens > 0,
                    "repeated prompts should hit the prefix cache"
                );
                let (free, in_use, pool, reserved) = eng.pool_stats().unwrap();
                assert_eq!(free + in_use, pool);
                assert_eq!(reserved, 0, "drained engine holds no reservations");
            }
            outs.into_iter().map(|o| o.tokens).collect()
        };
        let dense = run(false, 0);
        assert_eq!(dense, run(true, 4), "paged (bt=4) diverged from dense");
        assert_eq!(dense, run(true, 3), "paged (bt=3) diverged from dense");
    }

    #[test]
    fn prefix_cache_skips_prefill_for_repeated_prompts() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let prompt: Vec<i32> = (0..9).map(|k| ((k * 5 + 2) % cfg.vocab) as i32).collect();
        let gen = GenConfig {
            block_tokens: 4,
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let req = |id| GenRequest {
            id,
            prompt: prompt.clone(),
            max_new: 3,
            stop_id: None,
            ..Default::default()
        };
        let (outs_a, rep_a) = eng.generate(vec![req(0)]).unwrap();
        assert_eq!(rep_a.prefix_hit_tokens, 0, "nothing cached yet");
        assert_eq!(rep_a.prefill_tokens, 9);
        // Same prompt again: 8 of 9 prompt tokens (two full bt=4 blocks;
        // the last prompt token always feeds) come from the cache.
        let (outs_b, rep_b) = eng.generate(vec![req(1)]).unwrap();
        assert_eq!(rep_b.prefix_hit_tokens, 8);
        assert_eq!(rep_b.prefill_tokens - rep_a.prefill_tokens, 1);
        // Greedy + same prompt => identical continuations.
        assert_eq!(outs_a[0].tokens, outs_b[0].tokens);
        eng.check_paged_invariants().unwrap();
        assert!(eng.prefix_cache_nodes().unwrap() > 0);
    }

    #[test]
    fn small_pool_admits_by_blocks_and_evicts_cached_prefixes() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        // 4 slots but only 6 blocks of 4 tokens: a request needing 3
        // blocks limits concurrency to 2 in-flight sequences, and cached
        // prefixes must be evicted to admit fresh prompts.
        let gen = GenConfig {
            slots: 4,
            block_tokens: 4,
            pool_blocks: 6,
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let reqs: Vec<GenRequest> = (0..5)
            .map(|i| GenRequest {
                id: i,
                prompt: (0..8).map(|k| ((k * 7 + i * 31) % cfg.vocab) as i32).collect(),
                max_new: 4,
                stop_id: None,
                ..Default::default()
            })
            .collect();
        let (outs, rep) = eng.generate(reqs).unwrap();
        assert_eq!(outs.len(), 5);
        assert!(outs.iter().all(|o| o.finish == FinishReason::MaxTokens));
        assert!(rep.evicted_blocks > 0, "tight pool must evict cached prefixes");
        assert!(rep.peak_blocks_in_use <= rep.pool_blocks);
        eng.check_paged_invariants().unwrap();
    }

    #[test]
    fn paged_capacity_rejects_what_the_pool_cannot_ever_hold() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let gen = GenConfig {
            slots: 2,
            block_tokens: 4,
            pool_blocks: 3, // capacity: 3 * 4 + 1 = 13 tokens
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let req = |id, prompt_len: usize, max_new| GenRequest {
            id,
            prompt: (0..prompt_len).map(|k| (k % cfg.vocab) as i32).collect(),
            max_new,
            stop_id: None,
            ..Default::default()
        };
        let (outs, rep) = eng.generate(vec![req(0, 10, 4), req(1, 9, 4)]).unwrap();
        assert!(matches!(
            outs[0].finish,
            FinishReason::Rejected(RejectReason::TooLong { cap: 13, .. })
        ));
        assert_eq!(outs[1].finish, FinishReason::MaxTokens);
        assert_eq!(rep.rejected, 1);
    }

    #[test]
    fn exact_capacity_partial_prefix_hit_falls_back_instead_of_livelocking() {
        // Regression: pool_blocks=3, bt=4 (capacity 13). Complete a 9+4
        // request so the prefix cache holds all three blocks (free = 0),
        // then submit a request whose 10-token prompt extends the cached
        // stream with max_new 3 (10 + 3 = 13 — exact capacity). Its
        // prefix match ends mid-block; the pinned copy-on-write source
        // makes the free target unreachable, so admission must round
        // the hit down to the 8-token block boundary (evicting the
        // cached entry, pins keeping the shared blocks alive) rather
        // than spin forever.
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let gen = GenConfig {
            slots: 2,
            block_tokens: 4,
            pool_blocks: 3,
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let prompt: Vec<i32> = (0..9).map(|k| ((k * 3 + 1) % cfg.vocab) as i32).collect();
        let req = |id, prompt: Vec<i32>, max_new| GenRequest {
            id,
            prompt,
            max_new,
            stop_id: None,
            ..Default::default()
        };
        let (outs, _) = eng.generate(vec![req(0, prompt.clone(), 4)]).unwrap();
        assert_eq!(outs[0].finish, FinishReason::MaxTokens);
        // The cached 9 prompt tokens + the first generated token: a
        // strict 10-token prefix of the cached 12-token entry. Drive
        // step() with a bounded loop so a regression FAILS instead of
        // hanging the test run.
        let mut longer = prompt.clone();
        longer.push(outs[0].tokens[0]);
        assert!(eng.submit(req(1, longer, 3)).is_none(), "fits exact capacity");
        let mut outs2 = Vec::new();
        for _ in 0..200 {
            outs2.extend(eng.step().unwrap());
            eng.check_paged_invariants().unwrap();
            if !eng.has_work() {
                break;
            }
        }
        assert!(!eng.has_work(), "admission livelocked at exact capacity");
        assert_eq!(outs2[0].finish, FinishReason::MaxTokens);
        assert_eq!(outs2[0].tokens.len(), 3);
        // The hit was rounded down to the block boundary, not dropped.
        assert_eq!(eng.report().prefix_hit_tokens, 8);
    }

    #[test]
    fn prepared_and_unprepared_paths_generate_identical_tokens() {
        // The prepared (dequantize-once packed panels) path is
        // bit-identical to the seed path, so greedy generations match
        // token for token (DESIGN.md §11).
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let reqs = || -> Vec<GenRequest> {
            (0..3)
                .map(|i| GenRequest {
                    id: i,
                    prompt: vec![(i as i32 * 5) % cfg.vocab as i32, 2, 7],
                    max_new: 5,
                    stop_id: None,
                    ..Default::default()
                })
                .collect()
        };
        let run = |prepared: bool| -> Vec<Vec<i32>> {
            let gen = GenConfig {
                prepared,
                ..GenConfig::default()
            };
            let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
            let (outs, _) = eng.generate(reqs()).unwrap();
            outs.into_iter().map(|o| o.tokens).collect()
        };
        assert_eq!(run(true), run(false));
        // Both engines over the same artifact shared one prepared state.
        assert_eq!(rt.prepared_qweights(), 1);
    }

    #[test]
    fn rejections_are_immediate_and_counted() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let req = |id: usize, prompt: Vec<i32>, max_new: usize| GenRequest {
            id,
            prompt,
            max_new,
            stop_id: None,
            ..Default::default()
        };
        let bad = vec![
            req(0, vec![], 2),
            req(1, vec![1, -4], 2),
            req(2, vec![1; cfg.seq], 2),
            req(3, vec![1, 2], 0),
            req(4, vec![1, 2], 2),
        ];
        let (outs, rep) = eng.generate(bad).unwrap();
        assert_eq!(outs.len(), 5);
        assert!(matches!(
            outs[0].finish,
            FinishReason::Rejected(RejectReason::EmptyPrompt)
        ));
        assert!(matches!(
            outs[1].finish,
            FinishReason::Rejected(RejectReason::TokenOutOfRange { index: 1, id: -4 })
        ));
        assert!(matches!(
            outs[2].finish,
            FinishReason::Rejected(RejectReason::TooLong { .. })
        ));
        assert!(matches!(
            outs[3].finish,
            FinishReason::Rejected(RejectReason::ZeroMaxNew)
        ));
        assert_eq!(outs[4].finish, FinishReason::MaxTokens);
        assert_eq!(rep.rejected, 4);
        assert_eq!(rep.reject_counts.total(), 4);
        assert_eq!(rep.reject_counts.bad_token, 1);
        assert_eq!(rep.reject_counts.too_long, 1);
        assert_eq!(rep.sequences, 1);
    }

    #[test]
    fn stop_id_ends_generation_without_emitting_it() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        // Learn what greedy emits first, then rerun with that as stop id.
        let req = |id| GenRequest {
            id,
            prompt: vec![3, 1, 4, 1, 5],
            max_new: 3,
            stop_id: None,
            ..Default::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let (outs, _) = eng.generate(vec![req(0)]).unwrap();
        let first = outs[0].tokens[0];

        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let mut r = req(1);
        r.stop_id = Some(first);
        let (outs, rep) = eng.generate(vec![r]).unwrap();
        assert_eq!(outs[0].finish, FinishReason::Stop);
        assert!(outs[0].tokens.is_empty());
        assert_eq!(rep.sequences, 1);
    }

    /// Step until drained (bounded so regressions fail, not hang).
    fn drive(eng: &mut Engine<'_>) -> Vec<GenOutput> {
        let mut outs = Vec::new();
        for _ in 0..500 {
            outs.extend(eng.step().unwrap());
            if !eng.has_work() {
                break;
            }
        }
        assert!(!eng.has_work(), "engine failed to drain in 500 steps");
        outs.sort_by_key(|o| o.id);
        outs
    }

    #[test]
    fn deadline_expires_on_the_virtual_clock() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let gen = GenConfig {
            virtual_step: Some(Duration::from_millis(1)),
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let queued = eng.submit(GenRequest {
            id: 0,
            prompt: vec![3],
            max_new: 10,
            deadline: Some(Duration::from_millis(5)),
            ..Default::default()
        });
        assert!(queued.is_none());
        let outs = drive(&mut eng);
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].finish, FinishReason::DeadlineExceeded);
        // Tick-driven and therefore exact: submitted at tick 0, fed on
        // ticks 1..=4, swept at tick 5 — four tokens, run after run.
        assert_eq!(outs[0].tokens.len(), 4);
        let rep = eng.report();
        assert_eq!(rep.deadline_exceeded, 1);
        assert_eq!(rep.sequences, 0);
        eng.check_paged_invariants().unwrap();
        eng.assert_pool_all_free().unwrap();
    }

    #[test]
    fn zero_deadline_expires_before_any_feed() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let gen = GenConfig {
            virtual_step: Some(Duration::from_millis(1)),
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let queued = eng.submit(GenRequest {
            id: 0,
            prompt: vec![1, 2, 3],
            max_new: 4,
            deadline: Some(Duration::ZERO),
            ..Default::default()
        });
        assert!(queued.is_none(), "a zero budget still queues; the sweep expires it");
        let outs = drive(&mut eng);
        assert_eq!(outs[0].finish, FinishReason::DeadlineExceeded);
        assert!(outs[0].tokens.is_empty());
        let rep = eng.report();
        assert_eq!(rep.prefill_tokens, 0, "expired in queue: nothing was ever fed");
        assert_eq!(rep.deadline_exceeded, 1);
    }

    #[test]
    fn cancel_token_stops_a_running_sequence() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let token = CancelToken::new();
        let queued = eng.submit(GenRequest {
            id: 0,
            prompt: vec![1, 2],
            max_new: 50,
            cancel: Some(token.clone()),
            ..Default::default()
        });
        assert!(queued.is_none());
        let queued = eng.submit(GenRequest {
            id: 1,
            prompt: vec![2, 3],
            max_new: 5,
            ..Default::default()
        });
        assert!(queued.is_none());
        let mut outs = Vec::new();
        for _ in 0..4 {
            outs.extend(eng.step().unwrap());
        }
        token.cancel();
        for _ in 0..200 {
            outs.extend(eng.step().unwrap());
            if !eng.has_work() {
                break;
            }
        }
        assert!(!eng.has_work());
        outs.sort_by_key(|o| o.id);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].finish, FinishReason::Cancelled);
        assert!(
            !outs[0].tokens.is_empty() && outs[0].tokens.len() < 50,
            "cancel lands mid-generation"
        );
        assert_eq!(outs[1].finish, FinishReason::MaxTokens);
        assert_eq!(outs[1].tokens.len(), 5);
        let rep = eng.report();
        assert_eq!(rep.cancelled, 1);
        assert_eq!(rep.sequences, 1);
        eng.check_paged_invariants().unwrap();
    }

    #[test]
    fn bounded_queue_rejects_with_queue_full() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let gen = GenConfig {
            max_queue: 2,
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let req = |id| GenRequest {
            id,
            prompt: vec![1, 2],
            max_new: 2,
            ..Default::default()
        };
        assert!(eng.submit(req(0)).is_none());
        assert!(eng.submit(req(1)).is_none());
        let out = eng.submit(req(2)).unwrap();
        assert!(matches!(
            out.finish,
            FinishReason::Rejected(RejectReason::QueueFull { limit: 2 })
        ));
        let outs = drive(&mut eng);
        assert_eq!(outs.len(), 2);
        let rep = eng.report();
        assert_eq!(rep.reject_counts.queue_full, 1);
        assert_eq!(rep.rejected, 1);
        assert_eq!(rep.sequences, 2);
    }

    #[test]
    fn drain_stops_admission_and_finishes_in_flight() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let req = |id| GenRequest {
            id,
            prompt: vec![4, 5, 6],
            max_new: 3,
            ..Default::default()
        };
        assert!(eng.submit(req(0)).is_none());
        assert!(!eng.draining());
        eng.begin_drain();
        assert!(eng.draining());
        let out = eng.submit(req(1)).unwrap();
        assert!(matches!(
            out.finish,
            FinishReason::Rejected(RejectReason::Draining)
        ));
        let outs = drive(&mut eng);
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].finish, FinishReason::MaxTokens);
        assert_eq!(outs[0].tokens.len(), 3);
        let rep = eng.report();
        assert_eq!(rep.reject_counts.draining, 1);
        assert_eq!(rep.sequences, 1);
    }

    /// Fails every attempt that feeds the victim request id — the
    /// poisoned-sequence model the quarantine bisection must isolate.
    struct Blame {
        victim: usize,
    }

    impl FaultInjector for Blame {
        fn before_attempt(&mut self, _tick: usize, _attempt: usize, fed_ids: &[usize]) -> Result<()> {
            if fed_ids.contains(&self.victim) {
                bail!("injected poison on request {}", self.victim);
            }
            Ok(())
        }
    }

    #[test]
    fn quarantine_evicts_poisoned_sequence_and_survivors_match_clean_run() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let reqs = || -> Vec<GenRequest> {
            (0..3)
                .map(|i| GenRequest {
                    id: i,
                    prompt: vec![(i as i32 * 7 + 1) % cfg.vocab as i32, 2, 4],
                    max_new: 4,
                    ..Default::default()
                })
                .collect()
        };
        let gen = || GenConfig {
            slots: 3,
            ..GenConfig::default()
        };
        let mut clean = Engine::new(&rt, &cfg, &params, &qm, gen()).unwrap();
        let (clean_outs, _) = clean.generate(reqs()).unwrap();

        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen()).unwrap();
        eng.set_fault_injector(Box::new(Blame { victim: 1 }));
        let (outs, rep) = eng.generate(reqs()).unwrap();
        assert_eq!(outs.len(), 3);
        assert!(matches!(
            outs[1].finish,
            FinishReason::Rejected(RejectReason::Internal { .. })
        ));
        assert!(
            outs[1].tokens.is_empty(),
            "poisoned from its first feed: no tokens survive"
        );
        for i in [0usize, 2] {
            assert_eq!(outs[i].finish, FinishReason::MaxTokens);
            assert_eq!(outs[i].tokens, clean_outs[i].tokens, "survivor {i} diverged");
        }
        assert_eq!(rep.quarantined, 1);
        assert_eq!(rep.reject_counts.internal, 1);
        assert_eq!(rep.step_retried, 2, "the transient budget runs out first");
        assert!(rep.step_faults >= 3, "retries + at least one bisection probe");
        assert_eq!(rep.sequences, 2);
        eng.check_paged_invariants().unwrap();
        eng.flush_prefix_cache().unwrap();
        eng.assert_pool_all_free().unwrap();
    }

    /// Fails the first `remaining` compute attempts, then heals — the
    /// transient-fault model the bounded retry must absorb.
    struct Flaky {
        remaining: usize,
    }

    impl FaultInjector for Flaky {
        fn before_attempt(&mut self, _tick: usize, _attempt: usize, _fed: &[usize]) -> Result<()> {
            if self.remaining > 0 {
                self.remaining -= 1;
                bail!("transient backend hiccup");
            }
            Ok(())
        }
    }

    #[test]
    fn transient_step_failures_are_retried_without_quarantine() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let req = || GenRequest {
            id: 0,
            prompt: vec![5, 1, 2],
            max_new: 4,
            ..Default::default()
        };
        let mut clean = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let (clean_outs, _) = clean.generate(vec![req()]).unwrap();

        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        eng.set_fault_injector(Box::new(Flaky { remaining: 2 }));
        let (outs, rep) = eng.generate(vec![req()]).unwrap();
        assert_eq!(outs[0].finish, FinishReason::MaxTokens);
        assert_eq!(outs[0].tokens, clean_outs[0].tokens, "retries must not change the stream");
        assert_eq!(rep.step_faults, 2);
        assert_eq!(rep.step_retried, 2);
        assert_eq!(rep.quarantined, 0);
        assert_eq!(rep.sequences, 1);
    }

    #[test]
    fn flush_prefix_cache_releases_every_cached_block() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let gen = GenConfig {
            block_tokens: 4,
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let (outs, _) = eng
            .generate(vec![GenRequest {
                id: 0,
                prompt: (0..9).map(|k| ((k * 5 + 1) % cfg.vocab) as i32).collect(),
                max_new: 3,
                ..Default::default()
            }])
            .unwrap();
        assert_eq!(outs[0].finish, FinishReason::MaxTokens);
        assert!(eng.prefix_cache_nodes().unwrap() > 0);
        assert!(
            eng.assert_pool_all_free().is_err(),
            "the cache still holds block references"
        );
        let dropped = eng.flush_prefix_cache().unwrap();
        assert!(dropped >= 2, "two full bt=4 blocks were cached");
        assert_eq!(eng.prefix_cache_nodes().unwrap(), 0);
        eng.assert_pool_all_free().unwrap();
        eng.check_paged_invariants().unwrap();
    }
}
