//! Continuous-batching generation scheduler.
//!
//! [`Engine`] owns a fixed number of *slots* (default: the preset's batch
//! size), a KV store, and the uploaded quantized weight bundle. Every
//! [`Engine::step`] runs ONE batched decode step over all occupied slots —
//! sequences at completely different phases (prompt prefill, mid-decode)
//! share the same execution, each at its own cache position. Finished
//! sequences free their slot immediately and the queue backfills it on
//! the next step, so short requests never wait for long ones to drain
//! (continuous batching, the vLLM scheduling model at slot granularity).
//!
//! Two KV stores exist behind one scheduler:
//!
//! - **Dense** (`GenConfig { paged: false }`): the seed `[L, slots,
//!   T_max, d]` slabs + `decode_step_q`. A slot reserves `T_max` rows
//!   for its whole lifetime. Kept as the reference engine — the
//!   differential fuzz harness (`testutil::fuzz`) pins the paged engine
//!   bitwise against it.
//! - **Paged** (default): a refcounted [`BlockPool`] of fixed
//!   `block_tokens` pages, per-sequence block tables, and a [`RadixTree`]
//!   prefix cache + `decode_step_paged_q`. Admission is by free
//!   *blocks* (worst case `ceil((prompt + max_new - 1) / block_tokens)`,
//!   reserved up front so mid-decode allocation can never fail), a
//!   request whose prompt shares a cached prefix takes references on the
//!   matched full blocks and starts prefill after them (copy-on-write
//!   duplicates a partially-matched tail block), finished sequences
//!   insert their block-aligned prefix into the tree, and admission
//!   pressure evicts least-recently-used cached prefixes (DESIGN.md §12).
//!
//! Prefill feeds prompt tokens one position per step through the same
//! entry as decode: there is exactly one compute path per store, and the
//! paged gather reads bitwise-identical rows in the identical order, so
//! the bit-identity contract (module docs in [`super`]) holds across
//! stores, thread counts, and batch mixes. The [`GenReport`] splits wall
//! time between prefill and decode by each step's feed mix and carries
//! the paged pool/prefix counters.

use super::{
    BlockPool, FinishReason, GenOutput, GenReport, GenRequest, KvCache, RadixTree, RejectCounts,
    RejectReason, Sampler,
};
use crate::config::ModelConfig;
use crate::model::Params;
use crate::quant::QuantizedModel;
use crate::runtime::{Buffer, Runtime, Value};
use crate::serve::qmodel_literals;
use crate::tensor::{Tensor, TensorI32};
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// Default KV page size (tokens per block) for the paged engine.
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Generation settings shared by every sequence of an engine.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// <= 0 is greedy; otherwise softmax temperature.
    pub temperature: f32,
    /// 0 = unrestricted; otherwise sample among the k highest logits.
    pub top_k: usize,
    /// Base seed; each sequence forks its own stream keyed by request id.
    pub seed: u64,
    /// Batch slots (0 = the model preset's batch size).
    pub slots: usize,
    /// Use the runtime's prepared weight bundle (dequantize-once packed
    /// panels, DESIGN.md §11; bit-identical logits). `false` keeps the
    /// per-step dequantizing seed path — the perf bench's baseline.
    pub prepared: bool,
    /// Block-paged KV cache + radix prefix sharing (DESIGN.md §12)
    /// instead of the dense `[L, slots, T_max, d]` slabs. Token streams
    /// are bit-identical either way (pinned by `testutil::fuzz`).
    pub paged: bool,
    /// Tokens per KV page (paged only; 0 = [`DEFAULT_BLOCK_TOKENS`]).
    pub block_tokens: usize,
    /// Pool size in blocks (paged only; 0 = `slots * ceil(seq /
    /// block_tokens)`, the dense slab's capacity). Smaller pools trade
    /// admission concurrency for memory; many short sequences need far
    /// fewer blocks than `slots * T_max` rows.
    pub pool_blocks: usize,
    /// Keep finished prompts' KV blocks in the radix prefix cache so
    /// later requests sharing the prefix skip that prefill (paged only).
    pub prefix_cache: bool,
}

impl Default for GenConfig {
    fn default() -> Self {
        Self {
            temperature: 0.0,
            top_k: 0,
            seed: 7,
            slots: 0,
            prepared: true,
            paged: true,
            block_tokens: 0,
            pool_blocks: 0,
            prefix_cache: true,
        }
    }
}

/// One in-flight sequence.
struct SeqState {
    id: usize,
    prompt_len: usize,
    /// Prompt followed by generated tokens.
    tokens: Vec<i32>,
    /// Tokens fed through the cache so far (prefix-cache hits start it
    /// past zero: those positions' KV rows are shared, not re-fed).
    cursor: usize,
    max_new: usize,
    stop_id: Option<i32>,
    sampler: Sampler,
}

/// The paged KV state: pool + prefix tree + per-slot block tables and
/// worst-case reservations.
struct PagedKv {
    pool: BlockPool,
    tree: RadixTree,
    /// Per-slot block table (parallel to `Engine::slots`).
    tables: Vec<Vec<u32>>,
    /// Per-slot blocks still to allocate (worst case), pre-reserved at
    /// admission so a mid-decode `alloc` can never fail.
    reserved: Vec<usize>,
    reserved_total: usize,
    /// Block-table width: `ceil(t_max / block_tokens)`.
    max_blocks: usize,
    block_tokens: usize,
    t_max: usize,
    prefix_cache: bool,
    /// Monotonic LRU clock (bumped per admission/insert).
    clock: u64,
    prefix_hit_tokens: usize,
    evicted_refs: usize,
    peak_in_use: usize,
}

impl PagedKv {
    fn new(
        cfg: &ModelConfig,
        slots: usize,
        block_tokens: usize,
        pool_blocks: usize,
        prefix_cache: bool,
    ) -> Self {
        let bt = if block_tokens == 0 {
            DEFAULT_BLOCK_TOKENS
        } else {
            block_tokens
        };
        let max_blocks = cfg.seq.div_ceil(bt);
        let pool_blocks = if pool_blocks == 0 {
            slots * max_blocks
        } else {
            pool_blocks
        };
        Self {
            pool: BlockPool::new(cfg.n_layer, pool_blocks, bt, cfg.d_model),
            tree: RadixTree::new(bt),
            tables: (0..slots).map(|_| Vec::new()).collect(),
            reserved: vec![0; slots],
            reserved_total: 0,
            max_blocks,
            block_tokens: bt,
            t_max: cfg.seq,
            prefix_cache,
            clock: 0,
            prefix_hit_tokens: 0,
            evicted_refs: 0,
            peak_in_use: 0,
        }
    }

    /// Requests whose `prompt + max_new` exceeds this can never be
    /// admitted (position capacity or worst-case block need > pool).
    fn capacity(&self) -> usize {
        self.t_max.min(self.pool.n_blocks() * self.block_tokens + 1)
    }

    fn note_peak(&mut self) {
        self.peak_in_use = self.peak_in_use.max(self.pool.in_use_blocks());
    }

    /// Evict LRU cached prefixes until `target` blocks are free — but
    /// only if the target is reachable: eviction can free exactly the
    /// blocks whose every reference is the tree's, so when a waiting
    /// head couldn't be admitted anyway (blocks held by live sequences
    /// or admission pins), the cache is left intact instead of being
    /// pointlessly wiped a step at a time. Returns whether `target` is
    /// met.
    fn secure_free(&mut self, target: usize) -> Result<bool> {
        if self.pool.free_blocks() >= target {
            return Ok(true);
        }
        if self.tree.is_empty() {
            // Nothing cached: the missing blocks are held by live
            // sequences; only their completion can free them.
            return Ok(false);
        }
        // Full reachability walk — O(live tree nodes) per blocked
        // admission attempt. Fine at serving scale (a prefix cache
        // holds tens of nodes); revisit with an incremental
        // tree-only-referenced counter if tree sizes grow.
        let tree_refs = self.tree.block_refs();
        let freeable = tree_refs
            .iter()
            .filter(|&(&b, &refs)| self.pool.refcount(b) == refs)
            .count();
        if self.pool.free_blocks() + freeable < target {
            return Ok(false);
        }
        while self.pool.free_blocks() < target {
            let Some(dropped) = self.tree.evict_lru() else {
                break;
            };
            for b in dropped {
                self.evicted_refs += 1;
                self.pool.release(b)?;
            }
        }
        Ok(self.pool.free_blocks() >= target)
    }

    /// Try to admit a sequence into `slot`: prefix lookup, worst-case
    /// block reservation (evicting LRU cached prefixes as needed), and
    /// copy-on-write of a partially matched tail block. Returns the
    /// starting cursor (prefix tokens skipped) or `None` when the pool
    /// cannot cover the request right now.
    fn try_admit(
        &mut self,
        slot: usize,
        tokens: &[i32],
        prompt_len: usize,
        max_new: usize,
    ) -> Result<Option<usize>> {
        self.clock += 1;
        let bt = self.block_tokens;
        let prompt = tokens
            .get(..prompt_len)
            .ok_or_else(|| anyhow!("prompt_len {prompt_len} exceeds the token stream"))?;
        let (mut p, chain) = if self.prefix_cache {
            let (m, c) = self.tree.lookup(prompt, self.clock);
            // The last prompt token is always fed: its logits seed the
            // first sampled token.
            (m.min(prompt_len - 1), c)
        } else {
            (0, Vec::new())
        };
        let nfull = p / bt;
        let partial = p % bt;
        // Worst-case rows this sequence ever caches (the final sampled
        // token is returned, never fed).
        let rows_worst = prompt_len + max_new - 1;
        let need_total = rows_worst.div_ceil(bt);
        debug_assert!(need_total <= self.pool.n_blocks(), "validate() enforces this");
        let new_needed = need_total - nfull;
        // Pin every shared block (and the copy-on-write source) BEFORE
        // evicting, so eviction can only drop the tree's references —
        // never recycle a block this admission is about to read.
        let mut pinned: Vec<u32> = Vec::with_capacity(nfull + 1);
        for &b in chain.iter().take(nfull) {
            self.pool.retain(b)?;
            pinned.push(b);
        }
        let mut cow_src = None;
        if partial > 0 {
            let src = chain
                .get(nfull)
                .copied()
                .ok_or_else(|| anyhow!("lookup chain missing its partial tail block"))?;
            self.pool.retain(src)?;
            cow_src = Some(src);
        }
        // The free list must cover every outstanding reservation plus
        // this sequence's worst case.
        let target = self.reserved_total + new_needed;
        let mut ok = self.secure_free(target)?;
        if !ok && cow_src.is_some() {
            // The partial-tail hit is opportunistic: its pinned COW
            // source can make the target unreachable at exact pool
            // capacity (the source can never free while pinned). Drop
            // the pin, round the hit down to the full-block boundary,
            // and retry — provably admissible whenever an admission
            // with no hit at all would be.
            if let Some(src) = cow_src.take() {
                self.pool.release(src)?;
            }
            p = nfull * bt;
            ok = self.secure_free(target)?;
        }
        if !ok {
            // Not admissible right now: roll the pins back.
            for b in pinned {
                self.pool.release(b)?;
            }
            if let Some(src) = cow_src {
                self.pool.release(src)?;
            }
            return Ok(None);
        }
        let mut table = pinned;
        let mut reserve = new_needed;
        if let Some(src) = cow_src {
            // Copy-on-write: this sequence appends inside the matched
            // tail block, so it gets a private copy of the shared rows.
            let dst = self.pool.alloc()?;
            self.pool.cow_copy(src, dst, partial)?;
            self.pool.release(src)?;
            table.push(dst);
            reserve -= 1;
        }
        *self
            .tables
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))? = table;
        *self
            .reserved
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))? = reserve;
        self.reserved_total += reserve;
        self.prefix_hit_tokens += p;
        self.note_peak();
        Ok(Some(p))
    }

    /// Write one fed token's KV rows at `pos`, allocating the next block
    /// from the reservation when the position crosses a page boundary.
    fn append_row(
        &mut self,
        slot: usize,
        pos: usize,
        k_new: &Tensor,
        v_new: &Tensor,
    ) -> Result<()> {
        let bt = self.block_tokens;
        let bi = pos / bt;
        let Self {
            pool,
            tables,
            reserved,
            reserved_total,
            ..
        } = self;
        let table = tables
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))?;
        let res = reserved
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))?;
        if bi == table.len() {
            if *res == 0 {
                bail!("slot {slot}: paged append at pos {pos} without a reservation");
            }
            let b = pool.alloc()?;
            table.push(b);
            *res -= 1;
            *reserved_total -= 1;
        }
        let block = table
            .get(bi)
            .copied()
            .ok_or_else(|| anyhow!("slot {slot}: append at pos {pos} past its block table"))?;
        if pool.refcount(block) != 1 {
            bail!(
                "slot {slot}: writing block {block} with refcount {} (shared blocks \
                 are read-only; divergence must copy-on-write)",
                pool.refcount(block)
            );
        }
        pool.write_row(block, pos % bt, slot, k_new, v_new)?;
        self.note_peak();
        Ok(())
    }

    /// A sequence finished having fed `fed` tokens of `tokens`: cache its
    /// block-aligned prefix in the radix tree, then drop the sequence's
    /// own references (blocks the tree kept stay live; the rest free).
    fn on_finish(&mut self, slot: usize, fed: usize, tokens: &[i32]) -> Result<()> {
        let bt = self.block_tokens;
        if self.prefix_cache {
            let aligned = (fed / bt) * bt;
            if aligned > 0 {
                self.clock += 1;
                let table = self
                    .tables
                    .get(slot)
                    .ok_or_else(|| anyhow!("slot {slot} out of range"))?;
                let (prefix, chain) = match (tokens.get(..aligned), table.get(..aligned / bt)) {
                    (Some(p), Some(c)) => (p, c),
                    _ => bail!("slot {slot}: fed {fed} tokens but stream/table are shorter"),
                };
                let new_refs = self.tree.insert(prefix, chain, self.clock);
                for b in new_refs {
                    self.pool.retain(b)?;
                }
            }
        }
        let table = std::mem::take(
            self.tables
                .get_mut(slot)
                .ok_or_else(|| anyhow!("slot {slot} out of range"))?,
        );
        for b in table {
            self.pool.release(b)?;
        }
        let res = self
            .reserved
            .get_mut(slot)
            .ok_or_else(|| anyhow!("slot {slot} out of range"))?;
        self.reserved_total -= *res;
        *res = 0;
        Ok(())
    }
}

/// The engine's KV store: dense seed slabs or the paged block pool.
enum KvStore {
    Dense(KvCache),
    Paged(PagedKv),
}

/// The KV-cached continuous-batching generation engine.
pub struct Engine<'rt> {
    rt: &'rt Runtime,
    cfg: ModelConfig,
    gen: GenConfig,
    weight_bufs: std::sync::Arc<Vec<Buffer>>,
    store: KvStore,
    slots: Vec<Option<SeqState>>,
    queue: VecDeque<SeqState>,
    // Accumulated report state (across generate calls).
    steps: usize,
    prefill_tokens: usize,
    decode_tokens: usize,
    prefill_secs: f32,
    decode_secs: f32,
    occupancy_sum: f32,
    completed: usize,
    rejected: usize,
    reject_counts: RejectCounts,
}

impl<'rt> Engine<'rt> {
    /// Build an engine over a quantized model: prepares the weight
    /// bundle once — by default through the runtime's prepared-state map
    /// (dequantize-once packed panels on the native backend, DESIGN.md
    /// §11; shared across engines over the same artifact) — and sizes
    /// the KV store (paged block pool by default, dense `[L, slots, seq,
    /// d]` slabs with `paged: false`).
    pub fn new(
        rt: &'rt Runtime,
        cfg: &ModelConfig,
        params: &Params,
        qm: &QuantizedModel,
        gen: GenConfig,
    ) -> Result<Self> {
        let slots = match gen.slots {
            0 => cfg.batch,
            n => n,
        };
        let lits = qmodel_literals(params, qm)?;
        let weight_bufs = if gen.prepared {
            rt.prepare_qweights(&cfg.name, &lits)?
        } else {
            std::sync::Arc::new(
                lits.iter()
                    .map(|l| rt.upload_literal(l))
                    .collect::<Result<Vec<_>>>()?,
            )
        };
        let store = if gen.paged {
            KvStore::Paged(PagedKv::new(
                cfg,
                slots,
                gen.block_tokens,
                gen.pool_blocks,
                gen.prefix_cache,
            ))
        } else {
            KvStore::Dense(KvCache::new(cfg.n_layer, slots, cfg.seq, cfg.d_model))
        };
        Ok(Self {
            rt,
            cfg: cfg.clone(),
            gen,
            weight_bufs,
            store,
            slots: (0..slots).map(|_| None).collect(),
            queue: VecDeque::new(),
            steps: 0,
            prefill_tokens: 0,
            decode_tokens: 0,
            prefill_secs: 0.0,
            decode_secs: 0.0,
            occupancy_sum: 0.0,
            completed: 0,
            rejected: 0,
            reject_counts: RejectCounts::default(),
        })
    }

    /// Sequence-capacity cap in tokens (`prompt + max_new` must fit).
    fn capacity(&self) -> usize {
        match &self.store {
            KvStore::Dense(cache) => cache.t_max(),
            KvStore::Paged(ps) => ps.capacity(),
        }
    }

    /// Why a request cannot be admitted, if anything.
    pub fn validate(&self, req: &GenRequest) -> Option<RejectReason> {
        if req.prompt.is_empty() {
            return Some(RejectReason::EmptyPrompt);
        }
        if req.max_new == 0 {
            return Some(RejectReason::ZeroMaxNew);
        }
        for (index, &id) in req.prompt.iter().enumerate() {
            if id < 0 || id as usize >= self.cfg.vocab {
                return Some(RejectReason::TokenOutOfRange { index, id });
            }
        }
        let cap = self.capacity();
        if req.prompt.len() + req.max_new > cap {
            return Some(RejectReason::TooLong {
                prompt: req.prompt.len(),
                max_new: req.max_new,
                cap,
            });
        }
        None
    }

    /// Enqueue a request. Returns `Some(rejected output)` immediately
    /// when the request cannot be admitted; `None` means it is queued and
    /// will surface from a later [`Engine::step`].
    pub fn submit(&mut self, req: GenRequest) -> Option<GenOutput> {
        if let Some(reason) = self.validate(&req) {
            self.rejected += 1;
            self.reject_counts.note(&reason);
            return Some(GenOutput {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                finish: FinishReason::Rejected(reason),
            });
        }
        let sampler =
            Sampler::for_sequence(self.gen.temperature, self.gen.top_k, self.gen.seed, req.id);
        self.queue.push_back(SeqState {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens: req.prompt,
            cursor: 0,
            max_new: req.max_new,
            stop_id: req.stop_id,
            sampler,
        });
        None
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(Option::is_some)
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// Admit queued sequences into free slots. Dense: a free slot is all
    /// it takes. Paged: the head of the queue also needs its worst-case
    /// block reservation (FIFO — a stuck head does not let later
    /// requests starve it of blocks).
    fn admit(&mut self) -> Result<()> {
        let Self {
            slots,
            store,
            queue,
            ..
        } = self;
        for (slot, slot_ref) in slots.iter_mut().enumerate() {
            if slot_ref.is_some() {
                continue;
            }
            let Some(mut head) = queue.pop_front() else {
                break;
            };
            match store {
                KvStore::Dense(cache) => {
                    cache.reset(slot);
                    *slot_ref = Some(head);
                }
                KvStore::Paged(ps) => {
                    let admitted =
                        match ps.try_admit(slot, &head.tokens, head.prompt_len, head.max_new) {
                            Ok(a) => a,
                            Err(e) => {
                                // Keep the request queued; the error is the
                                // caller's to handle.
                                queue.push_front(head);
                                return Err(e);
                            }
                        };
                    match admitted {
                        Some(start) => {
                            head.cursor = start;
                            *slot_ref = Some(head);
                        }
                        // Head must wait for blocks; keep FIFO order.
                        None => {
                            queue.push_front(head);
                            break;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Admit queued sequences, run one batched decode step, and return
    /// the sequences that finished on it.
    pub fn step(&mut self) -> Result<Vec<GenOutput>> {
        self.admit()?;
        let b = self.slots.len();
        let vocab = self.cfg.vocab;
        let mut pos = vec![-1i32; b];
        let mut tok = vec![0i32; b];
        let mut prefill_feeds = 0usize;
        let mut decode_feeds = 0usize;
        for ((p, t), st) in pos.iter_mut().zip(tok.iter_mut()).zip(&self.slots) {
            let Some(st) = st else { continue };
            *p = st.cursor as i32;
            *t = st
                .tokens
                .get(st.cursor)
                .copied()
                .ok_or_else(|| anyhow!("sequence {}: cursor past its token stream", st.id))?;
            if st.cursor < st.prompt_len {
                prefill_feeds += 1;
            } else {
                decode_feeds += 1;
            }
        }
        let feeds = prefill_feeds + decode_feeds;
        if feeds == 0 {
            return Ok(Vec::new());
        }

        let t0 = Instant::now();
        let pos_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b], pos)?));
        let tok_buf = Buffer::Host(Value::I32(TensorI32::from_vec(&[b], tok)?));
        let outs = match &mut self.store {
            KvStore::Dense(cache) => {
                let (kt, vt) = cache.take()?;
                let k_buf = Buffer::Host(Value::F32(kt));
                let v_buf = Buffer::Host(Value::F32(vt));
                let outs = {
                    let mut args: Vec<&Buffer> = self.weight_bufs.iter().collect();
                    args.extend([&k_buf, &v_buf, &pos_buf, &tok_buf]);
                    self.rt.exec_b(&self.cfg.name, "decode_step_q", &args)
                };
                // The slabs go back whether or not the step succeeded.
                match (k_buf, v_buf) {
                    (Buffer::Host(Value::F32(k)), Buffer::Host(Value::F32(v))) => {
                        cache.put_back(k, v)?
                    }
                    _ => bail!("KV slabs must stay host-resident"),
                }
                outs
            }
            KvStore::Paged(ps) => {
                let mut tables = vec![-1i32; b * ps.max_blocks];
                for (row, table) in tables.chunks_mut(ps.max_blocks).zip(&ps.tables) {
                    if table.len() > row.len() {
                        bail!("block table wider than {} blocks", ps.max_blocks);
                    }
                    for (cell, &blk) in row.iter_mut().zip(table) {
                        *cell = blk as i32;
                    }
                }
                let tb_buf = Buffer::Host(Value::I32(TensorI32::from_vec(
                    &[b, ps.max_blocks],
                    tables,
                )?));
                let (kt, vt) = ps.pool.take()?;
                let k_buf = Buffer::Host(Value::F32(kt));
                let v_buf = Buffer::Host(Value::F32(vt));
                let outs = {
                    let mut args: Vec<&Buffer> = self.weight_bufs.iter().collect();
                    args.extend([&k_buf, &v_buf, &tb_buf, &pos_buf, &tok_buf]);
                    self.rt.exec_b(&self.cfg.name, "decode_step_paged_q", &args)
                };
                match (k_buf, v_buf) {
                    (Buffer::Host(Value::F32(k)), Buffer::Host(Value::F32(v))) => {
                        ps.pool.put_back(k, v)?
                    }
                    _ => bail!("KV pool must stay host-resident"),
                }
                outs
            }
        };
        let mut outs = outs?.into_iter();
        let (Some(logits_v), Some(k_v), Some(v_v)) = (outs.next(), outs.next(), outs.next())
        else {
            bail!("decode step returned fewer than three outputs");
        };
        let dt = t0.elapsed().as_secs_f32();
        self.steps += 1;
        self.occupancy_sum += feeds as f32 / b as f32;
        self.prefill_secs += dt * prefill_feeds as f32 / feeds as f32;
        self.decode_secs += dt * decode_feeds as f32 / feeds as f32;
        self.prefill_tokens += prefill_feeds;

        let logits = logits_v.as_f32()?;
        let k_new = k_v.as_f32()?;
        let v_new = v_v.as_f32()?;
        let mut finished = Vec::new();
        let Self {
            slots,
            store,
            decode_tokens,
            completed,
            ..
        } = self;
        for (slot, slot_ref) in slots.iter_mut().enumerate() {
            let Some(st) = slot_ref.as_mut() else { continue };
            match store {
                KvStore::Dense(cache) => cache.append(slot, k_new, v_new)?,
                KvStore::Paged(ps) => ps.append_row(slot, st.cursor, k_new, v_new)?,
            }
            st.cursor += 1;
            let mut fin = None;
            if st.cursor >= st.prompt_len {
                // This feed's logits predict the next position.
                let row = logits
                    .data()
                    .get(slot * vocab..(slot + 1) * vocab)
                    .ok_or_else(|| anyhow!("logits row {slot} out of range"))?;
                let next = st.sampler.sample(row) as i32;
                if st.stop_id == Some(next) {
                    fin = Some(FinishReason::Stop);
                } else {
                    st.tokens.push(next);
                    *decode_tokens += 1;
                    if st.tokens.len() - st.prompt_len >= st.max_new {
                        fin = Some(FinishReason::MaxTokens);
                    }
                }
            }
            let Some(finish) = fin else { continue };
            if let KvStore::Paged(ps) = store {
                ps.on_finish(slot, st.cursor, &st.tokens)?;
            }
            let Some(st) = slot_ref.take() else { continue };
            finished.push(GenOutput {
                id: st.id,
                prompt_len: st.prompt_len,
                tokens: st.tokens.get(st.prompt_len..).unwrap_or_default().to_vec(),
                finish,
            });
            *completed += 1;
        }
        Ok(finished)
    }

    /// Snapshot of the accumulated throughput/occupancy counters.
    pub fn report(&self) -> GenReport {
        let (prefix_hit_tokens, peak_blocks_in_use, pool_blocks, block_tokens, evicted_blocks) =
            match &self.store {
                KvStore::Dense(_) => (0, 0, 0, 0, 0),
                KvStore::Paged(ps) => (
                    ps.prefix_hit_tokens,
                    ps.peak_in_use,
                    ps.pool.n_blocks(),
                    ps.block_tokens,
                    ps.evicted_refs,
                ),
            };
        GenReport {
            sequences: self.completed,
            rejected: self.rejected,
            reject_counts: self.reject_counts.clone(),
            steps: self.steps,
            prefill_tokens: self.prefill_tokens,
            decode_tokens: self.decode_tokens,
            prefill_secs: self.prefill_secs,
            decode_secs: self.decode_secs,
            mean_slot_occupancy: if self.steps > 0 {
                self.occupancy_sum / self.steps as f32
            } else {
                0.0
            },
            prefix_hit_tokens,
            peak_blocks_in_use,
            pool_blocks,
            block_tokens,
            evicted_blocks,
        }
    }

    /// Paged-pool snapshot `(free, in_use, pool_blocks, reserved_total)`;
    /// `None` on the dense engine.
    pub fn pool_stats(&self) -> Option<(usize, usize, usize, usize)> {
        match &self.store {
            KvStore::Dense(_) => None,
            KvStore::Paged(ps) => Some((
                ps.pool.free_blocks(),
                ps.pool.in_use_blocks(),
                ps.pool.n_blocks(),
                ps.reserved_total,
            )),
        }
    }

    /// Live radix-tree node count; `None` on the dense engine.
    pub fn prefix_cache_nodes(&self) -> Option<usize> {
        match &self.store {
            KvStore::Dense(_) => None,
            KvStore::Paged(ps) => Some(ps.tree.node_count()),
        }
    }

    /// Verify every paged-store invariant (no-op on the dense engine).
    /// The differential fuzz harness calls this after every step:
    ///
    /// 1. pool partition: `free + in_use == pool_blocks`, refcount 0
    ///    exactly for free-listed blocks (no underflow can have happened —
    ///    `release` fails loudly instead of wrapping);
    /// 2. refcount accounting: each block's refcount equals its
    ///    references from slot tables plus the radix tree;
    /// 3. reservations are backed: `free >= reserved_total`, and each
    ///    active slot's `table + reserved` covers its worst case;
    /// 4. copy-on-write safety: a block shared by two active sequences
    ///    sits at the same block index and both sequences' tokens agree
    ///    through the shared span (diverged sequences share nothing).
    pub fn check_paged_invariants(&self) -> Result<()> {
        let KvStore::Paged(ps) = &self.store else {
            return Ok(());
        };
        ps.pool.check_invariants()?;
        if ps.pool.free_blocks() < ps.reserved_total {
            bail!(
                "reservations unbacked: {} free < {} reserved",
                ps.pool.free_blocks(),
                ps.reserved_total
            );
        }
        if ps.reserved.iter().sum::<usize>() != ps.reserved_total {
            bail!("reserved_total out of sync with per-slot reservations");
        }
        let mut want = ps.tree.block_refs();
        for table in &ps.tables {
            for &b in table {
                *want.entry(b).or_insert(0) += 1;
            }
        }
        for b in 0..ps.pool.n_blocks() as u32 {
            let rc = ps.pool.refcount(b);
            let w = want.get(&b).copied().unwrap_or(0);
            if rc != w {
                bail!("block {b}: refcount {rc} != {w} (tables + tree)");
            }
        }
        let bt = ps.block_tokens;
        if ps.tables.len() != self.slots.len() || ps.reserved.len() != self.slots.len() {
            bail!("paged per-slot arrays out of sync with the slot count");
        }
        for (slot, ((st, table), &reserved)) in self
            .slots
            .iter()
            .zip(&ps.tables)
            .zip(&ps.reserved)
            .enumerate()
        {
            match st {
                None => {
                    if !table.is_empty() || reserved != 0 {
                        bail!("empty slot {slot} holds blocks or reservations");
                    }
                }
                Some(st) => {
                    if table.len() != st.cursor.div_ceil(bt) {
                        bail!(
                            "slot {slot}: table {} blocks != ceil(cursor {} / {bt})",
                            table.len(),
                            st.cursor
                        );
                    }
                    let need = (st.prompt_len + st.max_new - 1).div_ceil(bt);
                    if table.len() + reserved != need {
                        bail!(
                            "slot {slot}: table {} + reserved {reserved} != worst case {need}",
                            table.len()
                        );
                    }
                }
            }
        }
        for (a, (sa, ta)) in self.slots.iter().zip(&ps.tables).enumerate() {
            for (c, (sc, tc)) in self.slots.iter().zip(&ps.tables).enumerate().skip(a + 1) {
                let (Some(sa), Some(sc)) = (sa, sc) else {
                    continue;
                };
                for (ia, &ba) in ta.iter().enumerate() {
                    for (ic, &bc) in tc.iter().enumerate() {
                        if ba != bc {
                            continue;
                        }
                        if ia != ic {
                            bail!("block {ba} shared at different positions {ia}/{ic}");
                        }
                        let l = ((ia + 1) * bt).min(sa.cursor).min(sc.cursor);
                        let (Some(pa), Some(pc)) = (sa.tokens.get(..l), sc.tokens.get(..l))
                        else {
                            bail!("slots {a}/{c}: cursor past the token stream");
                        };
                        if pa != pc {
                            bail!(
                                "diverged sequences in slots {a}/{c} share block {ba}"
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Convenience driver: submit everything, step until drained, return
    /// outputs sorted by request id plus the report snapshot.
    pub fn generate(&mut self, reqs: Vec<GenRequest>) -> Result<(Vec<GenOutput>, GenReport)> {
        let mut outs = Vec::with_capacity(reqs.len());
        for r in reqs {
            if let Some(rejected) = self.submit(r) {
                outs.push(rejected);
            }
        }
        while self.has_work() {
            outs.extend(self.step()?);
        }
        outs.sort_by_key(|o| o.id);
        Ok((outs, self.report()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::testutil::fixtures;

    fn pico_model(rt: &Runtime) -> (ModelConfig, Params, QuantizedModel) {
        fixtures::quantized_pico(rt, Method::Rtn, 11)
    }

    #[test]
    fn generate_greedy_runs_and_reports() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let reqs: Vec<GenRequest> = (0..6)
            .map(|i| GenRequest {
                id: i,
                prompt: vec![(i as i32 * 3) % cfg.vocab as i32, 1, 2, 5],
                max_new: 4,
                stop_id: None,
            })
            .collect();
        let (outs, rep) = eng.generate(reqs).unwrap();
        assert_eq!(outs.len(), 6);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.id, i);
            assert_eq!(o.finish, FinishReason::MaxTokens);
            assert_eq!(o.tokens.len(), 4);
            assert!(o.tokens.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
        }
        assert_eq!(rep.sequences, 6);
        assert_eq!(rep.rejected, 0);
        // 6 sequences x 4 prompt tokens; decode tokens delivered = 6 x 4.
        assert_eq!(rep.prefill_tokens, 24);
        assert_eq!(rep.decode_tokens, 24);
        assert!(rep.steps >= 7, "6 seqs over 4 slots need two waves");
        assert!(rep.mean_slot_occupancy > 0.0 && rep.mean_slot_occupancy <= 1.0);
        // Default engine is paged; everything is released at drain.
        assert!(rep.pool_blocks > 0 && rep.block_tokens > 0);
        eng.check_paged_invariants().unwrap();
    }

    #[test]
    fn paged_and_dense_generate_identical_tokens() {
        // THE tentpole contract at engine level: the block-paged store
        // (with prefix sharing enabled) produces exactly the dense
        // engine's token streams (DESIGN.md §12; testutil::fuzz sweeps
        // this over random workloads and thread counts).
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let reqs = || -> Vec<GenRequest> {
            (0..6)
                .map(|i| GenRequest {
                    id: i,
                    // Three pairs sharing a prompt: the second of each
                    // pair hits the prefix cache on the paged engine.
                    prompt: (0..10)
                        .map(|k| ((k * 3 + (i / 2) * 17) % cfg.vocab) as i32)
                        .collect(),
                    max_new: 5,
                    stop_id: None,
                })
                .collect()
        };
        let run = |paged: bool, block_tokens: usize| -> Vec<Vec<i32>> {
            let gen = GenConfig {
                temperature: 0.8,
                top_k: 6,
                seed: 99,
                slots: 3,
                paged,
                block_tokens,
                ..GenConfig::default()
            };
            let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
            let (outs, rep) = eng.generate(reqs()).unwrap();
            eng.check_paged_invariants().unwrap();
            if paged {
                assert!(
                    rep.prefix_hit_tokens > 0,
                    "repeated prompts should hit the prefix cache"
                );
                let (free, in_use, pool, reserved) = eng.pool_stats().unwrap();
                assert_eq!(free + in_use, pool);
                assert_eq!(reserved, 0, "drained engine holds no reservations");
            }
            outs.into_iter().map(|o| o.tokens).collect()
        };
        let dense = run(false, 0);
        assert_eq!(dense, run(true, 4), "paged (bt=4) diverged from dense");
        assert_eq!(dense, run(true, 3), "paged (bt=3) diverged from dense");
    }

    #[test]
    fn prefix_cache_skips_prefill_for_repeated_prompts() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let prompt: Vec<i32> = (0..9).map(|k| ((k * 5 + 2) % cfg.vocab) as i32).collect();
        let gen = GenConfig {
            block_tokens: 4,
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let req = |id| GenRequest {
            id,
            prompt: prompt.clone(),
            max_new: 3,
            stop_id: None,
        };
        let (outs_a, rep_a) = eng.generate(vec![req(0)]).unwrap();
        assert_eq!(rep_a.prefix_hit_tokens, 0, "nothing cached yet");
        assert_eq!(rep_a.prefill_tokens, 9);
        // Same prompt again: 8 of 9 prompt tokens (two full bt=4 blocks;
        // the last prompt token always feeds) come from the cache.
        let (outs_b, rep_b) = eng.generate(vec![req(1)]).unwrap();
        assert_eq!(rep_b.prefix_hit_tokens, 8);
        assert_eq!(rep_b.prefill_tokens - rep_a.prefill_tokens, 1);
        // Greedy + same prompt => identical continuations.
        assert_eq!(outs_a[0].tokens, outs_b[0].tokens);
        eng.check_paged_invariants().unwrap();
        assert!(eng.prefix_cache_nodes().unwrap() > 0);
    }

    #[test]
    fn small_pool_admits_by_blocks_and_evicts_cached_prefixes() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        // 4 slots but only 6 blocks of 4 tokens: a request needing 3
        // blocks limits concurrency to 2 in-flight sequences, and cached
        // prefixes must be evicted to admit fresh prompts.
        let gen = GenConfig {
            slots: 4,
            block_tokens: 4,
            pool_blocks: 6,
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let reqs: Vec<GenRequest> = (0..5)
            .map(|i| GenRequest {
                id: i,
                prompt: (0..8).map(|k| ((k * 7 + i * 31) % cfg.vocab) as i32).collect(),
                max_new: 4,
                stop_id: None,
            })
            .collect();
        let (outs, rep) = eng.generate(reqs).unwrap();
        assert_eq!(outs.len(), 5);
        assert!(outs.iter().all(|o| o.finish == FinishReason::MaxTokens));
        assert!(rep.evicted_blocks > 0, "tight pool must evict cached prefixes");
        assert!(rep.peak_blocks_in_use <= rep.pool_blocks);
        eng.check_paged_invariants().unwrap();
    }

    #[test]
    fn paged_capacity_rejects_what_the_pool_cannot_ever_hold() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let gen = GenConfig {
            slots: 2,
            block_tokens: 4,
            pool_blocks: 3, // capacity: 3 * 4 + 1 = 13 tokens
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let req = |id, prompt_len: usize, max_new| GenRequest {
            id,
            prompt: (0..prompt_len).map(|k| (k % cfg.vocab) as i32).collect(),
            max_new,
            stop_id: None,
        };
        let (outs, rep) = eng.generate(vec![req(0, 10, 4), req(1, 9, 4)]).unwrap();
        assert!(matches!(
            outs[0].finish,
            FinishReason::Rejected(RejectReason::TooLong { cap: 13, .. })
        ));
        assert_eq!(outs[1].finish, FinishReason::MaxTokens);
        assert_eq!(rep.rejected, 1);
    }

    #[test]
    fn exact_capacity_partial_prefix_hit_falls_back_instead_of_livelocking() {
        // Regression: pool_blocks=3, bt=4 (capacity 13). Complete a 9+4
        // request so the prefix cache holds all three blocks (free = 0),
        // then submit a request whose 10-token prompt extends the cached
        // stream with max_new 3 (10 + 3 = 13 — exact capacity). Its
        // prefix match ends mid-block; the pinned copy-on-write source
        // makes the free target unreachable, so admission must round
        // the hit down to the 8-token block boundary (evicting the
        // cached entry, pins keeping the shared blocks alive) rather
        // than spin forever.
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let gen = GenConfig {
            slots: 2,
            block_tokens: 4,
            pool_blocks: 3,
            ..GenConfig::default()
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
        let prompt: Vec<i32> = (0..9).map(|k| ((k * 3 + 1) % cfg.vocab) as i32).collect();
        let req = |id, prompt: Vec<i32>, max_new| GenRequest {
            id,
            prompt,
            max_new,
            stop_id: None,
        };
        let (outs, _) = eng.generate(vec![req(0, prompt.clone(), 4)]).unwrap();
        assert_eq!(outs[0].finish, FinishReason::MaxTokens);
        // The cached 9 prompt tokens + the first generated token: a
        // strict 10-token prefix of the cached 12-token entry. Drive
        // step() with a bounded loop so a regression FAILS instead of
        // hanging the test run.
        let mut longer = prompt.clone();
        longer.push(outs[0].tokens[0]);
        assert!(eng.submit(req(1, longer, 3)).is_none(), "fits exact capacity");
        let mut outs2 = Vec::new();
        for _ in 0..200 {
            outs2.extend(eng.step().unwrap());
            eng.check_paged_invariants().unwrap();
            if !eng.has_work() {
                break;
            }
        }
        assert!(!eng.has_work(), "admission livelocked at exact capacity");
        assert_eq!(outs2[0].finish, FinishReason::MaxTokens);
        assert_eq!(outs2[0].tokens.len(), 3);
        // The hit was rounded down to the block boundary, not dropped.
        assert_eq!(eng.report().prefix_hit_tokens, 8);
    }

    #[test]
    fn prepared_and_unprepared_paths_generate_identical_tokens() {
        // The prepared (dequantize-once packed panels) path is
        // bit-identical to the seed path, so greedy generations match
        // token for token (DESIGN.md §11).
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let reqs = || -> Vec<GenRequest> {
            (0..3)
                .map(|i| GenRequest {
                    id: i,
                    prompt: vec![(i as i32 * 5) % cfg.vocab as i32, 2, 7],
                    max_new: 5,
                    stop_id: None,
                })
                .collect()
        };
        let run = |prepared: bool| -> Vec<Vec<i32>> {
            let gen = GenConfig {
                prepared,
                ..GenConfig::default()
            };
            let mut eng = Engine::new(&rt, &cfg, &params, &qm, gen).unwrap();
            let (outs, _) = eng.generate(reqs()).unwrap();
            outs.into_iter().map(|o| o.tokens).collect()
        };
        assert_eq!(run(true), run(false));
        // Both engines over the same artifact shared one prepared state.
        assert_eq!(rt.prepared_qweights(), 1);
    }

    #[test]
    fn rejections_are_immediate_and_counted() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let req = |id: usize, prompt: Vec<i32>, max_new: usize| GenRequest {
            id,
            prompt,
            max_new,
            stop_id: None,
        };
        let bad = vec![
            req(0, vec![], 2),
            req(1, vec![1, -4], 2),
            req(2, vec![1; cfg.seq], 2),
            req(3, vec![1, 2], 0),
            req(4, vec![1, 2], 2),
        ];
        let (outs, rep) = eng.generate(bad).unwrap();
        assert_eq!(outs.len(), 5);
        assert!(matches!(
            outs[0].finish,
            FinishReason::Rejected(RejectReason::EmptyPrompt)
        ));
        assert!(matches!(
            outs[1].finish,
            FinishReason::Rejected(RejectReason::TokenOutOfRange { index: 1, id: -4 })
        ));
        assert!(matches!(
            outs[2].finish,
            FinishReason::Rejected(RejectReason::TooLong { .. })
        ));
        assert!(matches!(
            outs[3].finish,
            FinishReason::Rejected(RejectReason::ZeroMaxNew)
        ));
        assert_eq!(outs[4].finish, FinishReason::MaxTokens);
        assert_eq!(rep.rejected, 4);
        assert_eq!(rep.reject_counts.total(), 4);
        assert_eq!(rep.reject_counts.bad_token, 1);
        assert_eq!(rep.reject_counts.too_long, 1);
        assert_eq!(rep.sequences, 1);
    }

    #[test]
    fn stop_id_ends_generation_without_emitting_it() {
        let rt = Runtime::native();
        let (cfg, params, qm) = pico_model(&rt);
        // Learn what greedy emits first, then rerun with that as stop id.
        let req = |id| GenRequest {
            id,
            prompt: vec![3, 1, 4, 1, 5],
            max_new: 3,
            stop_id: None,
        };
        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let (outs, _) = eng.generate(vec![req(0)]).unwrap();
        let first = outs[0].tokens[0];

        let mut eng = Engine::new(&rt, &cfg, &params, &qm, GenConfig::default()).unwrap();
        let mut r = req(1);
        r.stop_id = Some(first);
        let (outs, rep) = eng.generate(vec![r]).unwrap();
        assert_eq!(outs[0].finish, FinishReason::Stop);
        assert!(outs[0].tokens.is_empty());
        assert_eq!(rep.sequences, 1);
    }
}
