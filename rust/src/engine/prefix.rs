//! Radix (compressed-trie) prefix cache: token prefixes -> KV block
//! chains.
//!
//! Finished sequences insert their token prefix together with the pool
//! blocks holding that prefix's keys/values; a later request whose
//! prompt shares a cached prefix looks it up, takes references on the
//! matched blocks, and skips that portion of prefill entirely (the
//! dominant win when many requests share a system prompt). The tree is
//! the only holder of a cached-but-idle prefix's blocks, so evicting its
//! least-recently-used leaves is exactly "drop unreferenced prefixes
//! under memory pressure" — the pool frees a block the moment its last
//! reference (tree or sequence) is released.
//!
//! Each node stores one block id *per edge token* (the block holding
//! that absolute position's KV rows). Per-token storage makes edge
//! splits trivial at any offset, while inserts aligned to `block_tokens`
//! guarantee the invariant the block-table gather relies on: the entry
//! that contributed the id at a span's last matched position followed
//! this exact token path through that position and wrote the block's
//! entire span, so every chain entry is a fully-written block whose rows
//! match the query. Lookups may still match an arbitrary (unaligned)
//! number of tokens — the caller shares whole blocks and copy-on-writes
//! the partial tail (DESIGN.md §12).
//!
//! The tree never touches the pool itself: [`RadixTree::insert`] returns
//! the blocks it newly references and [`RadixTree::evict_lru`] the blocks
//! it dropped; the engine mirrors those into `BlockPool` refcounts (and
//! the paged invariant check cross-verifies via [`RadixTree::block_refs`]).

use std::collections::BTreeMap;

#[derive(Debug)]
struct Node {
    /// Token run labeling the edge from the parent (empty only at root).
    edge: Vec<i32>,
    /// Block id holding each edge token's KV rows (parallel to `edge`).
    blocks: Vec<u32>,
    /// (first edge token, node id) — first tokens are distinct.
    children: Vec<(i32, usize)>,
    parent: usize,
    /// Monotonic use stamp (engine clock) for LRU eviction.
    last_use: u64,
}

#[derive(Debug)]
pub struct RadixTree {
    block_tokens: usize,
    /// Slab of nodes; `None` = evicted slot awaiting reuse. Node 0 is
    /// the root (empty edge, never evicted).
    nodes: Vec<Option<Node>>,
    free_ids: Vec<usize>,
}

impl RadixTree {
    pub fn new(block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        Self {
            block_tokens,
            nodes: vec![Some(Node {
                edge: Vec::new(),
                blocks: Vec::new(),
                children: Vec::new(),
                parent: 0,
                last_use: 0,
            })],
            free_ids: Vec::new(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Live nodes, root excluded.
    pub fn node_count(&self) -> usize {
        self.nodes.iter().flatten().count() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.node_count() == 0
    }

    /// Total tokens cached across all live edges — the
    /// `prefix_cached_tokens` gauge exported by the engine metrics
    /// (DESIGN.md §15).
    pub fn cached_tokens(&self) -> usize {
        self.nodes.iter().flatten().map(|n| n.edge.len()).sum()
    }

    fn node(&self, id: usize) -> &Node {
        self.nodes[id].as_ref().expect("dangling node id")
    }

    fn node_mut(&mut self, id: usize) -> &mut Node {
        self.nodes[id].as_mut().expect("dangling node id")
    }

    fn new_node(&mut self, node: Node) -> usize {
        match self.free_ids.pop() {
            Some(id) => {
                self.nodes[id] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        }
    }

    fn child_by_token(&self, id: usize, tok: i32) -> Option<usize> {
        self.node(id)
            .children
            .iter()
            .find(|(t, _)| *t == tok)
            .map(|&(_, c)| c)
    }

    /// Longest cached prefix of `tokens`: `(match_len, chain)` where
    /// `chain[i]` is the block holding positions `[i*bt, (i+1)*bt)` and
    /// `chain.len() == ceil(match_len / bt)` — the last entry may cover
    /// the match only partially (`match_len % bt != 0`, the
    /// partial-block boundary case; the caller copy-on-writes it).
    /// Bumps LRU stamps along the matched path with `clock`.
    pub fn lookup(&mut self, tokens: &[i32], clock: u64) -> (usize, Vec<u32>) {
        let mut per_token: Vec<u32> = Vec::new();
        let mut id = 0usize;
        self.node_mut(0).last_use = clock;
        while per_token.len() < tokens.len() {
            let Some(child) = self.child_by_token(id, tokens[per_token.len()]) else {
                break;
            };
            self.node_mut(child).last_use = clock;
            let n = self.node(child);
            let remaining = &tokens[per_token.len()..];
            let mut common = 0usize;
            while common < n.edge.len()
                && common < remaining.len()
                && n.edge[common] == remaining[common]
            {
                common += 1;
            }
            per_token.extend_from_slice(&n.blocks[..common]);
            if common < n.edge.len() {
                break; // diverged (or query exhausted) mid-edge
            }
            id = child;
        }
        let p = per_token.len();
        // Chain entry for span i = the block at the span's LAST matched
        // position: the entry that contributed it followed this exact
        // token path through that position and (inserts being aligned)
        // wrote the block's whole span, so its rows match the query on
        // every span position — which is not true of the span's first
        // position when an edge split from a later-diverging entry lies
        // inside the span.
        let chain: Vec<u32> = (0..p.div_ceil(self.block_tokens))
            .map(|i| per_token[((i + 1) * self.block_tokens).min(p) - 1])
            .collect();
        (p, chain)
    }

    /// Insert `tokens` (length MUST be a multiple of `block_tokens`)
    /// with `chain[i]` naming the block that holds span `i` (positions
    /// `[i*bt, (i+1)*bt)`). Already-cached prefixes are deduplicated
    /// (the existing blocks win); only genuinely new suffix nodes
    /// reference the caller's blocks. Returns every block reference the
    /// tree newly took — the caller must `retain` each on the pool
    /// exactly once.
    pub fn insert(&mut self, tokens: &[i32], chain: &[u32], clock: u64) -> Vec<u32> {
        assert_eq!(
            tokens.len() % self.block_tokens,
            0,
            "radix inserts must be block-aligned"
        );
        assert_eq!(
            chain.len(),
            tokens.len() / self.block_tokens,
            "one chain entry per block-sized token span"
        );
        let mut new_refs: Vec<u32> = Vec::new();
        let mut id = 0usize;
        let mut pos = 0usize;
        self.node_mut(0).last_use = clock;
        while pos < tokens.len() {
            let Some(child) = self.child_by_token(id, tokens[pos]) else {
                // No child starts with this token: hang the whole
                // remaining suffix off `id` as one new node.
                let edge: Vec<i32> = tokens[pos..].to_vec();
                let blocks: Vec<u32> = (pos..tokens.len())
                    .map(|p| chain[p / self.block_tokens])
                    .collect();
                push_distinct_runs(&blocks, &mut new_refs);
                let node = self.new_node(Node {
                    edge,
                    blocks,
                    children: Vec::new(),
                    parent: id,
                    last_use: clock,
                });
                self.node_mut(id).children.push((tokens[pos], node));
                return new_refs;
            };
            self.node_mut(child).last_use = clock;
            let n = self.node(child);
            let remaining = &tokens[pos..];
            let mut common = 0usize;
            while common < n.edge.len()
                && common < remaining.len()
                && n.edge[common] == remaining[common]
            {
                common += 1;
            }
            if common == n.edge.len() {
                // Fully matched this edge; descend.
                pos += common;
                id = child;
                continue;
            }
            pos += common;
            if pos == tokens.len() {
                // The insert is a strict prefix of an existing edge:
                // nothing new to record (the existing entry covers it).
                return new_refs;
            }
            // Divergence mid-edge: split the child at `common`.
            let (mid_edge, rest_edge, mid_blocks, rest_blocks) = {
                let n = self.node(child);
                (
                    n.edge[..common].to_vec(),
                    n.edge[common..].to_vec(),
                    n.blocks[..common].to_vec(),
                    n.blocks[common..].to_vec(),
                )
            };
            // A block whose span straddles the split point is now
            // referenced by both halves: one extra tree reference.
            if let (Some(&a), Some(&b)) = (mid_blocks.last(), rest_blocks.first()) {
                if a == b {
                    new_refs.push(a);
                }
            }
            let mid = self.new_node(Node {
                edge: mid_edge,
                blocks: mid_blocks,
                children: Vec::new(),
                parent: id,
                last_use: clock,
            });
            // Rewire: parent -> mid -> child(rest).
            let first = tokens[pos - common];
            for slot in self.node_mut(id).children.iter_mut() {
                if slot.0 == first {
                    slot.1 = mid;
                }
            }
            {
                let c = self.node_mut(child);
                c.edge = rest_edge;
                c.blocks = rest_blocks;
                c.parent = mid;
            }
            let rest_first = self.node(child).edge[0];
            self.node_mut(mid).children.push((rest_first, child));
            // New suffix node under mid.
            let edge: Vec<i32> = tokens[pos..].to_vec();
            let blocks: Vec<u32> = (pos..tokens.len())
                .map(|p| chain[p / self.block_tokens])
                .collect();
            push_distinct_runs(&blocks, &mut new_refs);
            let node = self.new_node(Node {
                edge,
                blocks,
                children: Vec::new(),
                parent: mid,
                last_use: clock,
            });
            let new_first = tokens[pos];
            self.node_mut(mid).children.push((new_first, node));
            return new_refs;
        }
        new_refs
    }

    /// Remove the least-recently-used leaf (deterministic tie-break on
    /// node id) and return the block references it held — the caller
    /// must `release` each on the pool. `None` when nothing is cached.
    pub fn evict_lru(&mut self) -> Option<Vec<u32>> {
        let mut victim: Option<(u64, usize)> = None;
        for (id, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            if id == 0 || !n.children.is_empty() {
                continue;
            }
            let better = match victim {
                None => true,
                Some((stamp, _)) => n.last_use < stamp,
            };
            if better {
                victim = Some((n.last_use, id));
            }
        }
        let (_, id) = victim?;
        let node = self.nodes[id].take().expect("victim is alive");
        self.free_ids.push(id);
        let parent = self.node_mut(node.parent);
        parent.children.retain(|&(_, c)| c != id);
        let mut dropped = Vec::new();
        push_distinct_runs(&node.blocks, &mut dropped);
        Some(dropped)
    }

    /// The tree's block-reference multiset: for each live node, each
    /// distinct block run counts one reference. Cross-checked against
    /// `BlockPool` refcounts by the paged invariant check. Ordered
    /// (`BTreeMap`) so callers may iterate it deterministically
    /// (faq-lint D1: no hash-order iteration on the serving path).
    pub fn block_refs(&self) -> BTreeMap<u32, u32> {
        let mut refs: BTreeMap<u32, u32> = BTreeMap::new();
        for slot in self.nodes.iter().flatten() {
            let mut runs = Vec::new();
            push_distinct_runs(&slot.blocks, &mut runs);
            for b in runs {
                *refs.entry(b).or_insert(0) += 1;
            }
        }
        refs
    }

    /// Structural sanity (test helper): parallel edge/block arrays,
    /// distinct child first-tokens, consistent parent links, non-empty
    /// edges off the root.
    pub fn check_structure(&self) -> anyhow::Result<()> {
        for (id, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            if n.edge.len() != n.blocks.len() {
                anyhow::bail!("node {id}: edge/blocks length mismatch");
            }
            if id != 0 && n.edge.is_empty() {
                anyhow::bail!("node {id}: empty edge off the root");
            }
            let mut firsts: Vec<i32> = n.children.iter().map(|&(t, _)| t).collect();
            firsts.sort_unstable();
            firsts.dedup();
            if firsts.len() != n.children.len() {
                anyhow::bail!("node {id}: duplicate child first-tokens");
            }
            for &(tok, c) in &n.children {
                let child = self
                    .nodes
                    .get(c)
                    .and_then(|s| s.as_ref())
                    .ok_or_else(|| anyhow::anyhow!("node {id}: dangling child {c}"))?;
                if child.parent != id {
                    anyhow::bail!("node {c}: parent link != {id}");
                }
                if child.edge.first() != Some(&tok) {
                    anyhow::bail!("node {c}: edge does not start with child key {tok}");
                }
            }
        }
        Ok(())
    }
}

/// Append each distinct consecutive run's block id (per-token block
/// arrays hold runs of up to `block_tokens` equal ids; distinct runs are
/// exactly the distinct blocks a node references).
fn push_distinct_runs(blocks: &[u32], out: &mut Vec<u32>) {
    let mut prev: Option<u32> = None;
    for &b in blocks {
        if prev != Some(b) {
            out.push(b);
            prev = Some(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Insert helper: span `i` maps to the synthetic block id `base + i`.
    fn ins(t: &mut RadixTree, tokens: &[i32], base: u32) -> Vec<u32> {
        let bt = t.block_tokens();
        let chain: Vec<u32> = (0..tokens.len() / bt).map(|i| base + i as u32).collect();
        t.insert(tokens, &chain, 1)
    }

    #[test]
    fn insert_lookup_roundtrip_and_partial_boundary() {
        let mut t = RadixTree::new(4);
        let refs = ins(&mut t, &[1, 2, 3, 4, 5, 6, 7, 8], 100);
        assert_eq!(refs, vec![100, 101]);
        t.check_structure().unwrap();

        // Exact full match.
        let (p, chain) = t.lookup(&[1, 2, 3, 4, 5, 6, 7, 8], 2);
        assert_eq!(p, 8);
        assert_eq!(chain, vec![100, 101]);

        // Partial-block boundary: diverges at position 6 (6 % 4 != 0) —
        // two chain entries, the second covering the match only partially.
        let (p, chain) = t.lookup(&[1, 2, 3, 4, 5, 6, 9, 9], 3);
        assert_eq!(p, 6);
        assert_eq!(chain, vec![100, 101]);

        // Query longer than the cached entry.
        let (p, chain) = t.lookup(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 9, 9, 9], 4);
        assert_eq!(p, 8);
        assert_eq!(chain.len(), 2);

        // No match at all.
        let (p, chain) = t.lookup(&[9, 9], 5);
        assert_eq!(p, 0);
        assert!(chain.is_empty());
    }

    #[test]
    fn divergent_insert_splits_and_dedupes() {
        let mut t = RadixTree::new(4);
        assert_eq!(ins(&mut t, &[1, 2, 3, 4, 5, 6, 7, 8], 100), vec![100, 101]);
        // Shares 6 tokens, diverges mid-block: the split makes block 101
        // referenced by both halves (one extra ref) and only the new
        // suffix's block 201 is taken from the second entry.
        let refs = ins(&mut t, &[1, 2, 3, 4, 5, 6, 9, 9], 200);
        assert_eq!(refs, vec![101, 201]);
        t.check_structure().unwrap();
        assert_eq!(t.node_count(), 3);

        // Both entries still resolve.
        assert_eq!(t.lookup(&[1, 2, 3, 4, 5, 6, 7, 8], 9).0, 8);
        let (p, chain) = t.lookup(&[1, 2, 3, 4, 5, 6, 9, 9], 9);
        assert_eq!(p, 8);
        assert_eq!(chain, vec![100, 201]);

        // Re-inserting an already-cached prefix takes no new references.
        assert!(ins(&mut t, &[1, 2, 3, 4], 300).is_empty());
        assert!(ins(&mut t, &[1, 2, 3, 4, 5, 6, 7, 8], 300).is_empty());
    }

    #[test]
    fn aligned_chain_entries_cover_full_blocks() {
        // The gather invariant: chain[i] comes from whichever entry
        // contributed the aligned position, and that entry wrote the
        // whole block. After the split above, a query matching 8 tokens
        // of the second entry gets [first entry's block 0, second
        // entry's block 1] — both fully written by their sequences.
        let mut t = RadixTree::new(4);
        ins(&mut t, &[1, 2, 3, 4, 5, 6, 7, 8], 100);
        ins(&mut t, &[1, 2, 3, 4, 5, 9, 9, 9], 200);
        let (p, chain) = t.lookup(&[1, 2, 3, 4, 5, 9, 9, 9], 3);
        assert_eq!(p, 8);
        assert_eq!(chain, vec![100, 201]);
    }

    #[test]
    fn lru_eviction_removes_leaves_bottom_up() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 2, 3, 4], &[10, 11], 1);
        t.insert(&[1, 2, 9, 9], &[20, 21], 2);
        t.check_structure().unwrap();
        assert_eq!(t.node_count(), 3);
        // Oldest leaf first: the [3,4] suffix (stamped at clock 1).
        let dropped = t.evict_lru().unwrap();
        assert_eq!(dropped, vec![11]);
        // Then the [9,9] suffix, then the shared [1,2] node (a leaf now).
        assert_eq!(t.evict_lru().unwrap(), vec![21]);
        assert_eq!(t.evict_lru().unwrap(), vec![10]);
        assert!(t.evict_lru().is_none());
        assert!(t.is_empty());
        // The slab reuses freed ids.
        t.insert(&[5, 6], &[30], 3);
        t.check_structure().unwrap();
        assert_eq!(t.lookup(&[5, 6], 4).0, 2);
    }

    #[test]
    fn block_refs_counts_split_shared_blocks_twice() {
        let mut t = RadixTree::new(4);
        ins(&mut t, &[1, 2, 3, 4, 5, 6, 7, 8], 100);
        ins(&mut t, &[1, 2, 3, 4, 5, 6, 9, 9], 200);
        let refs = t.block_refs();
        assert_eq!(refs[&100], 1);
        assert_eq!(refs[&101], 2, "straddling block referenced by both halves");
        assert_eq!(refs[&201], 1);
    }

    #[test]
    fn cached_tokens_tracks_edges_and_eviction() {
        let mut t = RadixTree::new(4);
        assert_eq!(t.cached_tokens(), 0);
        ins(&mut t, &[1, 2, 3, 4, 5, 6, 7, 8], 100);
        assert_eq!(t.cached_tokens(), 8);
        // Mid-edge split adds no tokens (6 shared + 2 + 2 suffixes).
        ins(&mut t, &[1, 2, 3, 4, 5, 6, 9, 9], 200);
        assert_eq!(t.cached_tokens(), 10);
        t.evict_lru().unwrap();
        assert_eq!(t.cached_tokens(), 8);
    }

    #[test]
    #[should_panic(expected = "block-aligned")]
    fn unaligned_insert_panics() {
        let mut t = RadixTree::new(4);
        t.insert(&[1, 2, 3], &[0], 1);
    }
}
