//! Token sampling over next-token logits: greedy, temperature, top-k.
//!
//! Driven by the repo's deterministic PRNG ([`crate::tensor::Rng`]), so a
//! generation is reproducible from integer seeds. Each sequence owns its
//! own sampler stream keyed by (seed, sequence id) — sampled tokens never
//! depend on slot assignment, batch composition, or thread count.

use crate::tensor::Rng;

#[derive(Debug)]
pub struct Sampler {
    /// 0 (or below) = greedy argmax; otherwise logits are divided by
    /// this before the softmax draw.
    pub temperature: f32,
    /// Restrict sampling to the k highest logits; 0 = no restriction.
    pub top_k: usize,
    rng: Rng,
}

impl Sampler {
    pub fn new(temperature: f32, top_k: usize, seed: u64) -> Self {
        Self {
            temperature,
            top_k,
            rng: Rng::new(seed),
        }
    }

    /// Per-sequence stream: one independent sampler per (seed, id) pair.
    pub fn for_sequence(temperature: f32, top_k: usize, seed: u64, id: usize) -> Self {
        // SplitMix-style mix so nearby ids land far apart in seed space.
        let mixed = seed ^ (id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Self::new(temperature, top_k, mixed)
    }

    /// Greedy argmax: first index of the maximum (NaN entries never win).
    pub fn argmax(logits: &[f32]) -> usize {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in logits.iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    /// Draw the next token id from unnormalized next-token logits.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        debug_assert!(!logits.is_empty());
        if self.temperature <= 0.0 {
            return Self::argmax(logits);
        }
        // Candidate set: all indices, or the top-k by logit (ties broken
        // toward lower index so the set is deterministic).
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if self.top_k > 0 && self.top_k < logits.len() {
            idx.sort_by(|&a, &b| {
                logits[b]
                    .partial_cmp(&logits[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            idx.truncate(self.top_k);
        }
        // Max-subtracted softmax weights at the given temperature.
        let mx = idx.iter().fold(f32::NEG_INFINITY, |m, &i| m.max(logits[i]));
        if !mx.is_finite() {
            return Self::argmax(logits);
        }
        let weights: Vec<f32> = idx
            .iter()
            .map(|&i| ((logits[i] - mx) / self.temperature).exp())
            .collect();
        let total: f32 = weights.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return Self::argmax(logits);
        }
        let mut x = self.rng.uniform() * total;
        for (w, &i) in weights.iter().zip(&idx) {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        *idx.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max_and_first_tie() {
        let mut s = Sampler::new(0.0, 0, 1);
        assert_eq!(s.sample(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(s.sample(&[2.0, 2.0, 1.0]), 0);
        assert_eq!(s.sample(&[f32::NAN, 1.0, 1.0]), 1);
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let logits = [0.3f32, 1.2, -0.5, 2.0, 0.0];
        let mut a = Sampler::new(0.8, 0, 42);
        let mut b = Sampler::new(0.8, 0, 42);
        for _ in 0..64 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
        let mut c = Sampler::new(0.8, 0, 43);
        let draws_a: Vec<usize> = (0..64).map(|_| a.sample(&logits)).collect();
        let draws_c: Vec<usize> = (0..64).map(|_| c.sample(&logits)).collect();
        assert_ne!(draws_a, draws_c, "different seeds should diverge");
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [0.0f32, 5.0, 4.0, -3.0, 1.0];
        let mut s = Sampler::new(1.0, 2, 7);
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 1 || t == 2, "token {t} outside top-2");
        }
    }

    #[test]
    fn low_temperature_concentrates_on_max() {
        let logits = [0.0f32, 10.0, 0.0];
        let mut s = Sampler::new(0.05, 0, 9);
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn degenerate_logits_fall_back_to_argmax() {
        let mut s = Sampler::new(1.0, 0, 3);
        let ninf = f32::NEG_INFINITY;
        assert_eq!(s.sample(&[ninf, ninf, ninf]), 0);
    }

    #[test]
    fn sequence_streams_are_independent() {
        let logits = [1.0f32, 1.1, 0.9, 1.05];
        let mut a = Sampler::for_sequence(1.0, 0, 5, 0);
        let mut b = Sampler::for_sequence(1.0, 0, 5, 1);
        let da: Vec<usize> = (0..64).map(|_| a.sample(&logits)).collect();
        let db: Vec<usize> = (0..64).map(|_| b.sample(&logits)).collect();
        assert_ne!(da, db);
        let mut a2 = Sampler::for_sequence(1.0, 0, 5, 0);
        let da2: Vec<usize> = (0..64).map(|_| a2.sample(&logits)).collect();
        assert_eq!(da, da2);
    }
}
