//! Key/value storage for KV-cached decode: the dense per-slot slabs
//! (the seed layout) and the block-paged pool that replaces them on the
//! serving path.
//!
//! **Dense** ([`KvCache`]): two `[L, slots, T_max, d]` tensors whose rows
//! `0..len[slot]` are the attention keys/values of every token a slot's
//! sequence has fed so far. Memory scales with `slots × T_max` even when
//! sequences are short. Kept as the reference engine — the differential
//! fuzz harness (`testutil::fuzz`) pins the paged engine bitwise against
//! it.
//!
//! **Paged** ([`BlockPool`]): two `[n_blocks, L, block_tokens, d]` pool
//! tensors plus per-block reference counts and a free list. A sequence
//! owns a *block table* (an ordered list of block ids) instead of a
//! `T_max` row range; blocks are refcounted so sequences with a common
//! prompt prefix share the prefix's blocks (see [`super::prefix`]), with
//! copy-on-write when a sequence must append into a partially shared
//! block. Rows inside a block are bit-for-bit the same f32 values the
//! dense slabs would hold, so the paged attention gather in
//! `runtime/native/decode.rs` reproduces dense logits exactly
//! (DESIGN.md §12).
//!
//! Both stores use the same take/put_back loan to cross the backend
//! boundary without copying multi-megabyte tensors each step.

use crate::obs::{Trace, TraceEvent};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

#[derive(Debug)]
pub struct KvCache {
    n_layer: usize,
    slots: usize,
    t_max: usize,
    d: usize,
    /// `None` while the slabs are out on loan via [`KvCache::take`].
    k: Option<Tensor>,
    v: Option<Tensor>,
    /// Valid rows per slot.
    len: Vec<usize>,
}

impl KvCache {
    pub fn new(n_layer: usize, slots: usize, t_max: usize, d: usize) -> Self {
        assert!(n_layer > 0 && slots > 0 && t_max > 0 && d > 0);
        let shape = [n_layer, slots, t_max, d];
        Self {
            n_layer,
            slots,
            t_max,
            d,
            k: Some(Tensor::zeros(&shape)),
            v: Some(Tensor::zeros(&shape)),
            len: vec![0; slots],
        }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn t_max(&self) -> usize {
        self.t_max
    }

    /// Tokens cached for `slot` (== the next append position).
    pub fn len(&self, slot: usize) -> usize {
        self.len[slot]
    }

    /// Recycle a slot for a new sequence. Stale rows need no zeroing:
    /// causal reads only ever touch rows `0..len[slot]`.
    pub fn reset(&mut self, slot: usize) {
        self.len[slot] = 0;
    }

    /// Move the slabs out (to wrap as backend arguments).
    pub fn take(&mut self) -> Result<(Tensor, Tensor)> {
        match (self.k.take(), self.v.take()) {
            (Some(k), Some(v)) => Ok((k, v)),
            _ => bail!("KvCache slabs already taken"),
        }
    }

    /// Return the slabs after a backend call.
    pub fn put_back(&mut self, k: Tensor, v: Tensor) -> Result<()> {
        let want = [self.n_layer, self.slots, self.t_max, self.d];
        if k.shape() != want || v.shape() != want {
            bail!(
                "put_back shapes k {:?} / v {:?} != {want:?}",
                k.shape(),
                v.shape()
            );
        }
        if self.k.is_some() || self.v.is_some() {
            bail!("KvCache slabs were never taken");
        }
        self.k = Some(k);
        self.v = Some(v);
        Ok(())
    }

    /// Append one token's key/value rows for `slot` from a decode step's
    /// `[L, B, d]` outputs, at the slot's current fill position.
    pub fn append(&mut self, slot: usize, k_new: &Tensor, v_new: &Tensor) -> Result<()> {
        let want = [self.n_layer, self.slots, self.d];
        if k_new.shape() != want || v_new.shape() != want {
            bail!(
                "append shapes k {:?} / v {:?} != {want:?}",
                k_new.shape(),
                v_new.shape()
            );
        }
        if slot >= self.slots {
            bail!("slot {slot} out of range [0, {})", self.slots);
        }
        let p = self.len[slot];
        if p >= self.t_max {
            bail!("slot {slot}: cache full ({p} of {} rows)", self.t_max);
        }
        let k = self.k.as_mut().context("KvCache slabs are taken")?;
        let v = self.v.as_mut().context("KvCache slabs are taken")?;
        for l in 0..self.n_layer {
            let src = (l * self.slots + slot) * self.d;
            let dst = ((l * self.slots + slot) * self.t_max + p) * self.d;
            k.data_mut()[dst..dst + self.d].copy_from_slice(&k_new.data()[src..src + self.d]);
            v.data_mut()[dst..dst + self.d].copy_from_slice(&v_new.data()[src..src + self.d]);
        }
        self.len[slot] = p + 1;
        Ok(())
    }

    /// Cached key row (layer, slot, t) — test/debug accessor.
    pub fn k_row(&self, layer: usize, slot: usize, t: usize) -> Result<&[f32]> {
        let k = self.k.as_ref().context("KvCache slabs are taken")?;
        if layer >= self.n_layer || slot >= self.slots || t >= self.len[slot] {
            bail!("k_row({layer}, {slot}, {t}) out of range");
        }
        let off = ((layer * self.slots + slot) * self.t_max + t) * self.d;
        Ok(&k.data()[off..off + self.d])
    }
}

/// Refcounted pool of fixed-size KV pages (`[n_blocks, L, block_tokens,
/// d]` for keys and values). Blocks are handed out by [`BlockPool::alloc`],
/// shared via [`BlockPool::retain`], and recycled onto the free list the
/// moment their refcount returns to zero — refcount arithmetic is
/// checked, never saturating, so underflow is a loud error instead of a
/// silent double-free.
#[derive(Debug)]
pub struct BlockPool {
    n_layer: usize,
    n_blocks: usize,
    block_tokens: usize,
    d: usize,
    /// `None` while on loan via [`BlockPool::take`].
    k: Option<Tensor>,
    v: Option<Tensor>,
    refcount: Vec<u32>,
    /// LIFO free list (deterministic allocation order).
    free: Vec<u32>,
    /// Observability: allocation events ([`TraceEvent::BlockAlloc`])
    /// stamped with the owner engine's tick. Disabled by default — the
    /// handle is a no-op unless the engine installed an enabled trace.
    trace: Trace,
    tick: u64,
}

impl BlockPool {
    pub fn new(n_layer: usize, n_blocks: usize, block_tokens: usize, d: usize) -> Self {
        assert!(n_layer > 0 && n_blocks > 0 && block_tokens > 0 && d > 0);
        let shape = [n_blocks, n_layer, block_tokens, d];
        Self {
            n_layer,
            n_blocks,
            block_tokens,
            d,
            k: Some(Tensor::zeros(&shape)),
            v: Some(Tensor::zeros(&shape)),
            refcount: vec![0; n_blocks],
            // Pop from the back => block 0 first (pure convention).
            free: (0..n_blocks as u32).rev().collect(),
            trace: Trace::disabled(),
            tick: 0,
        }
    }

    /// Install the engine's trace handle (cheap clone; disabled = no-op).
    pub fn set_trace(&mut self, trace: Trace) {
        self.trace = trace;
    }

    /// Advance the tick stamped onto this pool's trace events (the
    /// engine forwards its step counter once per step).
    pub fn set_tick(&mut self, tick: u64) {
        self.tick = tick;
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn in_use_blocks(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    /// Leak check for the drain path: every block must be back on the
    /// free list. Errors name the still-referenced blocks so a leak
    /// points at its owner (slot table, reservation, or tree reference
    /// that was never released).
    pub fn assert_all_free(&self) -> Result<()> {
        if self.free.len() == self.n_blocks {
            return Ok(());
        }
        let leaked: Vec<String> = self
            .refcount
            .iter()
            .enumerate()
            .filter(|(_, &rc)| rc > 0)
            .map(|(b, &rc)| format!("{b}(rc={rc})"))
            .collect();
        bail!(
            "{} of {} blocks leaked after drain: [{}]",
            leaked.len(),
            self.n_blocks,
            leaked.join(", ")
        );
    }

    pub fn refcount(&self, block: u32) -> u32 {
        self.refcount[block as usize]
    }

    /// Take one block off the free list (refcount 0 -> 1).
    pub fn alloc(&mut self) -> Result<u32> {
        let b = self.free.pop().context("block pool exhausted")?;
        debug_assert_eq!(self.refcount[b as usize], 0);
        self.refcount[b as usize] = 1;
        self.trace
            .emit(self.tick, TraceEvent::BlockAlloc { block: b as usize });
        Ok(b)
    }

    /// Add a reference to an already-live block.
    pub fn retain(&mut self, block: u32) -> Result<()> {
        let i = block as usize;
        if i >= self.n_blocks {
            bail!("retain: block {block} out of range [0, {})", self.n_blocks);
        }
        if self.refcount[i] == 0 {
            bail!("retain: block {block} is free (refcount 0)");
        }
        self.refcount[i] += 1;
        Ok(())
    }

    /// Drop a reference; the block returns to the free list at zero.
    pub fn release(&mut self, block: u32) -> Result<()> {
        let i = block as usize;
        if i >= self.n_blocks {
            bail!("release: block {block} out of range [0, {})", self.n_blocks);
        }
        if self.refcount[i] == 0 {
            bail!("release: block {block} refcount underflow");
        }
        self.refcount[i] -= 1;
        if self.refcount[i] == 0 {
            self.free.push(block);
        }
        Ok(())
    }

    /// Move the pool tensors out (to wrap as backend arguments).
    pub fn take(&mut self) -> Result<(Tensor, Tensor)> {
        match (self.k.take(), self.v.take()) {
            (Some(k), Some(v)) => Ok((k, v)),
            _ => bail!("BlockPool tensors already taken"),
        }
    }

    /// Return the pool tensors after a backend call.
    pub fn put_back(&mut self, k: Tensor, v: Tensor) -> Result<()> {
        let want = [self.n_blocks, self.n_layer, self.block_tokens, self.d];
        if k.shape() != want || v.shape() != want {
            bail!(
                "put_back shapes k {:?} / v {:?} != {want:?}",
                k.shape(),
                v.shape()
            );
        }
        if self.k.is_some() || self.v.is_some() {
            bail!("BlockPool tensors were never taken");
        }
        self.k = Some(k);
        self.v = Some(v);
        Ok(())
    }

    /// Write one token's key/value rows for `slot` (from a decode step's
    /// `[L, B, d]` outputs) into `block` at row `row`. Exactly the rows
    /// [`KvCache::append`] would write — a plain f32 copy, so the paged
    /// store is bitwise the dense store rearranged.
    pub fn write_row(
        &mut self,
        block: u32,
        row: usize,
        slot: usize,
        k_new: &Tensor,
        v_new: &Tensor,
    ) -> Result<()> {
        let bi = block as usize;
        if bi >= self.n_blocks || row >= self.block_tokens {
            bail!(
                "write_row: block {block} row {row} out of range ({} blocks x {} rows)",
                self.n_blocks,
                self.block_tokens
            );
        }
        let shape = k_new.shape();
        if shape.len() != 3 || shape[0] != self.n_layer || shape[2] != self.d {
            bail!(
                "write_row: k_new {shape:?} must be [{}, B, {}]",
                self.n_layer,
                self.d
            );
        }
        if v_new.shape() != shape {
            bail!("write_row: v_new {:?} != k_new {shape:?}", v_new.shape());
        }
        let b = shape[1];
        if slot >= b {
            bail!("write_row: slot {slot} out of range [0, {b})");
        }
        let k = self.k.as_mut().context("BlockPool tensors are taken")?;
        let v = self.v.as_mut().context("BlockPool tensors are taken")?;
        for l in 0..self.n_layer {
            let src = (l * b + slot) * self.d;
            let dst = ((bi * self.n_layer + l) * self.block_tokens + row) * self.d;
            k.data_mut()[dst..dst + self.d].copy_from_slice(&k_new.data()[src..src + self.d]);
            v.data_mut()[dst..dst + self.d].copy_from_slice(&v_new.data()[src..src + self.d]);
        }
        Ok(())
    }

    /// Copy-on-write: duplicate rows `0..rows` of `src` into `dst`
    /// across every layer, for both keys and values. A bitwise f32 copy —
    /// the diverging sequence sees exactly the shared prefix's rows.
    pub fn cow_copy(&mut self, src: u32, dst: u32, rows: usize) -> Result<()> {
        let (si, di) = (src as usize, dst as usize);
        if si >= self.n_blocks || di >= self.n_blocks {
            bail!("cow_copy: block {src} or {dst} out of range");
        }
        if si == di {
            bail!("cow_copy: src == dst ({src})");
        }
        if rows > self.block_tokens {
            bail!("cow_copy: {rows} rows > block_tokens {}", self.block_tokens);
        }
        let k = self.k.as_mut().context("BlockPool tensors are taken")?;
        let v = self.v.as_mut().context("BlockPool tensors are taken")?;
        let span = rows * self.d;
        for l in 0..self.n_layer {
            let s = ((si * self.n_layer + l) * self.block_tokens) * self.d;
            let t = ((di * self.n_layer + l) * self.block_tokens) * self.d;
            for data in [k.data_mut(), v.data_mut()] {
                let (src_row, dst_row) = if s < t {
                    let (a, b) = data.split_at_mut(t);
                    (&a[s..s + span], &mut b[..span])
                } else {
                    let (a, b) = data.split_at_mut(s);
                    (&b[..span], &mut a[t..t + span])
                };
                dst_row.copy_from_slice(src_row);
            }
        }
        Ok(())
    }

    /// Cached key row (layer, block, row) — test/debug accessor.
    pub fn k_row(&self, layer: usize, block: u32, row: usize) -> Result<&[f32]> {
        let k = self.k.as_ref().context("BlockPool tensors are taken")?;
        let bi = block as usize;
        if layer >= self.n_layer || bi >= self.n_blocks || row >= self.block_tokens {
            bail!("k_row({layer}, {block}, {row}) out of range");
        }
        let off = ((bi * self.n_layer + layer) * self.block_tokens + row) * self.d;
        Ok(&k.data()[off..off + self.d])
    }

    /// Structural invariants (property-tested by the fuzz harness after
    /// every scheduler step): the free list is a duplicate-free subset
    /// of the pool, and refcounts agree with free-list membership —
    /// together these make the free and live sets a partition.
    pub fn check_invariants(&self) -> Result<()> {
        if self.free.len() > self.n_blocks {
            bail!(
                "free list has {} entries for a {}-block pool",
                self.free.len(),
                self.n_blocks
            );
        }
        let mut on_free = vec![false; self.n_blocks];
        for &b in &self.free {
            let i = b as usize;
            if i >= self.n_blocks {
                bail!("free list holds out-of-range block {b}");
            }
            if on_free[i] {
                bail!("block {b} appears twice on the free list");
            }
            on_free[i] = true;
        }
        for (i, &rc) in self.refcount.iter().enumerate() {
            if (rc == 0) != on_free[i] {
                bail!("block {i}: refcount {rc} but on_free={}", on_free[i]);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step_rows(l: usize, slots: usize, d: usize, tag: f32) -> (Tensor, Tensor) {
        let n = l * slots * d;
        let kd: Vec<f32> = (0..n).map(|i| tag + i as f32).collect();
        let vd: Vec<f32> = kd.iter().map(|x| -x).collect();
        let k = Tensor::from_vec(&[l, slots, d], kd).unwrap();
        let v = Tensor::from_vec(&[l, slots, d], vd).unwrap();
        (k, v)
    }

    #[test]
    fn assert_all_free_names_leaked_blocks() {
        let mut pool = BlockPool::new(1, 3, 2, 4);
        pool.assert_all_free().unwrap();
        let b = pool.alloc().unwrap();
        let err = pool.assert_all_free().unwrap_err().to_string();
        assert!(err.contains("leaked"), "unexpected error '{err}'");
        assert!(err.contains(&format!("{b}(rc=1)")), "unexpected error '{err}'");
        pool.release(b).unwrap();
        pool.assert_all_free().unwrap();
    }

    #[test]
    fn append_then_read_back() {
        let (l, slots, t_max, d) = (2usize, 3usize, 4usize, 5usize);
        let mut c = KvCache::new(l, slots, t_max, d);
        let (k0, v0) = step_rows(l, slots, d, 100.0);
        c.append(1, &k0, &v0).unwrap();
        let (k1, v1) = step_rows(l, slots, d, 900.0);
        c.append(1, &k1, &v1).unwrap();
        assert_eq!(c.len(1), 2);
        assert_eq!(c.len(0), 0);
        // Row t=0 of layer 1 slot 1 equals the first step's (1, 1) row.
        let src = (slots + 1) * d;
        assert_eq!(c.k_row(1, 1, 0).unwrap(), &k0.data()[src..src + d]);
        assert_eq!(c.k_row(1, 1, 1).unwrap(), &k1.data()[src..src + d]);
        assert!(c.k_row(1, 1, 2).is_err());
    }

    #[test]
    fn reset_recycles_slot() {
        let mut c = KvCache::new(1, 2, 2, 3);
        let (k, v) = step_rows(1, 2, 3, 1.0);
        c.append(0, &k, &v).unwrap();
        c.append(0, &k, &v).unwrap();
        assert!(c.append(0, &k, &v).is_err()); // full
        c.reset(0);
        assert_eq!(c.len(0), 0);
        c.append(0, &k, &v).unwrap();
    }

    #[test]
    fn take_put_back_roundtrip() {
        let mut c = KvCache::new(1, 1, 2, 2);
        let (k, v) = c.take().unwrap();
        assert!(c.take().is_err());
        let (kn, vn) = step_rows(1, 1, 2, 5.0);
        assert!(c.append(0, &kn, &vn).is_err()); // slabs on loan
        assert!(c.put_back(Tensor::zeros(&[1, 1]), v.clone()).is_err());
        c.put_back(k, v).unwrap();
        c.append(0, &kn, &vn).unwrap();
        assert_eq!(c.len(0), 1);
    }

    #[test]
    fn shape_checks() {
        let mut c = KvCache::new(2, 2, 3, 4);
        let bad = Tensor::zeros(&[2, 2, 5]);
        assert!(c.append(0, &bad, &bad).is_err());
        assert!(c.append(9, &Tensor::zeros(&[2, 2, 4]), &Tensor::zeros(&[2, 2, 4])).is_err());
    }

    // ------------------------------------------------------- BlockPool

    #[test]
    fn pool_alloc_retain_release_lifecycle() {
        let mut p = BlockPool::new(2, 3, 4, 5);
        assert_eq!(p.free_blocks(), 3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.free_blocks(), 1);
        assert_eq!(p.in_use_blocks(), 2);
        p.retain(a).unwrap();
        assert_eq!(p.refcount(a), 2);
        p.release(a).unwrap();
        assert_eq!(p.free_blocks(), 1, "still one reference out");
        p.release(a).unwrap();
        assert_eq!(p.free_blocks(), 2);
        // Underflow and free-block retains are loud errors.
        assert!(p.release(a).is_err());
        assert!(p.retain(a).is_err());
        p.check_invariants().unwrap();
        p.release(b).unwrap();
        p.check_invariants().unwrap();
        assert_eq!(p.free_blocks(), 3);
    }

    #[test]
    fn pool_exhaustion_is_an_error() {
        let mut p = BlockPool::new(1, 2, 2, 2);
        p.alloc().unwrap();
        p.alloc().unwrap();
        assert!(p.alloc().is_err());
    }

    #[test]
    fn pool_write_and_cow_copy_rows() {
        let (l, bt, d) = (2usize, 3usize, 4usize);
        let mut p = BlockPool::new(l, 4, bt, d);
        let src = p.alloc().unwrap();
        let (k, v) = step_rows(l, 2, d, 10.0);
        p.write_row(src, 0, 1, &k, &v).unwrap();
        p.write_row(src, 1, 0, &k, &v).unwrap();
        // Layer 0 slot 1 of the step rows lands at block row 0.
        let want0 = &k.data()[d..2 * d];
        assert_eq!(p.k_row(0, src, 0).unwrap(), want0);
        // COW: rows 0..2 copied bit-exactly into a fresh block.
        let dst = p.alloc().unwrap();
        p.cow_copy(src, dst, 2).unwrap();
        for layer in 0..l {
            for row in 0..2 {
                assert_eq!(
                    p.k_row(layer, src, row).unwrap(),
                    p.k_row(layer, dst, row).unwrap()
                );
            }
        }
        assert!(p.cow_copy(src, src, 1).is_err());
        assert!(p.cow_copy(src, dst, bt + 1).is_err());
    }

    #[test]
    fn pool_take_put_back_loan() {
        let mut p = BlockPool::new(1, 2, 2, 2);
        let b = p.alloc().unwrap();
        let (kt, vt) = p.take().unwrap();
        assert!(p.take().is_err());
        let (k, v) = step_rows(1, 1, 2, 3.0);
        assert!(p.write_row(b, 0, 0, &k, &v).is_err()); // on loan
        assert!(p.put_back(Tensor::zeros(&[1]), vt.clone()).is_err());
        p.put_back(kt, vt).unwrap();
        p.write_row(b, 0, 0, &k, &v).unwrap();
    }
}
