//! Per-slot, per-layer key/value slabs for KV-cached decode.
//!
//! The cache owns two `[L, slots, T_max, d]` tensors whose rows
//! `0..len[slot]` are the attention keys/values of every token a slot's
//! sequence has fed so far. The backend entry `decode_step_q` *reads*
//! the slabs (they travel as ordinary arguments — backends stay
//! stateless) and returns the new token's `[L, B, d]` key/value rows,
//! which [`KvCache::append`] writes at the slot's fill position.
//!
//! To cross the backend boundary without copying multi-megabyte slabs
//! each step, [`KvCache::take`] moves the tensors out (for wrapping in
//! host `Buffer`s) and [`KvCache::put_back`] returns them — the scheduler
//! does this around every `decode_step_q` call.

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

#[derive(Debug)]
pub struct KvCache {
    n_layer: usize,
    slots: usize,
    t_max: usize,
    d: usize,
    /// `None` while the slabs are out on loan via [`KvCache::take`].
    k: Option<Tensor>,
    v: Option<Tensor>,
    /// Valid rows per slot.
    len: Vec<usize>,
}

impl KvCache {
    pub fn new(n_layer: usize, slots: usize, t_max: usize, d: usize) -> Self {
        assert!(n_layer > 0 && slots > 0 && t_max > 0 && d > 0);
        let shape = [n_layer, slots, t_max, d];
        Self {
            n_layer,
            slots,
            t_max,
            d,
            k: Some(Tensor::zeros(&shape)),
            v: Some(Tensor::zeros(&shape)),
            len: vec![0; slots],
        }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn t_max(&self) -> usize {
        self.t_max
    }

    /// Tokens cached for `slot` (== the next append position).
    pub fn len(&self, slot: usize) -> usize {
        self.len[slot]
    }

    /// Recycle a slot for a new sequence. Stale rows need no zeroing:
    /// causal reads only ever touch rows `0..len[slot]`.
    pub fn reset(&mut self, slot: usize) {
        self.len[slot] = 0;
    }

    /// Move the slabs out (to wrap as backend arguments).
    pub fn take(&mut self) -> Result<(Tensor, Tensor)> {
        match (self.k.take(), self.v.take()) {
            (Some(k), Some(v)) => Ok((k, v)),
            _ => bail!("KvCache slabs already taken"),
        }
    }

    /// Return the slabs after a backend call.
    pub fn put_back(&mut self, k: Tensor, v: Tensor) -> Result<()> {
        let want = [self.n_layer, self.slots, self.t_max, self.d];
        if k.shape() != want || v.shape() != want {
            bail!(
                "put_back shapes k {:?} / v {:?} != {want:?}",
                k.shape(),
                v.shape()
            );
        }
        if self.k.is_some() || self.v.is_some() {
            bail!("KvCache slabs were never taken");
        }
        self.k = Some(k);
        self.v = Some(v);
        Ok(())
    }

    /// Append one token's key/value rows for `slot` from a decode step's
    /// `[L, B, d]` outputs, at the slot's current fill position.
    pub fn append(&mut self, slot: usize, k_new: &Tensor, v_new: &Tensor) -> Result<()> {
        let want = [self.n_layer, self.slots, self.d];
        if k_new.shape() != want || v_new.shape() != want {
            bail!(
                "append shapes k {:?} / v {:?} != {want:?}",
                k_new.shape(),
                v_new.shape()
            );
        }
        if slot >= self.slots {
            bail!("slot {slot} out of range [0, {})", self.slots);
        }
        let p = self.len[slot];
        if p >= self.t_max {
            bail!("slot {slot}: cache full ({p} of {} rows)", self.t_max);
        }
        let k = self.k.as_mut().context("KvCache slabs are taken")?;
        let v = self.v.as_mut().context("KvCache slabs are taken")?;
        for l in 0..self.n_layer {
            let src = (l * self.slots + slot) * self.d;
            let dst = ((l * self.slots + slot) * self.t_max + p) * self.d;
            k.data_mut()[dst..dst + self.d].copy_from_slice(&k_new.data()[src..src + self.d]);
            v.data_mut()[dst..dst + self.d].copy_from_slice(&v_new.data()[src..src + self.d]);
        }
        self.len[slot] = p + 1;
        Ok(())
    }

    /// Cached key row (layer, slot, t) — test/debug accessor.
    pub fn k_row(&self, layer: usize, slot: usize, t: usize) -> Result<&[f32]> {
        let k = self.k.as_ref().context("KvCache slabs are taken")?;
        if layer >= self.n_layer || slot >= self.slots || t >= self.len[slot] {
            bail!("k_row({layer}, {slot}, {t}) out of range");
        }
        let off = ((layer * self.slots + slot) * self.t_max + t) * self.d;
        Ok(&k.data()[off..off + self.d])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step_rows(l: usize, slots: usize, d: usize, tag: f32) -> (Tensor, Tensor) {
        let n = l * slots * d;
        let kd: Vec<f32> = (0..n).map(|i| tag + i as f32).collect();
        let vd: Vec<f32> = kd.iter().map(|x| -x).collect();
        let k = Tensor::from_vec(&[l, slots, d], kd).unwrap();
        let v = Tensor::from_vec(&[l, slots, d], vd).unwrap();
        (k, v)
    }

    #[test]
    fn append_then_read_back() {
        let (l, slots, t_max, d) = (2usize, 3usize, 4usize, 5usize);
        let mut c = KvCache::new(l, slots, t_max, d);
        let (k0, v0) = step_rows(l, slots, d, 100.0);
        c.append(1, &k0, &v0).unwrap();
        let (k1, v1) = step_rows(l, slots, d, 900.0);
        c.append(1, &k1, &v1).unwrap();
        assert_eq!(c.len(1), 2);
        assert_eq!(c.len(0), 0);
        // Row t=0 of layer 1 slot 1 equals the first step's (1, 1) row.
        let src = (slots + 1) * d;
        assert_eq!(c.k_row(1, 1, 0).unwrap(), &k0.data()[src..src + d]);
        assert_eq!(c.k_row(1, 1, 1).unwrap(), &k1.data()[src..src + d]);
        assert!(c.k_row(1, 1, 2).is_err());
    }

    #[test]
    fn reset_recycles_slot() {
        let mut c = KvCache::new(1, 2, 2, 3);
        let (k, v) = step_rows(1, 2, 3, 1.0);
        c.append(0, &k, &v).unwrap();
        c.append(0, &k, &v).unwrap();
        assert!(c.append(0, &k, &v).is_err()); // full
        c.reset(0);
        assert_eq!(c.len(0), 0);
        c.append(0, &k, &v).unwrap();
    }

    #[test]
    fn take_put_back_roundtrip() {
        let mut c = KvCache::new(1, 1, 2, 2);
        let (k, v) = c.take().unwrap();
        assert!(c.take().is_err());
        let (kn, vn) = step_rows(1, 1, 2, 5.0);
        assert!(c.append(0, &kn, &vn).is_err()); // slabs on loan
        assert!(c.put_back(Tensor::zeros(&[1, 1]), v.clone()).is_err());
        c.put_back(k, v).unwrap();
        c.append(0, &kn, &vn).unwrap();
        assert_eq!(c.len(0), 1);
    }

    #[test]
    fn shape_checks() {
        let mut c = KvCache::new(2, 2, 3, 4);
        let bad = Tensor::zeros(&[2, 2, 5]);
        assert!(c.append(0, &bad, &bad).is_err());
        assert!(c.append(9, &Tensor::zeros(&[2, 2, 4]), &Tensor::zeros(&[2, 2, 4])).is_err());
    }
}
