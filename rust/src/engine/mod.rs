//! Autoregressive decode engine (S16): KV-cached generation over the
//! quantized deployment artifact with continuous batching.
//!
//! The serving path used to be score-only (`fwd_logits_q` over a fixed
//! [B, T] batch); this subsystem adds real token generation, the
//! workload that dominates quantized-LLM deployment:
//!
//! - [`KvCache`] — dense per-slot, per-layer key/value slabs (the seed
//!   layout, kept as the differential-fuzz oracle).
//! - [`BlockPool`] + [`RadixTree`] — the paged replacement (default):
//!   fixed-size refcounted KV pages with per-sequence block tables,
//!   radix-tree prompt-prefix sharing (a request whose prompt matches a
//!   cached prefix skips that prefill entirely), copy-on-write on
//!   divergence, and LRU eviction of idle prefixes (DESIGN.md §12).
//! - [`Sampler`] — greedy / temperature / top-k sampling on the repo's
//!   seeded PRNG; one independent stream per sequence.
//! - [`Engine`] — slot-based continuous batching: sequences of different
//!   lengths (prefilling or decoding) share one batched decode step,
//!   finished sequences free their slot for queued work, and a
//!   [`GenReport`] splits prefill vs decode throughput. The paged engine
//!   admits by free *blocks*, so many short sequences no longer reserve
//!   `T_max` rows each.
//!
//! **Bit-identity:** the logits a sequence sees at position `t` are
//! bitwise equal to `fwd_logits_q`'s logits at position `t` of the full
//! sequence — for every thread count and any batch composition (DESIGN.md
//! §10; pinned by `tests/props.rs`). Greedy generation is therefore
//! exactly "repeatedly score the growing sequence", just without the
//! O(T²) recompute.

mod kv_cache;
mod lifecycle;
mod prefix;
mod sampler;
mod scheduler;

pub use kv_cache::{BlockPool, KvCache};
pub use lifecycle::{CancelToken, EngineClock, FaultInjector, Heartbeat};
pub use prefix::RadixTree;
pub use sampler::Sampler;
pub use scheduler::{Engine, GenConfig, DEFAULT_BLOCK_TOKENS};

use std::time::Duration;

/// Why a request was refused admission (shared with `serve`'s intake).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// One-shot scoring path: sequence length != the artifact's T.
    WrongLength { got: usize, want: usize },
    /// A token id outside [0, vocab).
    TokenOutOfRange { index: usize, id: i32 },
    /// Generation: empty prompt (there is nothing to continue).
    EmptyPrompt,
    /// Generation: `max_new == 0` asks for no work.
    ZeroMaxNew,
    /// Generation: prompt + max_new exceeds the cache/position capacity.
    TooLong {
        prompt: usize,
        max_new: usize,
        cap: usize,
    },
    /// Admission queue at its configured bound — backpressure instead of
    /// unbounded growth (`GenConfig::max_queue`).
    QueueFull { limit: usize },
    /// The engine is draining for shutdown; no new admissions.
    Draining,
    /// The client's response channel was already gone at dispatch time
    /// (one-shot serve path; generation treats a mid-flight disconnect
    /// as a cancel instead).
    Disconnected,
    /// Evicted mid-flight by the step-failure quarantine (or another
    /// internal fault); `detail` carries the underlying error. Tokens
    /// generated before the fault travel in the `GenOutput`.
    Internal { detail: String },
    /// Sharded router: the request's engine worker crashed (or stalled
    /// and was quarantined) and no healthy worker remained to replay
    /// it. `worker` is the shard that lost the request. Failover
    /// normally re-executes crashed work invisibly; this reason
    /// surfaces only when the whole fleet is down or restarts are
    /// exhausted.
    WorkerCrashed { worker: usize },
}

impl RejectReason {
    /// Stable cause tag for per-cause accounting.
    pub fn cause(&self) -> &'static str {
        match self {
            RejectReason::WrongLength { .. } => "wrong_length",
            RejectReason::TokenOutOfRange { .. } => "bad_token",
            RejectReason::EmptyPrompt => "empty_prompt",
            RejectReason::ZeroMaxNew => "zero_max_new",
            RejectReason::TooLong { .. } => "too_long",
            RejectReason::QueueFull { .. } => "queue_full",
            RejectReason::Draining => "draining",
            RejectReason::Disconnected => "disconnected",
            RejectReason::Internal { .. } => "internal",
            RejectReason::WorkerCrashed { .. } => "worker_crashed",
        }
    }
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::WrongLength { got, want } => {
                write!(f, "sequence length {got} != required {want}")
            }
            RejectReason::TokenOutOfRange { index, id } => {
                write!(f, "token id {id} at index {index} outside vocab")
            }
            RejectReason::EmptyPrompt => write!(f, "empty prompt"),
            RejectReason::ZeroMaxNew => write!(f, "max_new must be >= 1"),
            RejectReason::TooLong { prompt, max_new, cap } => {
                write!(f, "prompt {prompt} + max_new {max_new} exceeds capacity {cap}")
            }
            RejectReason::QueueFull { limit } => {
                write!(f, "admission queue full (limit {limit})")
            }
            RejectReason::Draining => write!(f, "server draining; not accepting new requests"),
            RejectReason::Disconnected => write!(f, "client disconnected before dispatch"),
            RejectReason::Internal { detail } => write!(f, "internal failure: {detail}"),
            RejectReason::WorkerCrashed { worker } => {
                write!(f, "worker {worker} crashed with no healthy worker left to replay")
            }
        }
    }
}

/// Per-cause rejection counters (reported by serve + engine).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RejectCounts {
    pub wrong_length: usize,
    pub bad_token: usize,
    pub empty_prompt: usize,
    pub zero_max_new: usize,
    pub too_long: usize,
    pub queue_full: usize,
    pub draining: usize,
    pub disconnected: usize,
    pub internal: usize,
    pub worker_crashed: usize,
}

impl RejectCounts {
    pub fn note(&mut self, r: &RejectReason) {
        match r {
            RejectReason::WrongLength { .. } => self.wrong_length += 1,
            RejectReason::TokenOutOfRange { .. } => self.bad_token += 1,
            RejectReason::EmptyPrompt => self.empty_prompt += 1,
            RejectReason::ZeroMaxNew => self.zero_max_new += 1,
            RejectReason::TooLong { .. } => self.too_long += 1,
            RejectReason::QueueFull { .. } => self.queue_full += 1,
            RejectReason::Draining => self.draining += 1,
            RejectReason::Disconnected => self.disconnected += 1,
            RejectReason::Internal { .. } => self.internal += 1,
            RejectReason::WorkerCrashed { .. } => self.worker_crashed += 1,
        }
    }

    pub fn total(&self) -> usize {
        self.wrong_length
            + self.bad_token
            + self.empty_prompt
            + self.zero_max_new
            + self.too_long
            + self.queue_full
            + self.draining
            + self.disconnected
            + self.internal
            + self.worker_crashed
    }

    /// Fold another counter set into this one (sharded router: merge
    /// per-worker engine accounting into the fleet report).
    pub fn merge(&mut self, other: &RejectCounts) {
        self.wrong_length += other.wrong_length;
        self.bad_token += other.bad_token;
        self.empty_prompt += other.empty_prompt;
        self.zero_max_new += other.zero_max_new;
        self.too_long += other.too_long;
        self.queue_full += other.queue_full;
        self.draining += other.draining;
        self.disconnected += other.disconnected;
        self.internal += other.internal;
        self.worker_crashed += other.worker_crashed;
    }
}

/// How a generation ended.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced `max_new` tokens.
    MaxTokens,
    /// Sampled the request's stop id (not included in the output).
    Stop,
    /// The request's deadline expired mid-flight; tokens generated
    /// before expiry are returned (a bitwise prefix of what a
    /// deadline-free run would have produced).
    DeadlineExceeded,
    /// The request's cancel token fired (or its client disconnected
    /// mid-generation); partial tokens are returned.
    Cancelled,
    /// Refused at admission (no tokens generated), or evicted
    /// mid-flight by the step-failure quarantine
    /// ([`RejectReason::Internal`]; partial tokens returned).
    Rejected(RejectReason),
}

/// One generation request.
#[derive(Clone, Debug, Default)]
pub struct GenRequest {
    /// Caller-chosen id, echoed in the output and used to key the
    /// sequence's sampler stream.
    pub id: usize,
    pub prompt: Vec<i32>,
    /// Maximum tokens to generate (>= 1).
    pub max_new: usize,
    /// Stop generation when this id is sampled.
    pub stop_id: Option<i32>,
    /// Optional wall-clock budget, measured from submission. When it
    /// expires the sequence finishes with
    /// [`FinishReason::DeadlineExceeded`] (checked between steps, on
    /// the engine's [`EngineClock`]).
    pub deadline: Option<Duration>,
    /// Optional cooperative cancel token (checked between steps).
    pub cancel: Option<CancelToken>,
}

/// One finished (or rejected) generation.
#[derive(Clone, Debug)]
pub struct GenOutput {
    pub id: usize,
    pub prompt_len: usize,
    /// Generated tokens (prompt excluded; empty when rejected at
    /// admission, partial when cancelled / deadline-expired /
    /// quarantined mid-flight).
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
}

/// Throughput/occupancy summary of an engine run.
#[derive(Clone, Debug, Default)]
pub struct GenReport {
    /// Sequences that ran to completion (rejections excluded).
    pub sequences: usize,
    pub rejected: usize,
    pub reject_counts: RejectCounts,
    /// Batched `decode_step_q` executions.
    pub steps: usize,
    /// Prompt tokens fed through the cache.
    pub prefill_tokens: usize,
    /// Generated tokens fed back through the cache + final samples.
    pub decode_tokens: usize,
    pub prefill_secs: f32,
    pub decode_secs: f32,
    /// Mean fraction of slots busy per step.
    pub mean_slot_occupancy: f32,
    /// Prompt tokens skipped at admission via radix prefix-cache hits —
    /// never fed through prefill at all (paged engine only).
    pub prefix_hit_tokens: usize,
    /// High-water mark of pool blocks in use (paged engine only).
    pub peak_blocks_in_use: usize,
    /// Total KV pool blocks (0 = dense engine).
    pub pool_blocks: usize,
    /// Tokens per KV pool block (0 = dense engine).
    pub block_tokens: usize,
    /// Block references dropped from the prefix cache by LRU eviction
    /// under admission pressure (paged engine only).
    pub evicted_blocks: usize,
    /// Sequences ended by their cancel token (client disconnects that
    /// were converted to cancels included).
    pub cancelled: usize,
    /// Sequences ended by deadline expiry.
    pub deadline_exceeded: usize,
    /// Sequences evicted by the step-failure quarantine.
    pub quarantined: usize,
    /// Compute attempts that failed (transient + quarantine bisection).
    pub step_faults: usize,
    /// Failed attempts absorbed by the bounded same-batch retry.
    pub step_retried: usize,
    /// Latency percentile summary (TTFT, per-token, queue wait) from
    /// the engine's deterministic histograms (DESIGN.md §15).
    pub latency: crate::obs::LatencyStats,
}

impl GenReport {
    pub fn prefill_tps(&self) -> f32 {
        if self.prefill_secs > 0.0 {
            self.prefill_tokens as f32 / self.prefill_secs
        } else {
            0.0
        }
    }

    pub fn decode_tps(&self) -> f32 {
        if self.decode_secs > 0.0 {
            self.decode_tokens as f32 / self.decode_secs
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reject_counts_accumulate_per_cause() {
        let mut c = RejectCounts::default();
        c.note(&RejectReason::EmptyPrompt);
        c.note(&RejectReason::WrongLength { got: 3, want: 8 });
        c.note(&RejectReason::WrongLength { got: 9, want: 8 });
        assert_eq!(c.wrong_length, 2);
        assert_eq!(c.empty_prompt, 1);
        assert_eq!(c.total(), 3);
    }

    #[test]
    fn reject_reason_display_and_cause() {
        let r = RejectReason::TooLong {
            prompt: 100,
            max_new: 50,
            cap: 128,
        };
        assert_eq!(r.cause(), "too_long");
        assert!(r.to_string().contains("128"));
        assert_eq!(
            RejectReason::TokenOutOfRange { index: 2, id: -7 }.cause(),
            "bad_token"
        );
    }

    #[test]
    fn lifecycle_reject_causes_counted() {
        let mut c = RejectCounts::default();
        c.note(&RejectReason::QueueFull { limit: 4 });
        c.note(&RejectReason::Draining);
        c.note(&RejectReason::Disconnected);
        c.note(&RejectReason::Internal { detail: "step failed".into() });
        c.note(&RejectReason::WorkerCrashed { worker: 1 });
        assert_eq!(c.queue_full, 1);
        assert_eq!(c.draining, 1);
        assert_eq!(c.disconnected, 1);
        assert_eq!(c.internal, 1);
        assert_eq!(c.worker_crashed, 1);
        assert_eq!(c.total(), 5);
        assert_eq!(RejectReason::QueueFull { limit: 4 }.cause(), "queue_full");
        assert_eq!(RejectReason::Draining.cause(), "draining");
        assert_eq!(RejectReason::Disconnected.cause(), "disconnected");
        let internal = RejectReason::Internal { detail: "boom".into() };
        assert_eq!(internal.cause(), "internal");
        assert!(internal.to_string().contains("boom"));
        let crashed = RejectReason::WorkerCrashed { worker: 3 };
        assert_eq!(crashed.cause(), "worker_crashed");
        assert!(crashed.to_string().contains("worker 3"));
    }

    #[test]
    fn reject_counts_merge_folds_every_cause() {
        let mut a = RejectCounts::default();
        a.note(&RejectReason::EmptyPrompt);
        a.note(&RejectReason::Draining);
        let mut b = RejectCounts::default();
        b.note(&RejectReason::Draining);
        b.note(&RejectReason::WorkerCrashed { worker: 0 });
        a.merge(&b);
        assert_eq!(a.empty_prompt, 1);
        assert_eq!(a.draining, 2);
        assert_eq!(a.worker_crashed, 1);
        assert_eq!(a.total(), 4);
    }

    #[test]
    fn report_tps_handles_zero_time() {
        let r = GenReport::default();
        assert_eq!(r.decode_tps(), 0.0);
        let r = GenReport {
            decode_tokens: 30,
            decode_secs: 2.0,
            prefill_tokens: 100,
            prefill_secs: 0.5,
            ..GenReport::default()
        };
        assert_eq!(r.decode_tps(), 15.0);
        assert_eq!(r.prefill_tps(), 200.0);
    }
}
