//! Metrics: counters, gauges, and deterministic fixed-bucket
//! histograms.
//!
//! The histograms are the load-bearing piece. Latency percentiles in
//! this repo must be *reproducible* — under the virtual clock, two runs
//! of the same (seed, workload, config) must report identical p50/p95/
//! p99 — so bucket selection uses integer microseconds against a fixed
//! 1-2-5 geometric boundary table ([`Hist::BOUNDS_US`]) and percentiles
//! are an integer rank walk returning the bucket's upper bound. No
//! float enters the bucket math, so there is no platform- or
//! optimization-dependent rounding to drift across machines; the cost
//! is bucket-granular answers (a p95 of 3.1 ms reports as 5 ms), which
//! is the standard histogram trade every metrics system makes.
//!
//! [`Metrics`] is a small registry (BTreeMaps, so the text exposition
//! is byte-stable) used by the engine for counters/gauges and the
//! latency histograms; [`LatencyStats`] is the percentile summary that
//! rides in `GenReport` and feeds both the CLI summary line and
//! `BENCH_perf.json`.

use std::collections::BTreeMap;

/// Fixed-bucket histogram over integer microseconds.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Hist {
    /// counts[i] = samples with `us <= BOUNDS_US[i]` (and above the
    /// previous bound); the final slot counts overflow samples.
    counts: [u64; Self::BOUNDS_US.len() + 1],
    total: u64,
    sum_us: u64,
}

impl Hist {
    /// 1-2-5 geometric bucket upper bounds, 1 µs .. 1000 s. Chosen once,
    /// compiled in: every build on every machine buckets identically.
    pub const BOUNDS_US: [u64; 28] = [
        1,
        2,
        5,
        10,
        20,
        50,
        100,
        200,
        500,
        1_000,
        2_000,
        5_000,
        10_000,
        20_000,
        50_000,
        100_000,
        200_000,
        500_000,
        1_000_000,
        2_000_000,
        5_000_000,
        10_000_000,
        20_000_000,
        50_000_000,
        100_000_000,
        200_000_000,
        500_000_000,
        1_000_000_000,
    ];

    /// Reported value for samples beyond the last bound.
    pub const OVERFLOW_US: u64 = 2_000_000_000;

    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample. Integer-only: the bucket is the first bound
    /// `>= us` (binary search on a const table).
    pub fn record(&mut self, us: u64) {
        let idx = Self::BOUNDS_US.partition_point(|&b| b < us);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us = self.sum_us.saturating_add(us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Fold another histogram into this one, bucket by bucket. Buckets
    /// are a compiled-in constant, so merging per-worker histograms
    /// into a fleet histogram is exact: the merged percentiles equal
    /// those of a single histogram fed every sample (sharded router
    /// latency aggregation).
    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
    }

    /// p-th percentile (p in 0..=100) as the owning bucket's upper
    /// bound; 0 for an empty histogram. Integer rank walk — ceil(total
    /// * p / 100), clamped to at least rank 1 — so the answer is a pure
    /// function of the recorded multiset.
    pub fn percentile(&self, p: u64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((self.total * p).div_ceil(100)).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= rank {
                return if i < Self::BOUNDS_US.len() {
                    Self::BOUNDS_US[i]
                } else {
                    Self::OVERFLOW_US
                };
            }
        }
        Self::OVERFLOW_US
    }
}

/// Percentile summary of an engine run's request latencies, in
/// microseconds (bucket upper bounds — see [`Hist`]). Attached to
/// `GenReport`; timebase is the engine's accumulated step time, so
/// under the virtual clock every field is deterministic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LatencyStats {
    /// Time to first generated token (submit -> first sample).
    pub ttft_p50_us: u64,
    pub ttft_p95_us: u64,
    pub ttft_p99_us: u64,
    /// Batched step time attributed to each decoded token.
    pub per_token_p50_us: u64,
    pub per_token_p95_us: u64,
    pub per_token_p99_us: u64,
    /// Submit -> slot admission.
    pub queue_wait_p50_us: u64,
    pub queue_wait_p95_us: u64,
    pub ttft_samples: u64,
    pub per_token_samples: u64,
}

impl LatencyStats {
    /// Summarize the three engine histograms.
    pub fn from_hists(ttft: &Hist, per_token: &Hist, queue_wait: &Hist) -> Self {
        Self {
            ttft_p50_us: ttft.percentile(50),
            ttft_p95_us: ttft.percentile(95),
            ttft_p99_us: ttft.percentile(99),
            per_token_p50_us: per_token.percentile(50),
            per_token_p95_us: per_token.percentile(95),
            per_token_p99_us: per_token.percentile(99),
            queue_wait_p50_us: queue_wait.percentile(50),
            queue_wait_p95_us: queue_wait.percentile(95),
            ttft_samples: ttft.count(),
            per_token_samples: per_token.count(),
        }
    }

    /// One-line human summary (printed by `generate`; format pinned by
    /// a test — downstream log scrapers may rely on it).
    pub fn summary_line(&self) -> String {
        fn ms(us: u64) -> String {
            format!("{:.3}", us as f64 / 1000.0)
        }
        format!(
            "latency: ttft p50/p95/p99 {}/{}/{} ms | per-token {}/{}/{} ms | queue-wait p95 {} ms",
            ms(self.ttft_p50_us),
            ms(self.ttft_p95_us),
            ms(self.ttft_p99_us),
            ms(self.per_token_p50_us),
            ms(self.per_token_p95_us),
            ms(self.per_token_p99_us),
            ms(self.queue_wait_p95_us),
        )
    }
}

/// Named counters, gauges, and histograms. Keys are `&'static str` and
/// storage is BTreeMaps, so [`Metrics::render_text`] is byte-stable and
/// steady-state updates (key already present) allocate nothing.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, u64>,
    hists: BTreeMap<&'static str, Hist>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-create a histogram so later `observe` calls hit an existing
    /// entry (no node allocation on the hot path).
    pub fn register_hist(&mut self, name: &'static str) {
        self.hists.entry(name).or_default();
    }

    pub fn inc(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_insert(0) += by;
    }

    pub fn set_gauge(&mut self, name: &'static str, v: u64) {
        self.gauges.insert(name, v);
    }

    /// Gauge that only ratchets upward (high-water marks).
    pub fn max_gauge(&mut self, name: &'static str, v: u64) {
        let g = self.gauges.entry(name).or_insert(0);
        *g = (*g).max(v);
    }

    pub fn observe(&mut self, name: &'static str, us: u64) {
        self.hists.entry(name).or_default().record(us);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    /// Human-readable exposition dump: one line per series, sorted by
    /// kind then name — deterministic byte-for-byte given equal state.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            out.push_str(&format!("counter {name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!("gauge {name} {v}\n"));
        }
        for (name, h) in &self.hists {
            out.push_str(&format!(
                "hist {name} count {} sum_us {} p50 {} p95 {} p99 {}\n",
                h.count(),
                h.sum_us(),
                h.percentile(50),
                h.percentile(95),
                h.percentile(99),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection_is_boundary_inclusive() {
        let mut h = Hist::new();
        h.record(1); // first bucket (<= 1)
        h.record(2); // second (<= 2)
        h.record(3); // third (<= 5)
        h.record(1_000_000_000); // last real bucket
        h.record(1_000_000_001); // overflow
        assert_eq!(h.count(), 5);
        assert_eq!(h.percentile(100), Hist::OVERFLOW_US);
    }

    #[test]
    fn percentiles_walk_integer_ranks() {
        let mut h = Hist::new();
        // 90 fast samples at <=1ms, 10 slow at <=100ms.
        for _ in 0..90 {
            h.record(800);
        }
        for _ in 0..10 {
            h.record(70_000);
        }
        assert_eq!(h.percentile(50), 1_000);
        assert_eq!(h.percentile(90), 1_000);
        assert_eq!(h.percentile(95), 100_000);
        assert_eq!(h.percentile(99), 100_000);
        // Empty histogram answers 0, not garbage.
        assert_eq!(Hist::new().percentile(99), 0);
    }

    #[test]
    fn percentiles_are_monotone_in_p() {
        let mut h = Hist::new();
        for us in [3u64, 17, 170, 1_700, 17_000, 170_000, 1_700_000] {
            h.record(us);
        }
        let mut prev = 0;
        for p in 0..=100 {
            let v = h.percentile(p);
            assert!(v >= prev, "p{p}: {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn identical_sample_multisets_give_identical_state() {
        let samples = [5u64, 900, 1_000, 123_456, 7];
        let mut a = Hist::new();
        let mut b = Hist::new();
        for &s in &samples {
            a.record(s);
        }
        for &s in samples.iter().rev() {
            b.record(s); // order must not matter
        }
        assert_eq!(a, b);
    }

    #[test]
    fn merged_hist_equals_single_hist_over_all_samples() {
        let shard_a = [5u64, 900, 1_000, 123_456];
        let shard_b = [7u64, 42, 9_999_999];
        let mut a = Hist::new();
        let mut b = Hist::new();
        let mut whole = Hist::new();
        for &s in &shard_a {
            a.record(s);
            whole.record(s);
        }
        for &s in &shard_b {
            b.record(s);
            whole.record(s);
        }
        a.merge(&b);
        assert_eq!(a, whole);
        assert_eq!(a.count(), 7);
        assert_eq!(a.percentile(99), whole.percentile(99));
        // Merging an empty histogram is a no-op.
        a.merge(&Hist::new());
        assert_eq!(a, whole);
    }

    #[test]
    fn latency_summary_line_format_is_pinned() {
        let stats = LatencyStats {
            ttft_p50_us: 2_000,
            ttft_p95_us: 5_000,
            ttft_p99_us: 10_000,
            per_token_p50_us: 1_000,
            per_token_p95_us: 2_000,
            per_token_p99_us: 2_000,
            queue_wait_p50_us: 200,
            queue_wait_p95_us: 500,
            ttft_samples: 12,
            per_token_samples: 240,
        };
        assert_eq!(
            stats.summary_line(),
            "latency: ttft p50/p95/p99 2.000/5.000/10.000 ms | \
             per-token 1.000/2.000/2.000 ms | queue-wait p95 0.500 ms"
        );
    }

    #[test]
    fn metrics_registry_counts_gauges_and_renders_stably() {
        let mut m = Metrics::new();
        m.register_hist("ttft_us");
        m.inc("steps", 1);
        m.inc("steps", 2);
        m.set_gauge("pool_in_use", 7);
        m.max_gauge("pool_peak", 3);
        m.max_gauge("pool_peak", 9);
        m.max_gauge("pool_peak", 5);
        m.observe("ttft_us", 1_500);
        assert_eq!(m.counter("steps"), 3);
        assert_eq!(m.gauge("pool_in_use"), 7);
        assert_eq!(m.gauge("pool_peak"), 9);
        assert_eq!(m.hist("ttft_us").unwrap().count(), 1);
        assert_eq!(m.counter("missing"), 0);
        let text = m.render_text();
        assert_eq!(
            text,
            "counter steps 3\n\
             gauge pool_in_use 7\n\
             gauge pool_peak 9\n\
             hist ttft_us count 1 sum_us 1500 p50 2000 p95 2000 p99 2000\n"
        );
        // Render twice: byte-identical.
        assert_eq!(text, m.render_text());
    }
}
