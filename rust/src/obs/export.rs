//! Trace exporters: Chrome trace-event JSON (Perfetto /
//! chrome://tracing) and a plain-text dump.
//!
//! The JSON exporter is hand-rolled (this crate takes no serialization
//! dependency) against the Trace Event Format's stable subset:
//!
//! - metadata `"M"` events name one **lifecycle** track (tid 1) plus
//!   one track **per slot** (tid 10+slot), so a loaded trace shows each
//!   slot's admissions/prefills/finishes as its own row;
//! - prefill is a `"B"`/`"E"` duration pair on the slot's track;
//! - everything else is an instant `"i"` event (`"s":"t"`), with the
//!   payload (ids, block numbers, batch mix, causes) in `args` along
//!   with the engine tick.
//!
//! Timestamps are the record's `ts_us` — already microseconds, the unit
//! the format requires. Under the virtual clock the exported bytes are
//! a pure function of the trace content, so the export itself is
//! golden-testable too.

use super::trace::{TraceEvent, TraceRecord};
use std::collections::BTreeSet;
use std::fmt::Write as _;

/// Chrome trace-event JSON for a canonical record sequence (load the
/// written file in Perfetto or chrome://tracing).
pub fn chrome_trace_json(records: &[TraceRecord]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |line: &str, out: &mut String| {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(line);
    };

    // Track naming metadata: the lifecycle row plus one row per slot
    // that actually appears in the trace.
    push(
        "{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":1,\
         \"args\":{\"name\":\"lifecycle\"}}",
        &mut out,
    );
    let slots: BTreeSet<usize> = records.iter().filter_map(|r| r.ev.slot()).collect();
    for s in &slots {
        push(
            &format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
                 \"args\":{{\"name\":\"slot {s}\"}}}}",
                track_of_slot(*s)
            ),
            &mut out,
        );
    }

    for r in records {
        let tid = r.ev.slot().map_or(1, track_of_slot);
        let ph = match r.ev {
            TraceEvent::PrefillBegin { .. } => "B",
            TraceEvent::PrefillEnd { .. } => "E",
            _ => "i",
        };
        let mut line = String::new();
        let _ = write!(
            line,
            "{{\"name\":\"{}\",\"ph\":\"{ph}\",\"pid\":1,\"tid\":{tid},\"ts\":{}",
            r.ev.kind(),
            r.ts_us
        );
        if ph == "i" {
            line.push_str(",\"s\":\"t\"");
        }
        let _ = write!(line, ",\"args\":{{\"tick\":{}", r.tick);
        push_args(&r.ev, &mut line);
        line.push_str("}}");
        push(&line, &mut out);
    }
    out.push_str("\n]}\n");
    out
}

fn track_of_slot(slot: usize) -> usize {
    10 + slot
}

fn push_args(ev: &TraceEvent, out: &mut String) {
    match *ev {
        TraceEvent::Submit { id } => {
            let _ = write!(out, ",\"id\":{id}");
        }
        TraceEvent::Reject { id, cause } => {
            let _ = write!(out, ",\"id\":{id},\"cause\":\"{}\"", escape(cause));
        }
        TraceEvent::Admit { id, slot, start } => {
            let _ = write!(out, ",\"id\":{id},\"slot\":{slot},\"start\":{start}");
        }
        TraceEvent::PrefillBegin { id, slot, tokens } => {
            let _ = write!(out, ",\"id\":{id},\"slot\":{slot},\"tokens\":{tokens}");
        }
        TraceEvent::PrefillEnd { id, slot } => {
            let _ = write!(out, ",\"id\":{id},\"slot\":{slot}");
        }
        TraceEvent::Step { batch, prefill, decode } => {
            let _ = write!(out, ",\"batch\":{batch},\"prefill\":{prefill},\"decode\":{decode}");
        }
        TraceEvent::PrefixHit { id, tokens } => {
            let _ = write!(out, ",\"id\":{id},\"tokens\":{tokens}");
        }
        TraceEvent::BlockAlloc { block } => {
            let _ = write!(out, ",\"block\":{block}");
        }
        TraceEvent::BlockCow { src, dst } => {
            let _ = write!(out, ",\"src\":{src},\"dst\":{dst}");
        }
        TraceEvent::BlockEvict { block } => {
            let _ = write!(out, ",\"block\":{block}");
        }
        TraceEvent::StepRetry { attempt } => {
            let _ = write!(out, ",\"attempt\":{attempt}");
        }
        TraceEvent::Quarantine { id }
        | TraceEvent::Cancel { id }
        | TraceEvent::Deadline { id } => {
            let _ = write!(out, ",\"id\":{id}");
        }
        TraceEvent::Drain => {}
        TraceEvent::WorkerUp { worker, epoch } => {
            let _ = write!(out, ",\"worker\":{worker},\"epoch\":{epoch}");
        }
        TraceEvent::Route { id, worker, affinity } => {
            let _ = write!(out, ",\"id\":{id},\"worker\":{worker},\"affinity\":{affinity}");
        }
        TraceEvent::WorkerCrash { worker, epoch, cause } => {
            let _ = write!(
                out,
                ",\"worker\":{worker},\"epoch\":{epoch},\"cause\":\"{}\"",
                escape(cause)
            );
        }
        TraceEvent::Failover { id, from, epoch } => {
            let _ = write!(out, ",\"id\":{id},\"from\":{from},\"epoch\":{epoch}");
        }
        TraceEvent::Finish { id, slot, tokens, cause } => {
            let _ = write!(
                out,
                ",\"id\":{id},\"slot\":{slot},\"tokens\":{tokens},\"cause\":\"{}\"",
                escape(cause)
            );
        }
    }
}

/// Minimal JSON string escape. Causes/kinds are static snake_case tags
/// today; escaping anyway keeps the exporter safe if one ever grows
/// punctuation.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Plain-text dump: canonical one-line-per-record form plus a trailer
/// noting ring overflow, if any.
pub fn text_dump(records: &[TraceRecord], dropped: u64) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "# faquant trace: {} events", records.len());
    for r in records {
        out.push_str(&r.canonical());
        out.push('\n');
    }
    if dropped > 0 {
        let _ = writeln!(out, "# ring overflow: {dropped} oldest events dropped");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records() -> Vec<TraceRecord> {
        vec![
            TraceRecord {
                seq: 0,
                tick: 0,
                ts_us: 0,
                ev: TraceEvent::Submit { id: 4 },
            },
            TraceRecord {
                seq: 1,
                tick: 1,
                ts_us: 1000,
                ev: TraceEvent::Admit { id: 4, slot: 0, start: 0 },
            },
            TraceRecord {
                seq: 2,
                tick: 1,
                ts_us: 1000,
                ev: TraceEvent::PrefillBegin { id: 4, slot: 0, tokens: 8 },
            },
            TraceRecord {
                seq: 3,
                tick: 3,
                ts_us: 3000,
                ev: TraceEvent::PrefillEnd { id: 4, slot: 0 },
            },
            TraceRecord {
                seq: 4,
                tick: 4,
                ts_us: 4000,
                ev: TraceEvent::Finish { id: 4, slot: 0, tokens: 2, cause: "max_tokens" },
            },
        ]
    }

    #[test]
    fn chrome_export_names_tracks_and_balances_braces() {
        let json = chrome_trace_json(&sample_records());
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"name\":\"lifecycle\""));
        assert!(json.contains("\"name\":\"slot 0\""));
        assert!(json.contains("\"ph\":\"B\""));
        assert!(json.contains("\"ph\":\"E\""));
        assert!(json.contains("\"ph\":\"i\",\"args\""));
        assert!(json.contains("\"cause\":\"max_tokens\""));
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes, "unbalanced JSON braces:\n{json}");
        let braks = json.matches('[').count();
        assert_eq!(braks, json.matches(']').count());
    }

    #[test]
    fn chrome_export_is_deterministic() {
        let recs = sample_records();
        assert_eq!(chrome_trace_json(&recs), chrome_trace_json(&recs));
    }

    #[test]
    fn text_dump_reports_overflow() {
        let recs = sample_records();
        let clean = text_dump(&recs, 0);
        assert!(clean.starts_with("# faquant trace: 5 events\n"));
        assert!(!clean.contains("ring overflow"));
        assert_eq!(clean.lines().count(), 6);
        let shed = text_dump(&recs, 12);
        assert!(shed.ends_with("# ring overflow: 12 oldest events dropped\n"));
    }

    #[test]
    fn escape_handles_quotes_and_controls() {
        assert_eq!(escape("plain_tag"), "plain_tag");
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
    }
}
