//! Observability (S17): deterministic tracing, metrics, and exporters.
//!
//! Three pieces, all dependency-free (DESIGN.md §15):
//!
//! - [`trace`] — typed engine events ([`TraceEvent`]) in per-thread
//!   lock-light ring buffers, stamped with the engine tick plus a
//!   timestamp from the trace's [`StampMode`]: virtual (`tick *
//!   step_us`, a pure function of the tick — golden-testable) or wall
//!   (production). A disabled [`Trace`] is a no-op handle: no
//!   allocation, no clock read, one branch per event site.
//! - [`metrics`] — counters, gauges, and fixed-bucket [`Hist`]ograms
//!   whose bucket selection and percentile walk are integer-only, so
//!   p50/p95/p99 TTFT, per-token latency, and queue wait are bitwise
//!   reproducible under the virtual clock. [`LatencyStats`] carries
//!   the summary into `GenReport` and `BENCH_perf.json`.
//! - [`export`] — Chrome trace-event JSON (one track per slot + one
//!   for the lifecycle; loads in Perfetto / chrome://tracing) and a
//!   plain-text dump.
//!
//! The clock-domain discipline is enforced by faq-lint's
//! `untracked-clock` rule: `engine/` and `serve/` may not call
//! `Instant::now()` outside the `EngineClock`/obs seam without an
//! audited allow marker, so new timing reads cannot silently leak
//! nondeterminism into the serving path.

pub mod export;
pub mod metrics;
pub mod trace;

pub use export::{chrome_trace_json, text_dump};
pub use metrics::{Hist, LatencyStats, Metrics};
pub use trace::{StampMode, Trace, TraceEvent, TraceRecord};
