//! Structured event tracing: typed engine events in per-thread,
//! lock-light ring buffers.
//!
//! The engine emits a [`TraceEvent`] at every interesting lifecycle
//! point (admission, prefill, step composition, prefix-cache hits,
//! block pool traffic, retries/quarantine, cancel/deadline/drain).
//! Each event is stamped twice:
//!
//! - **tick** — the engine's step counter, the causal coordinate. Two
//!   runs of the same (seed, workload, config) execute the same tick
//!   sequence, so ticks are bitwise reproducible by construction.
//! - **ts_us** — microseconds on the trace's [`StampMode`]: under
//!   [`StampMode::Virtual`] it is `tick * step_us` (a pure function of
//!   the tick, golden-testable); under [`StampMode::Wall`] it is real
//!   elapsed time (what you want in production, and what Perfetto
//!   renders as the timeline).
//!
//! **Zero cost when disabled.** [`Trace`] is a cheap-clone handle over
//! `Option<Arc<TraceSink>>`. A disabled handle's [`Trace::emit`] is an
//! inlined `None` check — no allocation, no clock read, no lock — so
//! production engines that never asked for a trace pay one branch per
//! event site (pinned by `benches/alloc_probe.rs`). Events carry only
//! fixed-size payloads (`usize` ids and `&'static str` causes), so
//! even the enabled path never heap-allocates per event: records land
//! in ring buffers preallocated at sink construction.
//!
//! **Overflow semantics.** Each ring holds a fixed number of records;
//! when full, the *oldest* record is overwritten and a dropped counter
//! advances. A long run therefore keeps the most recent window — the
//! part you want when debugging "what just happened" — and the export
//! reports how much history was shed ([`Trace::dropped`]).
//!
//! **Ordering.** Every record takes a global sequence number from one
//! atomic counter, so the canonical order ([`Trace::snapshot`] sorts
//! by it) is the emission order regardless of which thread's ring a
//! record landed in. The engine emits from its driver thread only, so
//! under the virtual clock the canonical sequence is a pure function
//! of (seed, workload, config) — identical at 1/2/8 worker threads
//! (pinned by `tests/props.rs::trace_determinism_pinned_*`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default total record capacity of a sink (split across shards).
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// Ring shards per sink. Emission hashes the current thread id to a
/// shard, so concurrent emitters (if a caller ever drives one engine
/// from several threads) contend only per-shard, not globally.
const SHARDS: usize = 8;

/// One typed engine event. Payloads are fixed-size on purpose: no
/// `String`, no `Vec` — an event can be constructed and recorded
/// without touching the heap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    /// Request accepted into the admission queue.
    Submit { id: usize },
    /// Request refused at submission (`cause` = stable reject tag).
    Reject { id: usize, cause: &'static str },
    /// Request bound to a slot; `start` is the first cursor position
    /// actually fed (> 0 when a prefix-cache hit skipped prefill).
    Admit { id: usize, slot: usize, start: usize },
    /// First prefill feed for a slot (`tokens` = prompt tokens left).
    PrefillBegin { id: usize, slot: usize, tokens: usize },
    /// The slot's cursor crossed its prompt length.
    PrefillEnd { id: usize, slot: usize },
    /// One batched compute step: total feeds and the prefill/decode mix.
    Step { batch: usize, prefill: usize, decode: usize },
    /// Radix prefix-cache hit at admission (`tokens` skipped).
    PrefixHit { id: usize, tokens: usize },
    /// KV pool block handed out.
    BlockAlloc { block: usize },
    /// Copy-on-write: `src`'s rows copied into freshly owned `dst`.
    BlockCow { src: usize, dst: usize },
    /// Prefix-cache LRU eviction released a block reference.
    BlockEvict { block: usize },
    /// A compute attempt failed and the same batch is being retried.
    StepRetry { attempt: usize },
    /// Quarantine bisection evicted a poisoned request.
    Quarantine { id: usize },
    /// Cancel token observed (queued or mid-decode).
    Cancel { id: usize },
    /// Deadline expired (queued or mid-decode).
    Deadline { id: usize },
    /// Graceful drain began: no further admissions.
    Drain,
    /// Request left its slot (`cause` = finish tag, `tokens` generated).
    Finish { id: usize, slot: usize, tokens: usize, cause: &'static str },
    /// Router: a worker shard's engine came up and is serving. `epoch`
    /// counts engine incarnations on that shard (0 = first start; > 0
    /// means a post-crash restart).
    WorkerUp { worker: usize, epoch: usize },
    /// Router: request dispatched to a worker. `affinity` marks a
    /// prefix-affinity placement (vs least-loaded fallback).
    Route { id: usize, worker: usize, affinity: bool },
    /// Router: a worker's engine panicked, erred, or stalled past the
    /// heartbeat bound and was quarantined (`cause` = stable tag).
    WorkerCrash { worker: usize, epoch: usize, cause: &'static str },
    /// Router: an in-flight request lost to a crashed worker was
    /// requeued for deterministic re-execution (a later `Route` event
    /// shows its new placement).
    Failover { id: usize, from: usize, epoch: usize },
}

impl TraceEvent {
    /// Stable snake_case kind tag (used by both exporters and CI's
    /// per-category presence check).
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Submit { .. } => "submit",
            TraceEvent::Reject { .. } => "reject",
            TraceEvent::Admit { .. } => "admit",
            TraceEvent::PrefillBegin { .. } => "prefill_begin",
            TraceEvent::PrefillEnd { .. } => "prefill_end",
            TraceEvent::Step { .. } => "step",
            TraceEvent::PrefixHit { .. } => "prefix_hit",
            TraceEvent::BlockAlloc { .. } => "block_alloc",
            TraceEvent::BlockCow { .. } => "block_cow",
            TraceEvent::BlockEvict { .. } => "block_evict",
            TraceEvent::StepRetry { .. } => "step_retry",
            TraceEvent::Quarantine { .. } => "quarantine",
            TraceEvent::Cancel { .. } => "cancel",
            TraceEvent::Deadline { .. } => "deadline",
            TraceEvent::Drain => "drain",
            TraceEvent::Finish { .. } => "finish",
            TraceEvent::WorkerUp { .. } => "worker_up",
            TraceEvent::Route { .. } => "route",
            TraceEvent::WorkerCrash { .. } => "worker_crash",
            TraceEvent::Failover { .. } => "failover",
        }
    }

    /// Slot the event belongs to, when it is slot-scoped (drives the
    /// one-track-per-slot layout of the Chrome export).
    pub fn slot(&self) -> Option<usize> {
        match self {
            TraceEvent::Admit { slot, .. }
            | TraceEvent::PrefillBegin { slot, .. }
            | TraceEvent::PrefillEnd { slot, .. }
            | TraceEvent::Finish { slot, .. } => Some(*slot),
            _ => None,
        }
    }
}

/// One recorded event: global sequence number, engine tick, timestamp
/// on the sink's [`StampMode`], and the payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceRecord {
    pub seq: u64,
    pub tick: u64,
    pub ts_us: u64,
    pub ev: TraceEvent,
}

impl TraceRecord {
    /// Canonical text form — the golden representation the determinism
    /// tests compare across thread counts.
    pub fn canonical(&self) -> String {
        format!("tick={} ts_us={} {:?}", self.tick, self.ts_us, self.ev)
    }
}

/// Timestamp domain of a sink.
#[derive(Clone, Copy, Debug)]
pub enum StampMode {
    /// Deterministic: `ts_us = tick * step_us`. Pure function of the
    /// tick — no clock is ever read.
    Virtual { step_us: u64 },
    /// Production: microseconds since the sink was created. The only
    /// wall-clock read on the tracing path, and it happens here, inside
    /// an *enabled* sink — a disabled trace never touches a clock.
    Wall { t0: Instant },
}

impl StampMode {
    fn stamp(&self, tick: u64) -> u64 {
        match self {
            StampMode::Virtual { step_us } => tick.saturating_mul(*step_us),
            StampMode::Wall { t0 } => u64::try_from(t0.elapsed().as_micros()).unwrap_or(u64::MAX),
        }
    }
}

/// Fixed-capacity ring: full means overwrite-oldest, counting drops.
struct Ring {
    buf: Vec<TraceRecord>,
    cap: usize,
    /// Index of the oldest record once the ring has wrapped.
    head: usize,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Self {
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            dropped: 0,
        }
    }

    fn push(&mut self, rec: TraceRecord) {
        if self.buf.len() < self.cap {
            self.buf.push(rec); // within preallocated capacity: no alloc
        } else {
            self.buf[self.head] = rec;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    fn drain_in_order(&self, out: &mut Vec<TraceRecord>) {
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
    }
}

/// The shared sink behind an enabled [`Trace`].
pub struct TraceSink {
    mode: StampMode,
    seq: AtomicU64,
    shards: Vec<Mutex<Ring>>,
}

impl TraceSink {
    fn new(mode: StampMode, capacity: usize) -> Self {
        let per_shard = capacity.div_ceil(SHARDS).max(1);
        Self {
            mode,
            seq: AtomicU64::new(0),
            shards: (0..SHARDS).map(|_| Mutex::new(Ring::new(per_shard))).collect(),
        }
    }

    fn shard_index(&self) -> usize {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        h.finish() as usize % self.shards.len()
    }

    fn record(&self, tick: u64, ev: TraceEvent) {
        let rec = TraceRecord {
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            tick,
            ts_us: self.mode.stamp(tick),
            ev,
        };
        let mut ring = match self.shards[self.shard_index()].lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(), // a panicked emitter must not lose the trace
        };
        ring.push(rec);
    }
}

/// Cheap-clone tracing handle. [`Trace::default`] /
/// [`Trace::disabled`] is the no-op sink: `emit` reduces to a branch.
#[derive(Clone, Default)]
pub struct Trace(Option<Arc<TraceSink>>);

impl std::fmt::Debug for Trace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Trace")
            .field(&if self.0.is_some() { "enabled" } else { "disabled" })
            .finish()
    }
}

impl Trace {
    /// The no-op handle: emit does nothing, reads no clock, allocates
    /// nothing.
    pub fn disabled() -> Self {
        Self(None)
    }

    /// Enabled sink on the deterministic virtual clock (`step_us`
    /// microseconds per engine tick).
    pub fn virtual_clock(step_us: u64) -> Self {
        Self::with_mode(StampMode::Virtual { step_us }, DEFAULT_CAPACITY)
    }

    /// Enabled sink stamping wall time (microseconds since creation).
    pub fn wall_clock() -> Self {
        Self::with_mode(StampMode::Wall { t0: Instant::now() }, DEFAULT_CAPACITY)
    }

    /// Enabled sink with an explicit mode and total record capacity.
    pub fn with_mode(mode: StampMode, capacity: usize) -> Self {
        Self(Some(Arc::new(TraceSink::new(mode, capacity))))
    }

    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Record an event at `tick`. The disabled-path contract — no
    /// allocation, no clock read — is what makes it safe to leave these
    /// calls unconditionally in the scheduler hot path.
    #[inline]
    pub fn emit(&self, tick: u64, ev: TraceEvent) {
        if let Some(sink) = &self.0 {
            sink.record(tick, ev);
        }
    }

    /// All surviving records merged across shards, in canonical
    /// (emission) order. Empty for a disabled trace.
    pub fn snapshot(&self) -> Vec<TraceRecord> {
        let Some(sink) = &self.0 else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for shard in &sink.shards {
            let ring = match shard.lock() {
                Ok(g) => g,
                Err(poison) => poison.into_inner(),
            };
            ring.drain_in_order(&mut out);
        }
        out.sort_by_key(|r| r.seq);
        out
    }

    /// Records overwritten by ring overflow (0 = the full history
    /// survived).
    pub fn dropped(&self) -> u64 {
        let Some(sink) = &self.0 else { return 0 };
        sink.shards
            .iter()
            .map(|s| match s.lock() {
                Ok(g) => g.dropped,
                Err(poison) => poison.into_inner().dropped,
            })
            .sum()
    }

    /// Canonical golden form: one line per record, emission order.
    pub fn canonical_lines(&self) -> Vec<String> {
        self.snapshot().iter().map(TraceRecord::canonical).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_is_inert() {
        let t = Trace::disabled();
        assert!(!t.is_enabled());
        t.emit(3, TraceEvent::Drain);
        assert!(t.snapshot().is_empty());
        assert_eq!(t.dropped(), 0);
        assert!(t.canonical_lines().is_empty());
    }

    #[test]
    fn virtual_stamps_are_pure_functions_of_the_tick() {
        let t = Trace::virtual_clock(1000);
        t.emit(0, TraceEvent::Submit { id: 7 });
        t.emit(4, TraceEvent::Step { batch: 2, prefill: 1, decode: 1 });
        let recs = t.snapshot();
        assert_eq!(recs.len(), 2);
        assert_eq!((recs[0].tick, recs[0].ts_us), (0, 0));
        assert_eq!((recs[1].tick, recs[1].ts_us), (4, 4000));
        assert_eq!(
            recs[0].canonical(),
            "tick=0 ts_us=0 Submit { id: 7 }".to_string()
        );
    }

    #[test]
    fn snapshot_preserves_emission_order() {
        let t = Trace::virtual_clock(1);
        for i in 0..100 {
            t.emit(i, TraceEvent::BlockAlloc { block: i as usize });
        }
        let recs = t.snapshot();
        assert_eq!(recs.len(), 100);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
            assert_eq!(r.tick, i as u64);
        }
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        // Tiny sink: capacity 16 split over 8 shards = 2 per shard. A
        // single emitting thread lands on ONE shard, so 10 emits into a
        // 2-slot ring keep the newest 2 and drop 8.
        let t = Trace::with_mode(StampMode::Virtual { step_us: 1 }, 16);
        for i in 0..10u64 {
            t.emit(i, TraceEvent::Deadline { id: i as usize });
        }
        let recs = t.snapshot();
        assert_eq!(recs.len(), 2, "newest window survives");
        assert_eq!(recs[0].tick, 8);
        assert_eq!(recs[1].tick, 9);
        assert_eq!(t.dropped(), 8);
    }

    #[test]
    fn event_kind_and_slot_tags() {
        let ev = TraceEvent::Finish {
            id: 1,
            slot: 3,
            tokens: 5,
            cause: "max_tokens",
        };
        assert_eq!(ev.kind(), "finish");
        assert_eq!(ev.slot(), Some(3));
        assert_eq!(TraceEvent::Drain.kind(), "drain");
        assert_eq!(TraceEvent::Drain.slot(), None);
        assert_eq!(
            TraceEvent::PrefixHit { id: 0, tokens: 8 }.kind(),
            "prefix_hit"
        );
    }

    #[test]
    fn router_event_kinds_are_stable() {
        let up = TraceEvent::WorkerUp { worker: 1, epoch: 0 };
        let route = TraceEvent::Route { id: 4, worker: 1, affinity: true };
        let crash = TraceEvent::WorkerCrash { worker: 1, epoch: 0, cause: "panic" };
        let fo = TraceEvent::Failover { id: 4, from: 1, epoch: 0 };
        assert_eq!(up.kind(), "worker_up");
        assert_eq!(route.kind(), "route");
        assert_eq!(crash.kind(), "worker_crash");
        assert_eq!(fo.kind(), "failover");
        // Router events are fleet-scoped, never slot-scoped.
        for ev in [up, route, crash, fo] {
            assert_eq!(ev.slot(), None);
        }
    }
}
