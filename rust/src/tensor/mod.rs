//! Host-side tensor library (S1).
//!
//! Minimal dense f32 tensors for weight manipulation, statistics, and the
//! host halves of quantization. No external ndarray/rand crates exist in
//! the offline registry, so shapes, ops, the PRNG, and the thread pool
//! ([`par`]) live here.
//!
//! Since the native backend became the default execution path, the
//! matmuls in [`ops`] *are* the hot path: they run cache-blocked and
//! parallelized over row blocks (deterministically — see [`par`]), while
//! anything model-scale on an accelerator still belongs in an HLO
//! artifact executed by [`crate::runtime`].

pub mod arena;
pub mod intkern;
mod ops;
pub mod par;
mod rng;
mod stats;

pub use intkern::PackedIntB;
pub use ops::PackedB;
pub use rng::Rng;
pub use stats::*;

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from raw parts; `data.len()` must equal the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if data.len() != numel {
            bail!(
                "shape {:?} wants {} elements, got {}",
                shape,
                numel,
                data.len()
            );
        }
        Ok(Self {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Self::full(shape, 1.0)
    }

    /// Standard-normal init scaled by `std`.
    pub fn randn(rng: &mut Rng, shape: &[usize], std: f32) -> Self {
        let numel: usize = shape.iter().product();
        let data = (0..numel).map(|_| rng.normal() * std).collect();
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape (same number of elements).
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != self.data.len() {
            bail!("reshape {:?} -> {:?}: numel mismatch", self.shape, shape);
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data: self.data.clone(),
        })
    }

    /// 2-D accessor: element (i, j) of an [r, c] tensor.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    /// Copy rows [lo, hi) of a 2-D tensor into a new tensor.
    pub fn rows(&self, lo: usize, hi: usize) -> Tensor {
        debug_assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        Tensor {
            shape: vec![hi - lo, c],
            data: self.data[lo * c..hi * c].to_vec(),
        }
    }

    /// Gather the given rows of a 2-D tensor into a new [idx.len(), c] tensor.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        debug_assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        let mut data = Vec::with_capacity(idx.len() * c);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Tensor {
            shape: vec![idx.len(), c],
            data,
        }
    }

    /// Slice the leading dimension at index `i` (e.g. [L, R, n] -> [R, n]).
    pub fn index0(&self, i: usize) -> Tensor {
        assert!(self.shape.len() >= 2 && i < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
        }
    }
}

/// Dense row-major i32 tensor (token ids, integer codes on the wire).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl TensorI32 {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if data.len() != numel {
            bail!("shape {:?} wants {} elements, got {}", shape, numel, data.len());
        }
        Ok(Self {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![0; shape.iter().product()],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_checks_numel() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::from_vec(&[2, 6], (0..12).map(|i| i as f32).collect()).unwrap();
        let r = t.reshape(&[3, 4]).unwrap();
        assert_eq!(r.shape(), &[3, 4]);
        assert_eq!(r.at2(2, 3), 11.0);
        assert!(t.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn row_and_gather() {
        let t = Tensor::from_vec(&[3, 2], vec![0., 1., 2., 3., 4., 5.]).unwrap();
        assert_eq!(t.row(1), &[2., 3.]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.data(), &[4., 5., 0., 1.]);
    }

    #[test]
    fn index0_slices_leading_dim() {
        let t = Tensor::from_vec(&[2, 2, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        let s = t.index0(1);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[4., 5., 6., 7.]);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let a = Tensor::randn(&mut r1, &[4, 4], 1.0);
        let b = Tensor::randn(&mut r2, &[4, 4], 1.0);
        assert_eq!(a, b);
    }
}
