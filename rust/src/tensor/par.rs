//! Dependency-free scoped thread pool for the native compute core.
//!
//! rayon is not in the offline registry, so the parallel matmul kernels
//! ([`super::Tensor::matmul`] and friends), the native attention, and the
//! Phase-B scale search all share this pool. Design constraints, in
//! order:
//!
//! 1. **Determinism.** Results must be bit-identical for every thread
//!    count (DESIGN.md §9). The pool therefore never reduces across
//!    tasks: every task writes a disjoint output region (or a distinct
//!    `par_map` slot), and each output element is accumulated by exactly
//!    one task in a fixed order. Thread count only moves task
//!    *boundaries*, never the arithmetic inside an element.
//! 2. **An honest concurrency cap, nesting included.** Phase B
//!    parallelizes over linears while each linear's matmuls would like
//!    to parallelize over row blocks; a `par_*` call made from inside a
//!    pool task therefore runs serially (the top-level fan-out already
//!    owns the configured thread count), and a submitter waiting for
//!    its batch *helps* drain the queue instead of blocking, so
//!    progress never depends on a worker being free.
//! 3. **No per-call spawn.** Workers are spawned once (process
//!    lifetime) and parked on a condvar when idle.
//!
//! Thread count: `set_threads` (test/bench override) > `FAQUANT_THREADS`
//! (env) > `available_parallelism`. The env var is read per query so it
//! can be varied without process restarts.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Process-wide override for the worker count; 0 = unset (use the env
/// var / hardware default). Benches and the determinism property tests
/// use this instead of mutating the environment (env mutation races
/// across concurrently running tests; this is a single atomic).
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the effective thread count (0 restores auto-detection).
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::SeqCst);
}

/// Effective thread count: the [`set_threads`] override (so the perf
/// bench can pin its 1-thread baseline even under `FAQUANT_THREADS`),
/// else the `FAQUANT_THREADS` env var, else `available_parallelism`.
/// Always >= 1.
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    // faq-lint: allow(time-or-env) — the one sanctioned env read: it
    // selects the worker count, which the determinism props tests pin to
    // be bitwise-irrelevant to every result.
    if let Ok(v) = std::env::var("FAQUANT_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Minimum f32 mul-adds a second thread must bring to be worth a
/// dispatch (queue push + wake is on the order of microseconds).
pub const MIN_FLOPS_PER_THREAD: usize = 1 << 16;

/// Threads worth using for `work` total mul-adds: capped so every
/// participant gets at least [`MIN_FLOPS_PER_THREAD`].
pub fn threads_for(work: usize) -> usize {
    threads().min((work / MIN_FLOPS_PER_THREAD).max(1))
}

/// A queued unit of work. Lifetime-erased to `'static`: sound because
/// [`Pool::run_batch`] never returns before every task of its batch has
/// finished (the completion guard decrements even on panic).
type Task = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// True while the current thread is executing a pool task. Nested
    /// `par_*` calls inside a task run serially: the top-level fan-out
    /// already owns the configured concurrency, and letting inner calls
    /// enqueue sub-batches would engage more than `threads()` workers
    /// (the cap must hold even under Phase-B-over-matmul nesting).
    static IN_POOL_TASK: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Whether the caller is already running inside a pool task.
fn in_pool_task() -> bool {
    IN_POOL_TASK.with(|c| c.get())
}

struct PoolShared {
    queue: Mutex<VecDeque<Task>>,
    available: Condvar,
}

/// Per-batch completion state shared between the submitter and workers.
struct Batch {
    remaining: AtomicUsize,
    /// First panic payload from any task, re-raised by the submitter so
    /// the original message/location survives the pool boundary.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    mu: Mutex<()>,
    done: Condvar,
}

impl Batch {
    fn new(n: usize) -> Self {
        Self {
            remaining: AtomicUsize::new(n),
            panic: Mutex::new(None),
            mu: Mutex::new(()),
            done: Condvar::new(),
        }
    }

    fn complete_one(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Lock before notify so a submitter checking `remaining`
            // under the lock can never miss the wakeup.
            let _g = self.mu.lock().unwrap();
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }
}

/// Decrements the batch counter when dropped — runs even if the task
/// panicked, so a submitter can never wait forever.
struct CompletionGuard<'a>(&'a Batch);

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        self.0.complete_one();
    }
}

pub struct Pool {
    shared: Arc<PoolShared>,
}

impl Pool {
    fn new(workers: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
        });
        for i in 0..workers {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("faquant-par-{i}"))
                .spawn(move || loop {
                    let task = {
                        let mut q = shared.queue.lock().unwrap();
                        loop {
                            if let Some(t) = q.pop_front() {
                                break t;
                            }
                            q = shared.available.wait(q).unwrap();
                        }
                    };
                    task();
                })
                .expect("spawn pool worker");
        }
        Self { shared }
    }

    /// Run `jobs` to completion, blocking the caller (who helps drain
    /// the queue). Panics in jobs are surfaced as one panic here, after
    /// every job of the batch has finished.
    fn run_batch<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let n_jobs = jobs.len();
        let batch = Arc::new(Batch::new(n_jobs));
        {
            let mut q = self.shared.queue.lock().unwrap();
            for job in jobs {
                let batch = Arc::clone(&batch);
                let task: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                    let _guard = CompletionGuard(&batch);
                    let prev = IN_POOL_TASK.with(|c| c.replace(true));
                    if let Err(payload) = catch_unwind(AssertUnwindSafe(job)) {
                        // Poison recovery: a second panicking job must
                        // still reach the slot, not double-panic on the
                        // mutex the first one poisoned.
                        let mut slot = batch
                            .panic
                            .lock()
                            .unwrap_or_else(|e| e.into_inner());
                        if slot.is_none() {
                            *slot = Some(payload);
                        }
                    }
                    IN_POOL_TASK.with(|c| c.set(prev));
                });
                // SAFETY: erased to 'static, but `run_batch` blocks until
                // `batch.remaining == 0`, i.e. until every closure (and
                // everything it borrows from 'env) is done being used.
                let task: Task = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'env>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(task)
                };
                q.push_back(task);
            }
        }
        // Wake one parked worker per task (not notify_all: batches are
        // often much narrower than the worker set). Lost wakeups are
        // harmless — the submitter drains its own queue entries below.
        for _ in 0..n_jobs {
            self.shared.available.notify_one();
        }
        // Help-first wait: run queued tasks until our batch completes,
        // so completion never depends on a worker being free.
        loop {
            if batch.is_done() {
                break;
            }
            let task = self.shared.queue.lock().unwrap().pop_front();
            match task {
                Some(t) => t(),
                None => {
                    // Queue empty => all our tasks have at least started;
                    // wait for the in-flight ones. The notifier locks
                    // `mu` before notifying, so checking under the lock
                    // cannot miss the wakeup.
                    let guard = batch.mu.lock().unwrap();
                    if !batch.is_done() {
                        let _ = batch.done.wait(guard).unwrap();
                    }
                }
            }
        }
        let payload = batch
            .panic
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take();
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }
}

/// The process-wide pool. Worker count is fixed at first use: enough for
/// the hardware and for any `FAQUANT_THREADS` oversubscription the
/// determinism tests request (idle workers park on a condvar).
pub fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let hw = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Pool::new(hw.max(threads()).max(8))
    })
}

/// Split `out` into up to `max_chunks` contiguous row blocks
/// (`row_len` elements per row) and run `f(first_row, block)` on each in
/// parallel. Blocks are disjoint `&mut` slices, so any per-element
/// arithmetic inside `f` is untouched by the chunking — the foundation
/// of the bit-identical-across-thread-counts guarantee.
pub fn par_row_blocks<F>(out: &mut [f32], row_len: usize, max_chunks: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if out.is_empty() {
        return;
    }
    debug_assert!(row_len > 0 && out.len() % row_len == 0);
    let rows = out.len() / row_len;
    let chunks = max_chunks.min(rows).max(1);
    if chunks <= 1 || in_pool_task() {
        f(0, out);
        return;
    }
    let rows_per = rows.div_ceil(chunks);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(rows_per * row_len)
        .enumerate()
        .map(|(ci, block)| {
            let fr = &f;
            Box::new(move || fr(ci * rows_per, block)) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool().run_batch(jobs);
}

/// Deterministic indexed parallel map: `out[i] = f(i)`, order preserved.
/// Items are split into at most [`threads`] contiguous chunks, so the
/// configured thread count genuinely caps concurrency (FAQUANT_THREADS=2
/// on a 16-core box runs at most 2 jobs at once); falls back to a serial
/// loop when one thread is in effect.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_bounded(n, usize::MAX, f)
}

/// [`par_map`] with an extra concurrency bound — pass
/// [`threads_for`]`(total_work)` so dispatches that aren't worth a queue
/// round-trip stay on the calling thread (the same gate the matmul
/// kernels apply). Chunking never changes results, only boundaries.
pub fn par_map_bounded<T, F>(n: usize, max_threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let t = threads().min(max_threads).min(n);
    if t <= 1 || in_pool_task() {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(t);
    {
        let fr = &f;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(per)
            .enumerate()
            .map(|(ci, chunk)| {
                Box::new(move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        *slot = Some(fr(ci * per + j));
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool().run_batch(jobs);
    }
    out.into_iter()
        .map(|s| s.expect("pool task completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_is_positive_and_overridable() {
        assert!(threads() >= 1);
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
        assert!(threads() >= 1);
    }

    #[test]
    fn threads_for_caps_small_work() {
        assert_eq!(threads_for(1), 1);
        assert_eq!(threads_for(MIN_FLOPS_PER_THREAD - 1), 1);
        assert!(threads_for(usize::MAX / 2) >= 1);
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        let empty: Vec<usize> = par_map(0, |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn par_row_blocks_covers_every_row_once() {
        let rows = 37;
        let cols = 5;
        let mut out = vec![0.0f32; rows * cols];
        par_row_blocks(&mut out, cols, 8, |row0, block| {
            for (r, row) in block.chunks_mut(cols).enumerate() {
                for v in row.iter_mut() {
                    *v += (row0 + r) as f32;
                }
            }
        });
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(out[r * cols + c], r as f32, "row {r} col {c}");
            }
        }
    }

    #[test]
    fn nested_par_runs_serially_and_correctly() {
        // par_* inside a pool task degrades to the serial path (the
        // concurrency cap must hold under nesting) with identical
        // results; the outer batch still completes via submitter help.
        let outer = par_map(24, |i| {
            let inner = par_map(8, move |j| i * 100 + j);
            inner.iter().sum::<usize>()
        });
        for (i, &s) in outer.iter().enumerate() {
            assert_eq!(s, (0..8).map(|j| i * 100 + j).sum::<usize>());
        }
        assert!(!in_pool_task());
    }

    #[test]
    fn task_panic_is_propagated_with_payload() {
        let r = std::panic::catch_unwind(|| {
            par_map(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
                i
            })
        });
        // The original payload crosses the pool boundary intact.
        let payload = r.unwrap_err();
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // Pool still functional afterwards.
        assert_eq!(par_map(4, |i| i + 1), vec![1, 2, 3, 4]);
    }

    #[test]
    fn miri_canary_detects_dangling_read() {
        // Wired to the nightly `miri-par` job's must-fail step: with
        // FAQUANT_MIRI_CANARY set, a pool task reads through a dangling
        // pointer and Miri MUST abort the run. If this ever passes under
        // Miri, the job's UB detection is broken (wrong flags, wrong
        // filter), not the code. The env gate keeps the UB out of every
        // normal `cargo test` run.
        if std::env::var_os("FAQUANT_MIRI_CANARY").is_none() {
            return;
        }
        let addr = {
            let boxed = Box::new(17u8);
            std::ptr::from_ref::<u8>(&boxed) as usize
        };
        // `boxed` is freed here, so the read below is a use-after-free.
        let got = par_map(1, move |_| unsafe { std::ptr::read(addr as *const u8) });
        assert_eq!(got.len(), 1);
    }
}
