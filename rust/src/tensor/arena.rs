//! Per-thread scratch-tensor arenas (DESIGN.md §11).
//!
//! The decode hot path must not allocate per step: every quantized
//! linear needs a scaled-activation buffer and an output buffer, and a
//! heap allocation for each would dominate small-step overhead under
//! serving load. The arena is a thread-local LIFO pool of [`Tensor`]s:
//! [`take`] hands out a zero-filled tensor (reusing both the element
//! buffer and the shape vector of a pooled one), [`give`] returns it.
//! A fixed take/give sequence — e.g. a steady-state decode step —
//! cycles the same buffers every call and performs zero heap
//! allocations once warm (pinned by `benches/alloc_probe.rs`).
//!
//! The thread-local borrow is never held across a call into other code
//! — in particular not across a parallel kernel dispatch, whose
//! help-first waiting can run unrelated pool tasks on this thread that
//! themselves use the arena.

use super::Tensor;
use std::cell::RefCell;

/// Cap on pooled tensors per thread; anything given back beyond this is
/// simply dropped (bounds memory if takes and gives ever unbalance).
const MAX_POOLED: usize = 32;

thread_local! {
    static ARENA: RefCell<Vec<Tensor>> = const { RefCell::new(Vec::new()) };
}

/// Take a zero-filled tensor of `shape` from this thread's pool.
/// Allocation-free once the pool is warm for the caller's take/give
/// sequence (LIFO: the most recently given buffer is reused first).
pub fn take(shape: &[usize]) -> Tensor {
    let pooled = ARENA.with(|a| a.borrow_mut().pop());
    match pooled {
        Some(mut t) => {
            let numel: usize = shape.iter().product();
            t.shape.clear();
            t.shape.extend_from_slice(shape);
            t.data.clear();
            t.data.resize(numel, 0.0);
            t
        }
        None => Tensor::zeros(shape),
    }
}

/// Return a tensor to this thread's pool for reuse by a later [`take`].
pub fn give(t: Tensor) {
    ARENA.with(|a| {
        let mut pool = a.borrow_mut();
        if pool.len() < MAX_POOLED {
            pool.push(t);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zero_filled_and_shaped() {
        let mut t = take(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert!(t.data().iter().all(|&v| v == 0.0));
        t.data_mut()[5] = 7.0; // dirty it, give it back
        give(t);
        let t2 = take(&[2, 6]);
        assert_eq!(t2.shape(), &[2, 6]);
        assert!(t2.data().iter().all(|&v| v == 0.0), "pooled buffer not reset");
        give(t2);
    }

    #[test]
    fn take_reuses_the_given_buffer() {
        let t = take(&[4, 8]);
        let p = t.data().as_ptr();
        give(t);
        // Same size: the pooled Vec's capacity suffices, so the element
        // buffer must not move (the zero-allocation steady state).
        let t2 = take(&[4, 8]);
        assert_eq!(t2.data().as_ptr(), p, "steady-state take reallocated");
        give(t2);
    }
}
