//! Statistics reductions used by calibration and evaluation.

use super::Tensor;

impl Tensor {
    // faq-lint: allow(unordered-reduction) — `Sum for f32` folds
    // left-to-right over a contiguous slice; order pinned by construction.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        // faq-lint: allow(unordered-reduction) — delegates to in-order `sum`
        self.sum() / self.data.len() as f32
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// Mean squared difference to another tensor (quantization error metric).
    // faq-lint: allow(unordered-reduction) — zip over two contiguous
    // slices accumulates in index order; order pinned by construction.
    pub fn mse(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.shape, other.shape);
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b) * (a - b))
            .sum::<f32>()
            / n as f32
    }

    /// Frobenius norm of the difference.
    pub fn dist2(&self, other: &Tensor) -> f32 {
        (self.mse(other) * self.data.len() as f32).sqrt()
    }

    /// Per-channel mean |x| over rows of a 2-D [r, c] tensor -> Vec len c.
    /// Host mirror of the Pallas `absmean` kernel (used as a cross-check
    /// and for stats aggregation without a device round trip).
    pub fn absmean_cols(&self) -> Vec<f32> {
        assert_eq!(self.shape.len(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut acc = vec![0.0f32; c];
        for i in 0..r {
            for (a, &x) in acc.iter_mut().zip(self.row(i)) {
                *a += x.abs();
            }
        }
        for a in &mut acc {
            *a /= r as f32;
        }
        acc
    }

    /// Excess kurtosis of all elements — used to verify trained activations
    /// develop the heavy-tailed channel structure AWQ/FAQ exploit.
    // faq-lint: allow(unordered-reduction) — moment sums run in slice
    // index order; order pinned by construction.
    pub fn kurtosis(&self) -> f32 {
        let n = self.data.len() as f32;
        if n < 4.0 {
            return 0.0;
        }
        let mean = self.mean();
        let m2 = self.data.iter().map(|&x| (x - mean).powi(2)).sum::<f32>() / n;
        let m4 = self.data.iter().map(|&x| (x - mean).powi(4)).sum::<f32>() / n;
        if m2 <= 0.0 {
            return 0.0;
        }
        m4 / (m2 * m2) - 3.0
    }
}

/// Mean and (population) standard deviation of a slice — Table 3 reporting.
// faq-lint: allow(unordered-reduction) — sums run in slice index order;
// order pinned by construction.
pub fn mean_std(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f32;
    let mean = xs.iter().sum::<f32>() / n;
    let var = xs.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / n;
    (mean, var.sqrt())
}

/// Percentile (nearest-rank) of an unsorted slice — latency reporting.
pub fn percentile(xs: &[f32], p: f32) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f32 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_and_dist() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 6.]).unwrap();
        assert!((a.mse(&b) - 1.0).abs() < 1e-6);
        assert!((a.dist2(&b) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn absmean_cols_matches_manual() {
        let a = Tensor::from_vec(&[2, 2], vec![1., -2., -3., 4.]).unwrap();
        assert_eq!(a.absmean_cols(), vec![2.0, 3.0]);
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[2.0, 4.0]);
        assert_eq!(m, 3.0);
        assert_eq!(s, 1.0);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn percentile_basics() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn kurtosis_heavy_tail_positive() {
        // Mostly small values + rare large outliers => positive excess kurtosis.
        let mut v = vec![0.1f32; 100];
        v.extend([10.0, -10.0]);
        let t = Tensor::from_vec(&[v.len()], v).unwrap();
        assert!(t.kurtosis() > 1.0);
    }
}
