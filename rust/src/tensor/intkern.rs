//! Integer W4A8 microkernel: int8 activations × packed int4 weight codes.
//!
//! The prepared f32 path ([`super::ops::PackedB`]) dequantizes the codes
//! once and pays f32 weight traffic on every decode step. This module
//! computes directly on the stored codes instead: activations are
//! quantized per row to i8 (absmax / 127, round-ties-even), the codes
//! stay packed two-per-byte, and each output element is produced by an
//! exact widening i32 accumulation followed by one f32 scale fixup per
//! quantization group. Weight-side memory traffic drops ~8× vs the f32
//! panels (1 byte per 2 codes vs 4 bytes per dequantized value).
//!
//! Numerics contract (DESIGN.md §17):
//!
//! - The i32 group accumulation is *exact* — every |xq·code| ≤ 127·15 and
//!   a group contributes ≤ `group` terms, so no i32 (or f32, for
//!   group ≤ 8192: |acc| < 2^24) rounding occurs. Integer addition is
//!   associative, so the scalar and SIMD lanes are **bit-identical by
//!   construction**: they differ only in how the exact integers are
//!   computed, never in their values.
//! - All f32 arithmetic (activation quantize, per-group fixup in
//!   ascending-g order, final row scale) lives in shared scalar code, so
//!   kernel choice and thread count cannot move a single float op.
//!   Rows are distributed via [`par::par_row_blocks`] with each output
//!   row owned by exactly one task.
//! - Versus the f32 prepared path only a *tolerance* holds: the i8
//!   activation rounding injects ≤ 0.5·a_scale per input element (see
//!   [`row_error_bound`]). The f32 path therefore stays the differential
//!   oracle, never the twin.
//! - NaN/Inf activations are not propagated (quantization clamps; `as`
//!   casts saturate). The differential tests use finite inputs; the f32
//!   path is the place NaN debugging belongs.

use super::{par, Tensor};
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// The B operand of [`matmul_int`]: quantization codes packed two per
/// byte, plus the per-(group, column) dequant params. Analogous to
/// [`super::ops::PackedB`] but ~8× smaller on the code side.
///
/// Layout: `codes[kp * c + j]` holds rows `2kp` (low nibble) and
/// `2kp + 1` (high nibble) of column `j` — k-major like the f32 panels,
/// so the kernel streams bytes in ascending-k order. `delta`/`zero` are
/// `[k/group, c]` row-major, exactly as stored in the artifact.
#[derive(Clone, Debug)]
pub struct PackedIntB {
    k: usize,
    c: usize,
    group: usize,
    codes: Vec<u8>,
    delta: Vec<f32>,
    zero: Vec<f32>,
}

impl PackedIntB {
    /// Pack a `[k, c]` tensor of integer codes (stored as f32, as the
    /// quantizer emits them) with its `[k/group, c]` dequant params.
    ///
    /// Fails — with the reason the int path is unavailable — when any
    /// code is not an integer in `[0, 15]` (bits > 4) or the shapes
    /// don't line up. The caller records the reason instead of packing.
    pub fn from_codes(q: &Tensor, delta: &Tensor, zero: &Tensor, group: usize) -> Result<Self> {
        if q.shape().len() != 2 {
            bail!("PackedIntB: codes shape {:?} is not 2-D", q.shape());
        }
        let (k, c) = (q.shape()[0], q.shape()[1]);
        if group == 0 || group % 2 != 0 || k % group != 0 {
            bail!("PackedIntB: group {group} does not tile k {k} in byte pairs");
        }
        let ng = k / group;
        if delta.shape() != [ng, c] || zero.shape() != [ng, c] {
            bail!(
                "PackedIntB: dequant params {:?}/{:?} want [{ng}, {c}]",
                delta.shape(),
                zero.shape()
            );
        }
        let nibble = |v: f32| -> Result<u8> {
            if !(0.0..=15.0).contains(&v) || v.fract() != 0.0 {
                bail!("code {v} is not an int4 value — int compute needs bits <= 4");
            }
            Ok(v as u8)
        };
        let qd = q.data();
        let mut codes = vec![0u8; (k / 2) * c];
        for kp in 0..k / 2 {
            let lo_row = &qd[(2 * kp) * c..(2 * kp + 1) * c];
            let hi_row = &qd[(2 * kp + 1) * c..(2 * kp + 2) * c];
            let out = &mut codes[kp * c..(kp + 1) * c];
            for ((o, &lo), &hi) in out.iter_mut().zip(lo_row).zip(hi_row) {
                *o = nibble(lo)? | (nibble(hi)? << 4);
            }
        }
        Ok(Self {
            k,
            c,
            group,
            codes,
            delta: delta.data().to_vec(),
            zero: zero.data().to_vec(),
        })
    }

    /// Rows (the contraction dimension k).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Columns (the output dimension c).
    pub fn c(&self) -> usize {
        self.c
    }

    /// Quantization group size along k.
    pub fn group(&self) -> usize {
        self.group
    }

    /// Bytes the kernel reads per full pass: packed codes + dequant
    /// params. The weight-traffic accounting the bench reports against
    /// the f32 panels' `k * c * 4`.
    pub fn packed_bytes(&self) -> usize {
        self.codes.len() + (self.delta.len() + self.zero.len()) * 4
    }
}

/// Kernel selection for the group accumulator. `Auto` resolves to SIMD
/// when the CPU has it (AVX2 on x86_64, NEON on aarch64), else scalar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntKernel {
    Auto,
    Scalar,
    Simd,
}

/// Process-wide programmatic override (tests/benches force a lane the
/// same way [`par::set_threads`] forces a thread count — an atomic, not
/// env mutation, so concurrent tests cannot race the environment).
static KERNEL_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Force the accumulator lane ([`IntKernel::Auto`] restores detection).
pub fn set_int_kernel(k: IntKernel) {
    let v = match k {
        IntKernel::Auto => 0,
        IntKernel::Scalar => 1,
        IntKernel::Simd => 2,
    };
    KERNEL_OVERRIDE.store(v, Ordering::SeqCst);
}

/// Whether a SIMD lane exists on this CPU.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(target_arch = "aarch64")]
    {
        true
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// Parse a forced-dispatch request (the `FAQUANT_INT_KERNEL` value).
fn kernel_from_str(s: &str) -> Option<IntKernel> {
    match s.trim() {
        "scalar" => Some(IntKernel::Scalar),
        "simd" => Some(IntKernel::Simd),
        "auto" | "" => Some(IntKernel::Auto),
        _ => None,
    }
}

/// The env-var override, read once (a per-call `env::var` would allocate
/// on the decode hot path and break the zero-allocation contract).
fn env_kernel() -> IntKernel {
    static ENV_KERNEL: OnceLock<IntKernel> = OnceLock::new();
    *ENV_KERNEL.get_or_init(|| {
        // faq-lint: allow(time-or-env) — forced-dispatch override for the
        // scalar-vs-SIMD CI lanes; the bitwise-equality props tests pin
        // the choice to be irrelevant to every result.
        std::env::var("FAQUANT_INT_KERNEL")
            .ok()
            .and_then(|v| kernel_from_str(&v))
            .unwrap_or(IntKernel::Auto)
    })
}

/// Resolve the lane for this call: programmatic override > env > auto.
fn use_simd() -> bool {
    let k = match KERNEL_OVERRIDE.load(Ordering::SeqCst) {
        1 => IntKernel::Scalar,
        2 => IntKernel::Simd,
        _ => env_kernel(),
    };
    match k {
        IntKernel::Scalar => false,
        // A forced "simd" on hardware without it degrades to scalar —
        // the equality tests then compare scalar to itself, trivially.
        IntKernel::Simd | IntKernel::Auto => simd_available(),
    }
}

/// Human-readable name of the lane [`matmul_int`] would use right now
/// (bench reports record it next to the int tokens/sec).
pub fn active_kernel() -> &'static str {
    if !use_simd() {
        return "scalar";
    }
    #[cfg(target_arch = "x86_64")]
    {
        "avx2"
    }
    #[cfg(target_arch = "aarch64")]
    {
        "neon"
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        "scalar"
    }
}

/// Round to nearest, ties to even, then clamp to the symmetric i8 range.
/// Hand-rolled (not `f32::round`, which rounds ties away from zero) so
/// the activation grid matches the convention hardware int8 paths use.
/// Exact for |v| < 2^22: `v - floor(v)` loses no bits there.
fn rte_i8(v: f32) -> i8 {
    let f = v.floor();
    let d = v - f;
    let r = if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f * 0.5).fract() == 0.0 {
        f
    } else {
        f + 1.0
    };
    // NaN falls through every comparison to here and saturates to 0.
    r.clamp(-127.0, 127.0) as i8
}

/// Quantize one activation row to i8: symmetric absmax grid,
/// round-ties-even. Returns the dequant scale `a_scale = absmax / 127`
/// (0 for an all-zero row, which quantizes to all zeros).
///
/// Shared by every kernel lane *and* by the differential tests' bound
/// computation, so the grid is defined in exactly one place.
pub fn quantize_row_i8(xs: &[f32], xq: &mut [i8]) -> f32 {
    debug_assert_eq!(xs.len(), xq.len());
    let mut absmax = 0.0f32;
    for &v in xs {
        let a = v.abs();
        if a > absmax {
            absmax = a;
        }
    }
    if absmax == 0.0 || !absmax.is_finite() {
        xq.fill(0);
        return 0.0;
    }
    let inv = 127.0 / absmax;
    for (q, &v) in xq.iter_mut().zip(xs) {
        *q = rte_i8(v * inv);
    }
    absmax / 127.0
}

/// Per-element error bound of the int path vs the f32 oracle for one
/// activation row, in f64: `0.5 * a_scale * L1_j + slack`, where `L1_j`
/// is the column-j L1 norm of the dequantized weights (the worst case of
/// the ≤ half-step activation rounding) and `slack` covers f32
/// re-association between the two paths' summation orders. Derived from
/// the quantizer's own constants — no magic epsilon (DESIGN.md §17).
pub fn row_error_bound(a_scale: f32, col_l1: f64, col_abs_moment: f64, k: usize) -> f64 {
    let rounding = 0.5 * a_scale as f64 * col_l1;
    let slack = col_abs_moment * f32::EPSILON as f64 * (k as f64).sqrt() * 8.0;
    rounding + slack + 1e-6
}

/// Exact i32 accumulation of one quantization group, scalar lane:
/// `acc[j] = Σ_kp xq[2kp]·lo(codes[kp, j]) + xq[2kp+1]·hi(codes[kp, j])`.
/// `codes` is the group's `[group/2, c]` byte panel. The sum is exact in
/// i32 (|term| ≤ 127·15, ≤ `group` terms), so although the loop runs in
/// ascending-k order the value is order-independent — which is what
/// licenses the SIMD lanes to compute the same integers their own way.
// faq-lint: accum(ascending-k) — widening i32 MAC; exact, order pinned.
fn accum_group_scalar(xq: &[i8], codes: &[u8], c: usize, acc: &mut [i32]) {
    acc.fill(0);
    for (kp, pair) in codes.chunks_exact(c).enumerate() {
        let x0 = xq[2 * kp] as i32;
        let x1 = xq[2 * kp + 1] as i32;
        for (a, &b) in acc.iter_mut().zip(pair) {
            *a += x0 * ((b & 0xF) as i32) + x1 * ((b >> 4) as i32);
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod lane {
    /// AVX2 group accumulator: 8 columns per vector, codes widened with
    /// `cvtepu8` and split into nibbles in registers; the accumulator
    /// stays in a register across the whole group (one store per column
    /// block). Computes the exact same i32 values as the scalar lane.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support ([`super::simd_available`])
    /// and that `codes.len()` is a multiple of `c` with `acc.len() >= c`.
    // faq-lint: accum(ascending-k) — widening i32 MAC; exact, order pinned.
    #[target_feature(enable = "avx2")]
    pub unsafe fn accum_group(xq: &[i8], codes: &[u8], c: usize, acc: &mut [i32]) {
        use std::arch::x86_64::*;
        let pairs = codes.len() / c;
        let mask = _mm256_set1_epi32(0xF);
        let mut j = 0;
        while j + 8 <= c {
            let mut av = _mm256_setzero_si256();
            for kp in 0..pairs {
                let x0 = _mm256_set1_epi32(xq[2 * kp] as i32);
                let x1 = _mm256_set1_epi32(xq[2 * kp + 1] as i32);
                // SAFETY: kp * c + j + 8 <= pairs * c = codes.len().
                let bytes = _mm_loadl_epi64(codes.as_ptr().add(kp * c + j) as *const __m128i);
                let w = _mm256_cvtepu8_epi32(bytes);
                av = _mm256_add_epi32(av, _mm256_mullo_epi32(_mm256_and_si256(w, mask), x0));
                av = _mm256_add_epi32(av, _mm256_mullo_epi32(_mm256_srli_epi32::<4>(w), x1));
            }
            // SAFETY: j + 8 <= c <= acc.len().
            _mm256_storeu_si256(acc.as_mut_ptr().add(j) as *mut __m256i, av);
            j += 8;
        }
        super::accum_tail(xq, codes, c, acc, j);
    }
}

#[cfg(target_arch = "aarch64")]
mod lane {
    /// NEON group accumulator: 8 columns per iteration as two i32x4
    /// register accumulators; nibbles split after an u8→u16 widen.
    /// Computes the exact same i32 values as the scalar lane.
    ///
    /// # Safety
    /// Caller must ensure `codes.len()` is a multiple of `c` with
    /// `acc.len() >= c` (NEON itself is baseline on aarch64).
    // faq-lint: accum(ascending-k) — widening i32 MAC; exact, order pinned.
    #[target_feature(enable = "neon")]
    pub unsafe fn accum_group(xq: &[i8], codes: &[u8], c: usize, acc: &mut [i32]) {
        use std::arch::aarch64::*;
        let pairs = codes.len() / c;
        let mut j = 0;
        while j + 8 <= c {
            let mut av0 = vdupq_n_s32(0);
            let mut av1 = vdupq_n_s32(0);
            for kp in 0..pairs {
                let x0 = xq[2 * kp] as i32;
                let x1 = xq[2 * kp + 1] as i32;
                // SAFETY: kp * c + j + 8 <= pairs * c = codes.len().
                let bytes = vld1_u8(codes.as_ptr().add(kp * c + j));
                let w = vmovl_u8(bytes);
                let lo = vandq_u16(w, vdupq_n_u16(0xF));
                let hi = vshrq_n_u16::<4>(w);
                let lo0 = vreinterpretq_s32_u32(vmovl_u16(vget_low_u16(lo)));
                let lo1 = vreinterpretq_s32_u32(vmovl_u16(vget_high_u16(lo)));
                let hi0 = vreinterpretq_s32_u32(vmovl_u16(vget_low_u16(hi)));
                let hi1 = vreinterpretq_s32_u32(vmovl_u16(vget_high_u16(hi)));
                av0 = vmlaq_n_s32(av0, lo0, x0);
                av0 = vmlaq_n_s32(av0, hi0, x1);
                av1 = vmlaq_n_s32(av1, lo1, x0);
                av1 = vmlaq_n_s32(av1, hi1, x1);
            }
            // SAFETY: j + 8 <= c <= acc.len().
            vst1q_s32(acc.as_mut_ptr().add(j), av0);
            vst1q_s32(acc.as_mut_ptr().add(j + 4), av1);
            j += 8;
        }
        super::accum_tail(xq, codes, c, acc, j);
    }
}

/// Scalar tail for the SIMD lanes: columns `[j0, c)` that don't fill a
/// vector. Same exact integers, one column at a time.
// faq-lint: accum(ascending-k) — widening i32 MAC; exact, order pinned.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn accum_tail(xq: &[i8], codes: &[u8], c: usize, acc: &mut [i32], j0: usize) {
    for j in j0..c {
        let mut s = 0i32;
        for (kp, pair) in codes.chunks_exact(c).enumerate() {
            let b = pair[j];
            s += (xq[2 * kp] as i32) * ((b & 0xF) as i32)
                + (xq[2 * kp + 1] as i32) * ((b >> 4) as i32);
        }
        acc[j] = s;
    }
}

/// Dispatch to the SIMD lane. Only called when [`use_simd`] returned
/// true, which implies the feature check passed.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn accum_group_simd(xq: &[i8], codes: &[u8], c: usize, acc: &mut [i32]) {
    // SAFETY: use_simd() gates this path on simd_available(), and the
    // slices come from PackedIntB's checked layout (codes is [pairs, c],
    // acc is exactly c wide).
    unsafe { lane::accum_group(xq, codes, c, acc) }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn accum_group_simd(xq: &[i8], codes: &[u8], c: usize, acc: &mut [i32]) {
    accum_group_scalar(xq, codes, c, acc)
}

thread_local! {
    /// Per-thread int scratch (the f32 [`super::arena`] can't hold i8/i32
    /// rows): quantized activation row + one group-accumulator row.
    /// Capacity is retained across calls, so steady-state decode makes
    /// zero allocations (pinned by `benches/alloc_probe.rs`).
    static SCRATCH: RefCell<(Vec<i8>, Vec<i32>)> = const { RefCell::new((Vec::new(), Vec::new())) };
}

/// One activation row through the int path: quantize, accumulate each
/// group with the chosen lane, fix up in f32. All f32 ops here are
/// shared scalar code in a fixed (ascending-g, ascending-j) order — the
/// lane choice only swaps how the exact i32 values are produced.
fn row_int(
    xs_row: &[f32],
    b: &PackedIntB,
    simd: bool,
    xq: &mut [i8],
    acc: &mut [i32],
    out: &mut [f32],
) {
    let a_scale = quantize_row_i8(xs_row, xq);
    let gp = b.group / 2;
    for g in 0..b.k / b.group {
        let xg = &xq[g * b.group..(g + 1) * b.group];
        let mut rowsum = 0i32;
        for &q in xg {
            // faq-lint: accum(ascending-k) — i32 rowsum of the group, exact.
            rowsum += q as i32;
        }
        let codes = &b.codes[g * gp * b.c..(g + 1) * gp * b.c];
        if simd {
            accum_group_simd(xg, codes, b.c, acc);
        } else {
            accum_group_scalar(xg, codes, b.c, acc);
        }
        let dg = &b.delta[g * b.c..(g + 1) * b.c];
        let zg = &b.zero[g * b.c..(g + 1) * b.c];
        let rs = rowsum as f32;
        // The fixup: Σ_k xq·dequant(q) == Σ_g delta_g·(acc_g − zero_g·rowsum_g),
        // accumulated per element in ascending-g order (bit-identical for
        // every lane and thread count; the adds are f32, hence ordered).
        for (j, o) in out.iter_mut().enumerate() {
            *o += dg[j] * (acc[j] as f32 - zg[j] * rs);
        }
    }
    for o in out.iter_mut() {
        *o *= a_scale;
    }
}

/// `out = intpath(xs [r, k] @ b [k, c])`: per-row dynamic i8 activation
/// quantization feeding the fused int8×int4 kernel, `out` zero-initialized
/// by the caller. Parallel over row blocks like the f32 matmuls; each
/// output row is produced by exactly one task, so results are
/// bit-identical for every thread count and kernel lane.
pub fn matmul_int(xs: &Tensor, b: &PackedIntB, out: &mut [f32]) -> Result<()> {
    if xs.shape().len() != 2 || xs.shape()[1] != b.k {
        bail!("matmul_int {:?} @ packed [{}, {}]", xs.shape(), b.k, b.c);
    }
    let (r, k) = (xs.shape()[0], xs.shape()[1]);
    let c = b.c;
    if out.len() != r * c {
        bail!("matmul_int out len {} != {r} * {c}", out.len());
    }
    let simd = use_simd();
    let t = par::threads_for(r * k * c);
    let a = xs.data();
    par::par_row_blocks(out, c, t, |row0, block| {
        SCRATCH.with(|s| {
            let (xq, acc) = &mut *s.borrow_mut();
            if xq.len() < k {
                xq.resize(k, 0);
            }
            if acc.len() < c {
                acc.resize(c, 0);
            }
            for (rr, orow) in block.chunks_mut(c).enumerate() {
                let row = row0 + rr;
                row_int(&a[row * k..(row + 1) * k], b, simd, &mut xq[..k], &mut acc[..c], orow);
            }
        });
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    /// Random int4 codes + dequant params shaped like a quantized linear.
    fn random_packed(rng: &mut Rng, k: usize, c: usize, group: usize) -> (Tensor, Tensor, Tensor) {
        let q: Vec<f32> = (0..k * c).map(|_| (rng.below(16)) as f32).collect();
        let ng = k / group;
        let delta: Vec<f32> = (0..ng * c).map(|_| 0.01 + rng.uniform() * 0.05).collect();
        let zero: Vec<f32> = (0..ng * c).map(|_| (rng.below(16)) as f32).collect();
        (
            Tensor::from_vec(&[k, c], q).unwrap(),
            Tensor::from_vec(&[ng, c], delta).unwrap(),
            Tensor::from_vec(&[ng, c], zero).unwrap(),
        )
    }

    /// Naive reference replaying the exact f32 op order of [`row_int`]
    /// with i64 accumulators and no packing — the packing/kernels are
    /// what's under test.
    fn naive_int(xs: &Tensor, q: &Tensor, delta: &Tensor, zero: &Tensor, group: usize) -> Vec<f32> {
        let (r, k) = (xs.shape()[0], xs.shape()[1]);
        let c = q.shape()[1];
        let mut out = vec![0.0f32; r * c];
        let mut xq = vec![0i8; k];
        for i in 0..r {
            let a_scale = quantize_row_i8(xs.row(i), &mut xq);
            for g in 0..k / group {
                let rowsum: i64 = xq[g * group..(g + 1) * group]
                    .iter()
                    .map(|&v| v as i64)
                    .sum();
                for j in 0..c {
                    let mut acc = 0i64;
                    for l in g * group..(g + 1) * group {
                        acc += xq[l] as i64 * q.at2(l, j) as i64;
                    }
                    out[i * c + j] += delta.at2(g, j)
                        * (acc as i32 as f32 - zero.at2(g, j) * (rowsum as i32 as f32));
                }
            }
            for o in &mut out[i * c..(i + 1) * c] {
                *o *= a_scale;
            }
        }
        out
    }

    #[test]
    fn rte_ties_go_to_even() {
        assert_eq!(rte_i8(0.5), 0);
        assert_eq!(rte_i8(1.5), 2);
        assert_eq!(rte_i8(2.5), 2);
        assert_eq!(rte_i8(-0.5), 0);
        assert_eq!(rte_i8(-1.5), -2);
        assert_eq!(rte_i8(-2.5), -2);
        assert_eq!(rte_i8(3.2), 3);
        assert_eq!(rte_i8(-3.7), -4);
        assert_eq!(rte_i8(126.6), 127);
        assert_eq!(rte_i8(200.0), 127);
        assert_eq!(rte_i8(-200.0), -127);
        assert_eq!(rte_i8(f32::NAN), 0);
    }

    #[test]
    fn quantize_row_zero_and_roundtrip() {
        let mut xq = vec![0i8; 4];
        assert_eq!(quantize_row_i8(&[0.0; 4], &mut xq), 0.0);
        assert!(xq.iter().all(|&v| v == 0));
        // The absmax element lands exactly on ±127.
        let s = quantize_row_i8(&[1.0, -2.0, 0.5, 0.0], &mut xq);
        assert_eq!(xq[1], -127);
        assert!((s * 127.0 - 2.0).abs() < 1e-6);
    }

    #[test]
    fn from_codes_validates() {
        let ok = Tensor::from_vec(&[4, 2], vec![0., 15., 7., 3., 1., 2., 4., 5.]).unwrap();
        let d = Tensor::from_vec(&[2, 2], vec![0.1; 4]).unwrap();
        let z = Tensor::from_vec(&[2, 2], vec![1.0; 4]).unwrap();
        assert!(PackedIntB::from_codes(&ok, &d, &z, 2).is_ok());
        // Non-integral and out-of-range codes are refused with a reason.
        let bad = Tensor::from_vec(&[4, 2], vec![0.5; 8]).unwrap();
        assert!(PackedIntB::from_codes(&bad, &d, &z, 2).is_err());
        let wide = Tensor::from_vec(&[4, 2], vec![16.0; 8]).unwrap();
        assert!(PackedIntB::from_codes(&wide, &d, &z, 2).is_err());
        // Group must tile k in pairs; params must match [k/group, c].
        assert!(PackedIntB::from_codes(&ok, &d, &z, 3).is_err());
        assert!(PackedIntB::from_codes(&ok, &d, &z, 8).is_err());
        let b = PackedIntB::from_codes(&ok, &d, &z, 2).unwrap();
        assert_eq!((b.k(), b.c(), b.group()), (4, 2, 2));
        assert_eq!(b.packed_bytes(), 4 + 8 * 4);
    }

    #[test]
    fn matmul_int_matches_naive_all_lanes() {
        let mut rng = Rng::new(11);
        // Shapes straddle the 8-column vector edge (tails of 0..7).
        let shapes = [(3usize, 8usize, 9usize, 4usize), (5, 64, 16, 64), (2, 32, 7, 8)];
        for (r, k, c, group) in shapes {
            let (q, d, z) = random_packed(&mut rng, k, c, group);
            let b = PackedIntB::from_codes(&q, &d, &z, group).unwrap();
            let xs = Tensor::randn(&mut rng, &[r, k], 1.0);
            let want = naive_int(&xs, &q, &d, &z, group);
            for kern in [IntKernel::Scalar, IntKernel::Simd] {
                set_int_kernel(kern);
                let mut out = vec![0.0f32; r * c];
                matmul_int(&xs, &b, &mut out).unwrap();
                for (g, w) in out.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "lane {kern:?}");
                }
            }
            set_int_kernel(IntKernel::Auto);
        }
    }

    #[test]
    fn scalar_and_simd_bitwise_identical_across_threads() {
        let mut rng = Rng::new(23);
        let (q, d, z) = random_packed(&mut rng, 64, 33, 8);
        let b = PackedIntB::from_codes(&q, &d, &z, 8).unwrap();
        let xs = Tensor::randn(&mut rng, &[7, 64], 1.5);
        set_int_kernel(IntKernel::Scalar);
        let mut want = vec![0.0f32; 7 * 33];
        matmul_int(&xs, &b, &mut want).unwrap();
        for threads in [1usize, 2, 8] {
            crate::tensor::par::set_threads(threads);
            for kern in [IntKernel::Scalar, IntKernel::Simd] {
                set_int_kernel(kern);
                let mut out = vec![0.0f32; 7 * 33];
                matmul_int(&xs, &b, &mut out).unwrap();
                for (g, w) in out.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "lane {kern:?} threads {threads}");
                }
            }
        }
        crate::tensor::par::set_threads(0);
        set_int_kernel(IntKernel::Auto);
    }

    #[test]
    fn int_path_within_derived_bound_of_f32() {
        let mut rng = Rng::new(37);
        let (q, d, z) = random_packed(&mut rng, 64, 24, 8);
        let b = PackedIntB::from_codes(&q, &d, &z, 8).unwrap();
        let xs = Tensor::randn(&mut rng, &[4, 64], 1.0);
        let mut out = vec![0.0f32; 4 * 24];
        matmul_int(&xs, &b, &mut out).unwrap();
        // f32 oracle: dequantize and matmul.
        let wdq: Vec<f32> = (0..64 * 24)
            .map(|i| {
                let (l, j) = (i / 24, i % 24);
                (q.at2(l, j) - z.at2(l / 8, j)) * d.at2(l / 8, j)
            })
            .collect();
        let wt = Tensor::from_vec(&[64, 24], wdq.clone()).unwrap();
        let want = xs.matmul(&wt).unwrap();
        let mut xq = vec![0i8; 64];
        for i in 0..4 {
            let a_scale = quantize_row_i8(xs.row(i), &mut xq);
            for j in 0..24 {
                let col_l1: f64 = (0..64).map(|l| (wdq[l * 24 + j] as f64).abs()).sum();
                let moment: f64 = (0..64)
                    .map(|l| (wdq[l * 24 + j] as f64 * xs.at2(i, l) as f64).abs())
                    .sum();
                let bound = row_error_bound(a_scale, col_l1, moment, 64);
                let err = (out[i * 24 + j] as f64 - want.at2(i, j) as f64).abs();
                assert!(err <= bound, "err {err} > bound {bound} at ({i}, {j})");
            }
        }
    }

    #[test]
    fn lanes_agree_without_dispatch_globals() {
        // Calls the group accumulators directly — no override atomic, no
        // thread pool — so a lane bug cannot hide behind a concurrent
        // test flipping the global dispatch state.
        let mut rng = Rng::new(53);
        for c in [1usize, 7, 8, 9, 24, 33] {
            let pairs = 16;
            let codes: Vec<u8> = (0..pairs * c).map(|_| rng.below(256) as u8).collect();
            let xq: Vec<i8> = (0..2 * pairs).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            let mut a = vec![0i32; c];
            let mut b = vec![0i32; c];
            accum_group_scalar(&xq, &codes, c, &mut a);
            if simd_available() {
                accum_group_simd(&xq, &codes, c, &mut b);
                assert_eq!(a, b, "c = {c}");
            }
        }
    }

    #[test]
    fn kernel_from_str_parses_forced_dispatch() {
        assert_eq!(kernel_from_str("scalar"), Some(IntKernel::Scalar));
        assert_eq!(kernel_from_str(" simd\n"), Some(IntKernel::Simd));
        assert_eq!(kernel_from_str("auto"), Some(IntKernel::Auto));
        assert_eq!(kernel_from_str("avx512"), None);
        // The active-kernel label is always one of the known lanes.
        assert!(["scalar", "avx2", "neon"].contains(&active_kernel()));
    }

    #[test]
    fn matmul_int_shape_checks() {
        let q = Tensor::from_vec(&[4, 2], vec![1.0; 8]).unwrap();
        let d = Tensor::from_vec(&[1, 2], vec![0.1; 2]).unwrap();
        let z = Tensor::from_vec(&[1, 2], vec![0.0; 2]).unwrap();
        let b = PackedIntB::from_codes(&q, &d, &z, 4).unwrap();
        let xs = Tensor::zeros(&[2, 3]);
        let mut out = vec![0.0f32; 4];
        assert!(matmul_int(&xs, &b, &mut out).is_err()); // k mismatch
        let xs = Tensor::zeros(&[2, 4]);
        let mut short = vec![0.0f32; 3];
        assert!(matmul_int(&xs, &b, &mut short).is_err());
    }
}
