//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! No `rand` crate offline; this is the single randomness source for the
//! whole crate (init, corpus generation, sampling, property tests), so
//! every run is reproducible from integer seeds.

/// xoshiro256** generator (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for parallel workers / sub-tasks).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) / ((1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.uniform() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f32::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    // faq-lint: allow(unordered-reduction) — total runs in slice index
    // order; order pinned by construction.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices out of [0, n) (reservoir when k << n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(3);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(4);
        let picks = r.choose_k(10, 5);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(5);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
