//! Elementwise and linear-algebra ops on [`Tensor`].
//!
//! The matmuls here are the native backend's compute core: cache-blocked,
//! packed-panel microkernels parallelized over row blocks via
//! [`super::par`]. Determinism contract (DESIGN.md §9): each output
//! element is accumulated by exactly one task, in ascending-k order, so
//! results are bit-identical for every thread count and to the plain
//! naive triple loop (including NaN/Inf propagation — there is no
//! zero-skip branch).

use super::{par, Tensor};
use anyhow::{bail, Result};

/// Rows per microtile: small enough that MR output rows + one B row stay
/// in L1, large enough to amortize each B-row load across MR updates.
const MR: usize = 4;
/// k-dimension block: KC B-rows are reused by every microtile of a row
/// block before the next panel is touched (KC * row_len floats resident).
const KC: usize = 128;

/// Microkernel for `out[rows, c] += a_rows @ b` where `a_rows` starts at
/// absolute row `row0` of an [r, k] matrix. Accumulation over k runs in
/// ascending order per element (k-blocks ascend, rows inside a block
/// ascend), which makes the result bitwise equal to the naive (i, l, j)
/// triple loop regardless of blocking or thread count.
fn matmul_block(a: &[f32], b: &[f32], row0: usize, out: &mut [f32], k: usize, c: usize) {
    let rows = out.len() / c;
    let mut apack = [0.0f32; MR * KC];
    for l0 in (0..k).step_by(KC) {
        let lhi = (l0 + KC).min(k);
        let mut i = 0;
        while i < rows {
            let ihi = (i + MR).min(rows);
            let mr = ihi - i;
            // Pack the A microtile [mr, lhi-l0] l-major so the inner
            // loop reads its mr values from one contiguous stripe.
            for (ii, row) in (i..ihi).enumerate() {
                let arow = &a[(row0 + row) * k..];
                for l in l0..lhi {
                    apack[(l - l0) * MR + ii] = arow[l];
                }
            }
            for l in l0..lhi {
                let brow = &b[l * c..(l + 1) * c];
                let avs = &apack[(l - l0) * MR..(l - l0) * MR + mr];
                for (ii, &av) in avs.iter().enumerate() {
                    let orow = &mut out[(i + ii) * c..(i + ii + 1) * c];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
            i = ihi;
        }
    }
}

/// The B operand of [`Tensor::matmul`] in its packed panel layout,
/// prepared once and consumed zero-copy by [`Tensor::matmul_prepacked`].
///
/// The blocked microkernel ([`matmul_block`]) streams B as KC-row panels
/// in ascending-k order, each panel row contiguous; row-major `[k, c]`
/// with panels as consecutive row ranges *is* that consumption order, so
/// the packed buffer is byte-for-byte what the kernel reads. Producers
/// (e.g. the prepared quantized model) write dequantized values straight
/// into this buffer, skipping any intermediate unpacked matrix.
#[derive(Clone, Debug)]
pub struct PackedB {
    k: usize,
    c: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Wrap an already panel-ordered buffer (`data.len() == k * c`).
    pub fn from_parts(k: usize, c: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != k * c {
            bail!("PackedB [{k}, {c}] wants {} elements, got {}", k * c, data.len());
        }
        Ok(Self { k, c, data })
    }

    /// Pack a 2-D `[k, c]` tensor (copies into the panel buffer).
    pub fn from_tensor(t: &Tensor) -> Result<Self> {
        if t.shape.len() != 2 {
            bail!("PackedB::from_tensor on shape {:?}", t.shape);
        }
        Self::from_parts(t.shape[0], t.shape[1], t.data.clone())
    }

    /// Rows (the contraction dimension k).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Columns (the output dimension c).
    pub fn c(&self) -> usize {
        self.c
    }

    /// The panel buffer (k-major, as the microkernel consumes it).
    pub fn data(&self) -> &[f32] {
        &self.data
    }
}

/// Shared blocked-parallel matmul core: `out = a [r, k] @ b [k, c]`,
/// `out` zero-initialized by the caller. Identical partitioning and
/// per-element accumulation order for every entry point built on it
/// ([`Tensor::matmul`], [`Tensor::matmul_into`],
/// [`Tensor::matmul_prepacked`]), so all three are bit-identical.
fn matmul_core(a: &[f32], b: &[f32], r: usize, k: usize, c: usize, out: &mut [f32]) {
    let t = par::threads_for(r * k * c);
    par::par_row_blocks(out, c, t, |row0, block| {
        matmul_block(a, b, row0, block, k, c);
    });
}

impl Tensor {
    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise map in place (same values as [`Tensor::map`], without
    /// the allocation — the scratch-arena hot paths use this).
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Elementwise binary zip (shapes must match).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("zip shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, c: f32) -> Tensor {
        self.map(|x| x * c)
    }

    /// Multiply row i of a 2-D [n, m] tensor by s[i] (AWQ W * diag(s)).
    pub fn mul_rows(&self, s: &[f32]) -> Result<Tensor> {
        if self.shape.len() != 2 || self.shape[0] != s.len() {
            bail!("mul_rows: shape {:?} vs s len {}", self.shape, s.len());
        }
        let (n, m) = (self.shape[0], self.shape[1]);
        let mut data = self.data.clone();
        for i in 0..n {
            let si = s[i];
            for v in &mut data[i * m..(i + 1) * m] {
                *v *= si;
            }
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data,
        })
    }

    /// Divide row i by s[i] (inverse of `mul_rows`); s must be nonzero.
    pub fn div_rows(&self, s: &[f32]) -> Result<Tensor> {
        let inv: Vec<f32> = s.iter().map(|&x| 1.0 / x).collect();
        self.mul_rows(&inv)
    }

    /// Matmul: self [r, k] @ other [k, c] -> [r, c].
    ///
    /// Cache-blocked packed-panel kernel ([`matmul_block`]) parallelized
    /// over row blocks; bit-identical to the naive triple loop for every
    /// thread count (see module docs).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 || self.shape[1] != other.shape[0] {
            bail!("matmul {:?} @ {:?}", self.shape, other.shape);
        }
        let (r, k) = (self.shape[0], self.shape[1]);
        let c = other.shape[1];
        let mut out = vec![0.0f32; r * c];
        matmul_core(&self.data, &other.data, r, k, c, &mut out);
        Tensor::from_vec(&[r, c], out)
    }

    /// [`Tensor::matmul`] into a caller-provided (zero-initialized)
    /// buffer — the allocation-free entry the scratch-arena hot paths
    /// and the Phase-B candidate sweep use. Bit-identical to `matmul`.
    pub fn matmul_into(&self, other: &Tensor, out: &mut [f32]) -> Result<()> {
        if self.shape.len() != 2 || other.shape.len() != 2 || self.shape[1] != other.shape[0] {
            bail!("matmul_into {:?} @ {:?}", self.shape, other.shape);
        }
        let (r, k) = (self.shape[0], self.shape[1]);
        let c = other.shape[1];
        if out.len() != r * c {
            bail!("matmul_into out len {} != {r} * {c}", out.len());
        }
        matmul_core(&self.data, &other.data, r, k, c, out);
        Ok(())
    }

    /// `self [r, k] @ packed [k, c]` into a caller-provided
    /// (zero-initialized) buffer, with B pre-packed once via [`PackedB`]
    /// instead of re-streamed from a tensor per call. Same kernel, same
    /// partitioning: bit-identical to [`Tensor::matmul`] on the
    /// equivalent `[k, c]` tensor, for every thread count.
    pub fn matmul_prepacked(&self, packed: &PackedB, out: &mut [f32]) -> Result<()> {
        if self.shape.len() != 2 || self.shape[1] != packed.k {
            bail!(
                "matmul_prepacked {:?} @ packed [{}, {}]",
                self.shape,
                packed.k,
                packed.c
            );
        }
        let (r, k) = (self.shape[0], self.shape[1]);
        let c = packed.c;
        if out.len() != r * c {
            bail!("matmul_prepacked out len {} != {r} * {c}", out.len());
        }
        matmul_core(&self.data, &packed.data, r, k, c, out);
        Ok(())
    }

    /// self^T @ other without materializing the transpose:
    /// [r, n]^T @ [r, m] -> [n, m]. The gradient-accumulation shape
    /// (dW = x^T @ dy) in the native training backward.
    ///
    /// Parallel over blocks of *output* rows (columns of self); each
    /// block accumulates over the shared r dimension in ascending order,
    /// so results are thread-count invariant.
    pub fn matmul_tn(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 || self.shape[0] != other.shape[0] {
            bail!("matmul_tn {:?}^T @ {:?}", self.shape, other.shape);
        }
        let (r, n) = (self.shape[0], self.shape[1]);
        let m = other.shape[1];
        let mut out = vec![0.0f32; n * m];
        let t = par::threads_for(r * n * m);
        let a = &self.data;
        let b = &other.data;
        par::par_row_blocks(&mut out, m, t, |i0, block| {
            let ni = block.len() / m;
            for row in 0..r {
                let arow = &a[row * n..(row + 1) * n];
                let brow = &b[row * m..(row + 1) * m];
                for ii in 0..ni {
                    let av = arow[i0 + ii];
                    let orow = &mut block[ii * m..(ii + 1) * m];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        });
        Tensor::from_vec(&[n, m], out)
    }

    /// self @ other^T without materializing the transpose:
    /// [r, k] @ [m, k]^T -> [r, m]. The input-gradient shape
    /// (dx = dy @ W^T) in the native training backward.
    ///
    /// Row-parallel; each element is one single-accumulator dot product
    /// over ascending k (identical to the naive formulation).
    // faq-lint: allow(unordered-reduction) — per-element dot product over
    // ascending k inside a fixed row block; order pinned by construction
    // and covered by the thread-count determinism props tests.
    pub fn matmul_nt(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 || self.shape[1] != other.shape[1] {
            bail!("matmul_nt {:?} @ {:?}^T", self.shape, other.shape);
        }
        let (r, k) = (self.shape[0], self.shape[1]);
        let m = other.shape[0];
        let mut out = vec![0.0f32; r * m];
        let t = par::threads_for(r * k * m);
        let a = &self.data;
        let b = &other.data;
        par::par_row_blocks(&mut out, m, t, |row0, block| {
            for (ii, orow) in block.chunks_mut(m).enumerate() {
                let arow = &a[(row0 + ii) * k..(row0 + ii + 1) * k];
                for (j, o) in orow.iter_mut().enumerate() {
                    let brow = &b[j * k..(j + 1) * k];
                    *o = arow.iter().zip(brow).map(|(&x, &y)| x * y).sum();
                }
            }
        });
        Tensor::from_vec(&[r, m], out)
    }

    /// Transpose a 2-D tensor.
    pub fn transpose2(&self) -> Result<Tensor> {
        if self.shape.len() != 2 {
            bail!("transpose2 on {:?}", self.shape);
        }
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut data = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                data[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::from_vec(&[c, r], data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], v: Vec<f32>) -> Tensor {
        Tensor::from_vec(shape, v).unwrap()
    }

    #[test]
    fn matmul_small() {
        let a = t(&[2, 2], vec![1., 2., 3., 4.]);
        let b = t(&[2, 2], vec![1., 1., 1., 1.]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_propagates_nan_through_zero_rows() {
        // Regression: the old kernel skipped a == 0.0 terms, silently
        // swallowing NaN/Inf from the other operand.
        let a = t(&[1, 2], vec![0.0, 0.0]);
        let b = t(&[2, 1], vec![f32::NAN, 1.0]);
        assert!(a.matmul(&b).unwrap().data()[0].is_nan());
        let binf = t(&[2, 1], vec![f32::INFINITY, 1.0]);
        assert!(a.matmul(&binf).unwrap().data()[0].is_nan()); // 0 * inf
        assert!(a.matmul_tn(&t(&[1, 3], vec![f32::NAN; 3])).unwrap().data()[0].is_nan());
    }

    #[test]
    fn matmul_large_matches_blocked_boundaries() {
        // Shapes straddling the MR/KC tile edges against a local naive
        // triple loop, bitwise.
        let mut rng = crate::tensor::Rng::new(77);
        for (r, k, c) in [(5usize, 130usize, 9usize), (8, 256, 16), (3, 127, 33)] {
            let a = Tensor::randn(&mut rng, &[r, k], 1.0);
            let b = Tensor::randn(&mut rng, &[k, c], 1.0);
            let got = a.matmul(&b).unwrap();
            let mut want = vec![0.0f32; r * c];
            for i in 0..r {
                for l in 0..k {
                    let av = a.at2(i, l);
                    for j in 0..c {
                        want[i * c + j] += av * b.at2(l, j);
                    }
                }
            }
            for (g, w) in got.data().iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
        }
    }

    #[test]
    fn matmul_into_and_prepacked_match_matmul_bitwise() {
        let mut rng = crate::tensor::Rng::new(91);
        for (r, k, c) in [(5usize, 130usize, 9usize), (1, 64, 33), (7, 12, 3)] {
            let a = Tensor::randn(&mut rng, &[r, k], 1.0);
            let b = Tensor::randn(&mut rng, &[k, c], 1.0);
            let want = a.matmul(&b).unwrap();
            let mut out = vec![0.0f32; r * c];
            a.matmul_into(&b, &mut out).unwrap();
            for (g, w) in out.iter().zip(want.data()) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
            let packed = PackedB::from_tensor(&b).unwrap();
            assert_eq!((packed.k(), packed.c()), (k, c));
            let mut out2 = vec![0.0f32; r * c];
            a.matmul_prepacked(&packed, &mut out2).unwrap();
            for (g, w) in out2.iter().zip(want.data()) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
        }
    }

    #[test]
    fn prepacked_shape_checks() {
        let a = t(&[2, 3], vec![0.0; 6]);
        let b = t(&[3, 2], vec![1.0; 6]);
        let packed = PackedB::from_tensor(&b).unwrap();
        assert_eq!(packed.data().len(), 6);
        let mut short = vec![0.0f32; 3];
        assert!(a.matmul_prepacked(&packed, &mut short).is_err());
        let mut out = vec![0.0f32; 4];
        assert!(b.matmul_prepacked(&packed, &mut out).is_err()); // k mismatch
        assert!(a.matmul_into(&b, &mut short).is_err());
        assert!(PackedB::from_parts(2, 2, vec![0.0; 3]).is_err());
        assert!(PackedB::from_tensor(&t(&[4], vec![0.0; 4])).is_err());
    }

    #[test]
    fn map_inplace_matches_map() {
        let a = t(&[2, 2], vec![-1.0, 0.5, 2.0, -3.0]);
        let want = a.map(|x| x * x + 1.0);
        let mut b = a.clone();
        b.map_inplace(|x| x * x + 1.0);
        assert_eq!(b, want);
    }

    #[test]
    fn matmul_shape_check() {
        let a = t(&[2, 3], vec![0.0; 6]);
        let b = t(&[2, 3], vec![0.0; 6]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let a = t(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(&[3, 4], (0..12).map(|i| i as f32).collect());
        let want = a.transpose2().unwrap().matmul(&b).unwrap();
        let got = a.matmul_tn(&b).unwrap();
        assert_eq!(want, got);
        assert!(a.matmul_tn(&t(&[2, 2], vec![0.0; 4])).is_err());
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(&[4, 3], (0..12).map(|i| i as f32).collect());
        let want = a.matmul(&b.transpose2().unwrap()).unwrap();
        let got = a.matmul_nt(&b).unwrap();
        assert_eq!(want, got);
        assert!(a.matmul_nt(&t(&[4, 2], vec![0.0; 8])).is_err());
    }

    #[test]
    fn mul_div_rows_roundtrip() {
        let a = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let s = [2.0, 4.0];
        let b = a.mul_rows(&s).unwrap().div_rows(&s).unwrap();
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let back = a.transpose2().unwrap().transpose2().unwrap();
        assert_eq!(a, back);
    }

    #[test]
    fn zip_shape_mismatch_errors() {
        let a = t(&[2, 2], vec![0.0; 4]);
        let b = t(&[4], vec![0.0; 4]);
        assert!(a.add(&b).is_err());
    }
}
