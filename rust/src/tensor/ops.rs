//! Elementwise and linear-algebra ops on [`Tensor`].
//!
//! Host-side only: used for scale math, small verification matmuls, and
//! test oracles. The model-scale matmuls all run inside HLO artifacts.

use super::Tensor;
use anyhow::{bail, Result};

impl Tensor {
    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise binary zip (shapes must match).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("zip shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, c: f32) -> Tensor {
        self.map(|x| x * c)
    }

    /// Multiply row i of a 2-D [n, m] tensor by s[i] (AWQ W * diag(s)).
    pub fn mul_rows(&self, s: &[f32]) -> Result<Tensor> {
        if self.shape.len() != 2 || self.shape[0] != s.len() {
            bail!("mul_rows: shape {:?} vs s len {}", self.shape, s.len());
        }
        let (n, m) = (self.shape[0], self.shape[1]);
        let mut data = self.data.clone();
        for i in 0..n {
            let si = s[i];
            for v in &mut data[i * m..(i + 1) * m] {
                *v *= si;
            }
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data,
        })
    }

    /// Divide row i by s[i] (inverse of `mul_rows`); s must be nonzero.
    pub fn div_rows(&self, s: &[f32]) -> Result<Tensor> {
        let inv: Vec<f32> = s.iter().map(|&x| 1.0 / x).collect();
        self.mul_rows(&inv)
    }

    /// Naive blocked matmul: self [r, k] @ other [k, c] -> [r, c].
    ///
    /// Loop order (i, l, j) keeps both inner accesses sequential; good
    /// enough for verification-scale products (the hot path is in HLO).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 || self.shape[1] != other.shape[0] {
            bail!("matmul {:?} @ {:?}", self.shape, other.shape);
        }
        let (r, k) = (self.shape[0], self.shape[1]);
        let c = other.shape[1];
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * c..(i + 1) * c];
            for (l, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[l * c..(l + 1) * c];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Tensor::from_vec(&[r, c], out)
    }

    /// self^T @ other without materializing the transpose:
    /// [r, n]^T @ [r, m] -> [n, m]. The gradient-accumulation shape
    /// (dW = x^T @ dy) in the native training backward.
    pub fn matmul_tn(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 || self.shape[0] != other.shape[0] {
            bail!("matmul_tn {:?}^T @ {:?}", self.shape, other.shape);
        }
        let (r, n) = (self.shape[0], self.shape[1]);
        let m = other.shape[1];
        let mut out = vec![0.0f32; n * m];
        for row in 0..r {
            let arow = &self.data[row * n..(row + 1) * n];
            let brow = &other.data[row * m..(row + 1) * m];
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out[i * m..(i + 1) * m];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += a * bv;
                }
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    /// self @ other^T without materializing the transpose:
    /// [r, k] @ [m, k]^T -> [r, m]. The input-gradient shape
    /// (dx = dy @ W^T) in the native training backward.
    pub fn matmul_nt(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 || self.shape[1] != other.shape[1] {
            bail!("matmul_nt {:?} @ {:?}^T", self.shape, other.shape);
        }
        let (r, k) = (self.shape[0], self.shape[1]);
        let m = other.shape[0];
        let mut out = vec![0.0f32; r * m];
        for i in 0..r {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..m {
                let brow = &other.data[j * k..(j + 1) * k];
                out[i * m + j] = arow.iter().zip(brow).map(|(&a, &b)| a * b).sum();
            }
        }
        Tensor::from_vec(&[r, m], out)
    }

    /// Transpose a 2-D tensor.
    pub fn transpose2(&self) -> Result<Tensor> {
        if self.shape.len() != 2 {
            bail!("transpose2 on {:?}", self.shape);
        }
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut data = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                data[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::from_vec(&[c, r], data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], v: Vec<f32>) -> Tensor {
        Tensor::from_vec(shape, v).unwrap()
    }

    #[test]
    fn matmul_small() {
        let a = t(&[2, 2], vec![1., 2., 3., 4.]);
        let b = t(&[2, 2], vec![1., 1., 1., 1.]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_shape_check() {
        let a = t(&[2, 3], vec![0.0; 6]);
        let b = t(&[2, 3], vec![0.0; 6]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let a = t(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(&[3, 4], (0..12).map(|i| i as f32).collect());
        let want = a.transpose2().unwrap().matmul(&b).unwrap();
        let got = a.matmul_tn(&b).unwrap();
        assert_eq!(want, got);
        assert!(a.matmul_tn(&t(&[2, 2], vec![0.0; 4])).is_err());
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(&[4, 3], (0..12).map(|i| i as f32).collect());
        let want = a.matmul(&b.transpose2().unwrap()).unwrap();
        let got = a.matmul_nt(&b).unwrap();
        assert_eq!(want, got);
        assert!(a.matmul_nt(&t(&[4, 2], vec![0.0; 8])).is_err());
    }

    #[test]
    fn mul_div_rows_roundtrip() {
        let a = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let s = [2.0, 4.0];
        let b = a.mul_rows(&s).unwrap().div_rows(&s).unwrap();
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let back = a.transpose2().unwrap().transpose2().unwrap();
        assert_eq!(a, back);
    }

    #[test]
    fn zip_shape_mismatch_errors() {
        let a = t(&[2, 2], vec![0.0; 4]);
        let b = t(&[4], vec![0.0; 4]);
        assert!(a.add(&b).is_err());
    }
}
