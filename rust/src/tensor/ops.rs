//! Elementwise and linear-algebra ops on [`Tensor`].
//!
//! Host-side only: used for scale math, small verification matmuls, and
//! test oracles. The model-scale matmuls all run inside HLO artifacts.

use super::Tensor;
use anyhow::{bail, Result};

impl Tensor {
    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise binary zip (shapes must match).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("zip shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, c: f32) -> Tensor {
        self.map(|x| x * c)
    }

    /// Multiply row i of a 2-D [n, m] tensor by s[i] (AWQ W * diag(s)).
    pub fn mul_rows(&self, s: &[f32]) -> Result<Tensor> {
        if self.shape.len() != 2 || self.shape[0] != s.len() {
            bail!("mul_rows: shape {:?} vs s len {}", self.shape, s.len());
        }
        let (n, m) = (self.shape[0], self.shape[1]);
        let mut data = self.data.clone();
        for i in 0..n {
            let si = s[i];
            for v in &mut data[i * m..(i + 1) * m] {
                *v *= si;
            }
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data,
        })
    }

    /// Divide row i by s[i] (inverse of `mul_rows`); s must be nonzero.
    pub fn div_rows(&self, s: &[f32]) -> Result<Tensor> {
        let inv: Vec<f32> = s.iter().map(|&x| 1.0 / x).collect();
        self.mul_rows(&inv)
    }

    /// Naive blocked matmul: self [r, k] @ other [k, c] -> [r, c].
    ///
    /// Loop order (i, l, j) keeps both inner accesses sequential; good
    /// enough for verification-scale products (the hot path is in HLO).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 || self.shape[1] != other.shape[0] {
            bail!("matmul {:?} @ {:?}", self.shape, other.shape);
        }
        let (r, k) = (self.shape[0], self.shape[1]);
        let c = other.shape[1];
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * c..(i + 1) * c];
            for (l, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[l * c..(l + 1) * c];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Tensor::from_vec(&[r, c], out)
    }

    /// Transpose a 2-D tensor.
    pub fn transpose2(&self) -> Result<Tensor> {
        if self.shape.len() != 2 {
            bail!("transpose2 on {:?}", self.shape);
        }
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut data = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                data[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::from_vec(&[c, r], data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], v: Vec<f32>) -> Tensor {
        Tensor::from_vec(shape, v).unwrap()
    }

    #[test]
    fn matmul_small() {
        let a = t(&[2, 2], vec![1., 2., 3., 4.]);
        let b = t(&[2, 2], vec![1., 1., 1., 1.]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_shape_check() {
        let a = t(&[2, 3], vec![0.0; 6]);
        let b = t(&[2, 3], vec![0.0; 6]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn mul_div_rows_roundtrip() {
        let a = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let s = [2.0, 4.0];
        let b = a.mul_rows(&s).unwrap().div_rows(&s).unwrap();
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let back = a.transpose2().unwrap().transpose2().unwrap();
        assert_eq!(a, back);
    }

    #[test]
    fn zip_shape_mismatch_errors() {
        let a = t(&[2, 2], vec![0.0; 4]);
        let b = t(&[4], vec![0.0; 4]);
        assert!(a.add(&b).is_err());
    }
}
