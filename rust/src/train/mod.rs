//! Training driver (S5): runs the AOT `train_step` artifact (fwd/bwd +
//! AdamW, lowered once by python) from rust for a few hundred steps to
//! produce checkpoints with *trained* weight/activation structure — the
//! heavy-tailed channel statistics AWQ/FAQ exploit do not exist at random
//! init (DESIGN.md §4).
//!
//! Checkpoints are cached under `runs/<config>/checkpoint.fqt` keyed by
//! step count, so the paper-table benches train each scale once.

use crate::config::ModelConfig;
use crate::corpus::{Batcher, CorpusKind, Generator, Tokenizer};
use crate::model::Params;
use crate::runtime::{lit_f32, lit_i32, lit_scalar, scalar_f32, tensor_f32, Runtime};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Loss-curve entry: (step, cross-entropy loss).
pub type LossCurve = Vec<(usize, f32)>;

/// Outcome of `ensure_checkpoint`.
pub struct TrainOutcome {
    pub params: Params,
    pub curve: LossCurve,
    /// True when a cached checkpoint was reused (curve empty).
    pub cached: bool,
}

/// Training token stream: generated fresh (seed 43, disjoint from the
/// tokenizer-fit sample) and encoded with the CANONICAL tokenizer — the
/// same vocabulary eval and calibration use. Fitting a separate
/// vocabulary on the training text would silently permute token ids
/// between train and eval.
pub fn fit_tokenizer(cfg: &ModelConfig, steps: usize) -> (Tokenizer, Vec<i32>) {
    let tok = crate::eval::canonical_tokenizer(cfg);
    let mut wiki = Generator::new(CorpusKind::SynthWiki, 43);
    let mut c4 = Generator::new(CorpusKind::SynthC4, 44);
    let batcher = Batcher::new(cfg.batch, cfg.seq);
    let need_tokens = (steps + 2) * batcher.train_tokens_per_batch() + 4096;
    // Pretraining-style mixture: ~3:1 wiki:c4, interleaved in sentence
    // chunks so every batch sees both domains.
    let mut text = String::new();
    let mut words = 0usize;
    while words < need_tokens * 2 {
        for _ in 0..3 {
            let s = wiki.sentence();
            words += s.split_whitespace().count();
            text.push_str(&s);
            text.push(' ');
        }
        let s = c4.sentence();
        words += s.split_whitespace().count();
        text.push_str(&s);
        text.push(' ');
    }
    let ids = tok.encode(&text);
    (tok, ids)
}

/// Train for `steps` steps; returns final params + loss curve.
pub fn train(
    rt: &Runtime,
    cfg: &ModelConfig,
    init: &Params,
    ids: &[i32],
    steps: usize,
    log_every: usize,
) -> Result<(Params, LossCurve)> {
    let batcher = Batcher::new(cfg.batch, cfg.seq);
    let batches = batcher.train_batches(ids)?;
    if batches.len() < steps {
        bail!(
            "corpus too small: {} train batches < {steps} steps",
            batches.len()
        );
    }
    let n = init.tensors.len();
    let mut params: Vec<Tensor> = init.tensors.clone();
    let mut ms: Vec<Tensor> = init.tensors.iter().map(|t| Tensor::zeros(t.shape())).collect();
    let mut vs: Vec<Tensor> = ms.clone();
    let mut step_ctr = 0.0f32;
    let mut curve = LossCurve::new();

    for (step, batch) in batches.iter().take(steps).enumerate() {
        let mut args = Vec::with_capacity(3 * n + 2);
        for t in params.iter().chain(ms.iter()).chain(vs.iter()) {
            args.push(lit_f32(t)?);
        }
        args.push(lit_scalar(step_ctr)?);
        args.push(lit_i32(batch)?);
        let outs = rt.exec(&cfg.name, "train_step", &args)?;
        if outs.len() != 3 * n + 2 {
            bail!("train_step returned {} outputs, want {}", outs.len(), 3 * n + 2);
        }
        for i in 0..n {
            params[i] = tensor_f32(&outs[i])?;
            ms[i] = tensor_f32(&outs[n + i])?;
            vs[i] = tensor_f32(&outs[2 * n + i])?;
        }
        step_ctr = scalar_f32(&outs[3 * n])?;
        let loss = scalar_f32(&outs[3 * n + 1])?;
        if !loss.is_finite() {
            bail!("training diverged at step {step}: loss={loss}");
        }
        if step % log_every == 0 || step + 1 == steps {
            curve.push((step, loss));
        }
    }

    Ok((
        Params {
            cfg: cfg.clone(),
            tensors: params,
        },
        curve,
    ))
}

/// Checkpoint path for (config, steps).
pub fn checkpoint_path(runs_dir: &str, cfg: &ModelConfig, steps: usize) -> PathBuf {
    Path::new(runs_dir)
        .join(&cfg.name)
        .join(format!("checkpoint_s{steps}.fqt"))
}

/// Load a cached checkpoint or train one (and cache it).
pub fn ensure_checkpoint(
    rt: &Runtime,
    cfg: &ModelConfig,
    runs_dir: &str,
    steps: usize,
    seed: u64,
) -> Result<TrainOutcome> {
    let path = checkpoint_path(runs_dir, cfg, steps);
    if path.exists() {
        let params = Params::load(cfg, &path)
            .with_context(|| format!("load cached checkpoint {}", path.display()))?;
        return Ok(TrainOutcome {
            params,
            curve: Vec::new(),
            cached: true,
        });
    }
    let init = Params::init(cfg, seed);
    if steps == 0 {
        init.save(&path)?;
        return Ok(TrainOutcome {
            params: init,
            curve: Vec::new(),
            cached: false,
        });
    }
    let (_tok, ids) = fit_tokenizer(cfg, steps);
    let (params, curve) = train(rt, cfg, &init, &ids, steps, (steps / 20).max(1))?;
    params.save(&path)?;
    Ok(TrainOutcome {
        params,
        curve,
        cached: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_budget_sufficient() {
        let cfg = ModelConfig::preset("pico").unwrap();
        let (tok, ids) = fit_tokenizer(&cfg, 10);
        assert!(tok.vocab_size() <= cfg.vocab);
        let batcher = Batcher::new(cfg.batch, cfg.seq);
        assert!(batcher.train_batches(&ids).unwrap().len() >= 10);
        // All ids must be < vocab (artifact gathers would OOB otherwise).
        assert!(ids.iter().all(|&i| (i as usize) < cfg.vocab));
    }

    #[test]
    fn checkpoint_path_layout() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let p = checkpoint_path("runs", &cfg, 200);
        assert_eq!(p, Path::new("runs/nano/checkpoint_s200.fqt"));
    }
}
