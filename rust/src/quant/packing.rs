//! Bit-packing for low-bit integer codes (S10).
//!
//! Edge-deployment storage: codes of width `bits` are packed contiguously
//! into a little-endian u32 bit-stream (codes may straddle word
//! boundaries; 3-bit packing wastes zero bits). Round-trip is exact for
//! any bits in [1, 8].

use anyhow::{bail, Result};

/// Pack `codes` (each < 2^bits) into a dense u32 bit-stream.
pub fn pack(codes: &[u8], bits: u32) -> Result<Vec<u32>> {
    if !(1..=8).contains(&bits) {
        bail!("bits={bits} out of range [1, 8]");
    }
    let limit = (1u32 << bits) as u16;
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u32; total_bits.div_ceil(32)];
    let mut bitpos = 0usize;
    for &c in codes {
        if (c as u16) >= limit {
            bail!("code {c} does not fit in {bits} bits");
        }
        let word = bitpos / 32;
        let off = (bitpos % 32) as u32;
        out[word] |= (c as u32) << off;
        let spill = off + bits;
        if spill > 32 {
            out[word + 1] |= (c as u32) >> (32 - off);
        }
        bitpos += bits as usize;
    }
    Ok(out)
}

/// Unpack `count` codes of width `bits` from a bit-stream.
pub fn unpack(words: &[u32], bits: u32, count: usize) -> Result<Vec<u8>> {
    if !(1..=8).contains(&bits) {
        bail!("bits={bits} out of range [1, 8]");
    }
    let need_bits = count * bits as usize;
    if words.len() * 32 < need_bits {
        bail!(
            "stream of {} words too short for {count} codes of {bits} bits",
            words.len()
        );
    }
    let mask = (1u32 << bits) - 1;
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    for _ in 0..count {
        let word = bitpos / 32;
        let off = (bitpos % 32) as u32;
        let mut v = words[word] >> off;
        let spill = off + bits;
        if spill > 32 {
            v |= words[word + 1] << (32 - off);
        }
        out.push((v & mask) as u8);
        bitpos += bits as usize;
    }
    Ok(out)
}

/// Packed size in bytes for `count` codes of width `bits`.
pub fn packed_bytes(count: usize, bits: u32) -> usize {
    (count * bits as usize).div_ceil(32) * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;
    use crate::testutil::{forall, Pair, UsizeIn};

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Rng::new(1);
        for bits in 1..=8u32 {
            let max = (1u16 << bits) as usize;
            let codes: Vec<u8> = (0..1000).map(|_| rng.below(max) as u8).collect();
            let packed = pack(&codes, bits).unwrap();
            let back = unpack(&packed, bits, codes.len()).unwrap();
            assert_eq!(codes, back, "bits={bits}");
        }
    }

    #[test]
    fn property_roundtrip_random_lengths() {
        forall(7, 60, &Pair(UsizeIn(0, 500), UsizeIn(1, 8)), |&(len, bits)| {
            let bits = bits as u32;
            let mut rng = Rng::new(len as u64 * 31 + bits as u64);
            let max = (1u16 << bits) as usize;
            let codes: Vec<u8> = (0..len).map(|_| rng.below(max.max(1)) as u8).collect();
            let packed = pack(&codes, bits).map_err(|e| e.to_string())?;
            let back = unpack(&packed, bits, len).map_err(|e| e.to_string())?;
            if back != codes {
                return Err("roundtrip mismatch".into());
            }
            if packed.len() * 4 != packed_bytes(len, bits) {
                return Err("size accounting mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn compression_ratio() {
        // 3-bit packing: 1024 codes -> 3072 bits -> 96 u32 words.
        assert_eq!(packed_bytes(1024, 3), 384);
        // vs 1024 bytes unpacked: 2.67x smaller.
        assert!(packed_bytes(1024, 3) * 8 < 1024 * 4);
    }

    #[test]
    fn oversized_code_rejected() {
        assert!(pack(&[8], 3).is_err());
        assert!(pack(&[7], 3).is_ok());
    }

    #[test]
    fn short_stream_rejected() {
        assert!(unpack(&[0u32], 8, 5).is_err());
    }

    #[test]
    fn straddling_word_boundary() {
        // 3-bit codes: code #10 starts at bit 30 and straddles words 0/1.
        let codes: Vec<u8> = (0..22).map(|i| (i % 8) as u8).collect();
        let packed = pack(&codes, 3).unwrap();
        assert_eq!(unpack(&packed, 3, 22).unwrap(), codes);
    }
}
