//! Host-side asymmetric group quantization — the bit-exact rust mirror of
//! `python/compile/kernels/ref.py::ref_fakequant` / `ref_quantize_ints`.
//!
//! Used for RTN (no scale search) and for materializing the final
//! quantized model after the scale search picks s. Parity with the Pallas
//! kernel is asserted by `rust/tests/integration.rs` against the
//! `layer_loss`/`fwd_logits` artifacts.

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Integer codes + dequant parameters of one quantized weight matrix.
#[derive(Clone, Debug)]
pub struct QuantInts {
    /// n_in (rows, input channels).
    pub n: usize,
    /// n_out (cols).
    pub m: usize,
    pub bits: u32,
    pub group: usize,
    /// Codes in [0, 2^bits - 1], row-major [n, m], one byte each
    /// (bit-packing for storage lives in `packing.rs`).
    pub q: Vec<u8>,
    /// Per-(group, col) step size [n/group, m].
    pub delta: Vec<f32>,
    /// Per-(group, col) zero point [n/group, m] (f32; can be ±1 for
    /// degenerate constant groups).
    pub zero: Vec<f32>,
}

impl QuantInts {
    /// Dequantize back to f32 (without any channel scale).
    pub fn dequant(&self) -> Tensor {
        let mut out = vec![0.0f32; self.n * self.m];
        let ng = self.n / self.group;
        for g in 0..ng {
            for r in 0..self.group {
                let row = g * self.group + r;
                for c in 0..self.m {
                    let d = self.delta[g * self.m + c];
                    let z = self.zero[g * self.m + c];
                    out[row * self.m + c] = (self.q[row * self.m + c] as f32 - z) * d;
                }
            }
        }
        Tensor::from_vec(&[self.n, self.m], out).expect("shape by construction")
    }

    /// Deployment-path byte footprint: packed codes + f32 dequant params.
    pub fn packed_bytes(&self) -> usize {
        let code_bits = self.n * self.m * self.bits as usize;
        code_bits.div_ceil(8) + (self.delta.len() + self.zero.len()) * 4
    }
}

/// Quantize `w` [n, m] to integer codes, groups of `group` rows per column.
pub fn quantize_ints(w: &Tensor, bits: u32, group: usize) -> Result<QuantInts> {
    let shape = w.shape();
    if shape.len() != 2 {
        bail!("quantize_ints wants 2-D weight, got {shape:?}");
    }
    let (n, m) = (shape[0], shape[1]);
    if n % group != 0 {
        bail!("n={n} not divisible by group={group}");
    }
    let qmax = ((1u32 << bits) - 1) as f32;
    let ng = n / group;
    let mut q = vec![0u8; n * m];
    let mut delta = vec![0.0f32; ng * m];
    let mut zero = vec![0.0f32; ng * m];
    let data = w.data();
    for g in 0..ng {
        for c in 0..m {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for r in 0..group {
                let v = data[(g * group + r) * m + c];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            // Degenerate guard — must match ref.py: delta = |lo| (or 1).
            let mut d = (hi - lo) / qmax;
            if d <= 0.0 {
                d = if lo.abs() > 0.0 { lo.abs() } else { 1.0 };
            }
            let z = (-lo / d).round();
            delta[g * m + c] = d;
            zero[g * m + c] = z;
            for r in 0..group {
                let row = g * group + r;
                let v = data[row * m + c];
                let code = ((v / d).round() + z).clamp(0.0, qmax);
                q[row * m + c] = code as u8;
            }
        }
    }
    Ok(QuantInts {
        n,
        m,
        bits,
        group,
        q,
        delta,
        zero,
    })
}

/// Fake-quantize: quantize + dequantize in one step (no channel scale).
pub fn fakequant(w: &Tensor, bits: u32, group: usize) -> Result<Tensor> {
    Ok(quantize_ints(w, bits, group)?.dequant())
}

/// AWQ/FAQ weight transform: `fakequant(W * diag(s)) / diag(s)`.
pub fn scaled_fakequant(w: &Tensor, s: &[f32], bits: u32, group: usize) -> Result<Tensor> {
    let ws = w.mul_rows(s)?;
    fakequant(&ws, bits, group)?.div_rows(s)
}

/// Scaled integer quantization for deployment: codes of `W * diag(s)`
/// plus the reciprocal channel scale to apply to activations.
pub fn scaled_quantize_ints(
    w: &Tensor,
    s: &[f32],
    bits: u32,
    group: usize,
) -> Result<(QuantInts, Vec<f32>)> {
    let ws = w.mul_rows(s)?;
    let ints = quantize_ints(&ws, bits, group)?;
    let inv_s: Vec<f32> = s.iter().map(|&x| 1.0 / x).collect();
    Ok((ints, inv_s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn codes_in_range() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&mut rng, &[64, 16], 2.0);
        for bits in [2u32, 3, 4, 8] {
            let ints = quantize_ints(&w, bits, 32).unwrap();
            let qmax = (1u32 << bits) - 1;
            assert!(ints.q.iter().all(|&c| (c as u32) <= qmax));
        }
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&mut rng, &[128, 32], 1.0);
        let errs: Vec<f32> = [2u32, 3, 4, 8]
            .iter()
            .map(|&b| fakequant(&w, b, 32).unwrap().mse(&w))
            .collect();
        for pair in errs.windows(2) {
            assert!(pair[0] > pair[1], "{errs:?}");
        }
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&mut rng, &[64, 8], 3.0);
        let once = fakequant(&w, 4, 32).unwrap();
        let twice = fakequant(&once, 4, 32).unwrap();
        for (a, b) in once.data().iter().zip(twice.data()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn constant_group_exact() {
        let w = Tensor::full(&[32, 4], 0.7);
        let fq = fakequant(&w, 3, 32).unwrap();
        for &v in fq.data() {
            assert!((v - 0.7).abs() < 1e-6, "{v}");
        }
        let z = Tensor::zeros(&[32, 4]);
        let fqz = fakequant(&z, 3, 32).unwrap();
        assert_eq!(fqz.sum(), 0.0);
    }

    #[test]
    fn scaled_fakequant_protects_high_scale_channels() {
        // Boosting a channel's scale shrinks its relative quantization
        // error — AWQ's core mechanism (paper Sec. 2.1).
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&mut rng, &[64, 32], 1.0);
        let mut s = vec![1.0f32; 64];
        let plain = scaled_fakequant(&w, &s, 3, 32).unwrap();
        s[5] = 4.0;
        let boosted = scaled_fakequant(&w, &s, 3, 32).unwrap();
        let row_err = |fq: &Tensor, r: usize| -> f32 {
            (0..32)
                .map(|c| (fq.at2(r, c) - w.at2(r, c)).powi(2))
                .sum::<f32>()
        };
        assert!(row_err(&boosted, 5) < row_err(&plain, 5));
    }

    #[test]
    fn packed_bytes_accounting() {
        let mut rng = Rng::new(5);
        let w = Tensor::randn(&mut rng, &[64, 64], 1.0);
        let i3 = quantize_ints(&w, 3, 32).unwrap();
        let i4 = quantize_ints(&w, 4, 32).unwrap();
        assert!(i3.packed_bytes() < i4.packed_bytes());
        // 64*64 codes at 4 bits = 2048 bytes + 2*2*64*2 params * 4B.
        assert_eq!(i4.packed_bytes(), 2048 + 2 * 2 * 64 * 4);
    }

    #[test]
    fn dequant_matches_fakequant() {
        let mut rng = Rng::new(6);
        let w = Tensor::randn(&mut rng, &[32, 16], 1.5);
        let fq = fakequant(&w, 4, 16).unwrap();
        let dq = quantize_ints(&w, 4, 16).unwrap().dequant();
        assert_eq!(fq, dq);
    }

    #[test]
    fn rejects_bad_shapes() {
        let w = Tensor::zeros(&[30, 4]);
        assert!(quantize_ints(&w, 4, 32).is_err());
        let w3 = Tensor::zeros(&[2, 2, 2]);
        assert!(quantize_ints(&w3, 4, 2).is_err());
    }
}
