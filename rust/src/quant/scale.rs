//! Channel-scale rules (paper Sec. 2.1 / 2.2).
//!
//! AWQ's base rule: s = a_bar^alpha over the per-channel mean activation
//! magnitude a_bar, normalized by sqrt(max(s) * min(s)) so the scale is
//! centred around 1 (matches the AWQ reference implementation; keeps the
//! folded weights in a sane dynamic range). FAQ changes only *which*
//! a_bar goes in: the fused current+preview statistics (calib::window).

/// Numerical floor for activation stats (dead channels).
pub const STAT_FLOOR: f32 = 1e-6;

/// s = normalize(stats ^ alpha). `stats` are per-channel mean |a|.
pub fn alpha_scale(stats: &[f32], alpha: f32) -> Vec<f32> {
    let mut s: Vec<f32> = stats
        .iter()
        .map(|&x| x.max(STAT_FLOOR).powf(alpha))
        .collect();
    // Normalize: s <- s / sqrt(max * min) keeps geometric centre at 1.
    let mx = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mn = s.iter().copied().fold(f32::INFINITY, f32::min);
    let denom = (mx * mn).sqrt();
    if denom.is_finite() && denom > 0.0 {
        for v in &mut s {
            *v /= denom;
        }
    }
    // Clamp away from zero: s multiplies weight rows and is inverted on
    // the activation side.
    for v in &mut s {
        *v = v.max(1e-4);
    }
    s
}

/// The alpha grid searched by AWQ/FAQ: `n` points over [0, 1].
/// alpha = 0 degenerates to RTN (s = 1 after normalization).
pub fn alpha_grid(n: usize) -> Vec<f32> {
    assert!(n >= 2);
    (0..n).map(|i| i as f32 / (n - 1) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_zero_is_identity_scale() {
        let s = alpha_scale(&[0.1, 2.0, 30.0], 0.0);
        for v in s {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn higher_alpha_spreads_scales() {
        let stats = [0.1f32, 1.0, 10.0];
        let s_lo = alpha_scale(&stats, 0.25);
        let s_hi = alpha_scale(&stats, 1.0);
        let spread = |s: &[f32]| s.iter().cloned().fold(f32::MIN, f32::max)
            / s.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread(&s_hi) > spread(&s_lo));
    }

    #[test]
    fn monotone_in_stats() {
        let s = alpha_scale(&[0.5, 1.0, 2.0, 4.0], 0.5);
        for pair in s.windows(2) {
            assert!(pair[0] < pair[1]);
        }
    }

    #[test]
    fn normalization_centres_at_one() {
        let s = alpha_scale(&[0.25, 1.0, 4.0], 1.0);
        let mx = s.iter().cloned().fold(f32::MIN, f32::max);
        let mn = s.iter().cloned().fold(f32::MAX, f32::min);
        assert!(((mx * mn).sqrt() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn dead_channels_floored() {
        let s = alpha_scale(&[0.0, 1.0], 1.0);
        assert!(s[0] > 0.0);
        assert!(s.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn grid_covers_unit_interval() {
        let g = alpha_grid(20);
        assert_eq!(g.len(), 20);
        assert_eq!(g[0], 0.0);
        assert_eq!(*g.last().unwrap(), 1.0);
        assert!(g.windows(2).all(|w| w[1] > w[0]));
    }
}
