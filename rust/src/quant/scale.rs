//! Channel-scale rules (paper Sec. 2.1 / 2.2).
//!
//! AWQ's base rule: s = a_bar^alpha over the per-channel mean activation
//! magnitude a_bar, normalized by sqrt(max(s) * min(s)) so the scale is
//! centred around 1 (matches the AWQ reference implementation; keeps the
//! folded weights in a sane dynamic range). FAQ changes only *which*
//! a_bar goes in: the fused current+preview statistics (calib::window).

/// Numerical floor for activation stats (dead channels).
pub const STAT_FLOOR: f32 = 1e-6;

/// s = normalize(stats ^ alpha). `stats` are per-channel mean |a|.
///
/// Computed entirely in log space: log s_i = alpha * ln(max(stat, floor))
/// centred by (max + min)/2 of the logs, then exponentiated. This is
/// algebraically s / sqrt(max(s) * min(s)) but never forms the product
/// max * min (which overflows f32 for high-dynamic-range stats) and never
/// needs a post-normalization clamp (exp is strictly positive), so the
/// geometric-centre invariant sqrt(max(s) * min(s)) = 1 and strict
/// monotonicity in the stats hold for ANY finite input.
pub fn alpha_scale(stats: &[f32], alpha: f32) -> Vec<f32> {
    let logs: Vec<f32> = stats
        .iter()
        .map(|&x| alpha * x.max(STAT_FLOOR).ln())
        .collect();
    let mx = logs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mn = logs.iter().copied().fold(f32::INFINITY, f32::min);
    let centre = if mx.is_finite() && mn.is_finite() {
        0.5 * (mx + mn)
    } else {
        0.0
    };
    logs.iter().map(|&l| (l - centre).exp()).collect()
}

/// The alpha grid searched by AWQ/FAQ: `n` points over [0, 1].
/// alpha = 0 degenerates to RTN (s = 1 after normalization).
pub fn alpha_grid(n: usize) -> Vec<f32> {
    assert!(n >= 2);
    (0..n).map(|i| i as f32 / (n - 1) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_zero_is_identity_scale() {
        let s = alpha_scale(&[0.1, 2.0, 30.0], 0.0);
        for v in s {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn higher_alpha_spreads_scales() {
        let stats = [0.1f32, 1.0, 10.0];
        let s_lo = alpha_scale(&stats, 0.25);
        let s_hi = alpha_scale(&stats, 1.0);
        let spread = |s: &[f32]| s.iter().cloned().fold(f32::MIN, f32::max)
            / s.iter().cloned().fold(f32::MAX, f32::min);
        assert!(spread(&s_hi) > spread(&s_lo));
    }

    #[test]
    fn monotone_in_stats() {
        let s = alpha_scale(&[0.5, 1.0, 2.0, 4.0], 0.5);
        for pair in s.windows(2) {
            assert!(pair[0] < pair[1]);
        }
    }

    #[test]
    fn normalization_centres_at_one() {
        let s = alpha_scale(&[0.25, 1.0, 4.0], 1.0);
        let mx = s.iter().cloned().fold(f32::MIN, f32::max);
        let mn = s.iter().cloned().fold(f32::MAX, f32::min);
        assert!(((mx * mn).sqrt() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn dead_channels_floored() {
        let s = alpha_scale(&[0.0, 1.0], 1.0);
        assert!(s[0] > 0.0);
        assert!(s.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn high_dynamic_range_keeps_geometric_centre() {
        // Regression: max(s) * min(s) used to overflow f32 here, skipping
        // normalization and then clamping — breaking both invariants.
        let s = alpha_scale(&[1e-25, 1e25], 1.0);
        assert!(s.iter().all(|v| v.is_finite() && *v > 0.0));
        let centre = s[0].ln() + s[1].ln();
        assert!(centre.abs() < 1e-3, "log-centre {centre}");
        assert!(s[0] < s[1]);
    }

    #[test]
    fn prop_extreme_stats_keep_invariants() {
        use crate::tensor::Rng;
        use crate::testutil::{forall, UsizeIn};
        forall(29, 60, &UsizeIn(2, 12), |&n| {
            let mut rng = Rng::new(n as u64 * 131 + 7);
            // Log-uniform magnitudes spanning 1e-30 .. 1e30.
            let mut stats: Vec<f32> =
                (0..n).map(|_| 10f32.powf(rng.range_f32(-30.0, 30.0))).collect();
            stats.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for &alpha in &[0.0f32, 0.3, 1.0] {
                let s = alpha_scale(&stats, alpha);
                if s.iter().any(|v| !v.is_finite() || *v <= 0.0) {
                    return Err(format!("alpha={alpha}: non-finite/non-positive {s:?}"));
                }
                let mx = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let mn = s.iter().copied().fold(f32::INFINITY, f32::min);
                let centre = mx.ln() + mn.ln();
                if centre.abs() > 1e-3 {
                    return Err(format!("alpha={alpha}: log-centre {centre}"));
                }
                // Monotone (non-strict: sub-floor stats collapse equal).
                for w in s.windows(2) {
                    if w[1] < w[0] * (1.0 - 1e-5) {
                        return Err(format!("alpha={alpha}: not monotone {s:?}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn grid_covers_unit_interval() {
        let g = alpha_grid(20);
        assert_eq!(g.len(), 20);
        assert_eq!(g[0], 0.0);
        assert_eq!(*g.last().unwrap(), 1.0);
        assert!(g.windows(2).all(|w| w[1] > w[0]));
    }
}
