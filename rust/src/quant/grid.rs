//! Alpha grid search (paper eq. 3/8) — the calibration hot path.
//!
//! For each candidate alpha, build s = normalize(stats^alpha), then
//! evaluate the layer reconstruction loss ‖a·W − a·Q(W,s)‖² with the
//! `layer_loss_<role>_b<bits>` HLO artifact (Pallas `scaled_fakequant` +
//! two matmuls, fused by XLA). The activation sample `a` and weight `W`
//! are uploaded once per search; only the scale vector changes per step.

use crate::quant::scale::{alpha_grid, alpha_scale};
use crate::runtime::{scalar_f32, Runtime};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

/// Grid size baked into the `layer_loss_sweep_*` artifacts (model.N_ALPHA).
pub const SWEEP_N_ALPHA: usize = 20;

/// Result of one scale search.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub alpha: f32,
    pub loss: f32,
    pub scale: Vec<f32>,
    /// Losses over the whole grid (ablation/telemetry).
    pub grid_losses: Vec<(f32, f32)>,
}

/// Search alpha over the grid, minimizing the recon loss of (acts, w).
#[allow(clippy::too_many_arguments)]
pub fn search_alpha(
    rt: &Runtime,
    cfg_name: &str,
    role: &str,
    bits: u32,
    acts: &Tensor,
    w: &Tensor,
    stats: &[f32],
    n_grid: usize,
) -> Result<SearchResult> {
    let entry = format!("layer_loss_{role}_b{bits}");
    if stats.len() != w.shape()[0] {
        bail!(
            "stats len {} != weight n_in {}",
            stats.len(),
            w.shape()[0]
        );
    }
    // §Perf: the activation sample and weight are uploaded to the device
    // once per search; only the scale candidates change.
    let a_buf = rt.upload_f32(acts)?;
    let w_buf = rt.upload_f32(w)?;
    let alphas = alpha_grid(n_grid);
    let scales: Vec<Vec<f32>> = alphas.iter().map(|&a| alpha_scale(stats, a)).collect();

    // §Perf iteration 2: when the grid size matches the baked sweep
    // artifact, evaluate ALL candidates in one execution (20x fewer
    // dispatches); otherwise fall back to the per-alpha loop.
    let sweep_entry = format!("layer_loss_sweep_{role}_b{bits}");
    let losses: Vec<f32> = if rt.manifest.artifact(cfg_name, &sweep_entry).is_ok()
        && n_grid == SWEEP_N_ALPHA
    {
        let n = stats.len();
        let mut flat = Vec::with_capacity(n_grid * n);
        for s in &scales {
            flat.extend_from_slice(s);
        }
        let s_t = Tensor::from_vec(&[n_grid, n], flat)?;
        let outs = rt.exec_b(cfg_name, &sweep_entry, &[&a_buf, &w_buf, &rt.upload_f32(&s_t)?])?;
        crate::runtime::tensor_f32(&outs[0])?.into_vec()
    } else {
        let mut v = Vec::with_capacity(n_grid);
        for s in &scales {
            let s_t = Tensor::from_vec(&[s.len()], s.clone())?;
            let outs = rt.exec_b(cfg_name, &entry, &[&a_buf, &w_buf, &rt.upload_f32(&s_t)?])?;
            v.push(scalar_f32(&outs[0])?);
        }
        v
    };

    let best_i = best_finite_index(&losses)
        .with_context(|| format!("search_alpha({entry}) found no finite loss"))?;
    let grid_losses: Vec<(f32, f32)> = alphas.iter().copied().zip(losses.iter().copied()).collect();
    Ok(SearchResult {
        alpha: alphas[best_i],
        loss: losses[best_i],
        scale: scales[best_i].clone(),
        grid_losses,
    })
}

/// Index of the smallest *finite* loss. Non-finite losses (NaN from a
/// degenerate scale, inf from overflow) are skipped instead of silently
/// winning every `<` comparison; errors when no loss is finite so a
/// NaN-loss alpha can never be returned as a search result.
pub fn best_finite_index(losses: &[f32]) -> Result<usize> {
    let mut best: Option<usize> = None;
    for (i, &l) in losses.iter().enumerate() {
        if !l.is_finite() {
            continue;
        }
        let better = match best {
            None => true,
            Some(b) => l < losses[b],
        };
        if better {
            best = Some(i);
        }
    }
    best.with_context(|| format!("all {} grid losses are non-finite", losses.len()))
}

/// Evaluate the recon loss for one explicit scale vector (FAQ full search
/// re-uses this for its (alpha, j, gamma) triples).
pub fn eval_scale(
    rt: &Runtime,
    cfg_name: &str,
    role: &str,
    bits: u32,
    acts: &Tensor,
    w: &Tensor,
    scale: &[f32],
) -> Result<f32> {
    let entry = format!("layer_loss_{role}_b{bits}");
    let s_t = Tensor::from_vec(&[scale.len()], scale.to_vec())?;
    let outs = rt.exec_b(
        cfg_name,
        &entry,
        &[&rt.upload_f32(acts)?, &rt.upload_f32(w)?, &rt.upload_f32(&s_t)?],
    )?;
    scalar_f32(&outs[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_finite_index_skips_nan_and_inf() {
        // The original bug: losses[0] = NaN makes `l < losses[best_i]`
        // false for every candidate, silently returning index 0.
        assert_eq!(best_finite_index(&[f32::NAN, 2.0, 1.0]).unwrap(), 2);
        assert_eq!(
            best_finite_index(&[f32::INFINITY, 5.0, f32::NAN, 3.0]).unwrap(),
            3
        );
        assert_eq!(best_finite_index(&[4.0, 2.0, 8.0]).unwrap(), 1);
    }

    #[test]
    fn best_finite_index_errors_when_all_nonfinite() {
        let err = best_finite_index(&[f32::NAN, f32::INFINITY]).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        assert!(best_finite_index(&[]).is_err());
    }

    #[test]
    fn search_alpha_on_native_backend_prefers_finite_minimum() {
        // End-to-end through the native runtime: the search must return a
        // finite loss and an alpha from the grid.
        let rt = Runtime::native();
        let mut rng = crate::tensor::Rng::new(9);
        let n = 64;
        let acts = Tensor::randn(&mut rng, &[32, n], 1.0);
        let w = Tensor::randn(&mut rng, &[n, 16], 0.5);
        let stats: Vec<f32> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        let sr = search_alpha(&rt, "pico", "qkv", 3, &acts, &w, &stats, 5).unwrap();
        assert!(sr.loss.is_finite());
        assert!((0.0..=1.0).contains(&sr.alpha));
        assert_eq!(sr.grid_losses.len(), 5);
    }
}
