//! Alpha grid search (paper eq. 3/8) — the calibration hot path.
//!
//! For each candidate alpha, build s = normalize(stats^alpha), then
//! evaluate the layer reconstruction loss ‖a·W − a·Q(W,s)‖² with the
//! `layer_loss_<role>_b<bits>` HLO artifact (Pallas `scaled_fakequant` +
//! two matmuls, fused by XLA). The activation sample `a` and weight `W`
//! are uploaded once per search; only the scale vector changes per step.

use crate::quant::scale::{alpha_grid, alpha_scale};
use crate::runtime::{scalar_f32, Buffer, Runtime};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

/// Grid size baked into the `layer_loss_sweep_*` artifacts (model.N_ALPHA).
pub const SWEEP_N_ALPHA: usize = 20;

/// Result of one scale search.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub alpha: f32,
    pub loss: f32,
    pub scale: Vec<f32>,
    /// Losses over the whole grid (ablation/telemetry).
    pub grid_losses: Vec<(f32, f32)>,
}

/// One linear's layer-loss evaluation session (§Perf upload-once
/// convention): the activation sample and weight are uploaded exactly
/// once at construction and reused by every subsequent loss evaluation —
/// the whole alpha grid, every (alpha, j, gamma) triple of the FAQ full
/// search, and the RTN loss probe.
pub struct LossSession<'rt> {
    rt: &'rt Runtime,
    cfg_name: String,
    entry: String,
    sweep_entry: String,
    n_in: usize,
    a_buf: Buffer,
    w_buf: Buffer,
}

impl<'rt> LossSession<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        cfg_name: &str,
        role: &str,
        bits: u32,
        acts: &Tensor,
        w: &Tensor,
    ) -> Result<Self> {
        if w.shape().len() != 2 {
            bail!("LossSession wants a 2-D weight, got {:?}", w.shape());
        }
        Ok(Self {
            rt,
            cfg_name: cfg_name.to_string(),
            entry: format!("layer_loss_{role}_b{bits}"),
            sweep_entry: format!("layer_loss_sweep_{role}_b{bits}"),
            n_in: w.shape()[0],
            a_buf: rt.upload_f32(acts)?,
            w_buf: rt.upload_f32(w)?,
        })
    }

    /// Recon loss for one explicit scale vector, reusing the uploaded
    /// acts/weight buffers (the buffer-reusing variant of [`eval_scale`]).
    pub fn eval(&self, scale: &[f32]) -> Result<f32> {
        if scale.len() != self.n_in {
            bail!("scale len {} != weight n_in {}", scale.len(), self.n_in);
        }
        let s_t = Tensor::from_vec(&[scale.len()], scale.to_vec())?;
        let outs = self.rt.exec_b(
            &self.cfg_name,
            &self.entry,
            &[&self.a_buf, &self.w_buf, &self.rt.upload_f32(&s_t)?],
        )?;
        scalar_f32(&outs[0])
    }

    /// Search alpha over the grid, minimizing the recon loss.
    pub fn search(&self, stats: &[f32], n_grid: usize) -> Result<SearchResult> {
        if stats.len() != self.n_in {
            bail!("stats len {} != weight n_in {}", stats.len(), self.n_in);
        }
        let alphas = alpha_grid(n_grid);
        let scales: Vec<Vec<f32>> = alphas.iter().map(|&a| alpha_scale(stats, a)).collect();

        // §Perf: when the grid size matches the baked sweep artifact,
        // evaluate ALL candidates in one execution (20x fewer
        // dispatches); otherwise fall back to the per-alpha loop.
        let losses: Vec<f32> = if self
            .rt
            .manifest
            .artifact(&self.cfg_name, &self.sweep_entry)
            .is_ok()
            && n_grid == SWEEP_N_ALPHA
        {
            let n = stats.len();
            let mut flat = Vec::with_capacity(n_grid * n);
            for s in &scales {
                flat.extend_from_slice(s);
            }
            let s_t = Tensor::from_vec(&[n_grid, n], flat)?;
            let outs = self.rt.exec_b(
                &self.cfg_name,
                &self.sweep_entry,
                &[&self.a_buf, &self.w_buf, &self.rt.upload_f32(&s_t)?],
            )?;
            crate::runtime::tensor_f32(&outs[0])?.into_vec()
        } else {
            let mut v = Vec::with_capacity(n_grid);
            for s in &scales {
                v.push(self.eval(s)?);
            }
            v
        };

        let best_i = best_finite_index(&losses)
            .with_context(|| format!("search_alpha({}) found no finite loss", self.entry))?;
        let grid_losses: Vec<(f32, f32)> =
            alphas.iter().copied().zip(losses.iter().copied()).collect();
        Ok(SearchResult {
            alpha: alphas[best_i],
            loss: losses[best_i],
            scale: scales[best_i].clone(),
            grid_losses,
        })
    }
}

/// Search alpha over the grid, minimizing the recon loss of (acts, w).
/// One-shot wrapper over [`LossSession`] (uploads acts/w once per call;
/// callers evaluating many configurations per linear should hold a
/// session instead).
#[allow(clippy::too_many_arguments)]
pub fn search_alpha(
    rt: &Runtime,
    cfg_name: &str,
    role: &str,
    bits: u32,
    acts: &Tensor,
    w: &Tensor,
    stats: &[f32],
    n_grid: usize,
) -> Result<SearchResult> {
    LossSession::new(rt, cfg_name, role, bits, acts, w)?.search(stats, n_grid)
}

/// Index of the smallest *finite* loss. Non-finite losses (NaN from a
/// degenerate scale, inf from overflow) are skipped instead of silently
/// winning every `<` comparison; errors when no loss is finite so a
/// NaN-loss alpha can never be returned as a search result.
pub fn best_finite_index(losses: &[f32]) -> Result<usize> {
    let mut best: Option<usize> = None;
    for (i, &l) in losses.iter().enumerate() {
        if !l.is_finite() {
            continue;
        }
        let better = match best {
            None => true,
            Some(b) => l < losses[b],
        };
        if better {
            best = Some(i);
        }
    }
    best.with_context(|| format!("all {} grid losses are non-finite", losses.len()))
}

/// Evaluate the recon loss for one explicit scale vector. One-shot
/// wrapper over [`LossSession`]: uploads acts/w per call, so repeated
/// evaluations on the same linear should use a session (§Perf).
#[allow(clippy::too_many_arguments)]
pub fn eval_scale(
    rt: &Runtime,
    cfg_name: &str,
    role: &str,
    bits: u32,
    acts: &Tensor,
    w: &Tensor,
    scale: &[f32],
) -> Result<f32> {
    LossSession::new(rt, cfg_name, role, bits, acts, w)?.eval(scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_finite_index_skips_nan_and_inf() {
        // The original bug: losses[0] = NaN makes `l < losses[best_i]`
        // false for every candidate, silently returning index 0.
        assert_eq!(best_finite_index(&[f32::NAN, 2.0, 1.0]).unwrap(), 2);
        assert_eq!(
            best_finite_index(&[f32::INFINITY, 5.0, f32::NAN, 3.0]).unwrap(),
            3
        );
        assert_eq!(best_finite_index(&[4.0, 2.0, 8.0]).unwrap(), 1);
    }

    #[test]
    fn best_finite_index_errors_when_all_nonfinite() {
        let err = best_finite_index(&[f32::NAN, f32::INFINITY]).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        assert!(best_finite_index(&[]).is_err());
    }

    #[test]
    fn loss_session_reuses_buffers_and_matches_one_shots() {
        let rt = Runtime::native();
        let mut rng = crate::tensor::Rng::new(21);
        let n = 64;
        let acts = Tensor::randn(&mut rng, &[32, n], 1.0);
        let w = Tensor::randn(&mut rng, &[n, 16], 0.5);
        let stats: Vec<f32> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        let scale = alpha_scale(&stats, 0.5);

        let session = LossSession::new(&rt, "pico", "qkv", 3, &acts, &w).unwrap();
        // Buffer-reusing eval == the upload-per-call wrapper, bitwise.
        let a = session.eval(&scale).unwrap();
        let b = eval_scale(&rt, "pico", "qkv", 3, &acts, &w, &scale).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
        // Session search == the one-shot wrapper, and repeated searches
        // on one session agree (the buffers are not consumed).
        let s1 = session.search(&stats, 5).unwrap();
        let s2 = search_alpha(&rt, "pico", "qkv", 3, &acts, &w, &stats, 5).unwrap();
        assert_eq!(s1.loss.to_bits(), s2.loss.to_bits());
        assert_eq!(s1.alpha, s2.alpha);
        let s3 = session.search(&stats, 5).unwrap();
        assert_eq!(s1.loss.to_bits(), s3.loss.to_bits());
        // Mis-sized inputs are rejected.
        assert!(session.eval(&scale[..n - 1]).is_err());
        assert!(session.search(&stats[..n - 1], 5).is_err());
    }

    #[test]
    fn search_alpha_on_native_backend_prefers_finite_minimum() {
        // End-to-end through the native runtime: the search must return a
        // finite loss and an alpha from the grid.
        let rt = Runtime::native();
        let mut rng = crate::tensor::Rng::new(9);
        let n = 64;
        let acts = Tensor::randn(&mut rng, &[32, n], 1.0);
        let w = Tensor::randn(&mut rng, &[n, 16], 0.5);
        let stats: Vec<f32> = (0..n).map(|_| rng.uniform() + 0.1).collect();
        let sr = search_alpha(&rt, "pico", "qkv", 3, &acts, &w, &stats, 5).unwrap();
        assert!(sr.loss.is_finite());
        assert!((0.0..=1.0).contains(&sr.alpha));
        assert_eq!(sr.grid_losses.len(), 5);
    }
}
