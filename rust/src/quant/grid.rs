//! Alpha grid search (paper eq. 3/8) — the calibration hot path.
//!
//! For each candidate alpha, build s = normalize(stats^alpha), then
//! evaluate the layer reconstruction loss ‖a·W − a·Q(W,s)‖² with the
//! `layer_loss_<role>_b<bits>` HLO artifact (Pallas `scaled_fakequant` +
//! two matmuls, fused by XLA). The activation sample `a` and weight `W`
//! are uploaded once per search; only the scale vector changes per step.

use crate::quant::scale::{alpha_grid, alpha_scale};
use crate::runtime::{scalar_f32, Runtime};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Grid size baked into the `layer_loss_sweep_*` artifacts (model.N_ALPHA).
pub const SWEEP_N_ALPHA: usize = 20;

/// Result of one scale search.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub alpha: f32,
    pub loss: f32,
    pub scale: Vec<f32>,
    /// Losses over the whole grid (ablation/telemetry).
    pub grid_losses: Vec<(f32, f32)>,
}

/// Search alpha over the grid, minimizing the recon loss of (acts, w).
pub fn search_alpha(
    rt: &Runtime,
    cfg_name: &str,
    role: &str,
    bits: u32,
    acts: &Tensor,
    w: &Tensor,
    stats: &[f32],
    n_grid: usize,
) -> Result<SearchResult> {
    let entry = format!("layer_loss_{role}_b{bits}");
    if stats.len() != w.shape()[0] {
        bail!(
            "stats len {} != weight n_in {}",
            stats.len(),
            w.shape()[0]
        );
    }
    // §Perf: the activation sample and weight are uploaded to the device
    // once per search; only the scale candidates change.
    let a_buf = rt.upload_f32(acts)?;
    let w_buf = rt.upload_f32(w)?;
    let alphas = alpha_grid(n_grid);
    let scales: Vec<Vec<f32>> = alphas.iter().map(|&a| alpha_scale(stats, a)).collect();

    // §Perf iteration 2: when the grid size matches the baked sweep
    // artifact, evaluate ALL candidates in one execution (20x fewer
    // dispatches); otherwise fall back to the per-alpha loop.
    let sweep_entry = format!("layer_loss_sweep_{role}_b{bits}");
    let losses: Vec<f32> = if rt.manifest.artifact(cfg_name, &sweep_entry).is_ok()
        && n_grid == SWEEP_N_ALPHA
    {
        let n = stats.len();
        let mut flat = Vec::with_capacity(n_grid * n);
        for s in &scales {
            flat.extend_from_slice(s);
        }
        let s_t = Tensor::from_vec(&[n_grid, n], flat)?;
        let outs = rt.exec_b(cfg_name, &sweep_entry, &[&a_buf, &w_buf, &rt.upload_f32(&s_t)?])?;
        crate::runtime::tensor_f32(&outs[0])?.into_vec()
    } else {
        let mut v = Vec::with_capacity(n_grid);
        for s in &scales {
            let s_t = Tensor::from_vec(&[s.len()], s.clone())?;
            let outs = rt.exec_b(cfg_name, &entry, &[&a_buf, &w_buf, &rt.upload_f32(&s_t)?])?;
            v.push(scalar_f32(&outs[0])?);
        }
        v
    };

    let mut best_i = 0;
    for (i, &l) in losses.iter().enumerate() {
        if l < losses[best_i] {
            best_i = i;
        }
    }
    let grid_losses: Vec<(f32, f32)> = alphas.iter().copied().zip(losses.iter().copied()).collect();
    Ok(SearchResult {
        alpha: alphas[best_i],
        loss: losses[best_i],
        scale: scales[best_i].clone(),
        grid_losses,
    })
}

/// Evaluate the recon loss for one explicit scale vector (FAQ full search
/// re-uses this for its (alpha, j, gamma) triples).
pub fn eval_scale(
    rt: &Runtime,
    cfg_name: &str,
    role: &str,
    bits: u32,
    acts: &Tensor,
    w: &Tensor,
    scale: &[f32],
) -> Result<f32> {
    let entry = format!("layer_loss_{role}_b{bits}");
    let s_t = Tensor::from_vec(&[scale.len()], scale.to_vec())?;
    let outs = rt.exec_b(
        cfg_name,
        &entry,
        &[&rt.upload_f32(acts)?, &rt.upload_f32(w)?, &rt.upload_f32(&s_t)?],
    )?;
    scalar_f32(&outs[0])
}
