//! Quantizers (S8): RTN and AWQ baselines + FAQ, the paper's method.
//!
//! All three share the same mechanics — asymmetric group quantization of
//! each block linear under a per-input-channel scale s — and differ only
//! in *which activation statistics* drive s (paper Sec. 2.2):
//!
//! - RTN:  s = 1 (no activation awareness, no search)
//! - AWQ:  s = normalize(ā_i^α), ā_i = current layer's mean |a|
//! - FAQ:  s = normalize(ã_i^α), ã_i = γ·ā_i + (1−γ)·mean(ā_{i+1..i+j})
//!
//! α is grid-searched per linear against the layer reconstruction loss
//! (executed as an HLO artifact — grid.rs). FAQ defaults to the paper's
//! pre-searched configuration (γ = 0.85, window = 3) and optionally runs
//! the full (α, j, γ) greedy search of eq. 8.

mod fakequant;
mod grid;
pub mod packing;
mod scale;

pub use fakequant::{fakequant, quantize_ints, scaled_fakequant, scaled_quantize_ints, QuantInts};
pub use grid::{eval_scale, search_alpha, LossSession, SearchResult};
pub use scale::{alpha_grid, alpha_scale, STAT_FLOOR};

use crate::calib::{faq_stats, CalibStats};
use crate::config::{Method, ModelConfig, QuantConfig};
use crate::model::{role_param, Params, ROLES};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

/// One quantized block linear: search outcome + deployment tensors.
#[derive(Clone, Debug)]
pub struct LinearQuant {
    pub block: usize,
    pub role: &'static str,
    /// Chosen scale exponent (0 for RTN).
    pub alpha: f32,
    /// Reconstruction loss at the chosen configuration.
    pub loss: f32,
    /// Effective preview window used (0 = no preview / RTN / AWQ).
    pub window_used: usize,
    /// Effective fusion factor (1.0 when no preview).
    pub gamma_used: f32,
    /// Per-input-channel scale s.
    pub scale: Vec<f32>,
    /// Integer codes + dequant params of W·diag(s).
    pub ints: QuantInts,
    /// Reciprocal channel scale folded into activations at runtime.
    pub inv_s: Vec<f32>,
    /// Bit-packed codes (edge storage format).
    pub packed: Vec<u32>,
}

/// A fully quantized model: fake-quant params for the eval path plus the
/// integer deployment bundle per linear.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub cfg: ModelConfig,
    pub qcfg: QuantConfig,
    /// Full parameter set with every block linear replaced by its
    /// fake-quantized version (drives the `fwd_logits` eval path).
    pub fq_params: Params,
    pub linears: Vec<LinearQuant>,
}

impl QuantizedModel {
    /// Total packed weight bytes (codes + dequant params) vs FP32 bytes —
    /// the compression headline.
    // faq-lint: allow(unordered-reduction) — integer byte counts; the
    // lexer cannot prove the element type, and usize sums are
    // order-independent.
    pub fn compression(&self) -> (usize, usize) {
        let packed: usize = self
            .linears
            .iter()
            .map(|l| l.ints.packed_bytes() + l.inv_s.len() * 4)
            .sum();
        let fp: usize = self
            .linears
            .iter()
            .map(|l| l.ints.n * l.ints.m * 4)
            .sum();
        (packed, fp)
    }

    pub fn linear(&self, block: usize, role: &str) -> Option<&LinearQuant> {
        self.linears
            .iter()
            .find(|l| l.block == block && l.role == role)
    }

    /// Mean reconstruction loss across linears (summary metric).
    // faq-lint: allow(unordered-reduction) — accumulates in `linears`
    // Vec order (block-major, fixed at quantization time).
    pub fn mean_loss(&self) -> f32 {
        if self.linears.is_empty() {
            return 0.0;
        }
        self.linears.iter().map(|l| l.loss).sum::<f32>() / self.linears.len() as f32
    }
}

/// FAQ full-search grids (paper eq. 8). Kept small: the paper itself
/// recommends the pre-searched configuration to avoid this cost.
const FULL_SEARCH_GAMMAS: [f32; 5] = [0.6, 0.7, 0.8, 0.85, 0.95];

/// Quantize every block linear of `params` with the configured method.
///
/// `calib` is required for AWQ/FAQ (activation statistics + loss sample)
/// and unused by RTN. `Method::Fp` is rejected — there is nothing to do.
pub fn quantize_model(
    rt: &Runtime,
    qcfg: &QuantConfig,
    params: &Params,
    calib: Option<&CalibStats>,
) -> Result<QuantizedModel> {
    qcfg.validate()?;
    let cfg = params.cfg.clone();
    if qcfg.method == Method::Fp {
        bail!("quantize_model called with Method::Fp");
    }
    if matches!(qcfg.method, Method::Awq | Method::Faq) && calib.is_none() {
        bail!("{} requires calibration statistics", qcfg.method.name());
    }
    let group = rt.manifest.group;
    if group != qcfg.group {
        bail!(
            "artifact group={group} but quant config group={} — rebuild artifacts",
            qcfg.group
        );
    }

    // Phase B (DESIGN §2): with capture statistics in hand, every
    // linear's search is independent — fan the (block, role) grid out on
    // the thread pool. Results land in a fixed (block-major, ROLES-order)
    // vector, so the output is deterministic for any thread count.
    let n_linears = cfg.n_layer * ROLES.len();
    let jobs = crate::tensor::par::par_map(n_linears, |li| -> Result<(LinearQuant, Tensor)> {
        let block = li / ROLES.len();
        let ri = li % ROLES.len();
        let role = ROLES[ri];
        let w = params.role_weight(block, role)?;
        let lq = match qcfg.method {
            Method::Fp => unreachable!(),
            Method::Rtn => {
                let n = w.shape()[0];
                let ones = vec![1.0f32; n];
                let loss = match calib {
                    Some(c) => LossSession::new(
                        rt,
                        &cfg.name,
                        role,
                        qcfg.bits,
                        c.acts_for(block, ri),
                        w,
                    )?
                    .eval(&ones)?,
                    None => f32::NAN,
                };
                build_linear(block, role, 0.0, loss, 0, 1.0, ones, w, qcfg, group)?
            }
            Method::Awq => {
                let c = calib.unwrap();
                let stats = c.stats_for(block, ri);
                let sr = search_alpha(
                    rt,
                    &cfg.name,
                    role,
                    qcfg.bits,
                    c.acts_for(block, ri),
                    w,
                    stats,
                    qcfg.alpha_grid,
                )?;
                build_linear(block, role, sr.alpha, sr.loss, 0, 1.0, sr.scale, w, qcfg, group)?
            }
            Method::Faq => {
                let c = calib.unwrap();
                quantize_faq_linear(rt, &cfg, qcfg, c, block, ri, role, w, group)?
            }
        };
        let fq = scaled_fakequant(w, &lq.scale, qcfg.bits, group)?;
        Ok((lq, fq))
    });

    let mut fq_params = params.clone();
    let mut linears = Vec::with_capacity(n_linears);
    for job in jobs {
        let (lq, fq) = job?;
        fq_params.set(&role_param(lq.block, lq.role), fq)?;
        linears.push(lq);
    }

    Ok(QuantizedModel {
        cfg,
        qcfg: qcfg.clone(),
        fq_params,
        linears,
    })
}

/// FAQ per-linear quantization: pre-searched (γ, j) + α grid by default,
/// full greedy (α, j, γ) search when configured (paper eq. 8).
#[allow(clippy::too_many_arguments)]
fn quantize_faq_linear(
    rt: &Runtime,
    cfg: &ModelConfig,
    qcfg: &QuantConfig,
    c: &CalibStats,
    block: usize,
    ri: usize,
    role: &'static str,
    w: &crate::tensor::Tensor,
    group: usize,
) -> Result<LinearQuant> {
    let per_layer = c.role_stats_per_layer(ri);
    let acts = c.acts_for(block, ri);
    // §Perf: one upload of (acts, w) shared by every candidate triple.
    let session = LossSession::new(rt, &cfg.name, role, qcfg.bits, acts, w)?;
    let has_future = block + 1 < cfg.n_layer;

    let candidates: Vec<(usize, f32)> = if !has_future {
        vec![(0, 1.0)] // last block: AWQ fallback
    } else if qcfg.full_search {
        let max_j = (cfg.n_layer - 1 - block).min(4).max(1);
        let mut v = Vec::new();
        for j in 1..=max_j {
            for &g in &FULL_SEARCH_GAMMAS {
                v.push((j, g));
            }
        }
        v
    } else {
        vec![(qcfg.window, qcfg.gamma)]
    };

    let mut best: Option<(SearchResult, usize, f32)> = None;
    for (j, gamma) in candidates {
        let stats = if j == 0 {
            per_layer[block].to_vec()
        } else {
            faq_stats(&per_layer, block, j, gamma, qcfg.layerwise_preview)
        };
        let sr = session.search(&stats, qcfg.alpha_grid)?;
        let better = match &best {
            None => true,
            Some((b, _, _)) => sr.loss < b.loss,
        };
        if better {
            best = Some((sr, j, gamma));
        }
    }
    let (sr, j, gamma) = best.context("no FAQ candidates")?;
    let gamma_used = if j == 0 { 1.0 } else { gamma };
    build_linear(block, role, sr.alpha, sr.loss, j, gamma_used, sr.scale, w, qcfg, group)
}

#[allow(clippy::too_many_arguments)]
fn build_linear(
    block: usize,
    role: &'static str,
    alpha: f32,
    loss: f32,
    window_used: usize,
    gamma_used: f32,
    scale: Vec<f32>,
    w: &crate::tensor::Tensor,
    qcfg: &QuantConfig,
    group: usize,
) -> Result<LinearQuant> {
    let (ints, inv_s) = scaled_quantize_ints(w, &scale, qcfg.bits, group)?;
    let packed = packing::pack(&ints.q, qcfg.bits)?;
    Ok(LinearQuant {
        block,
        role,
        alpha,
        loss,
        window_used,
        gamma_used,
        scale,
        ints,
        inv_s,
        packed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Rng, Tensor};

    #[test]
    fn compression_headline_is_real() {
        // Direct check on the deployment bundle: a 3-bit packed linear is
        // >6x smaller than FP32 when group=32 amortizes dequant params.
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&mut rng, &[256, 128], 1.0);
        let ints = quantize_ints(&w, 3, 32).unwrap();
        let fp_bytes = 256 * 128 * 4;
        assert!(ints.packed_bytes() * 6 < fp_bytes);
    }

    #[test]
    fn fp_method_rejected() {
        // quantize_model(Method::Fp) must bail — needs no runtime to test
        // the validation order (validate -> method check happens before
        // any artifact access only if calib checks pass), so construct the
        // error through QuantConfig directly.
        let q = QuantConfig::with_method(Method::Fp);
        assert!(q.validate().is_ok());
        // The bail itself is covered by the pipeline integration test.
    }
}
