//! CLI argument parser (S14): subcommand + optional mode +
//! `--flag value` / `--flag`.
//!
//! clap is not in the offline registry. The grammar is intentionally
//! small: `faquant <subcommand> [mode] [--key value]... [--switch]...`
//! with typed accessors and unknown-flag/unused-mode rejection at
//! `finish()`. The single optional `mode` positional exists for
//! subcommand families like `serve bench`; a subcommand that never
//! reads [`Args::mode`] rejects one the same way it rejects a typo'd
//! flag.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Is the next raw token a flag name rather than a flag *value*?
///
/// Only `--`-prefixed tokens are flag names; single-dash tokens — in
/// particular negative numerics like `-0.5` or `-1` — bind to the
/// preceding flag as values (`--temperature -0.5`, `--stop-id -1`;
/// pinned by tests below). The numeric check additionally keeps any
/// token that parses as a number on the value side of the boundary.
fn looks_like_flag(tok: &str) -> bool {
    tok.starts_with("--") && tok.parse::<f64>().is_err()
}

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    mode: Option<String>,
    mode_read: std::cell::Cell<bool>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut it = raw.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        if subcommand.starts_with('-') {
            bail!("expected a subcommand before flags, got '{subcommand}'");
        }
        let mut mode = None;
        if let Some(next) = it.peek() {
            if !next.starts_with('-') {
                mode = it.next();
            }
        }
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        while let Some(tok) = it.next() {
            let Some(name) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument '{tok}'");
            };
            if name.is_empty() {
                bail!("bare '--' not supported");
            }
            match it.peek() {
                Some(next) if !looks_like_flag(next) => {
                    flags.insert(name.to_string(), it.next().unwrap());
                }
                _ => switches.push(name.to_string()),
            }
        }
        Ok(Self {
            subcommand,
            mode,
            mode_read: Default::default(),
            flags,
            switches,
            consumed: Default::default(),
        })
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, name: &str) {
        self.consumed.borrow_mut().push(name.to_string());
    }

    /// The optional positional after the subcommand (`serve bench` ->
    /// `Some("bench")`). Reading it marks it used; a mode nobody read
    /// is rejected by [`Args::finish`].
    pub fn mode(&self) -> Option<&str> {
        self.mode_read.set(true);
        self.mode.as_deref()
    }

    pub fn get(&self, name: &str) -> Option<String> {
        self.mark(name);
        self.flags.get(name).cloned()
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} '{v}' is not an integer")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} '{v}' is not an integer")),
        }
    }

    /// Signed integer flag (negative values parse: `--stop-id -1`).
    pub fn get_i64(&self, name: &str, default: i64) -> Result<i64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} '{v}' is not an integer")),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} '{v}' is not a float")),
        }
    }

    /// Optional millisecond-duration flag: absent or `0` means "off".
    pub fn get_ms_opt(&self, name: &str) -> Result<Option<std::time::Duration>> {
        let ms = self.get_u64(name, 0)?;
        Ok((ms > 0).then_some(std::time::Duration::from_millis(ms)))
    }

    pub fn has(&self, name: &str) -> bool {
        self.mark(name);
        self.switches.iter().any(|s| s == name)
    }

    /// Reject flags (and a mode positional) that no accessor ever
    /// looked at — catches typos and stray positionals alike.
    pub fn finish(&self) -> Result<()> {
        if let (Some(mode), false) = (self.mode.as_deref(), self.mode_read.get()) {
            bail!(
                "unexpected positional argument '{mode}' for subcommand '{}'",
                self.subcommand
            );
        }
        let seen = self.consumed.borrow();
        for k in self.flags.keys().chain(self.switches.iter()) {
            if !seen.iter().any(|s| s == k) {
                bail!("unknown flag '--{k}' for subcommand '{}'", self.subcommand);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("quantize --model tiny --bits 3 --verbose");
        assert_eq!(a.subcommand, "quantize");
        assert_eq!(a.get_or("model", "pico"), "tiny");
        assert_eq!(a.get_usize("bits", 4).unwrap(), 3);
        assert!(a.has("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse("eval");
        assert_eq!(a.get_or("model", "pico"), "pico");
        assert_eq!(a.get_f32("gamma", 0.85).unwrap(), 0.85);
        assert!(!a.has("full-search"));
    }

    #[test]
    fn negative_numeric_values_parse() {
        let a = parse("generate --temperature -0.5 --stop-id -1 --bias -3");
        assert_eq!(a.get_f32("temperature", 1.0).unwrap(), -0.5);
        assert_eq!(a.get_i64("stop-id", 0).unwrap(), -1);
        assert_eq!(a.get_i64("bias", 0).unwrap(), -3);
        a.finish().unwrap();
    }

    #[test]
    fn flag_after_flag_still_a_switch() {
        // A non-numeric `--` token after a flag stays a flag: the first
        // becomes a switch, the second takes the value.
        let a = parse("eval --full-search --gamma 0.7");
        assert!(a.has("full-search"));
        assert_eq!(a.get_f32("gamma", 0.0).unwrap(), 0.7);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse("eval --oops 1");
        assert!(a.finish().is_err());
    }

    #[test]
    fn ms_flag_zero_means_off() {
        let a = parse("generate --deadline-ms 0");
        assert_eq!(a.get_ms_opt("deadline-ms").unwrap(), None);
        let b = parse("generate --deadline-ms 250");
        assert_eq!(
            b.get_ms_opt("deadline-ms").unwrap(),
            Some(std::time::Duration::from_millis(250))
        );
        let c = parse("generate");
        assert_eq!(c.get_ms_opt("deadline-ms").unwrap(), None);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("eval --bits three");
        assert!(a.get_usize("bits", 4).is_err());
    }

    #[test]
    fn mode_positional_parses_and_is_read_once() {
        let a = parse("serve bench --clients 4");
        assert_eq!(a.subcommand, "serve");
        assert_eq!(a.mode(), Some("bench"));
        assert_eq!(a.get_usize("clients", 1).unwrap(), 4);
        a.finish().unwrap();
    }

    #[test]
    fn unread_mode_rejected_at_finish() {
        // Parsing accepts the positional (some subcommands take one),
        // but a subcommand that never reads it must reject it exactly
        // like an unknown flag.
        let a = parse("eval stray");
        assert!(a.finish().is_err());
    }

    #[test]
    fn second_positional_still_rejected() {
        assert!(Args::parse(["serve".into(), "bench".into(), "stray".into()]).is_err());
    }

    #[test]
    fn flag_before_subcommand_rejected() {
        assert!(Args::parse(["--model".into(), "x".into()]).is_err());
    }
}
