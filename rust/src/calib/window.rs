//! The FAQ preview window (paper Sec. 2.2, eq. 4–5).
//!
//! For layer i and a preview length j:
//! - layer-wise preview:  a_pvw = a_{i+j}            (single future layer)
//! - window-wise preview: a_pvw = mean(a_{i+1} … a_{i+j})
//!
//! then the fused statistics  ã_i = γ·a_i + (1−γ)·a_pvw  drive the scale
//! rule instead of a_i alone. Near the end of the network the window is
//! clipped to the available future layers; the last layer has no future
//! and falls back to pure AWQ (γ effectively 1) — documented behaviour,
//! covered by tests.
//!
//! Preview statistics are only meaningful between tensors with the same
//! channel dimension, so the window aggregates the *same role* across
//! future blocks (qkv with qkv, down with down, …) — see DESIGN.md §3.

/// Window/layer-wise preview over per-layer stats of one role.
///
/// `per_layer[l]` is the per-channel stat vector of layer `l`. Returns
/// `None` when `layer` has no future layer (preview impossible).
pub fn preview_stats(
    per_layer: &[&[f32]],
    layer: usize,
    window: usize,
    layerwise: bool,
) -> Option<Vec<f32>> {
    let n_layers = per_layer.len();
    assert!(layer < n_layers, "layer {layer} out of range {n_layers}");
    assert!(window >= 1, "window must be >= 1");
    if layer + 1 >= n_layers {
        return None;
    }
    if layerwise {
        // Single future layer at distance `window`, clipped to the last.
        let target = (layer + window).min(n_layers - 1);
        return Some(per_layer[target].to_vec());
    }
    let hi = (layer + window).min(n_layers - 1);
    let n = per_layer[layer].len();
    let mut acc = vec![0.0f32; n];
    let mut count = 0usize;
    for l in (layer + 1)..=hi {
        debug_assert_eq!(per_layer[l].len(), n, "role channel dim drift");
        for (a, &v) in acc.iter_mut().zip(per_layer[l]) {
            *a += v;
        }
        count += 1;
    }
    for a in &mut acc {
        *a /= count as f32;
    }
    Some(acc)
}

/// Fused statistics  ã = γ·current + (1−γ)·preview  (paper eq. 5).
pub fn fused_stats(current: &[f32], preview: &[f32], gamma: f32) -> Vec<f32> {
    debug_assert_eq!(current.len(), preview.len());
    current
        .iter()
        .zip(preview)
        .map(|(&c, &p)| gamma * c + (1.0 - gamma) * p)
        .collect()
}

/// The effective FAQ statistics for one layer: fused when a preview
/// exists, current-layer stats otherwise (last-layer fallback).
pub fn faq_stats(
    per_layer: &[&[f32]],
    layer: usize,
    window: usize,
    gamma: f32,
    layerwise: bool,
) -> Vec<f32> {
    match preview_stats(per_layer, layer, window, layerwise) {
        Some(pvw) => fused_stats(per_layer[layer], &pvw, gamma),
        None => per_layer[layer].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers() -> Vec<Vec<f32>> {
        vec![
            vec![1.0, 1.0],
            vec![2.0, 0.0],
            vec![4.0, 8.0],
            vec![6.0, 4.0],
        ]
    }

    fn refs(v: &[Vec<f32>]) -> Vec<&[f32]> {
        v.iter().map(|x| x.as_slice()).collect()
    }

    #[test]
    fn window_averages_future_layers() {
        let ls = layers();
        let p = preview_stats(&refs(&ls), 0, 2, false).unwrap();
        // mean of layers 1, 2
        assert_eq!(p, vec![3.0, 4.0]);
    }

    #[test]
    fn window_clips_at_network_end() {
        let ls = layers();
        let p = preview_stats(&refs(&ls), 2, 5, false).unwrap();
        assert_eq!(p, vec![6.0, 4.0]); // only layer 3 remains
    }

    #[test]
    fn last_layer_has_no_preview() {
        let ls = layers();
        assert!(preview_stats(&refs(&ls), 3, 3, false).is_none());
        // faq_stats falls back to AWQ (current stats).
        let f = faq_stats(&refs(&ls), 3, 3, 0.85, false);
        assert_eq!(f, ls[3]);
    }

    #[test]
    fn layerwise_picks_single_layer() {
        let ls = layers();
        let p = preview_stats(&refs(&ls), 0, 2, true).unwrap();
        assert_eq!(p, ls[2]);
        // distance clipped to the last layer
        let p = preview_stats(&refs(&ls), 1, 9, true).unwrap();
        assert_eq!(p, ls[3]);
    }

    #[test]
    fn window_one_equals_layerwise_one() {
        let ls = layers();
        let a = preview_stats(&refs(&ls), 1, 1, false).unwrap();
        let b = preview_stats(&refs(&ls), 1, 1, true).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn fusion_interpolates() {
        let f = fused_stats(&[1.0, 0.0], &[0.0, 1.0], 0.85);
        assert!((f[0] - 0.85).abs() < 1e-6);
        assert!((f[1] - 0.15).abs() < 1e-6);
        // gamma=1 is pure AWQ
        assert_eq!(fused_stats(&[3.0], &[9.0], 1.0), vec![3.0]);
    }

    #[test]
    fn gamma_one_faq_equals_awq() {
        let ls = layers();
        for layer in 0..ls.len() {
            let f = faq_stats(&refs(&ls), layer, 3, 1.0, false);
            for (a, b) in f.iter().zip(&ls[layer]) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }
}
