//! Calibration pipeline (S9, phase A): activation capture + the FAQ
//! preview window.
//!
//! One full-precision forward pass per calibration batch through the
//! `fwd_capture` artifact yields, for every (block, role):
//! - per-channel mean |a| statistics (Pallas `absmean` on-graph), and
//! - the raw activation rows, reservoir-sampled down to `loss_rows` rows
//!   used as the grid-search objective's input sample.

mod window;

pub use window::{faq_stats, fused_stats, preview_stats};

use crate::config::ModelConfig;
use crate::model::{Params, ROLES};
use crate::runtime::{tensor_f32, Buffer, Runtime};
use crate::tensor::{Rng, Tensor, TensorI32};
use anyhow::{bail, Result};

/// Per-(block, role) calibration data.
#[derive(Clone, Debug)]
pub struct CalibStats {
    pub cfg: ModelConfig,
    /// Batches consumed.
    pub n_batches: usize,
    /// stats[block][role] = per-channel mean |a| (len = n_in of the role),
    /// averaged over calibration batches.
    pub stats: Vec<Vec<Vec<f32>>>,
    /// acts[block][role] = sampled activation rows [loss_rows, n_in].
    pub acts: Vec<Vec<Tensor>>,
}

impl CalibStats {
    pub fn stats_for(&self, block: usize, role_idx: usize) -> &[f32] {
        &self.stats[block][role_idx]
    }

    pub fn acts_for(&self, block: usize, role_idx: usize) -> &Tensor {
        &self.acts[block][role_idx]
    }

    /// Stats of one role across all blocks (the preview window's input).
    pub fn role_stats_per_layer(&self, role_idx: usize) -> Vec<&[f32]> {
        self.stats.iter().map(|b| b[role_idx].as_slice()).collect()
    }
}

/// Reservoir sampler over activation rows for one (block, role).
struct Reservoir {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
    filled: usize,
    seen: usize,
}

impl Reservoir {
    fn new(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
            filled: 0,
            seen: 0,
        }
    }

    fn push_batch(&mut self, acts: &Tensor, rng: &mut Rng) {
        let shape = acts.shape();
        debug_assert_eq!(shape[1], self.cols);
        for r in 0..shape[0] {
            self.seen += 1;
            if self.filled < self.rows {
                let dst = self.filled * self.cols;
                self.data[dst..dst + self.cols].copy_from_slice(acts.row(r));
                self.filled += 1;
            } else {
                // Classic reservoir: replace slot with prob rows/seen.
                let j = rng.below(self.seen);
                if j < self.rows {
                    let dst = j * self.cols;
                    self.data[dst..dst + self.cols].copy_from_slice(acts.row(r));
                }
            }
        }
    }

    fn finish(self) -> Result<Tensor> {
        if self.filled < self.rows {
            bail!(
                "calibration set too small: reservoir has {}/{} rows",
                self.filled,
                self.rows
            );
        }
        Tensor::from_vec(&[self.rows, self.cols], self.data)
    }
}

/// Run the capture pass over `batches` and aggregate.
pub fn capture(
    rt: &Runtime,
    cfg: &ModelConfig,
    params: &Params,
    batches: &[TensorI32],
    seed: u64,
) -> Result<CalibStats> {
    if batches.is_empty() {
        bail!("capture: no calibration batches");
    }
    let loss_rows = rt.manifest.loss_rows;
    let mut rng = Rng::new(seed ^ 0xCA11B);
    let l = cfg.n_layer;

    let role_dims: Vec<usize> = ROLES
        .iter()
        .map(|r| crate::model::role_shape(cfg, r).0)
        .collect();
    let mut stat_acc: Vec<Vec<Vec<f64>>> = (0..l)
        .map(|_| role_dims.iter().map(|&n| vec![0.0f64; n]).collect())
        .collect();
    let mut reservoirs: Vec<Vec<Reservoir>> = (0..l)
        .map(|_| {
            role_dims
                .iter()
                .map(|&n| Reservoir::new(loss_rows, n))
                .collect()
        })
        .collect();

    // §Perf: parameters uploaded once for the whole calibration pass.
    let param_bufs = params
        .tensors
        .iter()
        .map(|t| rt.upload_f32(t))
        .collect::<Result<Vec<_>, _>>()?;

    for batch in batches {
        let tok_buf = rt.upload_i32(batch)?;
        let mut args: Vec<&Buffer> = param_bufs.iter().collect();
        args.push(&tok_buf);
        let outs = rt.exec_b(&cfg.name, "fwd_capture", &args)?;
        if outs.len() != 8 {
            bail!("fwd_capture returned {} outputs, want 8", outs.len());
        }
        // outs[0..4] = acts per role [L, R, n]; outs[4..8] = stats [L, n].
        for (ri, _) in ROLES.iter().enumerate() {
            let acts = tensor_f32(&outs[ri])?;
            let stats = tensor_f32(&outs[4 + ri])?;
            for b in 0..l {
                let a_b = acts.index0(b);
                reservoirs[b][ri].push_batch(&a_b, &mut rng);
                let s_b = stats.index0(b);
                for (acc, &v) in stat_acc[b][ri].iter_mut().zip(s_b.data()) {
                    *acc += v as f64;
                }
            }
        }
    }

    let nb = batches.len();
    let stats: Vec<Vec<Vec<f32>>> = stat_acc
        .into_iter()
        .map(|per_block| {
            per_block
                .into_iter()
                .map(|acc| acc.into_iter().map(|v| (v / nb as f64) as f32).collect())
                .collect()
        })
        .collect();
    let acts: Vec<Vec<Tensor>> = reservoirs
        .into_iter()
        .map(|per_block| {
            per_block
                .into_iter()
                .map(|r| r.finish())
                .collect::<Result<Vec<_>>>()
        })
        .collect::<Result<Vec<_>>>()?;

    Ok(CalibStats {
        cfg: cfg.clone(),
        n_batches: nb,
        stats,
        acts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_fills_then_samples() {
        let mut rng = Rng::new(1);
        let mut res = Reservoir::new(4, 2);
        let batch =
            Tensor::from_vec(&[6, 2], (0..12).map(|i| i as f32).collect()).unwrap();
        res.push_batch(&batch, &mut rng);
        assert_eq!(res.filled, 4);
        assert_eq!(res.seen, 6);
        let t = res.finish().unwrap();
        assert_eq!(t.shape(), &[4, 2]);
    }

    #[test]
    fn reservoir_underfill_errors() {
        let mut rng = Rng::new(2);
        let mut res = Reservoir::new(10, 2);
        let batch = Tensor::zeros(&[3, 2]);
        res.push_batch(&batch, &mut rng);
        assert!(res.finish().is_err());
    }

    #[test]
    fn reservoir_keeps_row_distribution() {
        // After many batches every row value should appear with roughly
        // uniform probability; check the mean lands near the stream mean.
        let mut rng = Rng::new(3);
        let mut res = Reservoir::new(32, 1);
        for chunk in 0..64 {
            let vals: Vec<f32> = (0..16).map(|i| (chunk * 16 + i) as f32).collect();
            let t = Tensor::from_vec(&[16, 1], vals).unwrap();
            res.push_batch(&t, &mut rng);
        }
        let t = res.finish().unwrap();
        let stream_mean = (64.0 * 16.0 - 1.0) / 2.0;
        assert!((t.mean() - stream_mean).abs() < stream_mean * 0.35);
    }
}
