//! Structured text generators: Zipf-unigram + sparse-bigram Markov text.
//!
//! Each word has a Zipf-weighted base frequency plus a small set of
//! preferred successors (the "bigram graph") that receive a large
//! multiplicative boost — this produces text with real sequential
//! structure a language model can learn, which is what makes perplexity
//! and continuation-plausibility evaluations meaningful.

use super::words::wordlist;
use crate::tensor::Rng;

/// Which synthetic corpus to generate (the WikiText2/C4 stand-ins).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CorpusKind {
    /// Clean, sentence-structured, strongly coherent ("wikitext2" column).
    SynthWiki,
    /// Noisier web-like mix: flatter distribution, fragments, numerics
    /// ("c4" column).
    SynthC4,
}

impl CorpusKind {
    pub fn label(&self) -> &'static str {
        match self {
            CorpusKind::SynthWiki => "synth-wikitext2",
            CorpusKind::SynthC4 => "synth-c4",
        }
    }

    fn params(&self) -> GenParams {
        match self {
            CorpusKind::SynthWiki => GenParams {
                n_words: 500,
                zipf_s: 1.1,
                succ_per_word: 4,
                bigram_boost: 24.0,
                sent_len_lo: 8,
                sent_len_hi: 24,
                noise_prob: 0.01,
                word_seed: 11,
                graph_seed: 12,
            },
            CorpusKind::SynthC4 => GenParams {
                n_words: 500,
                zipf_s: 0.85,
                succ_per_word: 6,
                bigram_boost: 9.0,
                sent_len_lo: 3,
                sent_len_hi: 14,
                noise_prob: 0.08,
                word_seed: 11, // shared lexicon, different dynamics
                graph_seed: 31,
            },
        }
    }
}

struct GenParams {
    n_words: usize,
    zipf_s: f32,
    succ_per_word: usize,
    bigram_boost: f32,
    sent_len_lo: usize,
    sent_len_hi: usize,
    noise_prob: f32,
    word_seed: u64,
    graph_seed: u64,
}

/// A seeded corpus generator. The word list and bigram graph depend only
/// on the corpus kind; the *sampling* stream depends on `seed`, so
/// distinct seeds give disjoint samples from the same distribution
/// (exactly what Table 3's calibration-bias experiment varies).
pub struct Generator {
    pub kind: CorpusKind,
    words: Vec<String>,
    base: Vec<f32>,
    succ: Vec<Vec<u32>>,
    params: GenParams,
    rng: Rng,
}

impl Generator {
    pub fn new(kind: CorpusKind, seed: u64) -> Self {
        let p = kind.params();
        let words = wordlist(p.n_words, p.word_seed);
        // Zipf base weights over rank.
        let base: Vec<f32> = (0..p.n_words)
            .map(|r| 1.0 / ((r + 1) as f32).powf(p.zipf_s))
            .collect();
        // Sparse successor graph, fixed per kind.
        let mut graph_rng = Rng::new(p.graph_seed);
        let succ: Vec<Vec<u32>> = (0..p.n_words)
            .map(|_| {
                (0..p.succ_per_word)
                    .map(|_| graph_rng.below(p.n_words) as u32)
                    .collect()
            })
            .collect();
        Self {
            kind,
            words,
            base,
            succ,
            params: p,
            rng: Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ kind as u64),
        }
    }

    pub fn vocab_words(&self) -> &[String] {
        &self.words
    }

    fn next_word_idx(&mut self, prev: Option<usize>) -> usize {
        match prev {
            None => self.rng.categorical(&self.base),
            Some(p) => {
                // Mixture: with boost, pick among preferred successors.
                let boost_total =
                    self.params.bigram_boost * self.params.succ_per_word as f32;
                let base_total: f32 = self.base.iter().sum();
                let x = self.rng.uniform() * (boost_total + base_total);
                if x < boost_total {
                    let k = self.succ[p][self.rng.below(self.params.succ_per_word)];
                    k as usize
                } else {
                    self.rng.categorical(&self.base)
                }
            }
        }
    }

    fn noise_token(&mut self) -> String {
        match self.rng.below(3) {
            0 => format!("{}", self.rng.below(10_000)),
            1 => format!("{}.{}", self.rng.below(100), self.rng.below(100)),
            _ => "http".to_string(),
        }
    }

    /// Generate one sentence of text.
    pub fn sentence(&mut self) -> String {
        let len = self.params.sent_len_lo
            + self.rng.below(self.params.sent_len_hi - self.params.sent_len_lo + 1);
        let mut prev = None;
        let mut parts = Vec::with_capacity(len);
        for _ in 0..len {
            if self.rng.uniform() < self.params.noise_prob {
                parts.push(self.noise_token());
                prev = None;
            } else {
                let idx = self.next_word_idx(prev);
                parts.push(self.words[idx].clone());
                prev = Some(idx);
            }
        }
        let mut s = parts.join(" ");
        s.push('.');
        // Capitalize.
        let mut chars = s.chars();
        match chars.next() {
            Some(c) => c.to_uppercase().collect::<String>() + chars.as_str(),
            None => s,
        }
    }

    /// Generate text with at least `min_words` word tokens.
    pub fn text(&mut self, min_words: usize) -> String {
        let mut out = String::new();
        let mut count = 0usize;
        while count < min_words {
            let s = self.sentence();
            count += s.split_whitespace().count();
            out.push_str(&s);
            out.push(' ');
        }
        out
    }

    /// Sample a continuation *consistent with the bigram dynamics* starting
    /// from word index `start` — used as the "plausible" option in the
    /// synthetic zero-shot suites.
    pub fn plausible_continuation(&mut self, start: Option<usize>, len: usize) -> Vec<String> {
        let mut prev = start;
        (0..len)
            .map(|_| {
                let idx = self.next_word_idx(prev);
                prev = Some(idx);
                self.words[idx].clone()
            })
            .collect()
    }

    /// Uniform-random word salad (maximally implausible distractor).
    pub fn random_words(&mut self, len: usize) -> Vec<String> {
        (0..len)
            .map(|_| {
                let i = self.rng.below(self.words.len());
                self.words[i].clone()
            })
            .collect()
    }

    /// Look up a word's index in the generator lexicon.
    pub fn word_index(&self, w: &str) -> Option<usize> {
        self.words.iter().position(|x| x == w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Generator::new(CorpusKind::SynthWiki, 5);
        let mut b = Generator::new(CorpusKind::SynthWiki, 5);
        assert_eq!(a.text(200), b.text(200));
    }

    #[test]
    fn seeds_give_different_samples() {
        let mut a = Generator::new(CorpusKind::SynthWiki, 5);
        let mut b = Generator::new(CorpusKind::SynthWiki, 6);
        assert_ne!(a.text(200), b.text(200));
    }

    #[test]
    fn corpora_share_lexicon_but_differ() {
        let a = Generator::new(CorpusKind::SynthWiki, 1);
        let b = Generator::new(CorpusKind::SynthC4, 1);
        assert_eq!(a.vocab_words(), b.vocab_words());
        let mut a = a;
        let mut b = b;
        assert_ne!(a.text(300), b.text(300));
    }

    #[test]
    fn c4_is_noisier() {
        let mut wiki = Generator::new(CorpusKind::SynthWiki, 2);
        let mut c4 = Generator::new(CorpusKind::SynthC4, 2);
        let count_digits = |s: &str| s.chars().filter(|c| c.is_ascii_digit()).count();
        let w = wiki.text(3000);
        let c = c4.text(3000);
        assert!(count_digits(&c) > count_digits(&w) * 2);
    }

    #[test]
    fn text_reaches_min_words() {
        let mut g = Generator::new(CorpusKind::SynthWiki, 3);
        let t = g.text(500);
        assert!(t.split_whitespace().count() >= 500);
    }

    #[test]
    fn bigram_structure_exists() {
        // Preferred successors should follow their predecessor far more
        // often than chance.
        let mut g = Generator::new(CorpusKind::SynthWiki, 4);
        let text = g.text(20_000);
        let words: Vec<&str> = text
            .split_whitespace()
            .map(|w| w.trim_end_matches('.'))
            .collect();
        let g2 = Generator::new(CorpusKind::SynthWiki, 0);
        let mut hits = 0usize;
        let mut total = 0usize;
        for pair in words.windows(2) {
            let (Some(i), Some(j)) = (g2.word_index(&pair[0].to_lowercase()), g2.word_index(&pair[1].to_lowercase())) else {
                continue;
            };
            total += 1;
            if g2.succ[i].contains(&(j as u32)) {
                hits += 1;
            }
        }
        let rate = hits as f32 / total.max(1) as f32;
        // succ_per_word=4 of 500 words => chance ~0.5%; structure >> that.
        assert!(rate > 0.2, "bigram hit rate {rate}");
    }
}
