//! Token-stream batcher: fixed-shape [B, T] (or [B, T+1]) i32 batches.
//!
//! The HLO artifacts have frozen batch/seq shapes, so the batcher's job is
//! to slice a token stream into exactly-shaped tensors. Training batches
//! carry T+1 tokens (input + shifted target); eval/calibration batches
//! carry T.

use crate::tensor::TensorI32;
use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub struct Batcher {
    pub batch: usize,
    pub seq: usize,
}

impl Batcher {
    pub fn new(batch: usize, seq: usize) -> Self {
        Self { batch, seq }
    }

    /// Split a stream into consecutive sequences of `len` tokens.
    fn sequences(&self, ids: &[i32], len: usize) -> Vec<Vec<i32>> {
        ids.chunks_exact(len).map(|c| c.to_vec()).collect()
    }

    /// Pack the stream into [B, len] batches, dropping the remainder.
    fn batches_of(&self, ids: &[i32], len: usize) -> Result<Vec<TensorI32>> {
        let seqs = self.sequences(ids, len);
        if seqs.len() < self.batch {
            bail!(
                "stream of {} tokens yields {} sequences < batch {}",
                ids.len(),
                seqs.len(),
                self.batch
            );
        }
        Ok(seqs
            .chunks_exact(self.batch)
            .map(|group| {
                let mut data = Vec::with_capacity(self.batch * len);
                for s in group {
                    data.extend_from_slice(s);
                }
                TensorI32::from_vec(&[self.batch, len], data).expect("shape by construction")
            })
            .collect())
    }

    /// Evaluation / calibration batches: [B, T].
    pub fn eval_batches(&self, ids: &[i32]) -> Result<Vec<TensorI32>> {
        self.batches_of(ids, self.seq)
    }

    /// Training batches: [B, T+1] (input plus next-token target).
    pub fn train_batches(&self, ids: &[i32]) -> Result<Vec<TensorI32>> {
        self.batches_of(ids, self.seq + 1)
    }

    /// Tokens consumed per training batch (sizing helper for generators).
    pub fn train_tokens_per_batch(&self) -> usize {
        self.batch * (self.seq + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_batches_shape_and_content() {
        let b = Batcher::new(2, 3);
        let ids: Vec<i32> = (0..14).collect();
        let batches = b.eval_batches(&ids).unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].shape(), &[2, 3]);
        assert_eq!(batches[0].data(), &[0, 1, 2, 3, 4, 5]);
        assert_eq!(batches[1].data(), &[6, 7, 8, 9, 10, 11]);
    }

    #[test]
    fn train_batches_have_extra_token() {
        let b = Batcher::new(2, 3);
        let ids: Vec<i32> = (0..16).collect();
        let batches = b.train_batches(&ids).unwrap();
        assert_eq!(batches[0].shape(), &[2, 4]);
    }

    #[test]
    fn too_short_stream_errors() {
        let b = Batcher::new(4, 128);
        assert!(b.eval_batches(&[1, 2, 3]).is_err());
    }

    #[test]
    fn remainder_dropped() {
        let b = Batcher::new(1, 4);
        let ids: Vec<i32> = (0..10).collect();
        let batches = b.eval_batches(&ids).unwrap();
        assert_eq!(batches.len(), 2); // 8 of 10 tokens used
    }
}
