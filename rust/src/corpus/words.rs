//! Deterministic synthetic word list.
//!
//! Pronounceable pseudo-words assembled from onset/nucleus/coda syllable
//! parts — deterministic in the seed, collision-free by construction
//! (dedup + regenerate), so every run sees the same vocabulary.

use crate::tensor::Rng;

const ONSETS: &[&str] = &[
    "b", "br", "c", "ch", "d", "dr", "f", "fl", "g", "gr", "h", "j", "k", "kl", "l", "m", "n",
    "p", "pr", "qu", "r", "s", "sh", "sk", "sl", "st", "t", "th", "tr", "v", "w", "z",
];
const NUCLEI: &[&str] = &[
    "a", "ai", "e", "ea", "ee", "i", "ia", "o", "oa", "oo", "u", "ue",
];
const CODAS: &[&str] = &[
    "", "b", "ck", "d", "g", "l", "ll", "m", "n", "nd", "ng", "nk", "p", "r", "rd", "s", "st",
    "t", "th", "x",
];

fn syllable(rng: &mut Rng) -> String {
    let mut s = String::new();
    s.push_str(ONSETS[rng.below(ONSETS.len())]);
    s.push_str(NUCLEI[rng.below(NUCLEI.len())]);
    s.push_str(CODAS[rng.below(CODAS.len())]);
    s
}

/// Generate `n` distinct pseudo-words, deterministic in `seed`.
pub fn wordlist(n: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed ^ 0x770D5);
    let mut seen = std::collections::HashSet::with_capacity(n);
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let n_syll = 1 + rng.below(3);
        let w: String = (0..n_syll).map(|_| syllable(&mut rng)).collect();
        if w.len() >= 2 && seen.insert(w.clone()) {
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct() {
        let a = wordlist(500, 9);
        let b = wordlist(500, 9);
        assert_eq!(a, b);
        let mut dedup = a.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 500);
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(wordlist(100, 1), wordlist(100, 2));
    }

    #[test]
    fn words_are_lowercase_alpha() {
        for w in wordlist(200, 3) {
            assert!(w.chars().all(|c| c.is_ascii_lowercase()), "{w}");
        }
    }
}
