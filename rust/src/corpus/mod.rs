//! Synthetic corpora, tokenizer, and batcher (S3).
//!
//! The paper calibrates on WikiText2/C4 and evaluates perplexity on both.
//! Offline we simulate the *domain gap* that matters for Table 1 and
//! Table 3 with two structured generators (DESIGN.md §4):
//!
//! - `synth-wiki`: sentence-structured Zipf bigram text — long sentences,
//!   low noise, strong bigram coherence (the "clean" corpus).
//! - `synth-c4`:  web-crawl-like mix — flatter unigram distribution,
//!   shorter fragments, numeric/url noise tokens (the "noisy" corpus).
//!
//! Both emit *text*; the [`Tokenizer`] fits a word vocabulary by frequency
//! and the [`Batcher`] packs token streams into fixed [B, T] batches — the
//! same pipeline a real deployment would run.

mod batcher;
mod generator;
mod tokenizer;
mod words;

pub use batcher::Batcher;
pub use generator::{CorpusKind, Generator};
pub use tokenizer::{Tokenizer, EOS, UNK};
pub use words::wordlist;
