//! Word-level tokenizer with frequency-fitted vocabulary.
//!
//! ids: 0 = `<unk>`, 1 = `<eos>` (sentence boundary), 2.. = words by
//! descending corpus frequency. Lowercases and strips trailing
//! punctuation, keeping the pipeline honest (text in, ids out) without a
//! BPE dependency.

use crate::tensor::TensorI32;
use anyhow::Result;
use std::collections::HashMap;

pub const UNK: i32 = 0;
pub const EOS: i32 = 1;
const RESERVED: usize = 2;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    vocab: HashMap<String, i32>,
    words: Vec<String>,
}

fn normalize(tok: &str) -> (String, bool) {
    let ends_sentence = tok.ends_with('.') || tok.ends_with('!') || tok.ends_with('?');
    let w = tok
        .trim_matches(|c: char| !c.is_ascii_alphanumeric())
        .to_lowercase();
    (w, ends_sentence)
}

impl Tokenizer {
    /// Fit a vocabulary of `vocab_size` entries (incl. reserved) on text.
    pub fn fit(text: &str, vocab_size: usize) -> Self {
        assert!(vocab_size > RESERVED);
        let mut counts: HashMap<String, usize> = HashMap::new();
        for tok in text.split_whitespace() {
            let (w, _) = normalize(tok);
            if !w.is_empty() {
                *counts.entry(w).or_insert(0) += 1;
            }
        }
        let mut by_freq: Vec<(String, usize)> = counts.into_iter().collect();
        by_freq.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        by_freq.truncate(vocab_size - RESERVED);
        let mut vocab = HashMap::with_capacity(by_freq.len());
        let mut words = vec!["<unk>".to_string(), "<eos>".to_string()];
        for (i, (w, _)) in by_freq.iter().enumerate() {
            vocab.insert(w.clone(), (i + RESERVED) as i32);
            words.push(w.clone());
        }
        Self { vocab, words }
    }

    pub fn vocab_size(&self) -> usize {
        self.words.len()
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids = Vec::new();
        for tok in text.split_whitespace() {
            let (w, eos) = normalize(tok);
            if !w.is_empty() {
                ids.push(*self.vocab.get(&w).unwrap_or(&UNK));
            }
            if eos {
                ids.push(EOS);
            }
        }
        ids
    }

    pub fn encode_words(&self, words: &[String]) -> Vec<i32> {
        words
            .iter()
            .map(|w| *self.vocab.get(&w.to_lowercase()).unwrap_or(&UNK))
            .collect()
    }

    /// Decode ids to words. Any id outside [0, vocab) — including
    /// *negative* ids, which signal a corrupted stream — renders as
    /// `<oob>`; mapping negatives to `<unk>` would mask the corruption.
    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&i| {
                if i < 0 {
                    return "<oob>";
                }
                self.words
                    .get(i as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<oob>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Unknown-token rate of an encoded stream (pipeline health metric).
    pub fn unk_rate(&self, ids: &[i32]) -> f32 {
        if ids.is_empty() {
            return 0.0;
        }
        ids.iter().filter(|&&i| i == UNK).count() as f32 / ids.len() as f32
    }

    /// Encode into a fixed-shape tensor, truncating or erroring if short.
    pub fn encode_exact(&self, text: &str, len: usize) -> Result<TensorI32> {
        let mut ids = self.encode(text);
        if ids.len() < len {
            anyhow::bail!("text too short: {} < {} tokens", ids.len(), len);
        }
        ids.truncate(len);
        TensorI32::from_vec(&[len], ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_encode_decode() {
        let text = "The cat sat. The cat ran. A dog sat.";
        let tok = Tokenizer::fit(text, 10);
        let ids = tok.encode("the cat sat.");
        assert_eq!(ids.last(), Some(&EOS));
        assert!(ids[..ids.len() - 1].iter().all(|&i| i >= RESERVED as i32));
        let dec = tok.decode(&ids);
        assert!(dec.contains("cat"));
    }

    #[test]
    fn decode_reports_out_of_bounds_ids() {
        let tok = Tokenizer::fit("alpha beta gamma", 5);
        // Negative ids are corruption, not unknown words.
        assert_eq!(tok.decode(&[-1]), "<oob>");
        assert_eq!(tok.decode(&[i32::MIN]), "<oob>");
        // Too-large ids likewise; valid ids still decode.
        let big = tok.vocab_size() as i32 + 10;
        let dec = tok.decode(&[0, -3, big]);
        assert_eq!(dec, "<unk> <oob> <oob>");
    }

    #[test]
    fn unknown_maps_to_unk() {
        let tok = Tokenizer::fit("alpha beta gamma", 5);
        let ids = tok.encode("zeta");
        assert_eq!(ids, vec![UNK]);
        assert_eq!(tok.unk_rate(&ids), 1.0);
    }

    #[test]
    fn vocab_size_capped() {
        let text: String = (0..100).map(|i| format!("w{i} ")).collect();
        let tok = Tokenizer::fit(&text, 20);
        assert_eq!(tok.vocab_size(), 20);
    }

    #[test]
    fn frequency_order() {
        let tok = Tokenizer::fit("b b b a a c", 10);
        let b = tok.encode("b")[0];
        let a = tok.encode("a")[0];
        let c = tok.encode("c")[0];
        assert!(b < a && a < c);
    }

    #[test]
    fn encode_exact_shapes() {
        let tok = Tokenizer::fit("x y z. x y. z x y.", 8);
        let t = tok.encode_exact("x y z. x y. z x y.", 5).unwrap();
        assert_eq!(t.shape(), &[5]);
        assert!(tok.encode_exact("x", 5).is_err());
    }

    #[test]
    fn real_corpus_low_unk() {
        use crate::corpus::{CorpusKind, Generator};
        let mut g = Generator::new(CorpusKind::SynthWiki, 1);
        let fit_text = g.text(30_000);
        let tok = Tokenizer::fit(&fit_text, 384);
        let mut g2 = Generator::new(CorpusKind::SynthWiki, 99);
        let ids = tok.encode(&g2.text(5_000));
        assert!(tok.unk_rate(&ids) < 0.2, "unk rate {}", tok.unk_rate(&ids));
    }
}
