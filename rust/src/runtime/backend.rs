//! The execution-backend abstraction.
//!
//! A [`Backend`] owns *how* an artifact entrypoint runs; the [`super::Runtime`]
//! owns everything backend-independent: the manifest (which entries exist
//! and their arities), argument-count checks, and [`super::ExecStats`]
//! accounting. Two implementations exist:
//!
//! - [`super::native::NativeBackend`] — pure-Rust reference execution of
//!   every entrypoint on host tensors (default; always available).
//! - `PjrtBackend` (`pjrt` feature) — the original AOT-HLO path: compile
//!   artifact text once per entry via the PJRT CPU client and execute on
//!   device buffers.
//!
//! The contract mirrors `python/compile/model.py`: entry names, flat
//! argument orders, and output orders are identical across backends, so
//! the coordinator code above never branches on the backend.

use super::registry::Manifest;
use super::value::{Buffer, Value};
use anyhow::Result;

/// One execution backend: everything the runtime needs to run artifacts.
///
/// `Send + Sync` is part of the contract: Phase B of the quantization
/// schedule executes `layer_loss*` entries from the thread pool
/// concurrently, so a backend must either be safely concurrent (native:
/// stateless) or serialize internally (PJRT: executable cache behind a
/// mutex).
pub trait Backend: Send + Sync {
    /// Human-readable platform tag (e.g. `native-cpu`, `cpu` for PJRT).
    fn platform(&self) -> String;

    /// Prepare an entry for execution (compile/warm caches). Returns the
    /// seconds spent compiling — 0.0 for backends with nothing to do.
    fn prepare(&self, manifest: &Manifest, cfg: &str, entry: &str) -> Result<f32>;

    /// Prepare a quantized-deployment weight bundle (`lits` = the
    /// `fwd_logits_q`/`decode_step_q` weight prefix in canonical order)
    /// for repeated execution. A backend with a one-time packed
    /// representation returns `Some(buffers)` — typically one opaque
    /// bundle buffer that replaces the whole prefix (the native backend's
    /// dequantize-once [`super::native::PreparedQModel`], DESIGN.md §11).
    /// The default `None` tells the runtime to fall back to uploading
    /// each literal individually.
    fn prepare_weights(
        &self,
        _manifest: &Manifest,
        _cfg: &str,
        _lits: &[Value],
    ) -> Result<Option<Vec<Buffer>>> {
        Ok(None)
    }

    /// Execute an entry on host values. Arity is pre-checked by the
    /// runtime against the manifest.
    fn exec(
        &self,
        manifest: &Manifest,
        cfg: &str,
        entry: &str,
        args: &[Value],
    ) -> Result<Vec<Value>>;

    /// Execute an entry on uploaded buffers (§Perf: no per-call host
    /// copies of the arguments on device backends).
    fn exec_buffers(
        &self,
        manifest: &Manifest,
        cfg: &str,
        entry: &str,
        args: &[&Buffer],
    ) -> Result<Vec<Value>>;

    /// Upload a host value into a reusable buffer (by value: the native
    /// backend keeps it as-is without another copy).
    fn upload(&self, v: Value) -> Result<Buffer>;
}
