//! PJRT/XLA execution backend (`pjrt` feature): load AOT HLO-text
//! artifacts, compile once per entry, execute from the hot path.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Executables are compiled on first use
//! and cached for the process lifetime; all entrypoints lower with
//! `return_tuple=True`, so outputs are always un-tupled here.
//!
//! The `xla` binding is not in the offline registry: building with
//! `--features pjrt` requires adding it as a path dependency (see
//! rust/Cargo.toml). Default builds never compile this module.

use super::backend::Backend;
use super::registry::Manifest;
use super::value::{Buffer, Value};
use crate::tensor::{Tensor, TensorI32};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Device-resident buffer handle (clonable via refcount).
#[derive(Clone)]
pub struct DeviceBuffer(Arc<xla::PjRtBuffer>);

// SAFETY: PJRT buffers are immutable once created and the PJRT CPU
// client's buffer operations are thread-safe; the binding's types only
// miss the auto traits because they hold raw pointers. Required by the
// `Backend: Send + Sync` contract (Phase B executes concurrently).
unsafe impl Send for DeviceBuffer {}
unsafe impl Sync for DeviceBuffer {} // SAFETY: as above

impl std::fmt::Debug for DeviceBuffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("DeviceBuffer")
    }
}

/// The PJRT CPU backend: one client + executable cache. Compilation and
/// the cache sit behind a mutex; `execute` calls are issued without the
/// lock (the PJRT CPU client supports concurrent execution).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    exes: Mutex<HashMap<(String, String), Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: see DeviceBuffer — the PJRT C API is thread-safe for
// compile/execute/upload; all interior mutability here is the mutexed
// executable cache.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {} // SAFETY: as above

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().context("create PJRT CPU client")?,
            exes: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) the executable for (cfg, entry).
    /// Returns (executable, compile seconds — 0 on cache hit). The cache
    /// lock is held across compilation so racing callers cannot compile
    /// the same entry twice.
    fn executable(
        &self,
        manifest: &Manifest,
        cfg: &str,
        entry: &str,
    ) -> Result<(Arc<xla::PjRtLoadedExecutable>, f32)> {
        let key = (cfg.to_string(), entry.to_string());
        let mut exes = self.exes.lock().unwrap();
        if let Some(exe) = exes.get(&key) {
            return Ok((exe.clone(), 0.0));
        }
        let info = manifest.artifact(cfg, entry)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&info.path)
            .with_context(|| format!("parse HLO text {}", info.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compile {cfg}/{entry}"))?,
        );
        let secs = t0.elapsed().as_secs_f32();
        exes.insert(key, exe.clone());
        Ok((exe, secs))
    }

    fn untuple(result: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Value>> {
        let lit = result[0][0]
            .to_literal_sync()
            .context("download result literal")?;
        let outs = lit.to_tuple().context("untuple result")?;
        outs.iter().map(value_from_literal).collect()
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn prepare(&self, manifest: &Manifest, cfg: &str, entry: &str) -> Result<f32> {
        let (_, secs) = self.executable(manifest, cfg, entry)?;
        Ok(secs)
    }

    fn exec(
        &self,
        manifest: &Manifest,
        cfg: &str,
        entry: &str,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let (exe, _) = self.executable(manifest, cfg, entry)?;
        let lits = args
            .iter()
            .map(literal_from_value)
            .collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("execute {cfg}/{entry}"))?;
        Self::untuple(result)
    }

    fn exec_buffers(
        &self,
        manifest: &Manifest,
        cfg: &str,
        entry: &str,
        args: &[&Buffer],
    ) -> Result<Vec<Value>> {
        let (exe, _) = self.executable(manifest, cfg, entry)?;
        let bufs = args
            .iter()
            .map(|b| match b {
                Buffer::Device(d) => Ok(d.0.as_ref()),
                Buffer::Host(_) => bail!("host buffer passed to the PJRT backend"),
                Buffer::PreparedQ(_) => {
                    bail!("prepared weight bundle passed to the PJRT backend")
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute_b(&bufs)
            .with_context(|| format!("execute_b {cfg}/{entry}"))?;
        Self::untuple(result)
    }

    fn upload(&self, v: Value) -> Result<Buffer> {
        let buf = match &v {
            Value::F32(t) => self
                .client
                .buffer_from_host_buffer(t.data(), t.shape(), None)
                .context("upload f32 buffer")?,
            Value::I32(t) => self
                .client
                .buffer_from_host_buffer(t.data(), t.shape(), None)
                .context("upload i32 buffer")?,
        };
        Ok(Buffer::Device(DeviceBuffer(Rc::new(buf))))
    }
}

fn as_bytes_f32(v: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding; alignment of u8 is 1; the byte length
    // equals the slice's size; the borrow pins the source slice alive.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

fn as_bytes_i32(v: &[i32]) -> &[u8] {
    // SAFETY: same as `as_bytes_f32` — plain-old-data reinterpret with
    // matching length, alignment 1, and a live source borrow.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// Value -> xla literal with the same shape.
pub fn literal_from_value(v: &Value) -> Result<xla::Literal> {
    match v {
        Value::F32(t) => xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            t.shape(),
            as_bytes_f32(t.data()),
        )
        .context("create f32 literal"),
        Value::I32(t) => xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            t.shape(),
            as_bytes_i32(t.data()),
        )
        .context("create i32 literal"),
    }
}

/// xla literal -> value (f32 or i32 by element type).
pub fn value_from_literal(lit: &xla::Literal) -> Result<Value> {
    let shape = lit.array_shape().context("literal shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.element_type() {
        xla::ElementType::S32 => {
            let data: Vec<i32> = lit.to_vec().context("literal to i32 vec")?;
            Ok(Value::I32(TensorI32::from_vec(&dims, data)?))
        }
        _ => {
            let data: Vec<f32> = lit.to_vec().context("literal to f32 vec")?;
            Ok(Value::F32(Tensor::from_vec(&dims, data)?))
        }
    }
}
