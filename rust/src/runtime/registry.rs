//! Artifact manifest parser + native-backend manifest synthesis.
//!
//! `artifacts/manifest.txt` is written by python/compile/aot.py (line
//! format documented there). The registry is the single source of truth
//! for which HLO modules exist, their argument counts, and the canonical
//! parameter order per model config — cross-checked against the rust-side
//! presets so L2 and L3 can never drift silently.
//!
//! [`Manifest::native`] synthesizes the same contract straight from the
//! rust presets (no python, no artifacts/ directory): the native backend
//! implements every entrypoint in-process, so the manifest only needs the
//! entry names and arities that `python/compile/model.py::entrypoints`
//! would have lowered.

use crate::config::ModelConfig;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub cfg: String,
    pub entry: String,
    pub path: PathBuf,
    pub nargs: usize,
}

#[derive(Debug, Default)]
pub struct Manifest {
    pub group: usize,
    pub loss_rows: usize,
    /// Ordered maps throughout: listings, validation failures, and op
    /// enumerations must come out byte-stable run-to-run (faq-lint D1).
    pub configs: BTreeMap<String, ModelConfig>,
    /// cfg -> canonical (name, shape) parameter list.
    pub params: BTreeMap<String, Vec<(String, Vec<usize>)>>,
    /// (cfg, entry) -> artifact.
    pub artifacts: BTreeMap<(String, String), ArtifactInfo>,
}

/// Number of arguments in the quantized-deployment weight prefix shared
/// by `fwd_logits_q` and `decode_step_q` (everything before each entry's
/// trailing tensors): tok_emb, pos_emb, per block {ln1, 4 dequant params
/// × 4 roles, ln2}, lnf_g, w_head. A prepared weight bundle
/// (`Buffer::PreparedQ`) replaces exactly this many positional args.
pub fn qweight_nargs(cfg: &ModelConfig) -> usize {
    2 + cfg.n_layer * 18 + 2
}

/// Quantization group size baked into the native manifest (matches
/// `QuantConfig::default().group`).
pub const NATIVE_GROUP: usize = 64;
/// Activation-sample rows for the layer-loss objective (native manifest).
pub const NATIVE_LOSS_ROWS: usize = 512;
/// Bit widths the native backend registers layer-loss entries for.
pub const NATIVE_BITS: [u32; 7] = [2, 3, 4, 5, 6, 7, 8];

fn kv(tok: &str, line_no: usize) -> Result<(&str, &str)> {
    tok.split_once('=')
        .with_context(|| format!("manifest line {line_no}: expected key=value, got '{tok}'"))
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let mut m = Manifest::default();
        for (i, line) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_whitespace();
            match toks.next().unwrap() {
                "group" => {
                    m.group = toks
                        .next()
                        .context("group value missing")?
                        .parse()
                        .context("group not an int")?;
                }
                "loss_rows" => {
                    m.loss_rows = toks
                        .next()
                        .context("loss_rows value missing")?
                        .parse()
                        .context("loss_rows not an int")?;
                }
                "config" => {
                    let name = toks.next().context("config name missing")?.to_string();
                    let mut fields: BTreeMap<&str, usize> = BTreeMap::new();
                    for tok in toks {
                        let (k, v) = kv(tok, line_no)?;
                        fields.insert(
                            k,
                            v.parse()
                                .with_context(|| format!("line {line_no}: bad int '{v}'"))?,
                        );
                    }
                    let get = |k: &str| -> Result<usize> {
                        fields
                            .get(k)
                            .copied()
                            .with_context(|| format!("line {line_no}: missing field {k}"))
                    };
                    let cfg = ModelConfig {
                        name: name.clone(),
                        n_layer: get("n_layer")?,
                        d_model: get("d_model")?,
                        n_head: get("n_head")?,
                        d_ff: get("d_ff")?,
                        vocab: get("vocab")?,
                        seq: get("seq")?,
                        batch: get("batch")?,
                    };
                    m.configs.insert(name, cfg);
                }
                "param" => {
                    let cfg = toks.next().context("param cfg missing")?.to_string();
                    let idx: usize = toks.next().context("param idx missing")?.parse()?;
                    let pname = toks.next().context("param name missing")?.to_string();
                    let dims_raw = toks.next().context("param dims missing")?;
                    let shape: Vec<usize> = if dims_raw == "scalar" {
                        vec![]
                    } else {
                        dims_raw
                            .split('x')
                            .map(|d| d.parse().map_err(anyhow::Error::from))
                            .collect::<Result<_>>()?
                    };
                    let list = m.params.entry(cfg).or_default();
                    if list.len() != idx {
                        bail!("line {line_no}: param idx {idx} out of order (have {})", list.len());
                    }
                    list.push((pname, shape));
                }
                "artifact" => {
                    let cfg = toks.next().context("artifact cfg missing")?.to_string();
                    let entry = toks.next().context("artifact entry missing")?.to_string();
                    let rel = toks.next().context("artifact path missing")?;
                    let (k, v) = kv(toks.next().context("nargs missing")?, line_no)?;
                    if k != "nargs" {
                        bail!("line {line_no}: expected nargs=, got {k}=");
                    }
                    m.artifacts.insert(
                        (cfg.clone(), entry.clone()),
                        ArtifactInfo {
                            cfg,
                            entry,
                            path: artifacts_dir.join(rel),
                            nargs: v.parse()?,
                        },
                    );
                }
                other => bail!("manifest line {line_no}: unknown record '{other}'"),
            }
        }
        m.validate()?;
        Ok(m)
    }

    /// Synthesize the manifest for the in-process native backend: all
    /// rust model presets, canonical parameter orders, and the full
    /// entrypoint set with the arities `python/compile/model.py` defines.
    pub fn native() -> Self {
        Self::native_with(NATIVE_GROUP, NATIVE_LOSS_ROWS)
    }

    /// Native manifest with a custom quantization geometry. The native
    /// backend reads `group`/`loss_rows` dynamically, so (unlike the AOT
    /// path, where these are baked into the artifacts at lowering time)
    /// any positive values work — this is how a run with e.g.
    /// `quant.group = 32` gets a matching runtime.
    pub fn native_with(group: usize, loss_rows: usize) -> Self {
        assert!(group > 0 && loss_rows > 0, "group/loss_rows must be positive");
        let mut m = Manifest {
            group,
            loss_rows,
            ..Manifest::default()
        };
        for name in ModelConfig::all_presets() {
            let cfg = ModelConfig::preset(name).expect("preset");
            let specs = crate::model::param_specs(&cfg);
            let n = specs.len();
            // fwd_logits_q per block: ln1 + 4x(qkv,o) + ln2 + 4x(up,down).
            let q_nargs = qweight_nargs(&cfg) + 1;
            let mut entries: Vec<(String, usize)> = vec![
                ("fwd_logits".to_string(), n + 1),
                ("fwd_capture".to_string(), n + 1),
                ("fwd_logits_q".to_string(), q_nargs),
                // Same weight prefix as fwd_logits_q, then k_cache,
                // v_cache, pos, tokens instead of the [B, T] batch.
                ("decode_step_q".to_string(), q_nargs + 3),
                // Paged variant: k_pool, v_pool, block_tables, pos,
                // tokens after the same weight prefix.
                ("decode_step_paged_q".to_string(), q_nargs + 4),
                // Int8×int4 twins: identical signatures (the weight
                // prefix is the same codes; only the kernel differs).
                // Prepared-bundle-only at execution time.
                ("fwd_logits_qi".to_string(), q_nargs),
                ("decode_step_qi".to_string(), q_nargs + 3),
                ("decode_step_paged_qi".to_string(), q_nargs + 4),
                ("train_step".to_string(), 3 * n + 2),
            ];
            for role in crate::model::ROLES {
                for bits in NATIVE_BITS {
                    entries.push((format!("layer_loss_{role}_b{bits}"), 3));
                    entries.push((format!("layer_loss_sweep_{role}_b{bits}"), 3));
                }
            }
            for (entry, nargs) in entries {
                m.artifacts.insert(
                    (name.to_string(), entry.clone()),
                    ArtifactInfo {
                        cfg: name.to_string(),
                        entry,
                        path: PathBuf::from("native://builtin"),
                        nargs,
                    },
                );
            }
            m.params.insert(name.to_string(), specs);
            m.configs.insert(name.to_string(), cfg);
        }
        m.validate().expect("native manifest is preset-consistent");
        m
    }

    /// Cross-check manifest configs + param lists against rust presets.
    fn validate(&self) -> Result<()> {
        for (name, cfg) in &self.configs {
            if let Ok(preset) = ModelConfig::preset(name) {
                if *cfg != preset {
                    bail!(
                        "manifest config '{name}' disagrees with rust preset: \
                         {cfg:?} vs {preset:?} — rebuild artifacts"
                    );
                }
            }
            let specs = crate::model::param_specs(cfg);
            let manifest_specs = self
                .params
                .get(name)
                .with_context(|| format!("manifest has no params for '{name}'"))?;
            if specs.len() != manifest_specs.len() {
                bail!(
                    "param count mismatch for '{name}': rust {} vs manifest {}",
                    specs.len(),
                    manifest_specs.len()
                );
            }
            for ((rn, rs), (mn, ms)) in specs.iter().zip(manifest_specs) {
                if rn != mn || rs != ms {
                    bail!(
                        "param order drift for '{name}': rust ({rn}, {rs:?}) vs \
                         manifest ({mn}, {ms:?})"
                    );
                }
            }
        }
        if self.group == 0 || self.loss_rows == 0 {
            bail!("manifest missing group/loss_rows headers");
        }
        Ok(())
    }

    pub fn artifact(&self, cfg: &str, entry: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(&(cfg.to_string(), entry.to_string()))
            .with_context(|| format!("no artifact '{entry}' for config '{cfg}'"))
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.configs
            .get(name)
            .with_context(|| format!("config '{name}' not in manifest — rebuild artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("faquant_manifest_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.group > 0);
            assert!(!m.configs.is_empty());
            let pico = m.config("pico").unwrap();
            assert_eq!(pico.d_model, 64);
            assert!(m.artifact("pico", "fwd_logits").is_ok());
            assert!(m.artifact("pico", "no_such").is_err());
        }
    }

    #[test]
    fn rejects_param_drift() {
        let d = tmpdir("drift");
        write_manifest(
            &d,
            "group 32\nloss_rows 512\n\
             config pico n_layer=2 d_model=64 n_head=2 d_ff=256 vocab=256 seq=128 batch=4\n\
             param pico 0 WRONG_NAME 256x64\n",
        );
        assert!(Manifest::load(&d).is_err());
        std::fs::remove_dir_all(d).ok();
    }

    #[test]
    fn rejects_config_drift() {
        let d = tmpdir("cfgdrift");
        write_manifest(
            &d,
            "group 32\nloss_rows 512\n\
             config pico n_layer=9 d_model=64 n_head=2 d_ff=256 vocab=256 seq=128 batch=4\n",
        );
        assert!(Manifest::load(&d).is_err());
        std::fs::remove_dir_all(d).ok();
    }

    #[test]
    fn native_manifest_supports_custom_geometry() {
        let m = Manifest::native_with(32, 128);
        assert_eq!(m.group, 32);
        assert_eq!(m.loss_rows, 128);
        assert!(m.artifact("pico", "layer_loss_qkv_b3").is_ok());
    }

    #[test]
    fn native_manifest_covers_all_presets_and_entries() {
        let m = Manifest::native();
        assert_eq!(m.group, NATIVE_GROUP);
        assert_eq!(m.loss_rows, NATIVE_LOSS_ROWS);
        for name in ModelConfig::all_presets() {
            let cfg = m.config(name).unwrap();
            let n = crate::model::param_specs(cfg).len();
            assert_eq!(m.artifact(name, "fwd_logits").unwrap().nargs, n + 1);
            assert_eq!(m.artifact(name, "train_step").unwrap().nargs, 3 * n + 2);
            assert_eq!(
                m.artifact(name, "fwd_logits_q").unwrap().nargs,
                2 + cfg.n_layer * 18 + 3
            );
            assert_eq!(
                m.artifact(name, "decode_step_q").unwrap().nargs,
                2 + cfg.n_layer * 18 + 6
            );
            // The int entries mirror their f32 twins' arities exactly —
            // the engine swaps entry names without touching its args.
            for (f32_entry, qi_entry) in [
                ("fwd_logits_q", "fwd_logits_qi"),
                ("decode_step_q", "decode_step_qi"),
                ("decode_step_paged_q", "decode_step_paged_qi"),
            ] {
                assert_eq!(
                    m.artifact(name, qi_entry).unwrap().nargs,
                    m.artifact(name, f32_entry).unwrap().nargs,
                );
            }
            assert_eq!(m.artifact(name, "layer_loss_qkv_b3").unwrap().nargs, 3);
            assert!(m.artifact(name, "layer_loss_sweep_down_b4").is_ok());
        }
        assert!(m.artifact("pico", "no_such_entry").is_err());
    }

    #[test]
    fn missing_manifest_errors_helpfully() {
        let d = tmpdir("none");
        let err = Manifest::load(&d.join("nope")).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        std::fs::remove_dir_all(d).ok();
    }
}
