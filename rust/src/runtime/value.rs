//! Backend-neutral argument/result values and device-buffer handles.
//!
//! `Value` replaces the raw XLA literal in every artifact signature:
//! the coordinator builds host tensors, wraps them, and gets host tensors
//! back regardless of which [`super::Backend`] executed the entrypoint.
//! `Buffer` is the opaque "uploaded once, reused across executions"
//! handle (§Perf): host memory for the native backend, a device-resident
//! PJRT buffer under the `pjrt` feature.
//!
//! The `lit_*` constructor names are kept from the PJRT-only era so the
//! training/eval/serving call sites read unchanged.

use crate::tensor::{Tensor, TensorI32};
use anyhow::{bail, Result};

/// A host-side artifact argument or result: an f32 or i32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Tensor),
    I32(TensorI32),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(t) => t.shape(),
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(t) => bail!("expected f32 value, got i32 {:?}", t.shape()),
        }
    }

    pub fn as_i32(&self) -> Result<&TensorI32> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(t) => bail!("expected i32 value, got f32 {:?}", t.shape()),
        }
    }
}

/// An uploaded argument: reusable across executions without re-copying.
#[derive(Clone, Debug)]
pub enum Buffer {
    /// Host-resident (native backend): the value itself.
    Host(Value),
    /// A whole quantized weight bundle, prepared once (dequantized into
    /// packed matmul panels — DESIGN.md §11). Stands in for the entire
    /// `fwd_logits_q`/`decode_step_q` weight-prefix argument list; cheap
    /// to clone (shared via `Arc`).
    PreparedQ(std::sync::Arc<super::native::PreparedQModel>),
    /// Device-resident (PJRT backend).
    #[cfg(feature = "pjrt")]
    Device(super::pjrt::DeviceBuffer),
}

impl Buffer {
    /// The host view of this buffer; errors for device-resident buffers
    /// and prepared bundles (neither is a single host tensor).
    pub fn host(&self) -> Result<&Value> {
        match self {
            Buffer::Host(v) => Ok(v),
            Buffer::PreparedQ(_) => bail!("prepared weight bundle has no single host view"),
            #[cfg(feature = "pjrt")]
            Buffer::Device(_) => bail!("device buffer has no host view"),
        }
    }
}

/// f32 tensor -> value with the same shape.
pub fn lit_f32(t: &Tensor) -> Result<Value> {
    Ok(Value::F32(t.clone()))
}

/// i32 tensor -> value with the same shape.
pub fn lit_i32(t: &TensorI32) -> Result<Value> {
    Ok(Value::I32(t.clone()))
}

/// f32 scalar value (shape []).
pub fn lit_scalar(v: f32) -> Result<Value> {
    Ok(Value::F32(Tensor::from_vec(&[], vec![v])?))
}

/// Value -> f32 tensor (shape taken from the value).
pub fn tensor_f32(v: &Value) -> Result<Tensor> {
    Ok(v.as_f32()?.clone())
}

/// Value -> f32 scalar.
pub fn scalar_f32(v: &Value) -> Result<f32> {
    let t = v.as_f32()?;
    if t.numel() != 1 {
        bail!("expected scalar, got shape {:?}", t.shape());
    }
    Ok(t.data()[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn f32_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&mut rng, &[3, 4], 1.0);
        let lit = lit_f32(&t).unwrap();
        let back = tensor_f32(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn i32_shape_preserved() {
        let t = TensorI32::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]).unwrap();
        let lit = lit_i32(&t).unwrap();
        assert_eq!(lit.shape(), &[2, 3]);
        assert_eq!(lit.as_i32().unwrap().data(), &[1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = lit_scalar(7.25).unwrap();
        assert_eq!(scalar_f32(&lit).unwrap(), 7.25);
    }

    #[test]
    fn type_mismatches_rejected() {
        let f = lit_scalar(1.0).unwrap();
        assert!(f.as_i32().is_err());
        let i = lit_i32(&TensorI32::zeros(&[2])).unwrap();
        assert!(i.as_f32().is_err());
        assert!(scalar_f32(&lit_f32(&Tensor::zeros(&[2])).unwrap()).is_err());
    }

    #[test]
    fn host_buffer_roundtrip() {
        let v = lit_scalar(3.5).unwrap();
        let b = Buffer::Host(v.clone());
        assert_eq!(b.host().unwrap(), &v);
    }
}
