//! Tensor <-> xla::Literal conversions.
//!
//! Host is little-endian (x86_64/aarch64 linux); literals are created from
//! raw LE bytes and read back with `to_vec`, so conversions are cheap
//! memcpys.

use crate::tensor::{Tensor, TensorI32};
use anyhow::{Context, Result};
use xla::{ElementType, Literal};

fn as_bytes_f32(v: &[f32]) -> &[u8] {
    // Safety: f32 has no padding; alignment of u8 is 1; LE host.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

fn as_bytes_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// f32 tensor -> literal with the same shape.
pub fn lit_f32(t: &Tensor) -> Result<Literal> {
    Literal::create_from_shape_and_untyped_data(ElementType::F32, t.shape(), as_bytes_f32(t.data()))
        .context("create f32 literal")
}

/// i32 tensor -> literal with the same shape.
pub fn lit_i32(t: &TensorI32) -> Result<Literal> {
    Literal::create_from_shape_and_untyped_data(ElementType::S32, t.shape(), as_bytes_i32(t.data()))
        .context("create i32 literal")
}

/// f32 scalar literal (shape []).
pub fn lit_scalar(v: f32) -> Result<Literal> {
    Literal::create_from_shape_and_untyped_data(ElementType::F32, &[], as_bytes_f32(&[v]))
        .context("create scalar literal")
}

/// Literal -> f32 tensor (shape taken from the literal).
pub fn tensor_f32(lit: &Literal) -> Result<Tensor> {
    let shape = lit.array_shape().context("literal shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = lit.to_vec().context("literal to f32 vec")?;
    Tensor::from_vec(&dims, data)
}

/// Literal -> f32 scalar.
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    let v: f32 = lit.get_first_element().context("scalar literal read")?;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn f32_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&mut rng, &[3, 4], 1.0);
        let lit = lit_f32(&t).unwrap();
        let back = tensor_f32(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn i32_shape_preserved() {
        let t = TensorI32::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]).unwrap();
        let lit = lit_i32(&t).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        let v: Vec<i32> = lit.to_vec().unwrap();
        assert_eq!(v, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = lit_scalar(7.25).unwrap();
        assert_eq!(scalar_f32(&lit).unwrap(), 7.25);
    }
}
