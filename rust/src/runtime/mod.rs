//! Execution runtime (S7): the backend-neutral artifact executor.
//!
//! The [`Runtime`] owns the artifact [`Manifest`] (which entrypoints
//! exist, their arities, the canonical parameter orders), performs the
//! argument-count checks, and keeps [`ExecStats`] counters; the actual
//! execution is delegated to a pluggable [`Backend`]:
//!
//! - **native** (default): [`native::NativeBackend`] runs every entry
//!   in-process on host tensors — no artifacts directory, no python, no
//!   external dependencies. Default builds always use it, so a fresh
//!   offline checkout is runnable.
//! - **pjrt** (`--features pjrt`): loads AOT HLO-text artifacts produced
//!   by `python/compile/aot.py`, compiles each entry once via the PJRT
//!   CPU client, and executes on device buffers (the original S7 path).
//!
//! The runtime is `Sync`: [`Backend`] requires `Send + Sync`, and the
//! stats/prepared bookkeeping sits behind mutexes, so Phase B of the
//! quantization schedule can issue `exec` calls from the thread pool
//! concurrently while [`ExecStats`] accounting stays exact.

mod backend;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
mod registry;
mod value;

pub use backend::Backend;
pub use registry::{qweight_nargs, ArtifactInfo, Manifest, NATIVE_GROUP, NATIVE_LOSS_ROWS};
pub use value::{lit_f32, lit_i32, lit_scalar, scalar_f32, tensor_f32, Buffer, Value};

use anyhow::Result;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Cumulative execution statistics (per entry name). Accumulated in
/// `f64`: a long serving run adds millions of sub-millisecond durations,
/// and `f32` accumulation stops advancing once the total dwarfs each
/// increment (at ~128 s total, adding 5 µs is a no-op in f32).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: usize,
    pub compile_secs: f64,
    pub exec_secs: f64,
}

/// The process-wide runtime: manifest + backend + stats. `Sync` — safe
/// to share across the thread pool (concurrent `exec` is the Phase-B
/// hot path).
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
    /// Ordered so stats reports (and the float total in
    /// [`Runtime::total_exec_secs`]) come out byte-stable run-to-run;
    /// `HashMap` iteration order used to leak into both (faq-lint D1).
    stats: Mutex<BTreeMap<String, ExecStats>>,
    /// Entries already prepared (compiled/validated) — prepare runs once
    /// per entry, keeping the per-exec hot path free of redundant lookups.
    prepared: Mutex<HashSet<String>>,
    /// Prepared quantized weight bundles, keyed by a content fingerprint
    /// of the literal prefix: prepare (dequantize + pack on native,
    /// upload on device backends) runs once per artifact, not once per
    /// engine/serving session or — worse — per step.
    qweights: Mutex<HashMap<u64, Arc<Vec<Buffer>>>>,
}

impl Runtime {
    /// Open a runtime for an artifacts directory.
    ///
    /// Default builds use the native CPU backend (which synthesizes its
    /// manifest from the rust presets and ignores the directory). With
    /// the `pjrt` feature this is the AOT/PJRT path, and a missing
    /// `manifest.txt` is a loud error rather than a silent fallback.
    #[cfg(feature = "pjrt")]
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        // A pjrt build asked for the AOT path explicitly — missing
        // artifacts must fail loudly, not silently swap in the native
        // backend (benches would record the wrong platform's numbers).
        if !artifacts_dir.join("manifest.txt").exists() {
            anyhow::bail!(
                "pjrt build: {} has no manifest.txt — run `make artifacts` \
                 (or build without --features pjrt for the native backend)",
                artifacts_dir.display()
            );
        }
        let manifest = Manifest::load(artifacts_dir)?;
        let backend = Box::new(pjrt::PjrtBackend::new()?);
        Ok(Self {
            manifest,
            backend,
            stats: Mutex::new(BTreeMap::new()),
            prepared: Mutex::new(HashSet::new()),
            qweights: Mutex::new(HashMap::new()),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let _ = artifacts_dir;
        Ok(Self::native())
    }

    /// The always-available pure-Rust reference runtime.
    pub fn native() -> Self {
        Self {
            manifest: Manifest::native(),
            backend: Box::new(native::NativeBackend),
            stats: Mutex::new(BTreeMap::new()),
            prepared: Mutex::new(HashSet::new()),
            qweights: Mutex::new(HashMap::new()),
        }
    }

    /// Native runtime with a custom quantization geometry (for runs with
    /// a non-default `quant.group`; see [`Manifest::native_with`]).
    pub fn native_with(group: usize, loss_rows: usize) -> Self {
        Self {
            manifest: Manifest::native_with(group, loss_rows),
            backend: Box::new(native::NativeBackend),
            stats: Mutex::new(BTreeMap::new()),
            prepared: Mutex::new(HashSet::new()),
            qweights: Mutex::new(HashMap::new()),
        }
    }

    /// Runtime matched to a run configuration: opens `cfg.artifacts_dir`,
    /// and on the native backend re-synthesizes the manifest so its
    /// quantization group matches the run's (the native backend reads the
    /// group dynamically; only the AOT path bakes it into artifacts).
    /// Library callers with a non-default `quant.group` should use this
    /// instead of [`Runtime::new`].
    pub fn for_run(cfg: &crate::config::RunConfig) -> Result<Self> {
        let rt = Self::new(Path::new(&cfg.artifacts_dir))?;
        if rt.platform() == "native-cpu" && rt.manifest.group != cfg.quant.group {
            return Ok(Self::native_with(cfg.quant.group, rt.manifest.loss_rows));
        }
        Ok(rt)
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Execute an artifact on host values: checks arity, runs, records stats.
    pub fn exec(&self, cfg: &str, entry: &str, args: &[Value]) -> Result<Vec<Value>> {
        let info = self.manifest.artifact(cfg, entry)?;
        if args.len() != info.nargs {
            anyhow::bail!(
                "{cfg}/{entry}: got {} args, artifact wants {}",
                args.len(),
                info.nargs
            );
        }
        // First-use compilation is accounted separately from execution
        // (the §9 executor-bound ratio must not absorb compile time).
        self.ensure_prepared(cfg, entry)?;
        let t0 = Instant::now();
        let outs = self.backend.exec(&self.manifest, cfg, entry, args)?;
        self.note_exec(cfg, entry, t0.elapsed().as_secs_f64());
        Ok(outs)
    }

    /// Execute with uploaded input buffers (§Perf: no per-call host copies
    /// of the arguments on device backends). Output handling identical to
    /// [`Runtime::exec`].
    pub fn exec_b<L: std::borrow::Borrow<Buffer>>(
        &self,
        cfg: &str,
        entry: &str,
        args: &[L],
    ) -> Result<Vec<Value>> {
        let info = self.manifest.artifact(cfg, entry)?;
        let refs: Vec<&Buffer> = args.iter().map(|l| l.borrow()).collect();
        // A prepared weight bundle replaces the whole weight prefix of
        // the quantized entries with a single buffer (DESIGN.md §11).
        let prepared_first = refs
            .first()
            .is_some_and(|b| matches!(*b, Buffer::PreparedQ(_)));
        let quantized_entry = matches!(
            entry,
            "fwd_logits_q"
                | "decode_step_q"
                | "decode_step_paged_q"
                | "fwd_logits_qi"
                | "decode_step_qi"
                | "decode_step_paged_qi"
        );
        let want = if prepared_first && quantized_entry {
            let cfgm = self.manifest.config(cfg)?;
            info.nargs - qweight_nargs(cfgm) + 1
        } else {
            info.nargs
        };
        if refs.len() != want {
            anyhow::bail!(
                "{cfg}/{entry}: got {} buffer args, artifact wants {want}",
                refs.len()
            );
        }
        self.ensure_prepared(cfg, entry)?;
        let t0 = Instant::now();
        let outs = self
            .backend
            .exec_buffers(&self.manifest, cfg, entry, &refs)?;
        self.note_exec(cfg, entry, t0.elapsed().as_secs_f64());
        Ok(outs)
    }

    /// Upload a host tensor to a reusable buffer (§Perf: weights and
    /// activation samples are uploaded once and reused across many
    /// executions instead of re-copying per call).
    pub fn upload_f32(&self, t: &crate::tensor::Tensor) -> Result<Buffer> {
        self.backend.upload(Value::F32(t.clone()))
    }

    /// Upload an i32 host tensor.
    pub fn upload_i32(&self, t: &crate::tensor::TensorI32) -> Result<Buffer> {
        self.backend.upload(Value::I32(t.clone()))
    }

    /// Upload a pre-built value (used for literal bundles like the
    /// serving weight set).
    pub fn upload_literal(&self, v: &Value) -> Result<Buffer> {
        self.backend.upload(v.clone())
    }

    /// Prepare a quantized weight bundle (`lits` = the canonical
    /// `fwd_logits_q`/`decode_step_q` weight prefix) for repeated
    /// execution, cached in the runtime's prepared-state map so the work
    /// runs once per artifact — not once per engine, serving session, or
    /// step. On the native backend this dequantizes every linear into
    /// packed matmul panels and returns one `Buffer::PreparedQ` bundle
    /// standing in for the whole prefix (DESIGN.md §11); backends
    /// without a packed representation fall back to uploading each
    /// literal, so callers can splice the result into their argument
    /// list either way. Prepare time is recorded as compile seconds
    /// under `{cfg}/prepare_qweights`.
    pub fn prepare_qweights(&self, cfg: &str, lits: &[Value]) -> Result<Arc<Vec<Buffer>>> {
        let key = weights_fingerprint(cfg, lits);
        // The map lock is held across the build so concurrent preparers
        // of the same artifact cannot both pay the full dequantize+pack
        // ("once per artifact" is the contract). Prepare is rare and
        // coarse; no exec path touches this lock.
        let mut map = self.qweights.lock().unwrap();
        if let Some(bufs) = map.get(&key) {
            return Ok(Arc::clone(bufs));
        }
        let t0 = Instant::now();
        let bufs = match self.backend.prepare_weights(&self.manifest, cfg, lits)? {
            Some(bufs) => bufs,
            None => lits
                .iter()
                .map(|l| self.backend.upload(l.clone()))
                .collect::<Result<Vec<_>>>()?,
        };
        let secs = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.lock().unwrap();
            let s = stats.entry(format!("{cfg}/prepare_qweights")).or_default();
            s.calls += 1;
            s.compile_secs += secs;
        }
        let bufs = Arc::new(bufs);
        map.insert(key, Arc::clone(&bufs));
        Ok(bufs)
    }

    /// Warm the backend for a set of entries (compiles on PJRT; validates
    /// entry names on native).
    pub fn warmup(&self, cfg: &str, entries: &[&str]) -> Result<()> {
        for e in entries {
            self.ensure_prepared(cfg, e)?;
        }
        Ok(())
    }

    /// Prepare (compile/validate) an entry exactly once per runtime,
    /// recording the compile time under the entry's stats. The prepared
    /// set's lock is NOT held across the backend call — a slow compile
    /// of one entry must not stall concurrent execs of already-prepared
    /// entries. Racing preparers of the same entry are harmless: the
    /// backend deduplicates (the PJRT executable cache hands the loser a
    /// cache hit with 0 compile seconds; native prepare is a pure
    /// lookup), so per-entry compile accounting stays correct.
    fn ensure_prepared(&self, cfg: &str, entry: &str) -> Result<()> {
        let key = format!("{cfg}/{entry}");
        if self.prepared.lock().unwrap().contains(&key) {
            return Ok(());
        }
        let secs = self.backend.prepare(&self.manifest, cfg, entry)?;
        self.stats
            .lock()
            .unwrap()
            .entry(key.clone())
            .or_default()
            .compile_secs += f64::from(secs);
        self.prepared.lock().unwrap().insert(key);
        Ok(())
    }

    fn note_exec(&self, cfg: &str, entry: &str, secs: f64) {
        let mut stats = self.stats.lock().unwrap();
        let s = stats.entry(format!("{cfg}/{entry}")).or_default();
        s.calls += 1;
        s.exec_secs += secs;
    }

    pub fn stats(&self) -> BTreeMap<String, ExecStats> {
        self.stats.lock().unwrap().clone()
    }

    /// Prepared-weight-bundle cache entries (artifacts prepared so far).
    pub fn prepared_qweights(&self) -> usize {
        self.qweights.lock().unwrap().len()
    }

    /// Total seconds spent inside backend execution calls.
    pub fn total_exec_secs(&self) -> f64 {
        self.stats.lock().unwrap().values().map(|s| s.exec_secs).sum()
    }
}

/// 64-bit FNV-1a content fingerprint of a weight-literal bundle: config
/// name, then per literal a type tag, the shape, and every element's bit
/// pattern. Keys the runtime's prepared-weights map — identical bundles
/// (e.g. two engines over the same artifact) share one prepared state.
fn weights_fingerprint(cfg: &str, lits: &[Value]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |byte: u8| {
        h ^= byte as u64;
        h = h.wrapping_mul(PRIME);
    };
    for b in cfg.as_bytes() {
        eat(*b);
    }
    for lit in lits {
        for d in lit.shape() {
            for b in (*d as u64).to_le_bytes() {
                eat(b);
            }
        }
        match lit {
            Value::F32(t) => {
                eat(1);
                for v in t.data() {
                    for b in v.to_bits().to_le_bytes() {
                        eat(b);
                    }
                }
            }
            Value::I32(t) => {
                eat(2);
                for v in t.data() {
                    for b in v.to_le_bytes() {
                        eat(b);
                    }
                }
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Rng, TensorI32};

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn native_runtime_always_available() {
        let rt = Runtime::new(Path::new("definitely/not/a/dir")).unwrap();
        assert_eq!(rt.platform(), "native-cpu");
        assert!(rt.manifest.config("pico").is_ok());
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn for_run_matches_quant_group_on_native() {
        let mut cfg = crate::config::RunConfig::new("pico").unwrap();
        cfg.quant.group = 32;
        let rt = Runtime::for_run(&cfg).unwrap();
        assert_eq!(rt.manifest.group, 32);
        // Default group keeps the stock native manifest.
        let rt = Runtime::for_run(&crate::config::RunConfig::new("pico").unwrap()).unwrap();
        assert_eq!(rt.manifest.group, NATIVE_GROUP);
    }

    #[test]
    fn exec_checks_arity_before_running() {
        let rt = Runtime::native();
        let err = rt.exec("pico", "fwd_logits", &[]).unwrap_err();
        assert!(err.to_string().contains("args"), "{err}");
    }

    #[test]
    fn exec_records_stats() {
        let rt = Runtime::native();
        let cfg = crate::config::ModelConfig::preset("pico").unwrap();
        let params = crate::model::Params::init(&cfg, 1);
        let mut rng = Rng::new(2);
        let toks = TensorI32::from_vec(
            &[cfg.batch, cfg.seq],
            (0..cfg.batch * cfg.seq)
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect(),
        )
        .unwrap();
        let mut args: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| lit_f32(t).unwrap())
            .collect();
        args.push(lit_i32(&toks).unwrap());
        rt.exec("pico", "fwd_logits", &args).unwrap();
        rt.exec("pico", "fwd_logits", &args).unwrap();
        let stats = rt.stats();
        assert_eq!(stats["pico/fwd_logits"].calls, 2);
        assert!(rt.total_exec_secs() >= 0.0);
    }

    #[test]
    fn weights_fingerprint_sensitive_to_content_and_cfg() {
        let a = Value::F32(crate::tensor::Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap());
        let b = Value::F32(crate::tensor::Tensor::from_vec(&[2, 2], vec![1., 2., 3., 5.]).unwrap());
        let base = weights_fingerprint("pico", std::slice::from_ref(&a));
        assert_eq!(base, weights_fingerprint("pico", std::slice::from_ref(&a)));
        assert_ne!(base, weights_fingerprint("pico", std::slice::from_ref(&b)));
        assert_ne!(base, weights_fingerprint("nano", std::slice::from_ref(&a)));
        // Shape participates even when the data matches.
        let flat = Value::F32(crate::tensor::Tensor::from_vec(&[4], vec![1., 2., 3., 4.]).unwrap());
        assert_ne!(base, weights_fingerprint("pico", std::slice::from_ref(&flat)));
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn prepare_qweights_caches_per_artifact() {
        let rt = Runtime::native();
        let cfg = crate::config::ModelConfig::preset("pico").unwrap();
        let params = crate::model::Params::init(&cfg, 3);
        let qcfg = crate::config::QuantConfig::with_method(crate::config::Method::Rtn);
        let qm = crate::quant::quantize_model(&rt, &qcfg, &params, None).unwrap();
        let lits = crate::serve::qmodel_literals(&params, &qm).unwrap();
        let a = rt.prepare_qweights(&cfg.name, &lits).unwrap();
        assert_eq!(a.len(), 1, "native backend returns one bundle buffer");
        assert!(matches!(a[0], Buffer::PreparedQ(_)));
        let b = rt.prepare_qweights(&cfg.name, &lits).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second prepare must hit the cache");
        assert_eq!(rt.prepared_qweights(), 1);
        assert_eq!(rt.stats()["pico/prepare_qweights"].calls, 1);
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn exec_b_checks_prepared_arity() {
        let rt = Runtime::native();
        let cfg = crate::config::ModelConfig::preset("pico").unwrap();
        let params = crate::model::Params::init(&cfg, 3);
        let qcfg = crate::config::QuantConfig::with_method(crate::config::Method::Rtn);
        let qm = crate::quant::quantize_model(&rt, &qcfg, &params, None).unwrap();
        let lits = crate::serve::qmodel_literals(&params, &qm).unwrap();
        let bufs = rt.prepare_qweights(&cfg.name, &lits).unwrap();
        // Bundle alone (missing the trailing tokens) must be rejected.
        let args: Vec<&Buffer> = bufs.iter().collect();
        let err = rt.exec_b(&cfg.name, "fwd_logits_q", &args).unwrap_err();
        assert!(err.to_string().contains("buffer args"), "{err}");
        // Bundle is rejected outright for non-quantized entries.
        let err = rt.exec_b(&cfg.name, "fwd_logits", &args).unwrap_err();
        assert!(err.to_string().contains("buffer args"), "{err}");
    }

    #[test]
    fn warmup_validates_entries() {
        let rt = Runtime::native();
        rt.warmup("pico", &["fwd_logits", "train_step"]).unwrap();
        assert!(rt.warmup("pico", &["nonexistent"]).is_err());
        // Native warmup compiles nothing.
        assert_eq!(rt.stats()["pico/fwd_logits"].compile_secs, 0.0);
    }
}
