//! PJRT runtime (S7): load AOT HLO-text artifacts, compile once, execute
//! from the L3 hot path.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Executables are compiled on first use
//! and cached for the process lifetime; all entrypoints lower with
//! `return_tuple=True`, so outputs are always un-tupled here.
//!
//! The runtime also keeps lightweight counters (`ExecStats`) used by the
//! perf pass to verify the coordinator is executor-bound (DESIGN.md §9).

mod literals;
mod registry;

pub use literals::{lit_f32, lit_i32, lit_scalar, scalar_f32, tensor_f32};
pub use registry::{ArtifactInfo, Manifest};

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// Cumulative execution statistics (per entry name).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: usize,
    pub compile_secs: f32,
    pub exec_secs: f32,
}

/// The process-wide runtime: one PJRT CPU client + executable cache.
///
/// Not `Sync` (PJRT pointers are not thread-safe here); multi-threaded
/// users own a `Runtime` per dedicated executor thread (see
/// [`crate::serve`]).
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<(String, String), Rc<PjRtLoadedExecutable>>>,
    stats: RefCell<HashMap<String, ExecStats>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for (cfg, entry).
    pub fn executable(&self, cfg: &str, entry: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        let key = (cfg.to_string(), entry.to_string());
        if let Some(exe) = self.exes.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let info = self.manifest.artifact(cfg, entry)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&info.path)
            .with_context(|| format!("parse HLO text {}", info.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compile {cfg}/{entry}"))?,
        );
        let dt = t0.elapsed().as_secs_f32();
        self.stats
            .borrow_mut()
            .entry(format!("{cfg}/{entry}"))
            .or_default()
            .compile_secs += dt;
        self.exes.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute an artifact: checks arity, runs, un-tuples the output.
    pub fn exec(&self, cfg: &str, entry: &str, args: &[Literal]) -> Result<Vec<Literal>> {
        let info = self.manifest.artifact(cfg, entry)?;
        if args.len() != info.nargs {
            anyhow::bail!(
                "{cfg}/{entry}: got {} args, artifact wants {}",
                args.len(),
                info.nargs
            );
        }
        let exe = self.executable(cfg, entry)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<Literal>(args)
            .with_context(|| format!("execute {cfg}/{entry}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("download result literal")?;
        let outs = lit.to_tuple().context("untuple result")?;
        let dt = t0.elapsed().as_secs_f32();
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(format!("{cfg}/{entry}")).or_default();
        s.calls += 1;
        s.exec_secs += dt;
        Ok(outs)
    }

    /// Upload a host tensor to a device-resident buffer (§Perf: weights
    /// and activation samples are uploaded once and reused across many
    /// executions instead of re-copying a Literal per call).
    pub fn upload_f32(&self, t: &crate::tensor::Tensor) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(t.data(), t.shape(), None)
            .context("upload f32 buffer")
    }

    /// Upload a host literal to a device buffer (used for pre-built
    /// literal bundles like the serving weight set).
    pub fn upload_literal(&self, lit: &Literal) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .context("upload literal buffer")
    }

    /// Upload an i32 host tensor to a device buffer.
    pub fn upload_i32(&self, t: &crate::tensor::TensorI32) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(t.data(), t.shape(), None)
            .context("upload i32 buffer")
    }

    /// Execute with device-resident input buffers (no per-call host
    /// copies of the arguments). Output handling identical to [`exec`].
    pub fn exec_b<L: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        cfg: &str,
        entry: &str,
        args: &[L],
    ) -> Result<Vec<Literal>> {
        let info = self.manifest.artifact(cfg, entry)?;
        if args.len() != info.nargs {
            anyhow::bail!(
                "{cfg}/{entry}: got {} buffer args, artifact wants {}",
                args.len(),
                info.nargs
            );
        }
        let exe = self.executable(cfg, entry)?;
        let t0 = Instant::now();
        let result = exe
            .execute_b(args)
            .with_context(|| format!("execute_b {cfg}/{entry}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("download result literal")?;
        let outs = lit.to_tuple().context("untuple result")?;
        let dt = t0.elapsed().as_secs_f32();
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(format!("{cfg}/{entry}")).or_default();
        s.calls += 1;
        s.exec_secs += dt;
        Ok(outs)
    }

    /// Warm the executable cache for a set of entries.
    pub fn warmup(&self, cfg: &str, entries: &[&str]) -> Result<()> {
        for e in entries {
            self.executable(cfg, e)?;
        }
        Ok(())
    }

    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    /// Total seconds spent inside PJRT `execute` calls.
    pub fn total_exec_secs(&self) -> f32 {
        self.stats.borrow().values().map(|s| s.exec_secs).sum()
    }
}
