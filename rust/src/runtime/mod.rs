//! Execution runtime (S7): the backend-neutral artifact executor.
//!
//! The [`Runtime`] owns the artifact [`Manifest`] (which entrypoints
//! exist, their arities, the canonical parameter orders), performs the
//! argument-count checks, and keeps [`ExecStats`] counters; the actual
//! execution is delegated to a pluggable [`Backend`]:
//!
//! - **native** (default): [`native::NativeBackend`] runs every entry
//!   in-process on host tensors — no artifacts directory, no python, no
//!   external dependencies. Default builds always use it, so a fresh
//!   offline checkout is runnable.
//! - **pjrt** (`--features pjrt`): loads AOT HLO-text artifacts produced
//!   by `python/compile/aot.py`, compiles each entry once via the PJRT
//!   CPU client, and executes on device buffers (the original S7 path).
//!
//! The runtime is `Sync`: [`Backend`] requires `Send + Sync`, and the
//! stats/prepared bookkeeping sits behind mutexes, so Phase B of the
//! quantization schedule can issue `exec` calls from the thread pool
//! concurrently while [`ExecStats`] accounting stays exact.

mod backend;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
mod registry;
mod value;

pub use backend::Backend;
pub use registry::{ArtifactInfo, Manifest, NATIVE_GROUP, NATIVE_LOSS_ROWS};
pub use value::{lit_f32, lit_i32, lit_scalar, scalar_f32, tensor_f32, Buffer, Value};

use anyhow::Result;
use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

/// Cumulative execution statistics (per entry name).
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub calls: usize,
    pub compile_secs: f32,
    pub exec_secs: f32,
}

/// The process-wide runtime: manifest + backend + stats. `Sync` — safe
/// to share across the thread pool (concurrent `exec` is the Phase-B
/// hot path).
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
    stats: Mutex<HashMap<String, ExecStats>>,
    /// Entries already prepared (compiled/validated) — prepare runs once
    /// per entry, keeping the per-exec hot path free of redundant lookups.
    prepared: Mutex<HashSet<String>>,
}

impl Runtime {
    /// Open a runtime for an artifacts directory.
    ///
    /// Default builds use the native CPU backend (which synthesizes its
    /// manifest from the rust presets and ignores the directory). With
    /// the `pjrt` feature this is the AOT/PJRT path, and a missing
    /// `manifest.txt` is a loud error rather than a silent fallback.
    #[cfg(feature = "pjrt")]
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        // A pjrt build asked for the AOT path explicitly — missing
        // artifacts must fail loudly, not silently swap in the native
        // backend (benches would record the wrong platform's numbers).
        if !artifacts_dir.join("manifest.txt").exists() {
            anyhow::bail!(
                "pjrt build: {} has no manifest.txt — run `make artifacts` \
                 (or build without --features pjrt for the native backend)",
                artifacts_dir.display()
            );
        }
        let manifest = Manifest::load(artifacts_dir)?;
        let backend = Box::new(pjrt::PjrtBackend::new()?);
        Ok(Self {
            manifest,
            backend,
            stats: Mutex::new(HashMap::new()),
            prepared: Mutex::new(HashSet::new()),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let _ = artifacts_dir;
        Ok(Self::native())
    }

    /// The always-available pure-Rust reference runtime.
    pub fn native() -> Self {
        Self {
            manifest: Manifest::native(),
            backend: Box::new(native::NativeBackend),
            stats: Mutex::new(HashMap::new()),
            prepared: Mutex::new(HashSet::new()),
        }
    }

    /// Native runtime with a custom quantization geometry (for runs with
    /// a non-default `quant.group`; see [`Manifest::native_with`]).
    pub fn native_with(group: usize, loss_rows: usize) -> Self {
        Self {
            manifest: Manifest::native_with(group, loss_rows),
            backend: Box::new(native::NativeBackend),
            stats: Mutex::new(HashMap::new()),
            prepared: Mutex::new(HashSet::new()),
        }
    }

    /// Runtime matched to a run configuration: opens `cfg.artifacts_dir`,
    /// and on the native backend re-synthesizes the manifest so its
    /// quantization group matches the run's (the native backend reads the
    /// group dynamically; only the AOT path bakes it into artifacts).
    /// Library callers with a non-default `quant.group` should use this
    /// instead of [`Runtime::new`].
    pub fn for_run(cfg: &crate::config::RunConfig) -> Result<Self> {
        let rt = Self::new(Path::new(&cfg.artifacts_dir))?;
        if rt.platform() == "native-cpu" && rt.manifest.group != cfg.quant.group {
            return Ok(Self::native_with(cfg.quant.group, rt.manifest.loss_rows));
        }
        Ok(rt)
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Execute an artifact on host values: checks arity, runs, records stats.
    pub fn exec(&self, cfg: &str, entry: &str, args: &[Value]) -> Result<Vec<Value>> {
        let info = self.manifest.artifact(cfg, entry)?;
        if args.len() != info.nargs {
            anyhow::bail!(
                "{cfg}/{entry}: got {} args, artifact wants {}",
                args.len(),
                info.nargs
            );
        }
        // First-use compilation is accounted separately from execution
        // (the §9 executor-bound ratio must not absorb compile time).
        self.ensure_prepared(cfg, entry)?;
        let t0 = Instant::now();
        let outs = self.backend.exec(&self.manifest, cfg, entry, args)?;
        self.note_exec(cfg, entry, t0.elapsed().as_secs_f32());
        Ok(outs)
    }

    /// Execute with uploaded input buffers (§Perf: no per-call host copies
    /// of the arguments on device backends). Output handling identical to
    /// [`Runtime::exec`].
    pub fn exec_b<L: std::borrow::Borrow<Buffer>>(
        &self,
        cfg: &str,
        entry: &str,
        args: &[L],
    ) -> Result<Vec<Value>> {
        let info = self.manifest.artifact(cfg, entry)?;
        if args.len() != info.nargs {
            anyhow::bail!(
                "{cfg}/{entry}: got {} buffer args, artifact wants {}",
                args.len(),
                info.nargs
            );
        }
        let refs: Vec<&Buffer> = args.iter().map(|l| l.borrow()).collect();
        self.ensure_prepared(cfg, entry)?;
        let t0 = Instant::now();
        let outs = self
            .backend
            .exec_buffers(&self.manifest, cfg, entry, &refs)?;
        self.note_exec(cfg, entry, t0.elapsed().as_secs_f32());
        Ok(outs)
    }

    /// Upload a host tensor to a reusable buffer (§Perf: weights and
    /// activation samples are uploaded once and reused across many
    /// executions instead of re-copying per call).
    pub fn upload_f32(&self, t: &crate::tensor::Tensor) -> Result<Buffer> {
        self.backend.upload(Value::F32(t.clone()))
    }

    /// Upload an i32 host tensor.
    pub fn upload_i32(&self, t: &crate::tensor::TensorI32) -> Result<Buffer> {
        self.backend.upload(Value::I32(t.clone()))
    }

    /// Upload a pre-built value (used for literal bundles like the
    /// serving weight set).
    pub fn upload_literal(&self, v: &Value) -> Result<Buffer> {
        self.backend.upload(v.clone())
    }

    /// Warm the backend for a set of entries (compiles on PJRT; validates
    /// entry names on native).
    pub fn warmup(&self, cfg: &str, entries: &[&str]) -> Result<()> {
        for e in entries {
            self.ensure_prepared(cfg, e)?;
        }
        Ok(())
    }

    /// Prepare (compile/validate) an entry exactly once per runtime,
    /// recording the compile time under the entry's stats. The prepared
    /// set's lock is NOT held across the backend call — a slow compile
    /// of one entry must not stall concurrent execs of already-prepared
    /// entries. Racing preparers of the same entry are harmless: the
    /// backend deduplicates (the PJRT executable cache hands the loser a
    /// cache hit with 0 compile seconds; native prepare is a pure
    /// lookup), so per-entry compile accounting stays correct.
    fn ensure_prepared(&self, cfg: &str, entry: &str) -> Result<()> {
        let key = format!("{cfg}/{entry}");
        if self.prepared.lock().unwrap().contains(&key) {
            return Ok(());
        }
        let secs = self.backend.prepare(&self.manifest, cfg, entry)?;
        self.stats
            .lock()
            .unwrap()
            .entry(key.clone())
            .or_default()
            .compile_secs += secs;
        self.prepared.lock().unwrap().insert(key);
        Ok(())
    }

    fn note_exec(&self, cfg: &str, entry: &str, secs: f32) {
        let mut stats = self.stats.lock().unwrap();
        let s = stats.entry(format!("{cfg}/{entry}")).or_default();
        s.calls += 1;
        s.exec_secs += secs;
    }

    pub fn stats(&self) -> HashMap<String, ExecStats> {
        self.stats.lock().unwrap().clone()
    }

    /// Total seconds spent inside backend execution calls.
    pub fn total_exec_secs(&self) -> f32 {
        self.stats.lock().unwrap().values().map(|s| s.exec_secs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Rng, TensorI32};

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn native_runtime_always_available() {
        let rt = Runtime::new(Path::new("definitely/not/a/dir")).unwrap();
        assert_eq!(rt.platform(), "native-cpu");
        assert!(rt.manifest.config("pico").is_ok());
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn for_run_matches_quant_group_on_native() {
        let mut cfg = crate::config::RunConfig::new("pico").unwrap();
        cfg.quant.group = 32;
        let rt = Runtime::for_run(&cfg).unwrap();
        assert_eq!(rt.manifest.group, 32);
        // Default group keeps the stock native manifest.
        let rt = Runtime::for_run(&crate::config::RunConfig::new("pico").unwrap()).unwrap();
        assert_eq!(rt.manifest.group, NATIVE_GROUP);
    }

    #[test]
    fn exec_checks_arity_before_running() {
        let rt = Runtime::native();
        let err = rt.exec("pico", "fwd_logits", &[]).unwrap_err();
        assert!(err.to_string().contains("args"), "{err}");
    }

    #[test]
    fn exec_records_stats() {
        let rt = Runtime::native();
        let cfg = crate::config::ModelConfig::preset("pico").unwrap();
        let params = crate::model::Params::init(&cfg, 1);
        let mut rng = Rng::new(2);
        let toks = TensorI32::from_vec(
            &[cfg.batch, cfg.seq],
            (0..cfg.batch * cfg.seq)
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect(),
        )
        .unwrap();
        let mut args: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| lit_f32(t).unwrap())
            .collect();
        args.push(lit_i32(&toks).unwrap());
        rt.exec("pico", "fwd_logits", &args).unwrap();
        rt.exec("pico", "fwd_logits", &args).unwrap();
        let stats = rt.stats();
        assert_eq!(stats["pico/fwd_logits"].calls, 2);
        assert!(rt.total_exec_secs() >= 0.0);
    }

    #[test]
    fn warmup_validates_entries() {
        let rt = Runtime::native();
        rt.warmup("pico", &["fwd_logits", "train_step"]).unwrap();
        assert!(rt.warmup("pico", &["nonexistent"]).is_err());
        // Native warmup compiles nothing.
        assert_eq!(rt.stats()["pico/fwd_logits"].compile_secs, 0.0);
    }
}
