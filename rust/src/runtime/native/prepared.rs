//! Dequantize-once prepared quantized model (DESIGN.md §11).
//!
//! The seed serving path re-materializes the full dequantized f32 weight
//! matrix of every linear on **every call** — each decode step pays
//! O(Σ n·m) dequantization plus the allocation traffic for it, per
//! token. [`PreparedQModel`] moves all of that to artifact-prepare time:
//! the weight bundle is parsed once, each linear's codes are dequantized
//! with the exact per-call expression ([`qmodel::dequant_into`]) and the
//! values are written *directly* into the packed panel layout the
//! blocked matmul microkernel consumes ([`PackedB`]) — the unpacked
//! weight matrix never exists as a separate intermediate, and step time
//! performs **no weight dequantization and no weight-panel packing**.
//!
//! The per-input-channel `inv_s` smoothing scale deliberately stays on
//! the activation side (applied into a per-thread scratch-arena buffer
//! per call, O(rows·n) — noise next to the O(rows·n·m) matmul). Folding
//! it into the weights (`W' = diag(inv_s)·dequant(q)`) is algebraically
//! identical but NOT bitwise stable in f32: `(x·s)·w != x·(s·w)` in
//! general (multiplication rounds once per operation and is not
//! associative), and bit-identity with the seed path is a hard contract
//! (DESIGN.md §10, pinned by `tests/props.rs`). See DESIGN.md §11.
//!
//! A steady-state decode step's quantized-linear path is allocation-free:
//! scaled activations and matmul outputs cycle through
//! [`crate::tensor::arena`] (pinned by `benches/alloc_probe.rs`).

use super::qmodel::{self, QLin, QWeights};
use crate::config::ModelConfig;
use crate::runtime::value::Value;
use crate::tensor::{arena, PackedB, Tensor};
use anyhow::{bail, Result};

/// One linear, prepared: dequantized weight panels + its smoothing scale.
#[derive(Debug)]
pub(super) struct PreparedLin {
    /// Per-input-channel smoothing scale, applied to the activation.
    pub inv_s: Vec<f32>,
    /// `dequant(q)` `[n, m]`, packed once into the matmul panel layout.
    pub w: PackedB,
}

impl PreparedLin {
    fn build(l: &QLin, group: usize) -> Result<Self> {
        let (n, m) = (l.q.shape()[0], l.q.shape()[1]);
        if l.inv_s.numel() != n {
            bail!("inv_s len {} != codes rows {n}", l.inv_s.numel());
        }
        // Fused dequant-and-pack: the dequant loop writes straight into
        // the panel buffer the kernel will consume.
        let mut panels = vec![0.0f32; n * m];
        qmodel::dequant_into(l, group, &mut panels)?;
        Ok(Self {
            inv_s: l.inv_s.data().to_vec(),
            w: PackedB::from_parts(n, m, panels)?,
        })
    }
}

/// One block, prepared: norm gains + four prepared linears (ROLES order).
#[derive(Debug)]
pub(super) struct PreparedBlock {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub lins: Vec<PreparedLin>,
}

/// A quantized deployment artifact, prepared once for an allocation-free
/// per-token hot path: dequantized packed weight panels per linear, a
/// prepacked head projection, and owned copies of the small dense
/// tensors (embeddings, norm gains).
#[derive(Debug)]
pub struct PreparedQModel {
    /// Model config the bundle was prepared for (revalidated at exec).
    pub(super) cfg: ModelConfig,
    /// Quantization group size baked into the panels.
    pub(super) group: usize,
    pub(super) tok_emb: Tensor,
    pub(super) pos_emb: Tensor,
    pub(super) blocks: Vec<PreparedBlock>,
    pub(super) lnf_g: Vec<f32>,
    pub(super) w_head: PackedB,
}

impl PreparedQModel {
    /// Parse + pack a `fwd_logits_q`/`decode_step_q` weight prefix.
    /// `args` must be exactly the [`qmodel::qweight_nargs`] weight
    /// values in canonical order.
    pub(super) fn build(cfg: &ModelConfig, group: usize, args: &[&Value]) -> Result<Self> {
        let want = qmodel::qweight_nargs(cfg);
        if args.len() != want {
            bail!(
                "prepare_weights({}): got {} weight args, want {want}",
                cfg.name,
                args.len()
            );
        }
        let wts = QWeights::parse(cfg, args)?;
        let mut blocks = Vec::with_capacity(wts.blocks.len());
        for blk in &wts.blocks {
            let lins = blk
                .lins
                .iter()
                .map(|l| PreparedLin::build(l, group))
                .collect::<Result<Vec<_>>>()?;
            blocks.push(PreparedBlock {
                ln1: blk.ln1.data().to_vec(),
                ln2: blk.ln2.data().to_vec(),
                lins,
            });
        }
        Ok(Self {
            cfg: cfg.clone(),
            group,
            tok_emb: wts.tok_emb.clone(),
            pos_emb: wts.pos_emb.clone(),
            blocks,
            lnf_g: wts.lnf_g.data().to_vec(),
            w_head: PackedB::from_tensor(wts.w_head)?,
        })
    }

    /// Guard against executing a bundle under a different config or
    /// quantization geometry than it was prepared for.
    pub(super) fn check_matches(&self, cfg: &ModelConfig, group: usize) -> Result<()> {
        if self.cfg != *cfg {
            bail!(
                "prepared weights were built for config '{}', executed as '{}'",
                self.cfg.name,
                cfg.name
            );
        }
        if self.group != group {
            bail!(
                "prepared weights baked group {}, runtime wants {group}",
                self.group
            );
        }
        Ok(())
    }

    /// Quantized linear on prepared panels: scale the activation rows by
    /// `inv_s` into a scratch buffer, then one prepacked matmul. Zero
    /// weight work, zero allocations once the arena is warm.
    pub(super) fn lin(&self, b: usize, role: usize, x: &Tensor) -> Result<Tensor> {
        let lin = &self.blocks[b].lins[role];
        let n = x.shape()[1];
        if lin.inv_s.len() != n {
            bail!("inv_s len {} != activation cols {n}", lin.inv_s.len());
        }
        let rows = x.shape()[0];
        let mut scaled = arena::take(&[rows, n]);
        qmodel::scale_rows(x.data(), &lin.inv_s, rows, n, scaled.data_mut());
        let mut out = arena::take(&[rows, lin.w.c()]);
        let res = scaled.matmul_prepacked(&lin.w, out.data_mut());
        arena::give(scaled);
        res?;
        Ok(out)
    }

    /// Head projection on the prepacked `w_head` panels (arena-backed).
    pub(super) fn head(&self, hf: &Tensor) -> Result<Tensor> {
        let rows = hf.shape()[0];
        let mut out = arena::take(&[rows, self.w_head.c()]);
        hf.matmul_prepacked(&self.w_head, out.data_mut())?;
        Ok(out)
    }
}
