//! Dequantize-once prepared quantized model (DESIGN.md §11).
//!
//! The seed serving path re-materializes the full dequantized f32 weight
//! matrix of every linear on **every call** — each decode step pays
//! O(Σ n·m) dequantization plus the allocation traffic for it, per
//! token. [`PreparedQModel`] moves all of that to artifact-prepare time:
//! the weight bundle is parsed once, each linear's codes are dequantized
//! with the exact per-call expression ([`qmodel::dequant_into`]) and the
//! values are written *directly* into the packed panel layout the
//! blocked matmul microkernel consumes ([`PackedB`]) — the unpacked
//! weight matrix never exists as a separate intermediate, and step time
//! performs **no weight dequantization and no weight-panel packing**.
//!
//! The per-input-channel `inv_s` smoothing scale deliberately stays on
//! the activation side (applied into a per-thread scratch-arena buffer
//! per call, O(rows·n) — noise next to the O(rows·n·m) matmul). Folding
//! it into the weights (`W' = diag(inv_s)·dequant(q)`) is algebraically
//! identical but NOT bitwise stable in f32: `(x·s)·w != x·(s·w)` in
//! general (multiplication rounds once per operation and is not
//! associative), and bit-identity with the seed path is a hard contract
//! (DESIGN.md §10, pinned by `tests/props.rs`). See DESIGN.md §11.
//!
//! A steady-state decode step's quantized-linear path is allocation-free:
//! scaled activations and matmul outputs cycle through
//! [`crate::tensor::arena`] (pinned by `benches/alloc_probe.rs`).

use super::qmodel::{self, QLin, QWeights};
use crate::config::ModelConfig;
use crate::runtime::value::Value;
use crate::tensor::{arena, intkern, PackedB, PackedIntB, Tensor};
use anyhow::{bail, Result};

/// One linear, prepared: dequantized weight panels + its smoothing scale.
#[derive(Debug)]
pub(super) struct PreparedLin {
    /// Per-input-channel smoothing scale, applied to the activation.
    pub inv_s: Vec<f32>,
    /// `dequant(q)` `[n, m]`, packed once into the matmul panel layout.
    pub w: PackedB,
    /// The same codes packed for the int8×int4 kernel, when they fit in
    /// int4 (bits <= 4). `None` carries no loss of function — the f32
    /// panels above are always present — it only gates `int_compute`.
    pub wi: Option<PackedIntB>,
}

impl PreparedLin {
    /// Build the f32 panels (always) and the int panels (when the codes
    /// are int4-representable). Returns the reason the int panels are
    /// unavailable, if they are.
    fn build(l: &QLin, group: usize) -> Result<(Self, Option<String>)> {
        let (n, m) = (l.q.shape()[0], l.q.shape()[1]);
        if l.inv_s.numel() != n {
            bail!("inv_s len {} != codes rows {n}", l.inv_s.numel());
        }
        // Fused dequant-and-pack: the dequant loop writes straight into
        // the panel buffer the kernel will consume.
        let mut panels = vec![0.0f32; n * m];
        qmodel::dequant_into(l, group, &mut panels)?;
        let (wi, int_reason) = match PackedIntB::from_codes(l.q, l.delta, l.zero, group) {
            Ok(p) => (Some(p), None),
            Err(e) => (None, Some(e.to_string())),
        };
        Ok((
            Self {
                inv_s: l.inv_s.data().to_vec(),
                w: PackedB::from_parts(n, m, panels)?,
                wi,
            },
            int_reason,
        ))
    }
}

/// One block, prepared: norm gains + four prepared linears (ROLES order).
#[derive(Debug)]
pub(super) struct PreparedBlock {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub lins: Vec<PreparedLin>,
}

/// A quantized deployment artifact, prepared once for an allocation-free
/// per-token hot path: dequantized packed weight panels per linear, a
/// prepacked head projection, and owned copies of the small dense
/// tensors (embeddings, norm gains).
#[derive(Debug)]
pub struct PreparedQModel {
    /// Model config the bundle was prepared for (revalidated at exec).
    pub(super) cfg: ModelConfig,
    /// Quantization group size baked into the panels.
    pub(super) group: usize,
    pub(super) tok_emb: Tensor,
    pub(super) pos_emb: Tensor,
    pub(super) blocks: Vec<PreparedBlock>,
    pub(super) lnf_g: Vec<f32>,
    pub(super) w_head: PackedB,
    /// Why the int8×int4 path is unavailable (first offending linear),
    /// `None` when every block linear packed int panels.
    int_reason: Option<String>,
}

impl PreparedQModel {
    /// Parse + pack a `fwd_logits_q`/`decode_step_q` weight prefix.
    /// `args` must be exactly the [`qmodel::qweight_nargs`] weight
    /// values in canonical order.
    pub(super) fn build(cfg: &ModelConfig, group: usize, args: &[&Value]) -> Result<Self> {
        let want = qmodel::qweight_nargs(cfg);
        if args.len() != want {
            bail!(
                "prepare_weights({}): got {} weight args, want {want}",
                cfg.name,
                args.len()
            );
        }
        let wts = QWeights::parse(cfg, args)?;
        let mut blocks = Vec::with_capacity(wts.blocks.len());
        let mut int_reason: Option<String> = None;
        for blk in &wts.blocks {
            let mut lins = Vec::with_capacity(blk.lins.len());
            for l in &blk.lins {
                let (lin, reason) = PreparedLin::build(l, group)?;
                if int_reason.is_none() {
                    int_reason = reason;
                }
                lins.push(lin);
            }
            blocks.push(PreparedBlock {
                ln1: blk.ln1.data().to_vec(),
                ln2: blk.ln2.data().to_vec(),
                lins,
            });
        }
        Ok(Self {
            cfg: cfg.clone(),
            group,
            tok_emb: wts.tok_emb.clone(),
            pos_emb: wts.pos_emb.clone(),
            blocks,
            lnf_g: wts.lnf_g.data().to_vec(),
            w_head: PackedB::from_tensor(wts.w_head)?,
            int_reason,
        })
    }

    /// Guard against executing a bundle under a different config or
    /// quantization geometry than it was prepared for.
    pub(super) fn check_matches(&self, cfg: &ModelConfig, group: usize) -> Result<()> {
        if self.cfg != *cfg {
            bail!(
                "prepared weights were built for config '{}', executed as '{}'",
                self.cfg.name,
                cfg.name
            );
        }
        if self.group != group {
            bail!(
                "prepared weights baked group {}, runtime wants {group}",
                self.group
            );
        }
        Ok(())
    }

    /// Quantized linear on prepared panels: scale the activation rows by
    /// `inv_s` into a scratch buffer, then one prepacked matmul. Zero
    /// weight work, zero allocations once the arena is warm.
    pub(super) fn lin(&self, b: usize, role: usize, x: &Tensor) -> Result<Tensor> {
        let lin = &self.blocks[b].lins[role];
        let n = x.shape()[1];
        if lin.inv_s.len() != n {
            bail!("inv_s len {} != activation cols {n}", lin.inv_s.len());
        }
        let rows = x.shape()[0];
        let mut scaled = arena::take(&[rows, n]);
        qmodel::scale_rows(x.data(), &lin.inv_s, rows, n, scaled.data_mut());
        let mut out = arena::take(&[rows, lin.w.c()]);
        let res = scaled.matmul_prepacked(&lin.w, out.data_mut());
        arena::give(scaled);
        res?;
        Ok(out)
    }

    /// Quantized linear on the int8×int4 path: scale the activation rows
    /// by `inv_s` into a scratch buffer (identical bits to the f32 path's
    /// scaling), then quantize each row to i8 and run the fused kernel on
    /// the packed codes. Zero weight dequantization ever; zero
    /// allocations once arena + int scratch are warm.
    pub(super) fn lin_int(&self, b: usize, role: usize, x: &Tensor) -> Result<Tensor> {
        let lin = &self.blocks[b].lins[role];
        let Some(wi) = &lin.wi else {
            bail!(
                "no int panels for block {b} linear {role}: {}",
                self.int_reason.as_deref().unwrap_or("not packed")
            );
        };
        let n = x.shape()[1];
        if lin.inv_s.len() != n {
            bail!("inv_s len {} != activation cols {n}", lin.inv_s.len());
        }
        let rows = x.shape()[0];
        let mut scaled = arena::take(&[rows, n]);
        qmodel::scale_rows(x.data(), &lin.inv_s, rows, n, scaled.data_mut());
        let mut out = arena::take(&[rows, wi.c()]);
        let res = intkern::matmul_int(&scaled, wi, out.data_mut());
        arena::give(scaled);
        res?;
        Ok(out)
    }

    /// Test support for the differential props tests (DESIGN.md §17):
    /// one prepared linear through BOTH paths on the same activations.
    /// Returns `(scaled activations, dequantized panel, f32 out, int
    /// out)` — the scaled rows and panel columns are exactly the inputs
    /// [`intkern::row_error_bound`] derives the tolerance from. The
    /// panel is recovered through the packed matmul itself (identity
    /// activations), so the comparison sees the same weights the f32
    /// kernel reads.
    #[doc(hidden)]
    pub fn qlin_diff(
        &self,
        b: usize,
        role: usize,
        x: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor, Tensor)> {
        let lin = &self.blocks[b].lins[role];
        let rows = x.shape()[0];
        let n = x.shape()[1];
        if lin.inv_s.len() != n {
            bail!("inv_s len {} != activation cols {n}", lin.inv_s.len());
        }
        let mut scaled = Tensor::zeros(&[rows, n]);
        qmodel::scale_rows(x.data(), &lin.inv_s, rows, n, scaled.data_mut());
        let mut eye = Tensor::zeros(&[n, n]);
        for i in 0..n {
            eye.data_mut()[i * n + i] = 1.0;
        }
        let mut wdq = Tensor::zeros(&[n, lin.w.c()]);
        eye.matmul_prepacked(&lin.w, wdq.data_mut())?;
        let f = self.lin(b, role, x)?;
        let i = self.lin_int(b, role, x)?;
        Ok((scaled, wdq, f, i))
    }

    /// Why `int_compute` is unavailable for this bundle, or `None` when
    /// every block linear carries int panels. Engines check this at
    /// construction so a misconfigured request fails fast, not mid-step.
    pub fn int_reason(&self) -> Option<&str> {
        self.int_reason.as_deref()
    }

    /// Weight bytes a full pass over the block linears reads:
    /// `(f32 panel bytes, int panel bytes)`. The int side counts packed
    /// codes + dequant params ([`PackedIntB::packed_bytes`]); linears
    /// without int panels count their f32 panels on both sides (the
    /// kernel would fall back). The bench divides by tokens to report
    /// weight traffic per token.
    pub fn weight_bytes(&self) -> (usize, usize) {
        let mut f = 0usize;
        let mut i = 0usize;
        for blk in &self.blocks {
            for lin in &blk.lins {
                let fb = lin.w.k() * lin.w.c() * 4;
                f += fb;
                i += lin.wi.as_ref().map_or(fb, |wi| wi.packed_bytes());
            }
        }
        (f, i)
    }

    /// f32 bytes of the (unquantized) head projection panels — read by
    /// both paths on every step that produces logits.
    pub fn head_bytes(&self) -> usize {
        self.w_head.k() * self.w_head.c() * 4
    }

    /// Head projection on the prepacked `w_head` panels (arena-backed).
    pub(super) fn head(&self, hf: &Tensor) -> Result<Tensor> {
        let rows = hf.shape()[0];
        let mut out = arena::take(&[rows, self.w_head.c()]);
        hf.matmul_prepacked(&self.w_head, out.data_mut())?;
        Ok(out)
    }
}
