//! Shared quantized-deployment weight handling for the native backend.
//!
//! Both `fwd_logits_q` (full-sequence scoring) and `decode_step_q`
//! (KV-cached incremental decode) consume the same flat argument prefix —
//! tok_emb, pos_emb, per block {ln1, (q, Δ, z, inv_s) × (qkv, o), ln2,
//! (…) × (up, down)}, lnf_g, w_head — and run the same quantized linear:
//! `(x · inv_s per input channel) @ dequant(q)`. This module owns the
//! parse and both kernels so the two entries cannot drift: logit
//! bit-identity between them (DESIGN.md §10) rests on sharing this code.
//!
//! Two interchangeable executions of that linear exist, unified by
//! [`QExec`] so the forward/decode loops are written exactly once:
//!
//! - **Seed** — weights borrowed from the call's arguments, dequantized
//!   per call ([`qlin`]); always available, the reference semantics.
//! - **Prepared** — a [`super::prepared::PreparedQModel`] whose weights
//!   were dequantized ONCE into packed matmul panels at prepare time;
//!   the per-step linear touches only activations (DESIGN.md §11).
//!
//! Both paths produce bit-identical logits: dequantization is the same
//! deterministic expression whether it runs at prepare or at call time,
//! the packed matmul is the same kernel, and the `inv_s` activation
//! scaling stays on the activation side in both (see DESIGN.md §11 for
//! why it is NOT folded into the weights).

use super::prepared::PreparedQModel;
use crate::config::ModelConfig;
use crate::runtime::value::Value;
use crate::tensor::{arena, Tensor};
use anyhow::{bail, Context, Result};

/// One quantized linear's deployment tensors, borrowed from the args.
pub(super) struct QLin<'a> {
    pub q: &'a Tensor,
    pub delta: &'a Tensor,
    pub zero: &'a Tensor,
    pub inv_s: &'a Tensor,
}

/// One block's norm gains + its four quantized linears in ROLES order
/// (qkv, o, up, down).
pub(super) struct QBlock<'a> {
    pub ln1: &'a Tensor,
    pub ln2: &'a Tensor,
    pub lins: Vec<QLin<'a>>,
}

/// The full quantized-deployment weight bundle, borrowed from the args.
pub(super) struct QWeights<'a> {
    pub tok_emb: &'a Tensor,
    pub pos_emb: &'a Tensor,
    pub blocks: Vec<QBlock<'a>>,
    pub lnf_g: &'a Tensor,
    pub w_head: &'a Tensor,
}

fn f32_at<'x>(args: &[&'x Value], i: usize, what: &str) -> Result<&'x Tensor> {
    args.get(i)
        .with_context(|| format!("missing arg {i} ({what})"))?
        .as_f32()
        .with_context(|| format!("arg {what} must be f32"))
}

/// Number of weight arguments [`QWeights::parse`] consumes (everything in
/// the `fwd_logits_q` signature except the trailing tokens tensor).
pub(super) fn qweight_nargs(cfg: &ModelConfig) -> usize {
    crate::runtime::registry::qweight_nargs(cfg)
}

impl<'a> QWeights<'a> {
    /// Parse the canonical weight prefix; callers read their entry's
    /// trailing arguments starting at [`qweight_nargs`].
    pub fn parse(cfg: &ModelConfig, args: &[&'a Value]) -> Result<Self> {
        let mut i = 0usize;
        let tok_emb = f32_at(args, i, "tok_emb")?;
        i += 1;
        let pos_emb = f32_at(args, i, "pos_emb")?;
        i += 1;
        let mut blocks = Vec::with_capacity(cfg.n_layer);
        for b in 0..cfg.n_layer {
            let ln1 = f32_at(args, i, &format!("blk{b}.ln1_g"))?;
            i += 1;
            let mut lins = Vec::with_capacity(4);
            for role in ["qkv", "o"] {
                lins.push(QLin {
                    q: f32_at(args, i, &format!("blk{b}.{role}.q"))?,
                    delta: f32_at(args, i + 1, &format!("blk{b}.{role}.delta"))?,
                    zero: f32_at(args, i + 2, &format!("blk{b}.{role}.zero"))?,
                    inv_s: f32_at(args, i + 3, &format!("blk{b}.{role}.inv_s"))?,
                });
                i += 4;
            }
            let ln2 = f32_at(args, i, &format!("blk{b}.ln2_g"))?;
            i += 1;
            for role in ["up", "down"] {
                lins.push(QLin {
                    q: f32_at(args, i, &format!("blk{b}.{role}.q"))?,
                    delta: f32_at(args, i + 1, &format!("blk{b}.{role}.delta"))?,
                    zero: f32_at(args, i + 2, &format!("blk{b}.{role}.zero"))?,
                    inv_s: f32_at(args, i + 3, &format!("blk{b}.{role}.inv_s"))?,
                });
                i += 4;
            }
            blocks.push(QBlock { ln1, ln2, lins });
        }
        let lnf_g = f32_at(args, i, "lnf_g")?;
        i += 1;
        let w_head = f32_at(args, i, "w_head")?;
        i += 1;
        debug_assert_eq!(i, qweight_nargs(cfg));
        Ok(Self {
            tok_emb,
            pos_emb,
            blocks,
            lnf_g,
            w_head,
        })
    }
}

/// Validate one linear's dequant-parameter shapes against its codes.
fn check_dequant_shapes(l: &QLin, group: usize) -> Result<(usize, usize)> {
    let (n, m) = (l.q.shape()[0], l.q.shape()[1]);
    if n % group != 0 {
        bail!("codes n={n} not divisible by group={group}");
    }
    let ng = n / group;
    if l.delta.shape() != [ng, m] || l.zero.shape() != [ng, m] || l.inv_s.numel() != n {
        bail!(
            "dequant params for codes [{n}, {m}] (group {group}): \
             delta {:?} (want [{ng}, {m}]), zero {:?} (want [{ng}, {m}]), \
             inv_s {:?} with {} elements (want {n})",
            l.delta.shape(),
            l.zero.shape(),
            l.inv_s.shape(),
            l.inv_s.numel()
        );
    }
    Ok((n, m))
}

/// Dequantize integer codes into `out` (`n * m` elements): `(q - z) *
/// delta` with per-(group, col) params (the `ref_qmatmul` contract).
/// The single source of the dequant expression — the per-call path and
/// the prepare-time panel pack both run exactly this loop, which is what
/// makes prepared weights bit-identical to per-call dequantization.
pub(super) fn dequant_into(l: &QLin, group: usize, out: &mut [f32]) -> Result<()> {
    let (n, m) = check_dequant_shapes(l, group)?;
    if out.len() != n * m {
        bail!("dequant out len {} != {n} * {m}", out.len());
    }
    for r in 0..n {
        let g = r / group;
        let qr = l.q.row(r);
        let dr = l.delta.row(g);
        let zr = l.zero.row(g);
        let dst = &mut out[r * m..(r + 1) * m];
        for c in 0..m {
            dst[c] = (qr[c] - zr[c]) * dr[c];
        }
    }
    Ok(())
}

/// Dequantize integer codes into a fresh tensor (fallback path).
pub(super) fn dequant(l: &QLin, group: usize) -> Result<Tensor> {
    let (n, m) = check_dequant_shapes(l, group)?;
    let mut out = vec![0.0f32; n * m];
    dequant_into(l, group, &mut out)?;
    Tensor::from_vec(&[n, m], out)
}

/// Quantized linear, fallback (per-call dequant) path:
/// `(x * inv_s per input channel) @ dequant(q)`.
///
/// Row-wise: the result for each row of `x` is independent of every
/// other row (the matmul accumulates each output element ascending-k),
/// which is what makes single-row decode bit-identical to full-sequence
/// scoring. The scaled activation and the output live in the per-thread
/// scratch arena (no per-call clone of the activation tensor); only the
/// dequantized weight is still materialized per call — the cost the
/// prepared path removes.
pub(super) fn qlin(x: &Tensor, l: &QLin, group: usize) -> Result<Tensor> {
    let n = x.shape()[1];
    if l.inv_s.numel() != n {
        bail!("inv_s len {} != activation cols {n}", l.inv_s.numel());
    }
    let w = dequant(l, group)?;
    let inv = l.inv_s.data();
    let rows = x.shape()[0];
    let mut scaled = arena::take(&[rows, n]);
    scale_rows(x.data(), inv, rows, n, scaled.data_mut());
    let mut out = arena::take(&[rows, w.shape()[1]]);
    let res = scaled.matmul_into(&w, out.data_mut());
    arena::give(scaled);
    res?;
    Ok(out)
}

/// `scaled[r, c] = x[r, c] * inv_s[c]` for every row (the activation
/// half of the quantized linear, shared by both paths — identical
/// products, so identical bits).
pub(super) fn scale_rows(x: &[f32], inv_s: &[f32], rows: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * n);
    debug_assert_eq!(out.len(), rows * n);
    for r in 0..rows {
        let src = &x[r * n..(r + 1) * n];
        let dst = &mut out[r * n..(r + 1) * n];
        for ((o, &v), &s) in dst.iter_mut().zip(src).zip(inv_s) {
            *o = v * s;
        }
    }
}

/// One execution of the quantized model: the seed (per-call dequant)
/// path, the prepared (dequantize-once, packed-panel) f32 path, or the
/// prepared int8×int4 path — behind a single accessor surface so
/// `fwd_logits_q` and `decode_step_q` are each written exactly once and
/// cannot drift between paths. `PreparedInt` differs from `Prepared`
/// only inside [`QExec::lin`] (the fused integer kernel instead of the
/// f32 panel matmul); embeddings, norms, attention, and the head are
/// byte-for-byte the same code.
pub(super) enum QExec<'a> {
    Seed { wts: QWeights<'a>, group: usize },
    Prepared(&'a PreparedQModel),
    PreparedInt(&'a PreparedQModel),
}

impl QExec<'_> {
    pub fn tok_emb(&self) -> &Tensor {
        match self {
            QExec::Seed { wts, .. } => wts.tok_emb,
            QExec::Prepared(pm) | QExec::PreparedInt(pm) => &pm.tok_emb,
        }
    }

    pub fn pos_emb(&self) -> &Tensor {
        match self {
            QExec::Seed { wts, .. } => wts.pos_emb,
            QExec::Prepared(pm) | QExec::PreparedInt(pm) => &pm.pos_emb,
        }
    }

    pub fn ln1(&self, b: usize) -> &[f32] {
        match self {
            QExec::Seed { wts, .. } => wts.blocks[b].ln1.data(),
            QExec::Prepared(pm) | QExec::PreparedInt(pm) => &pm.blocks[b].ln1,
        }
    }

    pub fn ln2(&self, b: usize) -> &[f32] {
        match self {
            QExec::Seed { wts, .. } => wts.blocks[b].ln2.data(),
            QExec::Prepared(pm) | QExec::PreparedInt(pm) => &pm.blocks[b].ln2,
        }
    }

    pub fn lnf(&self) -> &[f32] {
        match self {
            QExec::Seed { wts, .. } => wts.lnf_g.data(),
            QExec::Prepared(pm) | QExec::PreparedInt(pm) => &pm.lnf_g,
        }
    }

    /// Run quantized linear `role` (ROLES order) of block `b` on `x`.
    /// The returned tensor comes from the per-thread scratch arena on
    /// all paths — pass it back via [`QExec::give`] when done.
    pub fn lin(&self, b: usize, role: usize, x: &Tensor) -> Result<Tensor> {
        match self {
            QExec::Seed { wts, group } => qlin(x, &wts.blocks[b].lins[role], *group),
            QExec::Prepared(pm) => pm.lin(b, role, x),
            QExec::PreparedInt(pm) => pm.lin_int(b, role, x),
        }
    }

    /// Head projection `hf @ w_head` (not quantized; prepacked on the
    /// prepared paths — the int path shares the f32 head, which keeps
    /// the logit layer at full precision). Arena-backed like
    /// [`QExec::lin`].
    pub fn head(&self, hf: &Tensor) -> Result<Tensor> {
        match self {
            QExec::Seed { wts, .. } => {
                let rows = hf.shape()[0];
                let cols = wts.w_head.shape()[1];
                let mut out = arena::take(&[rows, cols]);
                hf.matmul_into(wts.w_head, out.data_mut())?;
                Ok(out)
            }
            QExec::Prepared(pm) | QExec::PreparedInt(pm) => pm.head(hf),
        }
    }

    /// Return a tensor obtained from [`QExec::lin`]/[`QExec::head`] to
    /// the per-thread scratch arena.
    pub fn give(&self, t: Tensor) {
        arena::give(t);
    }
}
