//! Shared quantized-deployment weight handling for the native backend.
//!
//! Both `fwd_logits_q` (full-sequence scoring) and `decode_step_q`
//! (KV-cached incremental decode) consume the same flat argument prefix —
//! tok_emb, pos_emb, per block {ln1, (q, Δ, z, inv_s) × (qkv, o), ln2,
//! (…) × (up, down)}, lnf_g, w_head — and run the same quantized linear:
//! `(x · inv_s per input channel) @ dequant(q)`. This module owns the
//! parse and both kernels so the two entries cannot drift: logit
//! bit-identity between them (DESIGN.md §10) rests on sharing this code.

use crate::config::ModelConfig;
use crate::runtime::value::Value;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

/// One quantized linear's deployment tensors, borrowed from the args.
pub(super) struct QLin<'a> {
    pub q: &'a Tensor,
    pub delta: &'a Tensor,
    pub zero: &'a Tensor,
    pub inv_s: &'a Tensor,
}

/// One block's norm gains + its four quantized linears in ROLES order
/// (qkv, o, up, down).
pub(super) struct QBlock<'a> {
    pub ln1: &'a Tensor,
    pub ln2: &'a Tensor,
    pub lins: Vec<QLin<'a>>,
}

/// The full quantized-deployment weight bundle, borrowed from the args.
pub(super) struct QWeights<'a> {
    pub tok_emb: &'a Tensor,
    pub pos_emb: &'a Tensor,
    pub blocks: Vec<QBlock<'a>>,
    pub lnf_g: &'a Tensor,
    pub w_head: &'a Tensor,
}

fn f32_at<'x>(args: &[&'x Value], i: usize, what: &str) -> Result<&'x Tensor> {
    args.get(i)
        .with_context(|| format!("missing arg {i} ({what})"))?
        .as_f32()
        .with_context(|| format!("arg {what} must be f32"))
}

/// Number of weight arguments [`QWeights::parse`] consumes (everything in
/// the `fwd_logits_q` signature except the trailing tokens tensor).
pub(super) fn qweight_nargs(cfg: &ModelConfig) -> usize {
    2 + cfg.n_layer * 18 + 2
}

impl<'a> QWeights<'a> {
    /// Parse the canonical weight prefix; callers read their entry's
    /// trailing arguments starting at [`qweight_nargs`].
    pub fn parse(cfg: &ModelConfig, args: &[&'a Value]) -> Result<Self> {
        let mut i = 0usize;
        let tok_emb = f32_at(args, i, "tok_emb")?;
        i += 1;
        let pos_emb = f32_at(args, i, "pos_emb")?;
        i += 1;
        let mut blocks = Vec::with_capacity(cfg.n_layer);
        for b in 0..cfg.n_layer {
            let ln1 = f32_at(args, i, &format!("blk{b}.ln1_g"))?;
            i += 1;
            let mut lins = Vec::with_capacity(4);
            for role in ["qkv", "o"] {
                lins.push(QLin {
                    q: f32_at(args, i, &format!("blk{b}.{role}.q"))?,
                    delta: f32_at(args, i + 1, &format!("blk{b}.{role}.delta"))?,
                    zero: f32_at(args, i + 2, &format!("blk{b}.{role}.zero"))?,
                    inv_s: f32_at(args, i + 3, &format!("blk{b}.{role}.inv_s"))?,
                });
                i += 4;
            }
            let ln2 = f32_at(args, i, &format!("blk{b}.ln2_g"))?;
            i += 1;
            for role in ["up", "down"] {
                lins.push(QLin {
                    q: f32_at(args, i, &format!("blk{b}.{role}.q"))?,
                    delta: f32_at(args, i + 1, &format!("blk{b}.{role}.delta"))?,
                    zero: f32_at(args, i + 2, &format!("blk{b}.{role}.zero"))?,
                    inv_s: f32_at(args, i + 3, &format!("blk{b}.{role}.inv_s"))?,
                });
                i += 4;
            }
            blocks.push(QBlock { ln1, ln2, lins });
        }
        let lnf_g = f32_at(args, i, "lnf_g")?;
        i += 1;
        let w_head = f32_at(args, i, "w_head")?;
        i += 1;
        debug_assert_eq!(i, qweight_nargs(cfg));
        Ok(Self {
            tok_emb,
            pos_emb,
            blocks,
            lnf_g,
            w_head,
        })
    }
}

/// Dequantize integer codes: `(q - z) * delta` with per-(group, col)
/// params (the `ref_qmatmul` contract).
pub(super) fn dequant(l: &QLin, group: usize) -> Result<Tensor> {
    let (n, m) = (l.q.shape()[0], l.q.shape()[1]);
    if n % group != 0 {
        bail!("codes n={n} not divisible by group={group}");
    }
    let ng = n / group;
    if l.delta.shape() != [ng, m] || l.zero.shape() != [ng, m] || l.inv_s.numel() != n {
        bail!(
            "dequant params: delta {:?} zero {:?} inv_s {:?} for codes [{n}, {m}]",
            l.delta.shape(),
            l.zero.shape(),
            l.inv_s.shape()
        );
    }
    let mut out = vec![0.0f32; n * m];
    for r in 0..n {
        let g = r / group;
        let qr = l.q.row(r);
        let dr = l.delta.row(g);
        let zr = l.zero.row(g);
        let dst = &mut out[r * m..(r + 1) * m];
        for c in 0..m {
            dst[c] = (qr[c] - zr[c]) * dr[c];
        }
    }
    Tensor::from_vec(&[n, m], out)
}

/// Quantized linear: `(x * inv_s per input channel) @ dequant(q)`.
///
/// Row-wise: the result for each row of `x` is independent of every
/// other row (the matmul accumulates each output element ascending-k),
/// which is what makes single-row decode bit-identical to full-sequence
/// scoring.
pub(super) fn qlin(x: &Tensor, l: &QLin, group: usize) -> Result<Tensor> {
    let n = x.shape()[1];
    if l.inv_s.numel() != n {
        bail!("inv_s len {} != activation cols {n}", l.inv_s.numel());
    }
    let inv = l.inv_s.data();
    let mut scaled = x.clone();
    let rows = x.shape()[0];
    for r in 0..rows {
        let row = &mut scaled.data_mut()[r * n..(r + 1) * n];
        for (v, &s) in row.iter_mut().zip(inv) {
            *v *= s;
        }
    }
    scaled.matmul(&dequant(l, group)?)
}
