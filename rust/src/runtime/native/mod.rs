//! The native CPU execution backend: every artifact entrypoint the
//! coordinator calls, implemented on host tensors with the exact math of
//! `python/compile/model.py` + `kernels/ref.py`.
//!
//! This is the reference backend: always available, zero dependencies,
//! deterministic — the path that makes `cargo test` and the end-to-end
//! pipeline (train → calibrate → FAQ quantize → eval → serve) run on a
//! fresh offline checkout. The PJRT/HLO backend (`pjrt` feature) is the
//! accelerated drop-in with the same entry contract.

mod decode;
mod nn;
pub mod prepared;
mod qmodel;
mod train;

pub use nn::{ParamView, RMS_EPS};
pub use prepared::PreparedQModel;
pub use train::loss_and_grads;

use super::backend::Backend;
use super::registry::Manifest;
use super::value::{Buffer, Value};
use crate::quant::scaled_fakequant;
use crate::tensor::{arena, Tensor};
use anyhow::{bail, Context, Result};

/// Pure-Rust reference backend (stateless; all state is in the args).
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    fn run(
        &self,
        manifest: &Manifest,
        cfg_name: &str,
        entry: &str,
        args: &[&Value],
    ) -> Result<Vec<Value>> {
        if let Some(rest) = entry.strip_prefix("layer_loss_sweep_") {
            let (_, bits) = parse_role_bits(rest)?;
            return layer_loss_sweep(args, bits, manifest.group);
        }
        if let Some(rest) = entry.strip_prefix("layer_loss_") {
            let (_, bits) = parse_role_bits(rest)?;
            return layer_loss(args, bits, manifest.group);
        }
        let cfg = manifest.config(cfg_name)?;
        match entry {
            "fwd_logits" => fwd_logits(cfg, args),
            "fwd_capture" => fwd_capture(cfg, args),
            "fwd_logits_q" => {
                let nw = qmodel::qweight_nargs(cfg);
                if args.len() != nw + 1 {
                    bail!("fwd_logits_q: got {} args, want {}", args.len(), nw + 1);
                }
                let wts = qmodel::QWeights::parse(cfg, args)?;
                let ex = qmodel::QExec::Seed {
                    wts,
                    group: manifest.group,
                };
                fwd_logits_q(cfg, &ex, args[nw])
            }
            "decode_step_q" => {
                let nw = qmodel::qweight_nargs(cfg);
                if args.len() != nw + 4 {
                    bail!("decode_step_q: got {} args, want {}", args.len(), nw + 4);
                }
                let wts = qmodel::QWeights::parse(cfg, args)?;
                let ex = qmodel::QExec::Seed {
                    wts,
                    group: manifest.group,
                };
                decode::decode_step_q(cfg, &ex, &args[nw..])
            }
            "decode_step_paged_q" => {
                let nw = qmodel::qweight_nargs(cfg);
                if args.len() != nw + 5 {
                    bail!(
                        "decode_step_paged_q: got {} args, want {}",
                        args.len(),
                        nw + 5
                    );
                }
                let wts = qmodel::QWeights::parse(cfg, args)?;
                let ex = qmodel::QExec::Seed {
                    wts,
                    group: manifest.group,
                };
                decode::decode_step_paged_q(cfg, &ex, &args[nw..])
            }
            "train_step" => train::train_step(cfg, args),
            // The int8×int4 entries compute on packed code panels, which
            // only exist in a prepared bundle — there is deliberately no
            // seed (per-call pack) fallback to pay for.
            "fwd_logits_qi" | "decode_step_qi" | "decode_step_paged_qi" => {
                bail!("entry '{entry}' requires prepared weights (GenConfig.prepared)")
            }
            other => bail!("native backend has no entry '{other}'"),
        }
    }

    /// Run an entry whose weight prefix was replaced by a prepared
    /// bundle: args are `[prepared, trailing…]`.
    fn run_prepared(
        &self,
        manifest: &Manifest,
        cfg_name: &str,
        entry: &str,
        pm: &PreparedQModel,
        trailing: &[&Value],
    ) -> Result<Vec<Value>> {
        let cfg = manifest.config(cfg_name)?;
        pm.check_matches(cfg, manifest.group)?;
        // The `_qi` twins of the quantized entries run the same forward/
        // decode loops over QExec::PreparedInt — the only difference is
        // which kernel QExec::lin dispatches to.
        let int = entry.ends_with("_qi");
        if int {
            if let Some(reason) = pm.int_reason() {
                bail!("entry '{entry}': int compute unavailable — {reason}");
            }
        }
        let ex = if int {
            qmodel::QExec::PreparedInt(pm)
        } else {
            qmodel::QExec::Prepared(pm)
        };
        match entry {
            "fwd_logits_q" | "fwd_logits_qi" => {
                if trailing.len() != 1 {
                    bail!(
                        "{entry}(prepared): got {} trailing args, want 1 (tokens)",
                        trailing.len()
                    );
                }
                fwd_logits_q(cfg, &ex, trailing[0])
            }
            "decode_step_q" | "decode_step_qi" => decode::decode_step_q(cfg, &ex, trailing),
            "decode_step_paged_q" | "decode_step_paged_qi" => {
                decode::decode_step_paged_q(cfg, &ex, trailing)
            }
            other => bail!("prepared weights are not supported for entry '{other}'"),
        }
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn prepare(&self, manifest: &Manifest, cfg: &str, entry: &str) -> Result<f32> {
        // Nothing to compile; validating the entry keeps warmup's
        // "unknown entry fails loudly" contract.
        manifest.artifact(cfg, entry)?;
        Ok(0.0)
    }

    fn prepare_weights(
        &self,
        manifest: &Manifest,
        cfg: &str,
        lits: &[Value],
    ) -> Result<Option<Vec<Buffer>>> {
        let cfgm = manifest.config(cfg)?;
        let refs: Vec<&Value> = lits.iter().collect();
        let pm = PreparedQModel::build(cfgm, manifest.group, &refs)?;
        Ok(Some(vec![Buffer::PreparedQ(std::sync::Arc::new(pm))]))
    }

    fn exec(
        &self,
        manifest: &Manifest,
        cfg: &str,
        entry: &str,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let refs: Vec<&Value> = args.iter().collect();
        self.run(manifest, cfg, entry, &refs)
    }

    fn exec_buffers(
        &self,
        manifest: &Manifest,
        cfg: &str,
        entry: &str,
        args: &[&Buffer],
    ) -> Result<Vec<Value>> {
        if let Some(first) = args.first() {
            if let Buffer::PreparedQ(pm) = &**first {
                let trailing: Vec<&Value> = args[1..]
                    .iter()
                    .map(|b| b.host())
                    .collect::<Result<Vec<_>>>()?;
                return self.run_prepared(manifest, cfg, entry, pm.as_ref(), &trailing);
            }
        }
        let refs: Vec<&Value> = args
            .iter()
            .map(|b| b.host())
            .collect::<Result<Vec<_>>>()?;
        self.run(manifest, cfg, entry, &refs)
    }

    fn upload(&self, v: Value) -> Result<Buffer> {
        Ok(Buffer::Host(v))
    }
}

/// Bench-only probe (`benches/alloc_probe.rs`): run one prepared
/// quantized linear exactly as a decode step does — `inv_s` scaling into
/// an arena buffer, prepacked matmul into another — and return the
/// output to the arena. The steady-state allocation count of this call
/// is asserted to be zero.
#[doc(hidden)]
pub fn prepared_qlin_probe(
    pm: &PreparedQModel,
    block: usize,
    role: usize,
    x: &Tensor,
) -> Result<usize> {
    let ex = qmodel::QExec::Prepared(pm);
    let out = ex.lin(block, role, x)?;
    let numel = out.numel();
    ex.give(out);
    Ok(numel)
}

/// Bench-only probe: the int8×int4 twin of [`prepared_qlin_probe`] —
/// `inv_s` scaling, per-row i8 activation quantize, fused int kernel,
/// f32 fixup — asserted allocation-free once arena + int scratch are
/// warm (`benches/alloc_probe.rs`).
#[doc(hidden)]
pub fn prepared_int_qlin_probe(
    pm: &PreparedQModel,
    block: usize,
    role: usize,
    x: &Tensor,
) -> Result<usize> {
    let ex = qmodel::QExec::PreparedInt(pm);
    let out = ex.lin(block, role, x)?;
    let numel = out.numel();
    ex.give(out);
    Ok(numel)
}

/// `"qkv_b3"` -> `("qkv", 3)`.
fn parse_role_bits(rest: &str) -> Result<(&str, u32)> {
    let (role, bits) = rest
        .rsplit_once("_b")
        .with_context(|| format!("malformed layer_loss entry suffix '{rest}'"))?;
    let bits: u32 = bits
        .parse()
        .with_context(|| format!("bad bit width in entry suffix '{rest}'"))?;
    Ok((role, bits))
}

/// (params…, tokens) -> (logits [B, T, V],).
fn fwd_logits(cfg: &crate::config::ModelConfig, args: &[&Value]) -> Result<Vec<Value>> {
    let (params, tokens) = split_tokens(args)?;
    let view = ParamView::from_values(cfg, params)?;
    let fwd = nn::forward(cfg, &view, tokens, false)?;
    Ok(vec![Value::F32(fwd.logits)])
}

/// (params…, tokens) -> per-role acts [L, R, n] x4, then stats [L, n] x4.
fn fwd_capture(cfg: &crate::config::ModelConfig, args: &[&Value]) -> Result<Vec<Value>> {
    let (params, tokens) = split_tokens(args)?;
    let view = ParamView::from_values(cfg, params)?;
    let fwd = nn::forward(cfg, &view, tokens, false)?;
    let l = cfg.n_layer;
    let r = fwd.b * fwd.t;
    // Role inputs per block, in ROLES order (qkv, o, up, down).
    fn role_of(blk: &nn::BlockCache, ri: usize) -> &Tensor {
        match ri {
            0 => &blk.h,
            1 => &blk.att,
            2 => &blk.h2,
            _ => &blk.u,
        }
    }
    let mut outs = Vec::with_capacity(8);
    for ri in 0..4 {
        let n = role_of(&fwd.blocks[0], ri).shape()[1];
        let mut data = Vec::with_capacity(l * r * n);
        for blk in &fwd.blocks {
            data.extend_from_slice(role_of(blk, ri).data());
        }
        outs.push(Value::F32(Tensor::from_vec(&[l, r, n], data)?));
    }
    for ri in 0..4 {
        let n = role_of(&fwd.blocks[0], ri).shape()[1];
        let mut data = Vec::with_capacity(l * n);
        for blk in &fwd.blocks {
            data.extend_from_slice(&role_of(blk, ri).absmean_cols());
        }
        outs.push(Value::F32(Tensor::from_vec(&[l, n], data)?));
    }
    Ok(outs)
}

/// Split a (params…, tokens) argument list.
fn split_tokens<'a>(
    args: &'a [&'a Value],
) -> Result<(&'a [&'a Value], &'a crate::tensor::TensorI32)> {
    let (tokens, params) = args
        .split_last()
        .context("entry needs at least a tokens argument")?;
    Ok((params, tokens.as_i32().context("trailing arg must be i32 tokens")?))
}

/// (a [S, n], w [n, m], s [n]) -> (scalar recon loss,).
fn layer_loss(args: &[&Value], bits: u32, group: usize) -> Result<Vec<Value>> {
    let (a, w, s) = loss_args(args)?;
    let y_fp = a.matmul(w)?;
    let wq = scaled_fakequant(w, s, bits, group)?;
    let loss = a.matmul(&wq)?.mse(&y_fp);
    Ok(vec![Value::F32(Tensor::from_vec(&[], vec![loss])?)])
}

/// (a [S, n], w [n, m], scales [n_alpha, n]) -> (losses [n_alpha],).
///
/// §Perf fast path: the reference product `a @ w` is computed ONCE and
/// shared by every alpha candidate (the dominant cost of a naive
/// per-candidate loop), and the candidates themselves — fakequant +
/// reconstruction matmul + mse, all independent — run in parallel with
/// their losses written back in grid order. Each candidate's
/// reconstruction product lands in a per-thread scratch-arena buffer via
/// `matmul_into` (same kernel, same bits as `matmul`) instead of a fresh
/// allocation per candidate.
fn layer_loss_sweep(args: &[&Value], bits: u32, group: usize) -> Result<Vec<Value>> {
    if args.len() != 3 {
        bail!("layer_loss_sweep wants 3 args, got {}", args.len());
    }
    let a = args[0].as_f32()?;
    let w = args[1].as_f32()?;
    let scales = args[2].as_f32()?;
    let sshape = scales.shape();
    if a.shape().len() != 2 || w.shape().len() != 2 || a.shape()[1] != w.shape()[0] {
        bail!("layer_loss_sweep shapes: a {:?} w {:?}", a.shape(), w.shape());
    }
    if sshape.len() != 2 || sshape[1] != w.shape()[0] {
        bail!("sweep scales {:?} vs weight {:?}", sshape, w.shape());
    }
    let y_fp = a.matmul(w)?;
    // One reconstruction matmul per candidate dominates; gate the
    // dispatch on that work like the kernels do.
    let work = sshape[0] * a.shape()[0] * w.shape()[0] * w.shape()[1];
    let losses = crate::tensor::par::par_map_bounded(
        sshape[0],
        crate::tensor::par::threads_for(work),
        |i| -> Result<f32> {
            let wq = scaled_fakequant(w, scales.row(i), bits, group)?;
            let mut y = arena::take(&[a.shape()[0], wq.shape()[1]]);
            let res = a.matmul_into(&wq, y.data_mut());
            let loss = res.map(|()| y.mse(&y_fp));
            arena::give(y);
            loss
        },
    )
    .into_iter()
    .collect::<Result<Vec<f32>>>()?;
    let n_alpha = losses.len();
    Ok(vec![Value::F32(Tensor::from_vec(&[n_alpha], losses)?)])
}

fn loss_args<'a>(args: &'a [&'a Value]) -> Result<(&'a Tensor, &'a Tensor, &'a [f32])> {
    if args.len() != 3 {
        bail!("layer_loss wants 3 args, got {}", args.len());
    }
    let a = args[0].as_f32()?;
    let w = args[1].as_f32()?;
    let s = args[2].as_f32()?;
    if a.shape().len() != 2 || w.shape().len() != 2 || a.shape()[1] != w.shape()[0] {
        bail!("layer_loss shapes: a {:?} w {:?}", a.shape(), w.shape());
    }
    if s.numel() != w.shape()[0] {
        bail!("scale len {} != weight n_in {}", s.numel(), w.shape()[0]);
    }
    Ok((a, w, s.data()))
}

/// Quantized-deployment forward: `fwd_logits_q` from integer codes +
/// dequant params (the `ref_qmatmul` contract: `(a * inv_s) @ dequant(q)`).
/// Runs over a [`qmodel::QExec`] — the seed (per-call dequant) or the
/// prepared (dequantize-once packed panels, DESIGN.md §11) path — and
/// shares that surface with the KV-cached [`decode::decode_step_q`], so
/// all four path/entry combinations stay bit-identical per position.
fn fwd_logits_q(
    cfg: &crate::config::ModelConfig,
    ex: &qmodel::QExec,
    tokens: &Value,
) -> Result<Vec<Value>> {
    let tokens = tokens
        .as_i32()
        .context("trailing fwd_logits_q arg must be i32 tokens")?;
    if tokens.shape().len() != 2 {
        bail!("fwd_logits_q tokens must be [B, T], got {:?}", tokens.shape());
    }
    let (b, t) = (tokens.shape()[0], tokens.shape()[1]);

    let mut x = nn::embed(ex.tok_emb(), ex.pos_emb(), tokens)?;
    for li in 0..cfg.n_layer {
        let (h, _) = nn::rmsnorm_fwd(&x, ex.ln1(li))?;
        let qkv = ex.lin(li, 0, &h)?;
        let (att, _) = nn::attention_fwd(&qkv, b, t, cfg.n_head, false)?;
        ex.give(qkv);
        let o = ex.lin(li, 1, &att)?;
        let x_mid = x.add(&o)?;
        ex.give(o);
        let (h2, _) = nn::rmsnorm_fwd(&x_mid, ex.ln2(li))?;
        let mut u = ex.lin(li, 2, &h2)?;
        u.map_inplace(nn::gelu);
        let dn = ex.lin(li, 3, &u)?;
        ex.give(u);
        x = x_mid.add(&dn)?;
        ex.give(dn);
    }
    let (hf, _) = nn::rmsnorm_fwd(&x, ex.lnf())?;
    let lg = ex.head(&hf)?;
    let logits = lg.reshape(&[b, t, cfg.vocab])?;
    ex.give(lg);
    Ok(vec![Value::F32(logits)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::Params;
    use crate::tensor::{Rng, TensorI32};

    fn pico() -> ModelConfig {
        ModelConfig::preset("pico").unwrap()
    }

    fn value_args(params: &Params, tokens: &TensorI32) -> Vec<Value> {
        let mut v: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| Value::F32(t.clone()))
            .collect();
        v.push(Value::I32(tokens.clone()));
        v
    }

    fn tokens(cfg: &ModelConfig, seed: u64) -> TensorI32 {
        let mut rng = Rng::new(seed);
        TensorI32::from_vec(
            &[cfg.batch, cfg.seq],
            (0..cfg.batch * cfg.seq)
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn parse_role_bits_roundtrip() {
        assert_eq!(parse_role_bits("qkv_b3").unwrap(), ("qkv", 3));
        assert_eq!(parse_role_bits("down_b4").unwrap(), ("down", 4));
        assert!(parse_role_bits("nounderscore").is_err());
    }

    #[test]
    fn capture_acts_and_stats_consistent() {
        let m = Manifest::native();
        let cfg = pico();
        let params = Params::init(&cfg, 3);
        let toks = tokens(&cfg, 4);
        let be = NativeBackend;
        let outs = be
            .exec(&m, &cfg.name, "fwd_capture", &value_args(&params, &toks))
            .unwrap();
        assert_eq!(outs.len(), 8);
        for ri in 0..4 {
            let acts = outs[ri].as_f32().unwrap();
            let stats = outs[4 + ri].as_f32().unwrap();
            assert_eq!(acts.shape()[0], cfg.n_layer);
            assert_eq!(acts.shape()[1], cfg.batch * cfg.seq);
            for b in 0..cfg.n_layer {
                let want = acts.index0(b).absmean_cols();
                let got = stats.index0(b);
                for (g, w) in got.data().iter().zip(&want) {
                    assert!((g - w).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn sweep_matches_single_losses() {
        let m = Manifest::native();
        let be = NativeBackend;
        let mut rng = Rng::new(5);
        let (n, cols) = (64usize, 32usize);
        let a = Value::F32(crate::tensor::Tensor::randn(&mut rng, &[16, n], 1.0));
        let w = Value::F32(crate::tensor::Tensor::randn(&mut rng, &[n, cols], 0.5));
        let scales: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..n).map(|_| rng.uniform() + 0.5).collect())
            .collect();
        let flat: Vec<f32> = scales.iter().flatten().copied().collect();
        let sw = Value::F32(Tensor::from_vec(&[3, n], flat).unwrap());
        let outs = be
            .exec(&m, "pico", "layer_loss_sweep_qkv_b3", &[a.clone(), w.clone(), sw])
            .unwrap();
        let sweep = outs[0].as_f32().unwrap().clone();
        for (i, s) in scales.iter().enumerate() {
            let sv = Value::F32(Tensor::from_vec(&[n], s.clone()).unwrap());
            let single = be
                .exec(&m, "pico", "layer_loss_qkv_b3", &[a.clone(), w.clone(), sv])
                .unwrap();
            let single = crate::runtime::value::scalar_f32(&single[0]).unwrap();
            assert!((single - sweep.data()[i]).abs() < 1e-9 + 1e-5 * single.abs());
        }
    }

    #[test]
    fn unknown_entry_rejected() {
        let m = Manifest::native();
        let be = NativeBackend;
        assert!(be.exec(&m, "pico", "no_such_entry", &[]).is_err());
    }

    #[test]
    fn prepare_weights_validates_count_and_entry() {
        let m = Manifest::native();
        let be = NativeBackend;
        // Wrong arg count is rejected at prepare time.
        let err = be.prepare_weights(&m, "pico", &[]).unwrap_err();
        assert!(err.to_string().contains("weight args"), "{err}");
        // A prepared bundle reaching a non-quantized entry is rejected.
        let cfg = pico();
        let params = Params::init(&cfg, 5);
        let qcfg = crate::config::QuantConfig::with_method(crate::config::Method::Rtn);
        let rt = crate::runtime::Runtime::native();
        let qm = crate::quant::quantize_model(&rt, &qcfg, &params, None).unwrap();
        let lits = crate::serve::qmodel_literals(&params, &qm).unwrap();
        let bufs = be.prepare_weights(&m, "pico", &lits).unwrap().unwrap();
        assert_eq!(bufs.len(), 1);
        let args: Vec<&super::Buffer> = bufs.iter().collect();
        let err = be.exec_buffers(&m, "pico", "fwd_logits", &args).unwrap_err();
        assert!(err.to_string().contains("not supported"), "{err}");
    }

    #[test]
    fn int_entry_needs_prepared_weights() {
        // Seed (non-prepared) execution of a `_qi` entry is refused with
        // a pointer at the prepared path; the prepared bundle runs it.
        let m = Manifest::native();
        let cfg = pico();
        let params = Params::init(&cfg, 5);
        let qcfg = crate::config::QuantConfig::with_method(crate::config::Method::Rtn);
        let rt = crate::runtime::Runtime::native();
        let qm = crate::quant::quantize_model(&rt, &qcfg, &params, None).unwrap();
        let lits = crate::serve::qmodel_literals(&params, &qm).unwrap();
        let be = NativeBackend;
        let err = be
            .exec(&m, "pico", "fwd_logits_qi", &[lits[0].clone()])
            .unwrap_err();
        assert!(err.to_string().contains("prepared"), "{err}");
        let bufs = be.prepare_weights(&m, "pico", &lits).unwrap().unwrap();
        let toks = tokens(&cfg, 4);
        let tok_buf = super::Buffer::Host(Value::I32(toks));
        let mut args: Vec<&super::Buffer> = bufs.iter().collect();
        args.push(&tok_buf);
        let out = be
            .exec_buffers(&m, "pico", "fwd_logits_qi", &args)
            .unwrap();
        let logits = out[0].as_f32().unwrap();
        assert_eq!(logits.shape(), &[cfg.batch, cfg.seq, cfg.vocab]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn prepared_bundle_rejects_mismatched_geometry() {
        // A bundle packed under one quantization group must not execute
        // under a manifest with another (the panels would be wrong).
        let m = Manifest::native();
        let cfg = pico();
        let params = Params::init(&cfg, 5);
        let qcfg = crate::config::QuantConfig::with_method(crate::config::Method::Rtn);
        let rt = crate::runtime::Runtime::native();
        let qm = crate::quant::quantize_model(&rt, &qcfg, &params, None).unwrap();
        let lits = crate::serve::qmodel_literals(&params, &qm).unwrap();
        let be = NativeBackend;
        let bufs = be.prepare_weights(&m, "pico", &lits).unwrap().unwrap();
        let toks = tokens(&cfg, 4);
        let tok_buf = super::Buffer::Host(Value::I32(toks));
        let mut args: Vec<&super::Buffer> = bufs.iter().collect();
        args.push(&tok_buf);
        // Same manifest: runs.
        assert!(be.exec_buffers(&m, "pico", "fwd_logits_q", &args).is_ok());
        // Mismatched group: refused loudly.
        let m32 = Manifest::native_with(32, 128);
        let err = be
            .exec_buffers(&m32, "pico", "fwd_logits_q", &args)
            .unwrap_err();
        assert!(err.to_string().contains("group"), "{err}");
    }
}
