//! Native `train_step`: next-token cross-entropy forward/backward plus
//! the AdamW update — the pure-Rust mirror of
//! `python/compile/model.py::train_step` (same constants, same decay
//! skip-list, same output order `params…, m…, v…, step, loss`).

use super::nn::{attention_bwd, dgelu, forward, rmsnorm_bwd, ParamView};
use crate::config::ModelConfig;
use crate::model::param_specs;
use crate::runtime::value::Value;
use crate::tensor::{Tensor, TensorI32};
use anyhow::{bail, Result};

// AdamW hyperparameters — must match python/compile/model.py.
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.95;
const ADAM_EPS: f32 = 1e-8;
const WEIGHT_DECAY: f32 = 0.01;
const LR: f32 = 3e-3;

/// Cross-entropy loss and parameter gradients for one token batch.
///
/// `params` in canonical order; `tokens` [B, T+1] (input = first T
/// columns, targets = shifted by one). Returns (loss, grads in canonical
/// order).
// faq-lint: allow(unordered-reduction) — per-row softmax denominator
// accumulates over ascending vocab index; order pinned by construction.
pub fn loss_and_grads(
    cfg: &ModelConfig,
    params: &[&Tensor],
    tokens: &TensorI32,
) -> Result<(f32, Vec<Tensor>)> {
    let view = ParamView::from_tensors(cfg, params)?;
    let shape = tokens.shape();
    if shape.len() != 2 || shape[1] < 2 {
        bail!("train tokens must be [B, T+1], got {shape:?}");
    }
    let (b, t) = (shape[0], shape[1] - 1);
    let v = cfg.vocab;
    let r_total = b * t;

    // Split input/target column views of the [B, T+1] batch.
    let mut inp = vec![0i32; r_total];
    let mut tgt = vec![0i32; r_total];
    for bi in 0..b {
        for ti in 0..t {
            inp[bi * t + ti] = tokens.data()[bi * (t + 1) + ti];
            tgt[bi * t + ti] = tokens.data()[bi * (t + 1) + ti + 1];
        }
    }
    let inp = TensorI32::from_vec(&[b, t], inp)?;

    let fwd = forward(cfg, &view, &inp, true)?;
    let logits2 = fwd.logits.reshape(&[r_total, v])?;

    // Loss = mean(logsumexp - gold); dlogits = (softmax - onehot)/R.
    let mut loss_sum = 0f64;
    let mut dlogits = vec![0.0f32; r_total * v];
    let inv_r = 1.0 / r_total as f32;
    for r in 0..r_total {
        let row = logits2.row(r);
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = row.iter().map(|&x| (x - mx).exp()).sum();
        let lse = mx + sum.ln();
        let gold = tgt[r];
        if gold < 0 || gold as usize >= v {
            bail!("target token {gold} out of vocab range [0, {v})");
        }
        loss_sum += (lse - row[gold as usize]) as f64;
        let dst = &mut dlogits[r * v..(r + 1) * v];
        for (d, &x) in dst.iter_mut().zip(row) {
            *d = (x - lse).exp() * inv_r;
        }
        dst[gold as usize] -= inv_r;
    }
    let loss = (loss_sum / r_total as f64) as f32;
    let dlogits = Tensor::from_vec(&[r_total, v], dlogits)?;

    // Gradients, canonical order.
    let specs = param_specs(cfg);
    let mut grads: Vec<Tensor> = specs
        .iter()
        .map(|(_, s)| Tensor::zeros(s))
        .collect();
    let idx = |name: &str| -> usize {
        specs
            .iter()
            .position(|(n, _)| n == name)
            .expect("canonical name")
    };

    // Head + final norm.
    grads[idx("w_head")] = fwd.hf.matmul_tn(&dlogits)?;
    let d_hf = dlogits.matmul_nt(view.get("w_head")?)?;
    let lnf_g = view.get("lnf_g")?;
    let (mut dx, d_lnf) = rmsnorm_bwd(&fwd.x_f, lnf_g.data(), &fwd.inv_f, &d_hf)?;
    grads[idx("lnf_g")] = Tensor::from_vec(&[cfg.d_model], d_lnf)?;

    // Blocks in reverse.
    for blk in (0..cfg.n_layer).rev() {
        let c = &fwd.blocks[blk];
        let w_qkv = view.get(&format!("blk{blk}.w_qkv"))?;
        let w_o = view.get(&format!("blk{blk}.w_o"))?;
        let w_up = view.get(&format!("blk{blk}.w_up"))?;
        let w_down = view.get(&format!("blk{blk}.w_down"))?;
        let ln1_g = view.get(&format!("blk{blk}.ln1_g"))?;
        let ln2_g = view.get(&format!("blk{blk}.ln2_g"))?;

        // x_out = x_mid + u @ w_down
        let d_u = dx.matmul_nt(w_down)?;
        grads[idx(&format!("blk{blk}.w_down"))] = c.u.matmul_tn(&dx)?;
        let d_upre = d_u.zip(&c.u_pre, |g, x| g * dgelu(x))?;
        let d_h2 = d_upre.matmul_nt(w_up)?;
        grads[idx(&format!("blk{blk}.w_up"))] = c.h2.matmul_tn(&d_upre)?;
        let (dx_ln2, d_ln2) = rmsnorm_bwd(&c.x_mid, ln2_g.data(), &c.inv2, &d_h2)?;
        grads[idx(&format!("blk{blk}.ln2_g"))] = Tensor::from_vec(&[cfg.d_model], d_ln2)?;
        let dx_mid = dx.add(&dx_ln2)?;

        // x_mid = x_in + att @ w_o
        let d_att = dx_mid.matmul_nt(w_o)?;
        grads[idx(&format!("blk{blk}.w_o"))] = c.att.matmul_tn(&dx_mid)?;
        let d_qkv = attention_bwd(&c.qkv, &c.probs, &d_att, fwd.b, fwd.t, cfg.n_head)?;
        let d_h = d_qkv.matmul_nt(w_qkv)?;
        grads[idx(&format!("blk{blk}.w_qkv"))] = c.h.matmul_tn(&d_qkv)?;
        let (dx_ln1, d_ln1) = rmsnorm_bwd(&c.x_in, ln1_g.data(), &c.inv1, &d_h)?;
        grads[idx(&format!("blk{blk}.ln1_g"))] = Tensor::from_vec(&[cfg.d_model], d_ln1)?;
        dx = dx_mid.add(&dx_ln1)?;
    }

    // Embeddings: scatter-add the input-stream gradient.
    let d = cfg.d_model;
    let mut d_tok = vec![0.0f32; cfg.vocab * d];
    let mut d_pos = vec![0.0f32; cfg.seq * d];
    for bi in 0..b {
        for ti in 0..t {
            let r = bi * t + ti;
            let row = dx.row(r);
            let id = inp.data()[r] as usize;
            let tok_dst = &mut d_tok[id * d..(id + 1) * d];
            for (a, &g) in tok_dst.iter_mut().zip(row) {
                *a += g;
            }
            let pos_dst = &mut d_pos[ti * d..(ti + 1) * d];
            for (a, &g) in pos_dst.iter_mut().zip(row) {
                *a += g;
            }
        }
    }
    grads[idx("tok_emb")] = Tensor::from_vec(&[cfg.vocab, d], d_tok)?;
    grads[idx("pos_emb")] = Tensor::from_vec(&[cfg.seq, d], d_pos)?;

    Ok((loss, grads))
}

/// Full native train_step artifact: fwd/bwd + AdamW.
///
/// Args: params… (n), m… (n), v… (n), step scalar, tokens [B, T+1].
/// Returns: params'… , m'… , v'… , step+1, loss.
pub fn train_step(cfg: &ModelConfig, args: &[&Value]) -> Result<Vec<Value>> {
    let specs = param_specs(cfg);
    let n = specs.len();
    if args.len() != 3 * n + 2 {
        bail!("train_step: got {} args, want {}", args.len(), 3 * n + 2);
    }
    let params: Vec<&Tensor> = args[..n]
        .iter()
        .map(|v| v.as_f32())
        .collect::<Result<Vec<_>>>()?;
    let ms: Vec<&Tensor> = args[n..2 * n]
        .iter()
        .map(|v| v.as_f32())
        .collect::<Result<Vec<_>>>()?;
    let vs: Vec<&Tensor> = args[2 * n..3 * n]
        .iter()
        .map(|v| v.as_f32())
        .collect::<Result<Vec<_>>>()?;
    let step0 = crate::runtime::value::scalar_f32(args[3 * n])?;
    let tokens = args[3 * n + 1].as_i32()?;

    let (loss, grads) = loss_and_grads(cfg, &params, tokens)?;

    let step = step0 + 1.0;
    let bc1 = 1.0 - ADAM_B1.powf(step);
    let bc2 = 1.0 - ADAM_B2.powf(step);
    let mut new_p = Vec::with_capacity(n);
    let mut new_m = Vec::with_capacity(n);
    let mut new_v = Vec::with_capacity(n);
    for i in 0..n {
        let (name, _) = &specs[i];
        let decay = if name.ends_with("_g") || name.contains("emb") {
            0.0
        } else {
            WEIGHT_DECAY
        };
        let numel = params[i].numel();
        let mut pd = Vec::with_capacity(numel);
        let mut md = Vec::with_capacity(numel);
        let mut vd = Vec::with_capacity(numel);
        for j in 0..numel {
            let g = grads[i].data()[j];
            let m = ADAM_B1 * ms[i].data()[j] + (1.0 - ADAM_B1) * g;
            let vv = ADAM_B2 * vs[i].data()[j] + (1.0 - ADAM_B2) * g * g;
            let upd = (m / bc1) / ((vv / bc2).sqrt() + ADAM_EPS);
            let p = params[i].data()[j];
            pd.push(p - LR * (upd + decay * p));
            md.push(m);
            vd.push(vv);
        }
        new_p.push(Value::F32(Tensor::from_vec(params[i].shape(), pd)?));
        new_m.push(Value::F32(Tensor::from_vec(params[i].shape(), md)?));
        new_v.push(Value::F32(Tensor::from_vec(params[i].shape(), vd)?));
    }

    let mut outs = new_p;
    outs.extend(new_m);
    outs.extend(new_v);
    outs.push(Value::F32(Tensor::from_vec(&[], vec![step])?));
    outs.push(Value::F32(Tensor::from_vec(&[], vec![loss])?));
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Params;
    use crate::tensor::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny-test".into(),
            n_layer: 2,
            d_model: 8,
            n_head: 2,
            d_ff: 16,
            vocab: 16,
            seq: 6,
            batch: 2,
        }
    }

    fn batch(cfg: &ModelConfig, seed: u64) -> TensorI32 {
        let mut rng = Rng::new(seed);
        TensorI32::from_vec(
            &[cfg.batch, cfg.seq + 1],
            (0..cfg.batch * (cfg.seq + 1))
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect(),
        )
        .unwrap()
    }

    /// The decisive correctness check for the whole backward pass: the
    /// directional derivative along a random direction must match the
    /// inner product of the analytic gradients with that direction.
    #[test]
    fn gradients_match_directional_derivative() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 11);
        let toks = batch(&cfg, 12);
        let refs: Vec<&Tensor> = params.tensors.iter().collect();
        let (_, grads) = loss_and_grads(&cfg, &refs, &toks).unwrap();

        let mut rng = Rng::new(13);
        let dirs: Vec<Tensor> = params
            .tensors
            .iter()
            .map(|t| Tensor::randn(&mut rng, t.shape(), 1.0))
            .collect();
        let analytic: f32 = grads
            .iter()
            .zip(&dirs)
            .map(|(g, u)| g.data().iter().zip(u.data()).map(|(&a, &b)| a * b).sum::<f32>())
            .sum();

        let eps = 5e-3f32;
        let loss_at = |sign: f32| -> f32 {
            let shifted: Vec<Tensor> = params
                .tensors
                .iter()
                .zip(&dirs)
                .map(|(p, u)| p.zip(u, |a, b| a + sign * eps * b).unwrap())
                .collect();
            let refs: Vec<&Tensor> = shifted.iter().collect();
            loss_and_grads(&cfg, &refs, &toks).unwrap().0
        };
        let numeric = (loss_at(1.0) - loss_at(-1.0)) / (2.0 * eps);
        assert!(
            (numeric - analytic).abs() < 5e-3 + 0.05 * analytic.abs(),
            "directional derivative: analytic {analytic} vs numeric {numeric}"
        );
    }

    #[test]
    fn pointwise_gradients_match_finite_difference() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 21);
        let toks = batch(&cfg, 22);
        let refs: Vec<&Tensor> = params.tensors.iter().collect();
        let (_, grads) = loss_and_grads(&cfg, &refs, &toks).unwrap();
        let specs = param_specs(&cfg);
        // One representative element per parameter kind.
        for name in ["tok_emb", "pos_emb", "blk0.ln1_g", "blk0.w_qkv", "blk1.w_down", "lnf_g", "w_head"] {
            let i = specs.iter().position(|(n, _)| n == name).unwrap();
            let idx = grads[i].numel() / 2;
            let eps = 5e-3f32;
            let loss_with = |delta: f32| -> f32 {
                let mut shifted: Vec<Tensor> = params.tensors.clone();
                shifted[i].data_mut()[idx] += delta;
                let refs: Vec<&Tensor> = shifted.iter().collect();
                loss_and_grads(&cfg, &refs, &toks).unwrap().0
            };
            let numeric = (loss_with(eps) - loss_with(-eps)) / (2.0 * eps);
            let analytic = grads[i].data()[idx];
            assert!(
                (numeric - analytic).abs() < 3e-3 + 0.05 * analytic.abs(),
                "{name}[{idx}]: analytic {analytic} vs numeric {numeric}"
            );
        }
    }

    #[test]
    fn adamw_step_moves_params_and_counts() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 31);
        let n = params.tensors.len();
        let zeros: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| Value::F32(Tensor::zeros(t.shape())))
            .collect();
        let pvals: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| Value::F32(t.clone()))
            .collect();
        let step = Value::F32(Tensor::from_vec(&[], vec![0.0]).unwrap());
        let toks = Value::I32(batch(&cfg, 32));
        let mut args: Vec<&Value> = Vec::new();
        args.extend(pvals.iter());
        args.extend(zeros.iter());
        args.extend(zeros.iter());
        args.push(&step);
        args.push(&toks);
        let outs = train_step(&cfg, &args).unwrap();
        assert_eq!(outs.len(), 3 * n + 2);
        let step_out = crate::runtime::value::scalar_f32(&outs[3 * n]).unwrap();
        let loss = crate::runtime::value::scalar_f32(&outs[3 * n + 1]).unwrap();
        assert_eq!(step_out, 1.0);
        assert!(loss.is_finite() && loss > 0.0);
        // Random-init loss near ln(vocab).
        assert!((loss - (cfg.vocab as f32).ln()).abs() < 1.5, "loss {loss}");
        // Weights moved.
        let w_new = outs[2].as_f32().unwrap();
        assert!(w_new.mse(&params.tensors[2]) > 0.0);
    }

    #[test]
    fn repeated_steps_reduce_loss() {
        let cfg = tiny_cfg();
        let params = Params::init(&cfg, 41);
        let toks = batch(&cfg, 42);
        let n = params.tensors.len();
        let mut p: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| Value::F32(t.clone()))
            .collect();
        let mut m: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| Value::F32(Tensor::zeros(t.shape())))
            .collect();
        let mut v = m.clone();
        let mut step = Value::F32(Tensor::from_vec(&[], vec![0.0]).unwrap());
        let tokens = Value::I32(toks);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for it in 0..30 {
            let mut args: Vec<&Value> = Vec::new();
            args.extend(p.iter());
            args.extend(m.iter());
            args.extend(v.iter());
            args.push(&step);
            args.push(&tokens);
            let outs = train_step(&cfg, &args).unwrap();
            let loss = crate::runtime::value::scalar_f32(&outs[3 * n + 1]).unwrap();
            if it == 0 {
                first = loss;
            }
            last = loss;
            p = outs[..n].to_vec();
            m = outs[n..2 * n].to_vec();
            v = outs[2 * n..3 * n].to_vec();
            step = outs[3 * n].clone();
        }
        assert!(
            last < first - 0.1,
            "overfitting one batch must cut the loss: {first} -> {last}"
        );
    }
}
