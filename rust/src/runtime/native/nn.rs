//! Pure-Rust transformer math for the native backend.
//!
//! Bit-for-bit the same graph as `python/compile/model.py` (`_forward` /
//! `_block_fwd` with the `ref.py` attention): RMSNorm, causal multi-head
//! attention with max-subtracted softmax, tanh-approximate GELU, residual
//! stream, weight convention `y = a @ W` with `[n_in, n_out]` weights.
//!
//! Every forward keeps the per-block intermediates ([`BlockCache`]):
//! they *are* the four quantizable role inputs `fwd_capture` returns
//! (qkv_in = ln1 out, o_in = merged attention, up_in = ln2 out,
//! down_in = gelu out), and they are exactly what the manual backward
//! pass in [`super::train`] consumes.

use crate::config::ModelConfig;
use crate::model::param_specs;
use crate::runtime::value::Value;
use crate::tensor::{Tensor, TensorI32};
use anyhow::{bail, Context, Result};

pub const RMS_EPS: f32 = 1e-5;
const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
const GELU_A: f32 = 0.044_715;

/// Borrowed view over a flat parameter argument list in canonical order.
pub struct ParamView<'a> {
    pub cfg: ModelConfig,
    names: Vec<String>,
    tensors: Vec<&'a Tensor>,
}

impl<'a> ParamView<'a> {
    /// Build from artifact arguments, checking count and every shape
    /// against the canonical spec (the contract python lowers with).
    pub fn from_values(cfg: &ModelConfig, args: &[&'a Value]) -> Result<Self> {
        let tensors = args
            .iter()
            .map(|v| v.as_f32())
            .collect::<Result<Vec<_>>>()?;
        Self::from_tensors(cfg, &tensors)
    }

    /// Build from borrowed tensors in canonical order, validating shapes.
    pub fn from_tensors(cfg: &ModelConfig, args: &[&'a Tensor]) -> Result<Self> {
        let specs = param_specs(cfg);
        if args.len() != specs.len() {
            bail!(
                "{}: got {} parameter args, spec wants {}",
                cfg.name,
                args.len(),
                specs.len()
            );
        }
        let mut names = Vec::with_capacity(specs.len());
        let mut tensors = Vec::with_capacity(specs.len());
        for ((name, shape), &t) in specs.into_iter().zip(args) {
            if t.shape() != shape.as_slice() {
                bail!(
                    "param '{name}': shape {:?} != expected {:?}",
                    t.shape(),
                    shape
                );
            }
            names.push(name);
            tensors.push(t);
        }
        Ok(Self {
            cfg: cfg.clone(),
            names,
            tensors,
        })
    }

    pub fn get(&self, name: &str) -> Result<&'a Tensor> {
        self.names
            .iter()
            .position(|n| n == name)
            .map(|i| self.tensors[i])
            .with_context(|| format!("unknown param '{name}'"))
    }
}

/// Per-block forward intermediates (also the capture-role inputs).
pub struct BlockCache {
    /// Residual stream entering the block [R, d].
    pub x_in: Tensor,
    /// RMSNorm-1 reciprocal RMS per row.
    pub inv1: Vec<f32>,
    /// ln1 output = qkv role input [R, d].
    pub h: Tensor,
    /// Packed q/k/v projections [R, 3d].
    pub qkv: Tensor,
    /// Softmax probabilities per (batch, head): [T, T], zero above diag.
    pub probs: Vec<Tensor>,
    /// Merged attention output = o role input [R, d].
    pub att: Tensor,
    /// Residual stream after attention [R, d].
    pub x_mid: Tensor,
    /// RMSNorm-2 reciprocal RMS per row.
    pub inv2: Vec<f32>,
    /// ln2 output = up role input [R, d].
    pub h2: Tensor,
    /// Pre-GELU MLP activations [R, ff].
    pub u_pre: Tensor,
    /// GELU output = down role input [R, ff].
    pub u: Tensor,
}

/// Full forward pass result with all caches.
pub struct Fwd {
    /// [B, T, V]
    pub logits: Tensor,
    pub blocks: Vec<BlockCache>,
    /// Final residual stream [R, d].
    pub x_f: Tensor,
    /// Final RMSNorm reciprocal RMS per row.
    pub inv_f: Vec<f32>,
    /// Final-norm output [R, d].
    pub hf: Tensor,
    pub b: usize,
    pub t: usize,
}

/// Token + positional embedding: [B, T] ids -> [R, d] rows.
pub fn embed(tok_emb: &Tensor, pos_emb: &Tensor, tokens: &TensorI32) -> Result<Tensor> {
    if tokens.shape().len() != 2 {
        bail!("tokens must be [B, T], got {:?}", tokens.shape());
    }
    let (vocab, d) = (tok_emb.shape()[0], tok_emb.shape()[1]);
    let (b, t) = (tokens.shape()[0], tokens.shape()[1]);
    if t > pos_emb.shape()[0] {
        bail!("sequence length {t} exceeds pos_emb rows {}", pos_emb.shape()[0]);
    }
    let mut x = vec![0.0f32; b * t * d];
    for bi in 0..b {
        for ti in 0..t {
            let id = tokens.data()[bi * t + ti];
            if id < 0 || id as usize >= vocab {
                bail!("token id {id} out of vocab range [0, {vocab})");
            }
            let dst = (bi * t + ti) * d;
            let te = tok_emb.row(id as usize);
            let pe = pos_emb.row(ti);
            for j in 0..d {
                x[dst + j] = te[j] + pe[j];
            }
        }
    }
    Tensor::from_vec(&[b * t, d], x)
}

/// RMSNorm: y = x * g * r with r = 1/sqrt(mean(x^2) + eps), per row.
/// Returns (y, r per row) — r is cached for the backward pass.
// faq-lint: allow(unordered-reduction) — per-row mean-square runs in
// slice index order; order pinned by construction.
pub fn rmsnorm_fwd(x: &Tensor, g: &[f32]) -> Result<(Tensor, Vec<f32>)> {
    let shape = x.shape();
    if shape.len() != 2 || shape[1] != g.len() {
        bail!("rmsnorm: x {:?} vs g len {}", shape, g.len());
    }
    let (r, d) = (shape[0], shape[1]);
    let mut out = vec![0.0f32; r * d];
    let mut inv = vec![0.0f32; r];
    for i in 0..r {
        let row = x.row(i);
        let ms = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let ri = 1.0 / (ms + RMS_EPS).sqrt();
        inv[i] = ri;
        for j in 0..d {
            out[i * d + j] = row[j] * g[j] * ri;
        }
    }
    Ok((Tensor::from_vec(&[r, d], out)?, inv))
}

/// RMSNorm backward: given cached r per row, returns (dx, dg).
pub fn rmsnorm_bwd(
    x: &Tensor,
    g: &[f32],
    inv: &[f32],
    dy: &Tensor,
) -> Result<(Tensor, Vec<f32>)> {
    let shape = x.shape();
    let (r, d) = (shape[0], shape[1]);
    if dy.shape() != shape || inv.len() != r || g.len() != d {
        bail!("rmsnorm_bwd shape mismatch");
    }
    let mut dx = vec![0.0f32; r * d];
    let mut dg = vec![0.0f32; d];
    for i in 0..r {
        let xr = x.row(i);
        let dyr = dy.row(i);
        let ri = inv[i];
        // c = sum_j dy_j * g_j * x_j
        let mut c = 0.0f32;
        for j in 0..d {
            c += dyr[j] * g[j] * xr[j];
            dg[j] += dyr[j] * xr[j] * ri;
        }
        let k = ri * ri * ri * c / d as f32;
        for j in 0..d {
            dx[i * d + j] = g[j] * dyr[j] * ri - xr[j] * k;
        }
    }
    Ok((Tensor::from_vec(&[r, d], dx)?, dg))
}

/// Tanh-approximate GELU (jax.nn.gelu default).
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_A * x * x * x)).tanh())
}

/// d gelu(x) / dx.
pub fn dgelu(x: f32) -> f32 {
    let inner = GELU_C * (x + GELU_A * x * x * x);
    let t = inner.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * GELU_C * (1.0 + 3.0 * GELU_A * x * x)
}

/// One (batch, head) pair of the causal attention forward: returns the
/// head's output panel [t, hd] and, when `keep_probs`, its softmax
/// matrix [t, t] (dropped inside the task otherwise, so the eval/serve
/// paths never hold b*n_head score matrices at once).
// faq-lint: allow(unordered-reduction) — q·k dot products accumulate
// over ascending head-dim index within one (batch, head) task; order
// pinned by construction.
fn attention_head_fwd(
    qkv: &Tensor,
    bi: usize,
    h: usize,
    t: usize,
    d: usize,
    hd: usize,
    keep_probs: bool,
) -> (Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (hd as f32).sqrt();
    // Gather this head's panels [t, hd] for sequential access.
    let mut q = vec![0.0f32; t * hd];
    let mut k = vec![0.0f32; t * hd];
    let mut v = vec![0.0f32; t * hd];
    for ti in 0..t {
        let row = qkv.row(bi * t + ti);
        let o = h * hd;
        q[ti * hd..(ti + 1) * hd].copy_from_slice(&row[o..o + hd]);
        k[ti * hd..(ti + 1) * hd].copy_from_slice(&row[d + o..d + o + hd]);
        v[ti * hd..(ti + 1) * hd].copy_from_slice(&row[2 * d + o..2 * d + o + hd]);
    }
    let mut p = vec![0.0f32; t * t];
    let mut out = vec![0.0f32; t * hd];
    for i in 0..t {
        let qi = &q[i * hd..(i + 1) * hd];
        let mut mx = f32::NEG_INFINITY;
        for j in 0..=i {
            let kj = &k[j * hd..(j + 1) * hd];
            let s: f32 = qi.iter().zip(kj).map(|(&a, &c)| a * c).sum::<f32>() * scale;
            p[i * t + j] = s;
            mx = mx.max(s);
        }
        let mut sum = 0.0f32;
        for j in 0..=i {
            let e = (p[i * t + j] - mx).exp();
            p[i * t + j] = e;
            sum += e;
        }
        let orow = &mut out[i * hd..(i + 1) * hd];
        for j in 0..=i {
            let pj = p[i * t + j] / sum;
            p[i * t + j] = pj;
            let vj = &v[j * hd..(j + 1) * hd];
            for (o, &vv) in orow.iter_mut().zip(vj) {
                *o += pj * vv;
            }
        }
    }
    if !keep_probs {
        p = Vec::new();
    }
    (out, p)
}

/// Causal multi-head attention over packed projections.
///
/// `qkv` [R, 3d] with R = b*t; q/k/v occupy column blocks [0,d), [d,2d),
/// [2d,3d), heads are contiguous `hd`-column stripes within each block.
/// Returns the merged output [R, d] and, when `keep_probs`, the softmax
/// matrix per (batch, head) for the backward pass.
///
/// Parallel over (batch, head) pairs: each pair computes an independent
/// [t, hd] panel that is scattered into the merged output afterwards in
/// fixed order, so outputs (and the probs ordering) are identical for
/// every thread count.
pub fn attention_fwd(
    qkv: &Tensor,
    b: usize,
    t: usize,
    n_head: usize,
    keep_probs: bool,
) -> Result<(Tensor, Vec<Tensor>)> {
    let d3 = qkv.shape()[1];
    let d = d3 / 3;
    if qkv.shape()[0] != b * t || d3 != 3 * d || d % n_head != 0 {
        bail!("attention_fwd: qkv {:?} b={b} t={t} heads={n_head}", qkv.shape());
    }
    let hd = d / n_head;
    // ~t*t*hd mul-adds per head (scores + AV); tiny serve-path batches
    // stay serial rather than paying a pool dispatch.
    let work = b * n_head * t * t * hd;
    let panels = crate::tensor::par::par_map_bounded(
        b * n_head,
        crate::tensor::par::threads_for(work),
        |bh| attention_head_fwd(qkv, bh / n_head, bh % n_head, t, d, hd, keep_probs),
    );
    let mut att = vec![0.0f32; b * t * d];
    let mut probs = Vec::new();
    for (bh, (panel, p)) in panels.into_iter().enumerate() {
        let (bi, h) = (bh / n_head, bh % n_head);
        for ti in 0..t {
            let dst = (bi * t + ti) * d + h * hd;
            att[dst..dst + hd].copy_from_slice(&panel[ti * hd..(ti + 1) * hd]);
        }
        if keep_probs {
            probs.push(Tensor::from_vec(&[t, t], p)?);
        }
    }
    Ok((Tensor::from_vec(&[b * t, d], att)?, probs))
}

/// Attention backward: gradient of the merged output w.r.t. the packed
/// qkv projections, using the cached softmax matrices.
// faq-lint: allow(unordered-reduction) — dout·v dot products accumulate
// over ascending head-dim index within one (batch, head) task; order
// pinned by construction.
pub fn attention_bwd(
    qkv: &Tensor,
    probs: &[Tensor],
    d_att: &Tensor,
    b: usize,
    t: usize,
    n_head: usize,
) -> Result<Tensor> {
    let d3 = qkv.shape()[1];
    let d = d3 / 3;
    let hd = d / n_head;
    let scale = 1.0 / (hd as f32).sqrt();
    if probs.len() != b * n_head || d_att.shape() != [b * t, d] {
        bail!("attention_bwd shape mismatch");
    }
    // Parallel over (batch, head): each pair owns disjoint dq/dk/dv
    // panels, scattered into the packed layout afterwards (fixed order,
    // thread-count invariant). Work-gated like the forward.
    let work = 2 * b * n_head * t * t * hd;
    let panels = crate::tensor::par::par_map_bounded(
        b * n_head,
        crate::tensor::par::threads_for(work),
        |bh| {
        let (bi, h) = (bh / n_head, bh % n_head);
        let p = probs[bi * n_head + h].data();
        let o = h * hd;
        // Re-gather panels.
        let mut q = vec![0.0f32; t * hd];
        let mut k = vec![0.0f32; t * hd];
        let mut v = vec![0.0f32; t * hd];
        let mut dout = vec![0.0f32; t * hd];
        for ti in 0..t {
            let row = qkv.row(bi * t + ti);
            q[ti * hd..(ti + 1) * hd].copy_from_slice(&row[o..o + hd]);
            k[ti * hd..(ti + 1) * hd].copy_from_slice(&row[d + o..d + o + hd]);
            v[ti * hd..(ti + 1) * hd].copy_from_slice(&row[2 * d + o..2 * d + o + hd]);
            let dr = d_att.row(bi * t + ti);
            dout[ti * hd..(ti + 1) * hd].copy_from_slice(&dr[o..o + hd]);
        }
        let mut dq = vec![0.0f32; t * hd];
        let mut dk = vec![0.0f32; t * hd];
        let mut dv = vec![0.0f32; t * hd];
        for i in 0..t {
            let doi = &dout[i * hd..(i + 1) * hd];
            // dp and the softmax-Jacobian contraction over row i.
            let mut dp = vec![0.0f32; i + 1];
            let mut dot = 0.0f32;
            for (j, dpj) in dp.iter_mut().enumerate() {
                let vj = &v[j * hd..(j + 1) * hd];
                *dpj = doi.iter().zip(vj).map(|(&a, &c)| a * c).sum();
                dot += *dpj * p[i * t + j];
            }
            for (j, &dpj) in dp.iter().enumerate() {
                let pij = p[i * t + j];
                // dv_j += p_ij * dout_i
                let dvj = &mut dv[j * hd..(j + 1) * hd];
                for (dvv, &dov) in dvj.iter_mut().zip(doi) {
                    *dvv += pij * dov;
                }
                // No ds == 0.0 skip: same policy as the matmul kernels
                // (a branch on the hot path, and 0 * NaN/Inf must reach
                // the accumulator) — DESIGN §9.
                let ds = pij * (dpj - dot) * scale;
                let kj = &k[j * hd..(j + 1) * hd];
                let qi = &q[i * hd..(i + 1) * hd];
                let dqi = &mut dq[i * hd..(i + 1) * hd];
                for (a, &kv) in dqi.iter_mut().zip(kj) {
                    *a += ds * kv;
                }
                let dkj = &mut dk[j * hd..(j + 1) * hd];
                for (a, &qv) in dkj.iter_mut().zip(qi) {
                    *a += ds * qv;
                }
            }
        }
        (dq, dk, dv)
    });
    let mut d_qkv = vec![0.0f32; b * t * 3 * d];
    for (bh, (dq, dk, dv)) in panels.into_iter().enumerate() {
        let (bi, h) = (bh / n_head, bh % n_head);
        let o = h * hd;
        for ti in 0..t {
            let dst = (bi * t + ti) * 3 * d;
            d_qkv[dst + o..dst + o + hd].copy_from_slice(&dq[ti * hd..(ti + 1) * hd]);
            d_qkv[dst + d + o..dst + d + o + hd]
                .copy_from_slice(&dk[ti * hd..(ti + 1) * hd]);
            d_qkv[dst + 2 * d + o..dst + 2 * d + o + hd]
                .copy_from_slice(&dv[ti * hd..(ti + 1) * hd]);
        }
    }
    Tensor::from_vec(&[b * t, 3 * d], d_qkv)
}

/// Full forward pass with caches (`python _forward`, use_pallas-agnostic).
pub fn forward(
    cfg: &ModelConfig,
    p: &ParamView,
    tokens: &TensorI32,
    keep_probs: bool,
) -> Result<Fwd> {
    if tokens.shape().len() != 2 {
        bail!("tokens must be [B, T], got {:?}", tokens.shape());
    }
    let (b, t) = (tokens.shape()[0], tokens.shape()[1]);
    let mut x = embed(p.get("tok_emb")?, p.get("pos_emb")?, tokens)?;
    let mut blocks = Vec::with_capacity(cfg.n_layer);
    for blk in 0..cfg.n_layer {
        let ln1 = p.get(&format!("blk{blk}.ln1_g"))?;
        let (h, inv1) = rmsnorm_fwd(&x, ln1.data())?;
        let qkv = h.matmul(p.get(&format!("blk{blk}.w_qkv"))?)?;
        let (att, probs) = attention_fwd(&qkv, b, t, cfg.n_head, keep_probs)?;
        let x_mid = x.add(&att.matmul(p.get(&format!("blk{blk}.w_o"))?)?)?;
        let ln2 = p.get(&format!("blk{blk}.ln2_g"))?;
        let (h2, inv2) = rmsnorm_fwd(&x_mid, ln2.data())?;
        let u_pre = h2.matmul(p.get(&format!("blk{blk}.w_up"))?)?;
        let u = u_pre.map(gelu);
        let x_out = x_mid.add(&u.matmul(p.get(&format!("blk{blk}.w_down"))?)?)?;
        blocks.push(BlockCache {
            x_in: x,
            inv1,
            h,
            qkv,
            probs,
            att,
            x_mid,
            inv2,
            h2,
            u_pre,
            u,
        });
        x = x_out;
    }
    let (hf, inv_f) = rmsnorm_fwd(&x, p.get("lnf_g")?.data())?;
    let logits2 = hf.matmul(p.get("w_head")?)?;
    let logits = logits2.reshape(&[b, t, cfg.vocab])?;
    Ok(Fwd {
        logits,
        blocks,
        x_f: x,
        inv_f,
        hf,
        b,
        t,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny-test".into(),
            n_layer: 1,
            d_model: 8,
            n_head: 2,
            d_ff: 16,
            vocab: 16,
            seq: 6,
            batch: 2,
        }
    }

    #[test]
    fn gelu_reference_values() {
        // jax.nn.gelu(x, approximate=True) at a few points.
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158_808).abs() < 1e-4);
        assert!((gelu(3.0) - 2.996_363).abs() < 1e-4);
    }

    #[test]
    fn dgelu_matches_finite_difference() {
        for &x in &[-2.5f32, -1.0, -0.1, 0.0, 0.3, 1.7, 3.0] {
            let eps = 1e-3;
            let num = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((dgelu(x) - num).abs() < 1e-3, "x={x}: {} vs {num}", dgelu(x));
        }
    }

    #[test]
    fn rmsnorm_normalizes_rows() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&mut rng, &[4, 8], 2.0);
        let g = vec![1.0f32; 8];
        let (y, inv) = rmsnorm_fwd(&x, &g).unwrap();
        for i in 0..4 {
            let ms = y.row(i).iter().map(|&v| v * v).sum::<f32>() / 8.0;
            assert!((ms - 1.0).abs() < 1e-3, "row {i} mean-square {ms}");
            assert!(inv[i] > 0.0);
        }
    }

    #[test]
    fn rmsnorm_backward_matches_finite_difference() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&mut rng, &[3, 6], 1.0);
        let g: Vec<f32> = (0..6).map(|i| 0.5 + 0.2 * i as f32).collect();
        let dy = Tensor::randn(&mut rng, &[3, 6], 1.0);
        let (_, inv) = rmsnorm_fwd(&x, &g).unwrap();
        let (dx, dg) = rmsnorm_bwd(&x, &g, &inv, &dy).unwrap();
        // J = sum(y * dy); check d J / d x and d J / d g numerically.
        let j_of = |xx: &Tensor, gg: &[f32]| -> f32 {
            let (y, _) = rmsnorm_fwd(xx, gg).unwrap();
            y.data().iter().zip(dy.data()).map(|(&a, &b)| a * b).sum()
        };
        let eps = 1e-2;
        for &idx in &[0usize, 5, 11, 17] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += eps;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= eps;
            let num = (j_of(&xp, &g) - j_of(&xm, &g)) / (2.0 * eps);
            let ana = dx.data()[idx];
            assert!((num - ana).abs() < 2e-2 + 0.02 * ana.abs(), "dx[{idx}]: {ana} vs {num}");
        }
        for idx in [0usize, 3, 5] {
            let mut gp = g.clone();
            gp[idx] += eps;
            let mut gm = g.clone();
            gm[idx] -= eps;
            let num = (j_of(&x, &gp) - j_of(&x, &gm)) / (2.0 * eps);
            assert!((num - dg[idx]).abs() < 2e-2 + 0.02 * dg[idx].abs());
        }
    }

    #[test]
    fn attention_is_causal() {
        // Changing a future token's projections must not change earlier rows.
        let mut rng = Rng::new(3);
        let (b, t, heads, d) = (1usize, 5usize, 2usize, 8usize);
        let qkv = Tensor::randn(&mut rng, &[b * t, 3 * d], 1.0);
        let (att1, _) = attention_fwd(&qkv, b, t, heads, false).unwrap();
        let mut qkv2 = qkv.clone();
        for v in qkv2.data_mut()[(t - 1) * 3 * d..].iter_mut() {
            *v += 5.0;
        }
        let (att2, _) = attention_fwd(&qkv2, b, t, heads, false).unwrap();
        for r in 0..t - 1 {
            for (a, b2) in att1.row(r).iter().zip(att2.row(r)) {
                assert_eq!(a, b2, "row {r} leaked future information");
            }
        }
    }

    #[test]
    fn attention_probs_rows_sum_to_one() {
        let mut rng = Rng::new(4);
        let (b, t, heads, d) = (2usize, 4usize, 2usize, 8usize);
        let qkv = Tensor::randn(&mut rng, &[b * t, 3 * d], 1.0);
        let (_, probs) = attention_fwd(&qkv, b, t, heads, true).unwrap();
        assert_eq!(probs.len(), b * heads);
        for p in &probs {
            for i in 0..t {
                let s: f32 = p.row(i).iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
                // strictly causal: zero above the diagonal
                for j in i + 1..t {
                    assert_eq!(p.at2(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn attention_backward_matches_finite_difference() {
        let mut rng = Rng::new(5);
        let (b, t, heads, d) = (1usize, 4usize, 2usize, 6usize);
        let qkv = Tensor::randn(&mut rng, &[b * t, 3 * d], 0.8);
        let d_att = Tensor::randn(&mut rng, &[b * t, d], 1.0);
        let (_, probs) = attention_fwd(&qkv, b, t, heads, true).unwrap();
        let d_qkv = attention_bwd(&qkv, &probs, &d_att, b, t, heads).unwrap();
        let j_of = |q: &Tensor| -> f32 {
            let (att, _) = attention_fwd(q, b, t, heads, false).unwrap();
            att.data().iter().zip(d_att.data()).map(|(&a, &c)| a * c).sum()
        };
        let eps = 1e-2;
        for idx in (0..qkv.numel()).step_by(7) {
            let mut qp = qkv.clone();
            qp.data_mut()[idx] += eps;
            let mut qm = qkv.clone();
            qm.data_mut()[idx] -= eps;
            let num = (j_of(&qp) - j_of(&qm)) / (2.0 * eps);
            let ana = d_qkv.data()[idx];
            assert!(
                (num - ana).abs() < 3e-2 + 0.03 * ana.abs(),
                "d_qkv[{idx}]: analytic {ana} vs numeric {num}"
            );
        }
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let cfg = tiny_cfg();
        let params = crate::model::Params::init(&cfg, 7);
        let values: Vec<Value> = params.tensors.iter().map(|t| Value::F32(t.clone())).collect();
        let refs: Vec<&Value> = values.iter().collect();
        let view = ParamView::from_values(&cfg, &refs).unwrap();
        let mut rng = Rng::new(8);
        let toks = TensorI32::from_vec(
            &[cfg.batch, cfg.seq],
            (0..cfg.batch * cfg.seq)
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect(),
        )
        .unwrap();
        let fwd = forward(&cfg, &view, &toks, true).unwrap();
        assert_eq!(fwd.logits.shape(), &[cfg.batch, cfg.seq, cfg.vocab]);
        assert!(fwd.logits.data().iter().all(|v| v.is_finite()));
        assert_eq!(fwd.blocks.len(), cfg.n_layer);
        assert_eq!(fwd.blocks[0].u.shape(), &[cfg.batch * cfg.seq, cfg.d_ff]);
    }

    #[test]
    fn param_view_rejects_bad_shapes() {
        let cfg = tiny_cfg();
        let params = crate::model::Params::init(&cfg, 9);
        let mut values: Vec<Value> =
            params.tensors.iter().map(|t| Value::F32(t.clone())).collect();
        values[0] = Value::F32(Tensor::zeros(&[1, 1]));
        let refs: Vec<&Value> = values.iter().collect();
        assert!(ParamView::from_values(&cfg, &refs).is_err());
    }
}
