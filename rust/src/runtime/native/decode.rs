//! `decode_step_q` / `decode_step_paged_q`: one KV-cached autoregressive
//! step over the quantized deployment artifact.
//!
//! Both entries share the weight prefix of [`super::qmodel`] (or a
//! prepared bundle in its place) and the whole per-token forward; they
//! differ only in how cached key/value rows are addressed:
//!
//! **Dense** (`decode_step_q`) trailing args:
//!
//! | arg       | shape                | meaning |
//! |---|---|---|
//! | `k_cache` | `[L, B, T_max, d]` f32 | per-(layer, slot) key slab, rows `0..pos[b]` valid |
//! | `v_cache` | `[L, B, T_max, d]` f32 | value slab, same layout |
//! | `pos`     | `[B]` i32            | position of the new token per slot; `-1` = inactive |
//! | `tokens`  | `[B]` i32            | new token id per slot (ignored when inactive) |
//!
//! **Paged** (`decode_step_paged_q`) trailing args:
//!
//! | arg            | shape                      | meaning |
//! |---|---|---|
//! | `k_pool`       | `[NB, L, block_tokens, d]` f32 | block pool of key pages |
//! | `v_pool`       | `[NB, L, block_tokens, d]` f32 | value pages, same layout |
//! | `block_tables` | `[B, max_blocks]` i32      | per-slot block ids, `-1` padded |
//! | `pos`          | `[B]` i32                  | as dense |
//! | `tokens`       | `[B]` i32                  | as dense |
//!
//! Cached position `j` of slot `b` lives in pool block
//! `block_tables[b][j / block_tokens]` at page row `j % block_tokens` —
//! the address changes, the f32 values and every arithmetic expression
//! consuming them do not (DESIGN.md §12).
//!
//! Both return `(logits [B, V], k_new [L, B, d], v_new [L, B, d])`: the
//! next-token logits per slot plus this token's key/value rows, which the
//! caller writes into its store at `pos[b]` (the entry never mutates its
//! inputs — backends are stateless). Inactive slots get zero rows.
//!
//! The quantized linears run through a [`QExec`]: the seed path
//! dequantizes weights per call, the prepared path (DESIGN.md §11)
//! consumes dequantize-once packed panels so a steady-state step does no
//! weight dequantization, no panel packing, and no heap allocation in
//! the linear path. One deliberate per-step cost remains either way: the
//! head projection runs for every active row, including prefill rows
//! whose logits the scheduler discards.
//!
//! **Bit-identity contract** (DESIGN.md §10, §12): for any schedule of
//! steps that feeds a sequence's tokens in order, the logits emitted at
//! position `t` are bitwise equal to `fwd_logits_q`'s logits at position
//! `t` of the full sequence, for every thread count, any mix of other
//! sequences sharing the batch, both `QExec` paths, and both cache
//! layouts. Every per-row computation (embedding, RMSNorm, the quantized
//! linears, residual adds, GELU) is shared with or identical to the
//! full-sequence path, and the attention below replays
//! `nn::attention_head_fwd`'s row-`t` arithmetic exactly: scores, the
//! running max, exponentials, and the output accumulation all run over
//! keys `j = 0..=t` in ascending order with the same expressions — the
//! [`KvView`] only changes which slice each `j` is read from.

use super::nn;
use super::qmodel::QExec;
use crate::config::ModelConfig;
use crate::runtime::value::Value;
use crate::tensor::{par, Tensor, TensorI32};
use anyhow::{bail, Context, Result};

/// One active slot this step: (slot index, position, token id).
struct Active {
    slot: usize,
    pos: usize,
    tok: usize,
}

/// Where a slot's cached key/value rows live: dense per-slot slabs or
/// block-table-indexed pool pages. Purely an addressing layer — the
/// returned slices feed the exact same arithmetic either way.
enum KvView<'a> {
    Dense {
        k: &'a Tensor,
        v: &'a Tensor,
        t_max: usize,
        b: usize,
    },
    Paged {
        k: &'a Tensor,
        v: &'a Tensor,
        tables: &'a TensorI32,
        max_blocks: usize,
        block_tokens: usize,
        n_layer: usize,
    },
}

impl KvView<'_> {
    /// Flat data offset of cached position `j` for (layer, slot), to be
    /// sliced `[.. + hd]` after adding the head offset.
    #[inline]
    fn row_offset(&self, layer: usize, slot: usize, j: usize, d: usize) -> usize {
        match self {
            KvView::Dense { t_max, b, .. } => ((layer * b + slot) * t_max + j) * d,
            KvView::Paged {
                tables,
                max_blocks,
                block_tokens,
                n_layer,
                ..
            } => {
                let blk = tables.data()[slot * max_blocks + j / block_tokens] as usize;
                ((blk * n_layer + layer) * block_tokens + j % block_tokens) * d
            }
        }
    }

    #[inline]
    fn k_data(&self) -> &[f32] {
        match self {
            KvView::Dense { k, .. } | KvView::Paged { k, .. } => k.data(),
        }
    }

    #[inline]
    fn v_data(&self) -> &[f32] {
        match self {
            KvView::Dense { v, .. } | KvView::Paged { v, .. } => v.data(),
        }
    }
}

/// Run one dense decode step. `targs` is the trailing argument list
/// after the weight prefix: `[k_cache, v_cache, pos, tokens]`.
pub(super) fn decode_step_q(
    cfg: &ModelConfig,
    ex: &QExec,
    targs: &[&Value],
) -> Result<Vec<Value>> {
    if targs.len() != 4 {
        bail!(
            "decode_step_q: got {} trailing args, want 4 (k_cache, v_cache, pos, tokens)",
            targs.len()
        );
    }
    let k_cache = targs[0].as_f32().context("k_cache must be f32")?;
    let v_cache = targs[1].as_f32().context("v_cache must be f32")?;
    let pos = targs[2].as_i32().context("pos must be i32")?;
    let toks = targs[3].as_i32().context("tokens must be i32")?;

    let (l, d) = (cfg.n_layer, cfg.d_model);
    if pos.shape().len() != 1 || toks.shape() != pos.shape() {
        bail!(
            "decode_step_q: pos {:?} / tokens {:?} must both be [B]",
            pos.shape(),
            toks.shape()
        );
    }
    let b = pos.shape()[0];
    let ks = k_cache.shape();
    if ks.len() != 4 || ks[0] != l || ks[1] != b || ks[3] != d {
        bail!("k_cache {ks:?} must be [{l}, {b}, T_max, {d}]");
    }
    if v_cache.shape() != ks {
        bail!("v_cache {:?} != k_cache {ks:?}", v_cache.shape());
    }
    let t_max = ks[2];
    let active = collect_active(cfg, ex, pos, toks, t_max)?;
    let view = KvView::Dense {
        k: k_cache,
        v: v_cache,
        t_max,
        b,
    };
    run_step(cfg, ex, &view, &active, b)
}

/// Run one paged decode step. `targs` is the trailing argument list
/// after the weight prefix: `[k_pool, v_pool, block_tables, pos, tokens]`.
pub(super) fn decode_step_paged_q(
    cfg: &ModelConfig,
    ex: &QExec,
    targs: &[&Value],
) -> Result<Vec<Value>> {
    if targs.len() != 5 {
        bail!(
            "decode_step_paged_q: got {} trailing args, want 5 \
             (k_pool, v_pool, block_tables, pos, tokens)",
            targs.len()
        );
    }
    let k_pool = targs[0].as_f32().context("k_pool must be f32")?;
    let v_pool = targs[1].as_f32().context("v_pool must be f32")?;
    let tables = targs[2].as_i32().context("block_tables must be i32")?;
    let pos = targs[3].as_i32().context("pos must be i32")?;
    let toks = targs[4].as_i32().context("tokens must be i32")?;

    let (l, d) = (cfg.n_layer, cfg.d_model);
    if pos.shape().len() != 1 || toks.shape() != pos.shape() {
        bail!(
            "decode_step_paged_q: pos {:?} / tokens {:?} must both be [B]",
            pos.shape(),
            toks.shape()
        );
    }
    let b = pos.shape()[0];
    let ks = k_pool.shape();
    if ks.len() != 4 || ks[1] != l || ks[3] != d {
        bail!("k_pool {ks:?} must be [NB, {l}, block_tokens, {d}]");
    }
    if v_pool.shape() != ks {
        bail!("v_pool {:?} != k_pool {ks:?}", v_pool.shape());
    }
    let (n_blocks, block_tokens) = (ks[0], ks[2]);
    if block_tokens == 0 {
        bail!("k_pool has zero block_tokens");
    }
    let ts = tables.shape();
    if ts.len() != 2 || ts[0] != b {
        bail!("block_tables {ts:?} must be [{b}, max_blocks]");
    }
    let max_blocks = ts[1];
    let t_cap = max_blocks * block_tokens;
    let active = collect_active(cfg, ex, pos, toks, t_cap)?;
    // Every cached position an active slot will read must resolve to a
    // real pool block (positions `0..pos[b]`; the new token's row comes
    // from this step's projection, not the pool).
    for act in &active {
        let covered = act.pos.div_ceil(block_tokens);
        for bi in 0..covered {
            let e = tables.data()[act.slot * max_blocks + bi];
            if e < 0 || e as usize >= n_blocks {
                bail!(
                    "slot {}: block_tables[{bi}] = {e} invalid for pool of {n_blocks} \
                     (pos {})",
                    act.slot,
                    act.pos
                );
            }
        }
    }
    let view = KvView::Paged {
        k: k_pool,
        v: v_pool,
        tables,
        max_blocks,
        block_tokens,
        n_layer: l,
    };
    run_step(cfg, ex, &view, &active, b)
}

/// Validate pos/tokens and collect the active slots.
fn collect_active(
    cfg: &ModelConfig,
    ex: &QExec,
    pos: &TensorI32,
    toks: &TensorI32,
    t_cap: usize,
) -> Result<Vec<Active>> {
    let vocab = cfg.vocab;
    let b = pos.shape()[0];
    let t_max = t_cap.min(ex.pos_emb().shape()[0]);
    let mut active = Vec::with_capacity(b);
    for slot in 0..b {
        let p = pos.data()[slot];
        if p < 0 {
            continue;
        }
        let p = p as usize;
        if p >= t_max {
            bail!("slot {slot}: pos {p} out of cache range [0, {t_max})");
        }
        let id = toks.data()[slot];
        if id < 0 || id as usize >= vocab {
            bail!("slot {slot}: token id {id} out of vocab range [0, {vocab})");
        }
        active.push(Active {
            slot,
            pos: p,
            tok: id as usize,
        });
    }
    if active.is_empty() {
        bail!("decode step: no active slots (every pos is -1)");
    }
    Ok(active)
}

/// The shared per-step forward: embed the new tokens, run every block
/// (attention against the cache view + MLP), project the head.
fn run_step(
    cfg: &ModelConfig,
    ex: &QExec,
    view: &KvView<'_>,
    active: &[Active],
    b: usize,
) -> Result<Vec<Value>> {
    let (l, d, vocab) = (cfg.n_layer, cfg.d_model, cfg.vocab);
    let a = active.len();

    // Embed the new tokens: same per-row expression as `nn::embed`.
    let mut x = vec![0.0f32; a * d];
    for (i, act) in active.iter().enumerate() {
        let te = ex.tok_emb().row(act.tok);
        let pe = ex.pos_emb().row(act.pos);
        let dst = &mut x[i * d..(i + 1) * d];
        for ((o, &t), &p) in dst.iter_mut().zip(te).zip(pe) {
            *o = t + p;
        }
    }
    let mut x = Tensor::from_vec(&[a, d], x)?;

    let mut k_new = vec![0.0f32; l * b * d];
    let mut v_new = vec![0.0f32; l * b * d];
    for li in 0..l {
        let (h, _) = nn::rmsnorm_fwd(&x, ex.ln1(li))?;
        let qkv = ex.lin(li, 0, &h)?;
        // This token's key/value rows (qkv columns [d, 2d) and [2d, 3d)),
        // reported to the caller for the cache append.
        for (i, act) in active.iter().enumerate() {
            let row = qkv.row(i);
            let dst = (li * b + act.slot) * d;
            k_new[dst..dst + d].copy_from_slice(&row[d..2 * d]);
            v_new[dst..dst + d].copy_from_slice(&row[2 * d..3 * d]);
        }
        let att = attention_decode(&qkv, view, li, active, cfg.n_head)?;
        ex.give(qkv);
        let o = ex.lin(li, 1, &att)?;
        let x_mid = x.add(&o)?;
        ex.give(o);
        let (h2, _) = nn::rmsnorm_fwd(&x_mid, ex.ln2(li))?;
        let mut u = ex.lin(li, 2, &h2)?;
        u.map_inplace(nn::gelu);
        let dn = ex.lin(li, 3, &u)?;
        ex.give(u);
        x = x_mid.add(&dn)?;
        ex.give(dn);
    }
    let (hf, _) = nn::rmsnorm_fwd(&x, ex.lnf())?;
    let lg = ex.head(&hf)?;

    let mut logits = vec![0.0f32; b * vocab];
    for (i, act) in active.iter().enumerate() {
        logits[act.slot * vocab..(act.slot + 1) * vocab].copy_from_slice(lg.row(i));
    }
    ex.give(lg);
    Ok(vec![
        Value::F32(Tensor::from_vec(&[b, vocab], logits)?),
        Value::F32(Tensor::from_vec(&[l, b, d], k_new)?),
        Value::F32(Tensor::from_vec(&[l, b, d], v_new)?),
    ])
}

/// Causal attention for one new token per active slot against the cache.
///
/// Replays row `pos` of `nn::attention_head_fwd` exactly: for each
/// (active slot, head) pair the scores over keys `j = 0..=pos` (cached
/// rows for `j < pos`, this step's projection for `j == pos`) are
/// computed in ascending order with a single-accumulator dot product,
/// then max-subtracted exponentials and the value accumulation run over
/// the same ascending range — so each output row is bitwise what the
/// full-sequence kernel produces at that position, whichever [`KvView`]
/// supplies the cached slices. Parallel over (slot, head) pairs with a
/// fixed-order merge, like the full kernel.
// faq-lint: allow(unordered-reduction) — q·k dot products accumulate
// over ascending head-dim index within one (slot, head) task; order
// pinned by construction and covered by the paged-vs-dense props tests.
fn attention_decode(
    qkv: &Tensor,
    view: &KvView<'_>,
    layer: usize,
    active: &[Active],
    n_head: usize,
) -> Result<Tensor> {
    let d3 = qkv.shape()[1];
    let d = d3 / 3;
    if d3 != 3 * d || d % n_head != 0 {
        bail!("attention_decode: qkv {:?} heads={n_head}", qkv.shape());
    }
    let hd = d / n_head;
    let scale = 1.0 / (hd as f32).sqrt();
    let a = active.len();
    let kd = view.k_data();
    let vd = view.v_data();
    let max_pos = active.iter().map(|act| act.pos).max().unwrap_or(0);
    let work = 2 * a * n_head * (max_pos + 1) * hd;
    let panels = par::par_map_bounded(a * n_head, par::threads_for(work), |ih| {
        let (i, h) = (ih / n_head, ih % n_head);
        let act = &active[i];
        let o = h * hd;
        let row = qkv.row(i);
        let qi = &row[o..o + hd];
        let k_step = &row[d + o..d + o + hd];
        let v_step = &row[2 * d + o..2 * d + o + hd];
        let p = act.pos;
        let mut s = vec![0.0f32; p + 1];
        let mut mx = f32::NEG_INFINITY;
        for (j, sj) in s.iter_mut().enumerate() {
            let kj: &[f32] = if j < p {
                let off = view.row_offset(layer, act.slot, j, d) + o;
                &kd[off..off + hd]
            } else {
                k_step
            };
            let sc: f32 = qi.iter().zip(kj).map(|(&x, &y)| x * y).sum::<f32>() * scale;
            *sj = sc;
            mx = mx.max(sc);
        }
        let mut sum = 0.0f32;
        for sj in s.iter_mut() {
            let e = (*sj - mx).exp();
            *sj = e;
            sum += e;
        }
        let mut out = vec![0.0f32; hd];
        for (j, &ej) in s.iter().enumerate() {
            let pj = ej / sum;
            let vj: &[f32] = if j < p {
                let off = view.row_offset(layer, act.slot, j, d) + o;
                &vd[off..off + hd]
            } else {
                v_step
            };
            for (ov, &vv) in out.iter_mut().zip(vj) {
                *ov += pj * vv;
            }
        }
        out
    });
    let mut att = vec![0.0f32; a * d];
    for (ih, panel) in panels.into_iter().enumerate() {
        let (i, h) = (ih / n_head, ih % n_head);
        att[i * d + h * hd..i * d + (h + 1) * hd].copy_from_slice(&panel);
    }
    Tensor::from_vec(&[a, d], att)
}
