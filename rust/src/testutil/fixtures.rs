//! Shared tiny-model fixture builders for the crate's test suites.
//!
//! `tests/integration.rs`, `tests/pipeline.rs`, and `tests/props.rs`
//! (plus in-crate engine tests) all need the same handful of fixtures: a
//! runtime, the pico preset, random token batches, a tempdir-backed run
//! configuration, and a quantized pico model. They used to copy-paste
//! these; this module is the single source so the builders cannot drift.

use crate::config::{Method, ModelConfig, QuantConfig, RunConfig};
use crate::model::Params;
use crate::quant::{quantize_model, QuantizedModel};
use crate::runtime::Runtime;
use crate::tensor::{Rng, TensorI32};
use std::path::Path;

/// The test runtime: native CPU by default; under `--features pjrt` with
/// `make artifacts` the same tests cover the PJRT path.
pub fn runtime() -> Runtime {
    Runtime::new(Path::new("artifacts")).expect("runtime")
}

/// The smallest model preset (2 layers, d=64) — every test fixture's
/// architecture.
pub fn pico() -> ModelConfig {
    ModelConfig::preset("pico").expect("pico preset")
}

/// A seeded `[batch, seq]` batch of valid token ids.
pub fn random_tokens(cfg: &ModelConfig, seed: u64) -> TensorI32 {
    let mut rng = Rng::new(seed);
    let data: Vec<i32> = (0..cfg.batch * cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    TensorI32::from_vec(&[cfg.batch, cfg.seq], data).expect("token batch")
}

/// A pico run configuration with tiny budgets and a tempdir runs/
/// directory (tagged + pid-suffixed so parallel tests never collide with
/// each other or with user checkpoints). Callers should remove
/// `cfg.runs_dir` when done.
pub fn tiny_run_config(tag: &str) -> RunConfig {
    let mut cfg = RunConfig::new("pico").expect("pico run config");
    cfg.train_steps = 25;
    cfg.calib_seqs = 8;
    cfg.eval_seqs = 4;
    cfg.task_items = 6;
    cfg.runs_dir = std::env::temp_dir()
        .join(format!("faquant_test_runs_{tag}_{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    cfg
}

/// Seeded-random pico params quantized with `method` (no calibration —
/// RTN needs none; AWQ/FAQ degenerate gracefully). The standard fixture
/// for engine/decode tests that need a deployable artifact fast.
pub fn quantized_pico(
    rt: &Runtime,
    method: Method,
    seed: u64,
) -> (ModelConfig, Params, QuantizedModel) {
    let cfg = pico();
    let params = Params::init(&cfg, seed);
    let qcfg = QuantConfig::with_method(method);
    let qm = quantize_model(rt, &qcfg, &params, None).expect("quantize pico");
    (cfg, params, qm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixtures_build_and_are_deterministic() {
        let cfg = pico();
        assert_eq!(cfg.n_layer, 2);
        let t1 = random_tokens(&cfg, 5);
        let t2 = random_tokens(&cfg, 5);
        assert_eq!(t1, t2);
        assert!(t1.data().iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
        let rc = tiny_run_config("fixture_smoke");
        assert!(rc.runs_dir.contains("fixture_smoke"));
        assert_eq!(rc.train_steps, 25);
    }

    #[test]
    fn quantized_pico_is_deployable() {
        let rt = Runtime::native();
        let (cfg, params, qm) = quantized_pico(&rt, Method::Rtn, 3);
        assert_eq!(qm.linears.len(), cfg.n_layer * 4);
        assert_eq!(params.tensors.len(), crate::model::param_specs(&cfg).len());
    }
}
