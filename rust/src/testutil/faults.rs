//! Deterministic fault-injection harness for the request lifecycle.
//!
//! The differential fuzz harness ([`super::fuzz`]) pins the HAPPY path:
//! paged engine == dense engine, bit for bit. This module pins the
//! FAILURE path (DESIGN.md §14): from one `u64` seed it derives a
//! [`FaultPlan`] — forced step errors (transient and poisoned-request),
//! forced admission stalls, a client cancel, and deadline storms — and
//! replays the same seeded workload under it, asserting after every
//! step that
//!
//! 1. paged-store invariants hold (`Engine::check_paged_invariants`),
//! 2. the drain epilogue leaks zero blocks (prefix cache flushed, pool
//!    fully free, reservations zero),
//! 3. every SURVIVING request's token stream is bitwise identical to
//!    the fault-free run of the same seed, and every aborted request's
//!    partial tokens are a bitwise prefix of it,
//! 4. the whole faulted run is itself bitwise reproducible at 1/2/8
//!    threads.
//!
//! **Why it is deterministic:** every fault decision is a pure function
//! of the engine's tick counter, the attempt index, and the fed request
//! ids — never wall time (the engine runs its virtual clock,
//! [`VIRTUAL_STEP_MS`] per tick) and never ambient randomness. Thread
//! count changes how a step's arithmetic is scheduled, not which steps
//! run, so the fault schedule — and therefore every abort — lands on
//! identical ticks in every configuration.

use super::{fixtures, fuzz};
use crate::config::Method;
use crate::engine::{
    CancelToken, Engine, FaultInjector, FinishReason, GenConfig, GenOutput, GenReport, GenRequest,
};
use crate::model::Params;
use crate::quant::QuantizedModel;
use crate::runtime::Runtime;
use crate::tensor::{par, Rng};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::time::Duration;

/// Virtual-clock advance per engine tick (milliseconds). Deadlines in a
/// fault plan are budgets in these units, so expiry is tick-exact.
pub const VIRTUAL_STEP_MS: u64 = 1;

/// A seeded schedule of faults over one fuzz workload. All request
/// targets are distinct ids of *valid* requests
/// ([`fuzz::request_is_valid`]) — faults must land on sequences that
/// actually decode, or the assertions would be vacuous.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// tick -> number of transient attempt failures to inject there.
    /// Each budget is <= the engine's `step_retries`, so transients are
    /// always absorbed by the bounded retry and never quarantine anyone.
    pub transient: BTreeMap<usize, usize>,
    /// Poisoned request: every compute attempt feeding it fails once
    /// `blame_from_tick` is reached — the quarantine bisection must
    /// isolate exactly this id.
    pub blamed: Option<usize>,
    pub blame_from_tick: usize,
    /// Forced pool exhaustion: admission stalls for ticks in `[a, b)`.
    pub stall_ticks: Option<(usize, usize)>,
    /// Client cancel: `(id, delay)` — the token fires `delay` driver
    /// steps after the request's submission step.
    pub cancel: Option<(usize, usize)>,
    /// Deadline storm, instant flavor: this request gets a zero budget
    /// and must expire in the queue with no tokens.
    pub zero_deadline: Option<usize>,
    /// Deadline storm, timed flavor: `(id, budget_ms)` on the virtual
    /// clock — may expire mid-decode or finish first; either way the
    /// tokens must prefix the fault-free stream.
    pub timed_deadline: Option<(usize, u64)>,
}

impl FaultPlan {
    /// Derive the plan for `workload` from the case seed alone.
    pub fn from_seed(seed: u64, workload: &[(usize, GenRequest)], spec: &fuzz::FuzzSpec) -> Self {
        let mut rng = Rng::new(seed ^ 0x00FA_0717);
        let mut valid: Vec<usize> = workload
            .iter()
            .filter(|(_, r)| fuzz::request_is_valid(r, spec))
            .map(|(_, r)| r.id)
            .collect();
        // Fisher–Yates on the seeded stream: target picks are a pure
        // function of the seed and the workload order.
        for i in (1..valid.len()).rev() {
            let j = rng.below(i + 1);
            valid.swap(i, j);
        }
        let mut picks = valid.into_iter();
        let blamed = picks.next();
        let zero_deadline = picks.next();
        let cancel = picks.next().map(|id| (id, 1 + rng.below(5)));
        let timed_deadline = picks.next().map(|id| (id, 2 + rng.below(10) as u64));
        let mut transient = BTreeMap::new();
        for _ in 0..(1 + rng.below(2)) {
            transient.insert(rng.below(8), 1 + rng.below(2));
        }
        let blame_from_tick = if rng.below(2) == 0 { 0 } else { 2 + rng.below(8) };
        let stall_ticks = (rng.below(2) == 0).then(|| {
            let a = 1 + rng.below(4);
            (a, a + 1 + rng.below(4))
        });
        Self {
            seed,
            transient,
            blamed,
            blame_from_tick,
            stall_ticks,
            cancel,
            zero_deadline,
            timed_deadline,
        }
    }

    fn cancel_id(&self) -> Option<usize> {
        self.cancel.map(|(id, _)| id)
    }

    fn timed_deadline_id(&self) -> Option<usize> {
        self.timed_deadline.map(|(id, _)| id)
    }
}

/// Executes a [`FaultPlan`] through the engine's injection seam.
pub struct PlanInjector {
    plan: FaultPlan,
    /// Per-tick transient failures already injected.
    seen: BTreeMap<usize, usize>,
}

impl PlanInjector {
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            plan,
            seen: BTreeMap::new(),
        }
    }
}

impl FaultInjector for PlanInjector {
    fn before_attempt(&mut self, tick: usize, attempt: usize, fed_ids: &[usize]) -> Result<()> {
        // Transient check FIRST: its budget (<= step_retries) must be
        // consumed by the bounded retry before any bisection probe, so
        // a transient tick can never get an innocent slot quarantined —
        // even when it collides with a tick where the poisoned request
        // is fed.
        if let Some(&fails) = self.plan.transient.get(&tick) {
            let seen = self.seen.entry(tick).or_insert(0);
            if *seen < fails {
                *seen += 1;
                bail!(
                    "fault plan {:#x}: transient failure {}/{fails} at tick {tick} \
                     (attempt {attempt})",
                    self.plan.seed,
                    *seen
                );
            }
        }
        if let Some(victim) = self.plan.blamed {
            if tick >= self.plan.blame_from_tick && fed_ids.contains(&victim) {
                bail!(
                    "fault plan {:#x}: poisoned request {victim} fed at tick {tick} \
                     (attempt {attempt})",
                    self.plan.seed
                );
            }
        }
        Ok(())
    }

    fn stall_admission(&mut self, tick: usize) -> bool {
        self.plan
            .stall_ticks
            .is_some_and(|(a, b)| tick >= a && tick < b)
    }
}

/// Outputs + report of one faulted run.
pub struct FaultRunResult {
    /// All workload outputs (drain-probe rejection excluded), sorted by
    /// request id.
    pub outs: Vec<GenOutput>,
    pub report: GenReport,
    /// Canonically rendered trace-event lines: faulted runs always trace
    /// (retry, quarantine, cancel, deadline, and drain events included),
    /// and under the virtual clock the lines must be identical at every
    /// thread count.
    pub trace_lines: Vec<String>,
}

/// Drive one engine through the workload under `plan`: per-request
/// deadline/cancel mutations applied at submit, the injector installed
/// at the engine seam, paged invariants checked after EVERY step, a
/// graceful drain (with a probe submit that must answer `Draining`)
/// once the workload is fully submitted, and a zero-leak pool check at
/// the end.
pub fn run_workload_faulted(
    rt: &Runtime,
    params: &Params,
    qm: &QuantizedModel,
    gen: GenConfig,
    workload: &[(usize, GenRequest)],
    plan: &FaultPlan,
) -> Result<FaultRunResult> {
    if let Some(&max_fails) = plan.transient.values().max() {
        if gen.step_retries < max_fails {
            bail!(
                "fault plan {:#x}: transient budget {max_fails} exceeds step_retries {} — \
                 an innocent slot could be quarantined",
                plan.seed,
                gen.step_retries
            );
        }
    }
    let cfg = fixtures::pico();
    // Faulted runs always trace: the failure path is exactly where the
    // event log must stay deterministic, and the survivor checks against
    // the untraced baseline double as the observer-effect pin.
    let gen = GenConfig { trace: true, ..gen };
    let mut eng = Engine::new(rt, &cfg, params, qm, gen)?;
    eng.set_fault_injector(Box::new(PlanInjector::new(plan.clone())));
    let cancel_token = CancelToken::new();
    let mut cancel_fire: Option<usize> = None;
    let mut outs = Vec::new();
    let mut next = 0usize;
    let mut step = 0usize;
    let mut draining = false;
    let step_bound = 10_000 + workload.iter().map(|(at, _)| *at).max().unwrap_or(0);
    loop {
        while next < workload.len() && workload[next].0 <= step {
            let (at, req) = &workload[next];
            let mut req = req.clone();
            if plan.zero_deadline == Some(req.id) {
                req.deadline = Some(Duration::ZERO);
            }
            if let Some((id, ms)) = plan.timed_deadline {
                if id == req.id {
                    req.deadline = Some(Duration::from_millis(ms));
                }
            }
            if let Some((id, delay)) = plan.cancel {
                if id == req.id {
                    req.cancel = Some(cancel_token.clone());
                    cancel_fire = Some(at + delay);
                }
            }
            if let Some(rejected) = eng.submit(req) {
                outs.push(rejected);
            }
            next += 1;
        }
        if next == workload.len() && !draining {
            draining = true;
            eng.begin_drain();
            // Drain gate: a fresh submit must be answered `Draining`
            // (this also guarantees `reject_counts.draining >= 1`).
            let probe = eng.submit(GenRequest {
                id: workload.len() + 1000,
                prompt: vec![0],
                max_new: 1,
                ..Default::default()
            });
            let probe_rejected = matches!(
                probe.as_ref().map(|o| &o.finish),
                Some(FinishReason::Rejected(r)) if r.cause() == "draining"
            );
            if !probe_rejected {
                bail!(
                    "fault seed {}: draining engine did not reject a fresh submit: {probe:?}",
                    plan.seed
                );
            }
        }
        if cancel_fire == Some(step) {
            cancel_token.cancel();
        }
        if next == workload.len() && !eng.has_work() {
            break;
        }
        outs.extend(eng.step()?);
        eng.check_paged_invariants()?;
        step += 1;
        if step > step_bound {
            bail!(
                "fault seed {}: engine failed to drain within {step_bound} steps \
                 ({} of {} outputs)",
                plan.seed,
                outs.len(),
                workload.len()
            );
        }
    }
    // Zero leaked blocks after drain: once the prefix cache lets go of
    // its references, every pool block must be back on the free list
    // and no reservation may survive.
    eng.flush_prefix_cache()?;
    eng.assert_pool_all_free()?;
    eng.check_paged_invariants()?;
    if let Some((free, in_use, pool, reserved)) = eng.pool_stats() {
        if in_use != 0 || reserved != 0 || free != pool {
            bail!(
                "fault seed {}: pool leaked after drain: free {free}, in_use {in_use}, \
                 pool {pool}, reserved {reserved}",
                plan.seed
            );
        }
    }
    outs.sort_by_key(|o| o.id);
    let trace_lines = eng.trace().canonical_lines();
    Ok(FaultRunResult {
        outs,
        report: eng.report(),
        trace_lines,
    })
}

/// Assert one faulted run against the fault-free baseline of the same
/// seed: survivors bitwise identical, aborts only where the plan aimed
/// them and always a bitwise prefix, and the report's fault counters
/// consistent with the plan.
pub fn check_faulted_outputs(
    seed: u64,
    plan: &FaultPlan,
    base: &[GenOutput],
    res: &FaultRunResult,
) -> Result<()> {
    if base.len() != res.outs.len() {
        bail!(
            "fault seed {seed}: {} baseline vs {} faulted outputs",
            base.len(),
            res.outs.len()
        );
    }
    for (b, f) in base.iter().zip(&res.outs) {
        if b.id != f.id {
            bail!("fault seed {seed}: output ids diverge ({} vs {})", b.id, f.id);
        }
        let prefix_ok = b.tokens.starts_with(&f.tokens);
        match &f.finish {
            FinishReason::MaxTokens | FinishReason::Stop => {
                if f.finish != b.finish || f.tokens != b.tokens {
                    bail!(
                        "fault seed {seed}: survivor {} diverged from the fault-free run:\n  \
                         base: {:?} {:?}\n  got:  {:?} {:?}",
                        f.id,
                        b.finish,
                        b.tokens,
                        f.finish,
                        f.tokens
                    );
                }
            }
            FinishReason::DeadlineExceeded => {
                let targeted = plan.zero_deadline == Some(f.id)
                    || plan.timed_deadline_id() == Some(f.id);
                if !targeted {
                    bail!("fault seed {seed}: request {} hit a deadline nobody set", f.id);
                }
                if plan.zero_deadline == Some(f.id) && !f.tokens.is_empty() {
                    bail!(
                        "fault seed {seed}: zero-budget request {} produced {} tokens",
                        f.id,
                        f.tokens.len()
                    );
                }
                if !prefix_ok {
                    bail!(
                        "fault seed {seed}: request {} deadline tokens are not a prefix \
                         of the fault-free stream",
                        f.id
                    );
                }
            }
            FinishReason::Cancelled => {
                if plan.cancel_id() != Some(f.id) {
                    bail!(
                        "fault seed {seed}: request {} cancelled but the plan targets {:?}",
                        f.id,
                        plan.cancel_id()
                    );
                }
                if !prefix_ok {
                    bail!(
                        "fault seed {seed}: request {} cancel tokens are not a prefix \
                         of the fault-free stream",
                        f.id
                    );
                }
            }
            FinishReason::Rejected(r) if r.cause() == "internal" => {
                if plan.blamed != Some(f.id) {
                    bail!(
                        "fault seed {seed}: request {} quarantined but the plan blamed {:?}",
                        f.id,
                        plan.blamed
                    );
                }
                if !prefix_ok {
                    bail!(
                        "fault seed {seed}: request {} quarantine tokens are not a prefix \
                         of the fault-free stream",
                        f.id
                    );
                }
            }
            FinishReason::Rejected(r) => {
                let same = matches!(&b.finish,
                    FinishReason::Rejected(rb) if rb.cause() == r.cause());
                if !same {
                    bail!(
                        "fault seed {seed}: request {} rejection mismatch: {:?} vs {:?}",
                        f.id,
                        b.finish,
                        f.finish
                    );
                }
            }
        }
    }
    let rep = &res.report;
    if rep.reject_counts.draining == 0 {
        bail!("fault seed {seed}: the drain probe was never counted");
    }
    if plan.zero_deadline.is_some() && rep.deadline_exceeded == 0 {
        bail!("fault seed {seed}: the zero-budget deadline never fired");
    }
    if plan.blamed.is_some() && plan.blame_from_tick == 0 && rep.quarantined == 0 {
        bail!("fault seed {seed}: tick-0 poison never quarantined its victim");
    }
    if rep.quarantined > 0 && rep.step_faults == 0 {
        bail!("fault seed {seed}: quarantine without any recorded step fault");
    }
    Ok(())
}

/// One full fault-injection case from a single seed: seeded workload,
/// seeded fault plan, fault-free paged baseline (1 thread), then the
/// faulted run at 1/2/8 threads — per-run checks against the baseline
/// plus bitwise cross-thread identity of the faulted runs themselves.
/// Prints spec + plan so a CI failure reproduces from the log alone.
pub fn fault_injection_case(seed: u64) -> Result<()> {
    let spec = fuzz::FuzzSpec::from_seed(seed);
    let rt = Runtime::native();
    let (cfg, params, qm) = fixtures::quantized_pico(&rt, Method::Rtn, seed ^ 0x9E37);
    let workload = fuzz::build_workload(cfg.vocab, cfg.seq, &spec);
    let plan = FaultPlan::from_seed(seed, &workload, &spec);
    println!("fault-injection seed {seed}: {spec:?}\n  plan: {plan:?}");
    let gen = GenConfig {
        temperature: spec.temperature,
        top_k: spec.top_k,
        seed: spec.seed ^ 1,
        slots: spec.slots,
        paged: true,
        block_tokens: spec.block_tokens,
        pool_blocks: spec.pool_blocks,
        prefix_cache: true,
        virtual_step: Some(Duration::from_millis(VIRTUAL_STEP_MS)),
        ..GenConfig::default()
    };

    par::set_threads(1);
    let baseline = fuzz::run_workload(&rt, &params, &qm, gen.clone(), &workload, false);
    par::set_threads(0);
    let baseline = baseline?;

    let mut first: Option<FaultRunResult> = None;
    for &threads in &[1usize, 2, 8] {
        par::set_threads(threads);
        let res = run_workload_faulted(&rt, &params, &qm, gen.clone(), &workload, &plan);
        par::set_threads(0);
        let res = res?;
        check_faulted_outputs(seed, &plan, &baseline, &res)?;
        if res.trace_lines.is_empty() {
            bail!("fault seed {seed}: traced faulted run produced no events");
        }
        if let Some(ref f) = first {
            fuzz::assert_streams_equal(
                &f.outs,
                &res.outs,
                &format!("faulted run at {threads} threads vs 1 thread (fault seed {seed})"),
            )?;
            if f.trace_lines != res.trace_lines {
                let i = f
                    .trace_lines
                    .iter()
                    .zip(&res.trace_lines)
                    .position(|(a, b)| a != b)
                    .unwrap_or_else(|| f.trace_lines.len().min(res.trace_lines.len()));
                bail!(
                    "fault seed {seed}: trace diverges at {threads} threads \
                     ({} vs {} events), first at line {i}:\n  want: {:?}\n  got:  {:?}",
                    f.trace_lines.len(),
                    res.trace_lines.len(),
                    f.trace_lines.get(i),
                    res.trace_lines.get(i)
                );
            }
        } else {
            first = Some(res);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_is_seed_deterministic() {
        let spec = fuzz::FuzzSpec::from_seed(7);
        let w = fuzz::build_workload(256, 128, &spec);
        let a = FaultPlan::from_seed(7, &w, &spec);
        let b = FaultPlan::from_seed(7, &w, &spec);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn fault_plan_targets_are_distinct_valid_requests() {
        for seed in [1u64, 42, 0xFA17] {
            let spec = fuzz::FuzzSpec::from_seed(seed);
            let w = fuzz::build_workload(256, 128, &spec);
            let plan = FaultPlan::from_seed(seed, &w, &spec);
            let targets: Vec<usize> = [
                plan.blamed,
                plan.zero_deadline,
                plan.cancel_id(),
                plan.timed_deadline_id(),
            ]
            .into_iter()
            .flatten()
            .collect();
            for (i, &a) in targets.iter().enumerate() {
                for &b in targets.iter().skip(i + 1) {
                    assert_ne!(a, b, "seed {seed}: duplicate fault target");
                }
                let req = w
                    .iter()
                    .map(|(_, r)| r)
                    .find(|r| r.id == a)
                    .expect("target id exists in the workload");
                assert!(
                    fuzz::request_is_valid(req, &spec),
                    "seed {seed}: fault target {a} is not a valid request"
                );
            }
            // Transient budgets stay within the default retry budget.
            assert!(plan.transient.values().all(|&f| f <= 2));
        }
    }
}
