//! Mini-proptest (S16): seeded generators + a forall runner with
//! counterexample reporting and one-level shrinking for numeric cases.
//!
//! proptest is not in the offline registry; crate tests use this for the
//! coordinator/quantizer invariants (routing, packing round-trips,
//! Theorem 1's error ordering, …). Two submodules extend the kit:
//!
//! - [`fixtures`] — the shared tiny-model builders every test suite
//!   uses (runtime, pico preset, token batches, quantized artifacts).
//! - [`fuzz`] — the deterministic differential fuzz harness pinning the
//!   paged decode engine bitwise against the dense seed engine.
//! - [`faults`] — the deterministic fault-injection harness: seeded
//!   fault plans (step failures, pool stalls, cancels, deadline storms)
//!   driven through the engine's injection seam, with invariants and
//!   survivor bit-identity pinned after every fault.
//! - [`router_faults`] — the router-level extension: seeded
//!   worker-crash/stall/restart plans against the sharded router,
//!   pinning deterministic failover (streams bitwise equal to the
//!   fault-free run) and zero leaked KV blocks after drain.

pub mod faults;
pub mod fixtures;
pub mod fuzz;
pub mod router_faults;

use crate::tensor::{Rng, Tensor};

/// A value generator: samples from an `Rng`.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn sample(&self, rng: &mut Rng) -> Self::Value;
}

/// Uniform usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);
impl Gen for UsizeIn {
    type Value = usize;
    fn sample(&self, rng: &mut Rng) -> usize {
        self.0 + rng.below(self.1 - self.0 + 1)
    }
}

/// Uniform f32 in [lo, hi).
pub struct F32In(pub f32, pub f32);
impl Gen for F32In {
    type Value = f32;
    fn sample(&self, rng: &mut Rng) -> f32 {
        rng.range_f32(self.0, self.1)
    }
}

/// Random-normal tensor with shape sampled per-dimension from ranges,
/// each dim rounded to a multiple of `multiple_of`.
pub struct TensorGen {
    pub dims: Vec<(usize, usize)>,
    pub multiple_of: usize,
    pub std: f32,
}

impl Gen for TensorGen {
    type Value = Tensor;
    fn sample(&self, rng: &mut Rng) -> Tensor {
        let m = self.multiple_of.max(1);
        let shape: Vec<usize> = self
            .dims
            .iter()
            .map(|&(lo, hi)| {
                let raw = lo + rng.below(hi - lo + 1);
                (raw.max(1).div_ceil(m)) * m
            })
            .collect();
        Tensor::randn(rng, &shape, self.std)
    }
}

/// Pair combinator.
pub struct Pair<A, B>(pub A, pub B);
impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn sample(&self, rng: &mut Rng) -> Self::Value {
        (self.0.sample(rng), self.1.sample(rng))
    }
}

/// Run `prop` on `cases` sampled inputs; panic with seed + debug repr of
/// the first counterexample. Returning `Err(msg)` marks failure.
pub fn forall<G: Gen>(
    seed: u64,
    cases: usize,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let value = gen.sample(&mut case_rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property failed at case {case}/{cases} (case_seed={case_seed:#x}):\n  \
                 {msg}\n  input: {value:?}"
            );
        }
    }
}

/// Assert two f32 slices are close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= atol,
            "{ctx}: [{i}] {x} vs {y} (atol {atol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_valid_property() {
        forall(1, 50, &UsizeIn(1, 10), |&n| {
            if n >= 1 && n <= 10 {
                Ok(())
            } else {
                Err(format!("{n} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_counterexample() {
        forall(2, 50, &UsizeIn(0, 5), |&n| {
            if n < 5 {
                Ok(())
            } else {
                Err("hit 5".into())
            }
        });
    }

    #[test]
    fn tensor_gen_respects_multiple() {
        let g = TensorGen {
            dims: vec![(10, 50), (10, 50)],
            multiple_of: 16,
            std: 1.0,
        };
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let t = g.sample(&mut rng);
            assert!(t.shape().iter().all(|d| d % 16 == 0), "{:?}", t.shape());
        }
    }

    #[test]
    fn pair_samples_both() {
        let g = Pair(UsizeIn(1, 2), F32In(0.0, 1.0));
        let mut rng = Rng::new(4);
        let (a, b) = g.sample(&mut rng);
        assert!((1..=2).contains(&a));
        assert!((0.0..1.0).contains(&b));
    }
}
